"""Fused ghost-norm probes: per-sample norms computed INSIDE the backward pass.

The tap mechanism (taps.py) exposes dL/ds as an explicit output — simple, but
the stacked cotangents of every layer then coexist in HBM ((L, B, T, p) per
tap: ~4 TB/device on qwen2-72b).  The paper's PyTorch hooks never have this
problem: the norm is computed layer-by-layer during backprop and the gradient
tensor dies immediately.

This module restores that lifetime structure in JAX.  Each parameterized op
routes its pre-activation through a ``custom_vjp`` identity *probe* carrying a
dummy (B,) input z.  The probe's backward rule computes the layer's
per-sample squared-norm contribution (ghost or instantiated, per the Eq. 4.1
decision) from its residual ``a`` and the incoming cotangent ``g`` — and
returns it as z's cotangent::

    forward:   s -> s                      (identity; residual = a)
    backward:  ds = g
               da = 0                      (a's real grad flows via the matmul)
               dz = ||dL_i/dW||^2  (B,)    <- the hijacked side channel

``vjp(..., zs)`` then yields every layer's norms as (B,)-sized cotangents —
inside ``lax.scan`` they stack to (L, B) — while g itself never leaves the
backward scan.  Under the second pullback (cotangent C_i) the dz computation
is dead code and XLA eliminates it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import taps as taps_mod


@dataclasses.dataclass(frozen=True)
class ProbeSpec:
    """Static description of the norm computation for one tap."""

    meta: "taps_mod.TapMeta"
    branch_mode: str  # clipping mode used by decide()
    decision_by: str = "space"
    ghost_block: int = 512
    inst_block_d: int = 8192
    override: Optional[str] = None  # tuner ClipPlan branch, wins over decide()


def make_probe(spec: ProbeSpec):
    from repro.core import ghost  # local import to avoid cycles

    @jax.custom_vjp
    def probe(s, a, z):
        del a, z
        return s

    def fwd(s, a, z):
        del z
        return s, a

    def bwd(a, g):
        dz = ghost.tap_norm_sq(
            spec.meta,
            a,
            g,
            mode=spec.branch_mode,
            decision_by=spec.decision_by,
            ghost_block=spec.ghost_block,
            inst_block_d=spec.inst_block_d,
            override=spec.override,
        )
        da = jnp.zeros(a.shape, a.dtype) if a is not None else None
        return g, da, dz

    probe.defvjp(fwd, bwd)
    return probe
