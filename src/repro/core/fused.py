"""Fused probes: per-sample norms (and book-keeping banks) computed INSIDE
the backward pass.

The tap mechanism (taps.py) exposes dL/ds as an explicit output — simple, but
the stacked cotangents of every layer then coexist in HBM ((L, B, T, p) per
tap: ~4 TB/device on qwen2-72b).  The paper's PyTorch hooks never have this
problem: the norm is computed layer-by-layer during backprop and the gradient
tensor dies immediately.

This module restores that lifetime structure in JAX.  Each parameterized op
routes its pre-activation through a ``custom_vjp`` identity *probe* carrying a
dummy *bank* input z.  The probe's backward rule computes the layer's
side-channel payload from its residual ``a`` and the incoming cotangent ``g``
— and returns it as z's cotangent::

    forward:   s -> s                      (identity; residual = a)
    backward:  ds = g
               da = 0                      (a's real grad flows via the matmul)
               dz = bank                   <- the hijacked side channel

For the second-backward modes (ghost / fastgradclip / mixed_ghost) the bank
is just ``{"n": (B,)}`` — the per-sample squared-norm contribution (ghost or
instantiated, per the Eq. 4.1 decision).  ``vjp(..., zs)`` then yields every
layer's norms as (B,)-sized cotangents — inside ``lax.scan`` they stack to
(L, B) — while g itself never leaves the backward scan.  Under the second
pullback (cotangent C_i) the bank computation is dead code and XLA
eliminates it.

For ``bk_mixed`` (book-keeping, arXiv:2210.00038) there is no second
pullback, so the bank must also carry the residuals the weighted-grad
einsum ``sum_i C_i g_i`` needs (see ghost.tap_bank): banked per-sample
gradients for instantiate-branch taps, the (a, g) book for ghost-branch
taps.  The dummy bank inputs are broadcast-zeros created inside the traced
function and deleted by the probe's forward rule — XLA never materializes
them; only the cotangents (the banks themselves, which the algorithm
fundamentally requires) occupy memory.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ghost as ghost_mod
from repro.core import taps as taps_mod
from repro.core.decision import decide


@dataclasses.dataclass(frozen=True)
class ProbeSpec:
    """Static description of the side-channel computation for one tap."""

    meta: "taps_mod.TapMeta"
    branch_mode: str  # clipping mode used by decide()
    decision_by: str = "space"
    ghost_block: int = 512
    inst_block_d: int = 8192
    override: Optional[str] = None  # tuner ClipPlan branch, wins over decide()
    # measured (op, impl) kernel choices for this tap (repro.kernels.dispatch)
    kernels: tuple[tuple[str, str], ...] = ()


def bank_struct(
    meta: "taps_mod.TapMeta",
    *,
    mode: str,
    decision_by: str = "space",
    override: Optional[str] = None,
) -> dict[str, jax.ShapeDtypeStruct]:
    """Shapes/dtypes of one tap's bank (stack dims follow ``meta``).

    Must mirror ghost.tap_bank exactly: the probes' backward rule emits the
    bank as the cotangent of a dummy input built from this structure, and
    custom_vjp requires the two to agree.
    """
    sd = meta.stack_dims
    b = meta.batch_size
    f32 = jnp.float32
    out = {"n": jax.ShapeDtypeStruct(sd + (b,), f32)}
    if mode != "bk_mixed":
        return out

    banks_book = False
    if meta.kind == "matmul":
        branch = decide(meta, mode="bk_mixed", by=decision_by, override=override)
        if branch == "instantiate":
            out["psg"] = jax.ShapeDtypeStruct(
                sd + (b,) + ghost_mod.psg_param_shape(meta), f32
            )
        else:
            banks_book = True
    elif meta.kind == "embedding":
        banks_book = True
    elif meta.kind in ("dw_conv", "scale", "scale_grouped", "bias"):
        out["psg"] = jax.ShapeDtypeStruct(
            sd + (b,) + ghost_mod.psg_param_shape(meta), f32
        )
    else:
        raise ValueError(f"unknown tap kind {meta.kind!r}")

    if banks_book:
        out["a"] = jax.ShapeDtypeStruct(tuple(meta.a_shape), meta.a_dtype)
        out["g"] = jax.ShapeDtypeStruct(tuple(meta.s_shape), meta.s_dtype)
    elif meta.bias_path is not None:
        out["psg_b"] = jax.ShapeDtypeStruct(sd + (b, meta.p), f32)
    return out


def make_bank_zeros(struct: dict[str, jax.ShapeDtypeStruct]) -> dict[str, jax.Array]:
    """Dummy bank primals: broadcast-zeros, unused in the forward pass."""
    return {k: jnp.zeros(s.shape, s.dtype) for k, s in struct.items()}


def make_probe(spec: ProbeSpec):
    from repro.core import ghost  # local import to avoid cycles

    @jax.custom_vjp
    def probe(s, a, z):
        del a, z
        return s

    def fwd(s, a, z):
        del z
        return s, a

    def bwd(a, g):
        bank = ghost.tap_bank(
            spec.meta,
            a,
            g,
            mode=spec.branch_mode,
            decision_by=spec.decision_by,
            ghost_block=spec.ghost_block,
            inst_block_d=spec.inst_block_d,
            override=spec.override,
            kernels=dict(spec.kernels) if spec.kernels else None,
        )
        da = jnp.zeros(a.shape, a.dtype) if a is not None else None
        return g, da, bank

    probe.defvjp(fwd, bwd)
    return probe
