"""Clipping functions C(||g_i||; R) — any map bounded by R/||g_i|| (Eq. 2.1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def abadi_clip(norms: jax.Array, clip_norm: float) -> jax.Array:
    """min(R/||g||, 1) — Abadi et al. 2016."""
    return jnp.minimum(clip_norm / jnp.maximum(norms, 1e-12), 1.0)


def global_clip(norms: jax.Array, clip_norm: float, z: float = 1.0) -> jax.Array:
    """I(||g|| < Z) * R/Z — Bu et al. 2021 (global clipping)."""
    return jnp.where(norms < z, clip_norm / z, 0.0)


def automatic_clip(norms: jax.Array, clip_norm: float, gamma: float = 0.01) -> jax.Array:
    """R/(||g|| + gamma) — automatic (normalized) clipping, Bu et al. 2022."""
    return clip_norm / (norms + gamma)


CLIP_FUNCTIONS = {
    "abadi": abadi_clip,
    "global": global_clip,
    "automatic": automatic_clip,
}


def get_clip_fn(name: str):
    try:
        return CLIP_FUNCTIONS[name]
    except KeyError:
        raise ValueError(
            f"unknown clip function {name!r}; have {list(CLIP_FUNCTIONS)}"
        ) from None
