"""The paper's complexity model (Tables 1-2) and layerwise decision (Eq 4.1).

All quantities are per layer, in elements (multiply by dtype size for bytes).
B = batch, T = output positions, D = fan-in (d*kh*kw), p = fan-out.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.taps import TapMeta


@dataclasses.dataclass(frozen=True)
class ModuleCost:
    time: float
    space: float


def back_propagation(B, T, D, p) -> ModuleCost:
    # Table 1 col 1: 2BTD(2p+1) time; BTp + 2BTD + pD space.
    return ModuleCost(time=2 * B * T * D * (2 * p + 1), space=B * T * p + 2 * B * T * D + p * D)


def ghost_norm(B, T, D, p) -> ModuleCost:
    # Table 1 col 2: 2BT^2(D+p+1) - B time; B(2T^2+1) space.
    return ModuleCost(time=2 * B * T * T * (D + p + 1) - B, space=B * (2 * T * T + 1))


def grad_instantiation(B, T, D, p) -> ModuleCost:
    # Table 1 col 3: 2B(T+1)pD time; B(pD+1) space.
    return ModuleCost(time=2 * B * (T + 1) * p * D, space=B * (p * D + 1))


def weighted_grad(B, T, D, p) -> ModuleCost:
    # Table 1 col 4: 2BpD time; 0 space.
    return ModuleCost(time=2 * B * p * D, space=0.0)


def ghost_is_cheaper(T: int, D: int, p: int, *, by: str = "space") -> bool:
    """Eq (4.1): choose ghost norm over instantiation iff 2T^2 < pD.

    ``by="time"`` implements the speed-priority variant (Remark 4.1):
    ghost iff 2T^2(D+p+1) < 2(T+1)pD.
    """
    if by == "time":
        return 2 * T * T * (D + p + 1) < 2 * (T + 1) * p * D
    return 2 * T * T < p * D


def bk_bank_prefers_ghost(
    T: int, D: int, p: int, *, groups: int = 1, a_elems: Optional[int] = None
) -> bool:
    """Book-keeping branch rule: which residual bank is smaller per sample?

    Book-keeping (arXiv:2210.00038) skips the second backward pass, so Eq
    (4.1) does not apply: every tap must *bank* enough of the backward pass to
    reconstruct ``sum_i C_i g_i`` after the clip factors are known.  The two
    banks are

    - ``instantiate``: the per-sample gradients a_i^T g_i themselves
      (G*pD elements; the per-sample norm falls out for free), or
    - ``ghost``: the (a_i, g_i) book (``a_elems`` + G*Tp elements — for
      convolutions ``a`` is banked *raw*, not unfolded, so the book is the
      true activation size) plus the ghost-norm Gram tiles (~2T^2
      transient), contracting with C_i afterwards.

    Time always favours ``instantiate`` (the psg einsum doubles as the norm),
    so — unlike Eq 4.1 — the rule is purely space-driven: bank the gradients
    unless the (a, g) book is strictly smaller.
    """
    book = (a_elems if a_elems is not None else groups * T * D) + groups * T * p
    return book + 2 * T * T < groups * D * p


def decide(
    meta: TapMeta,
    *,
    mode: str = "mixed_ghost",
    by: str = "space",
    override: Optional[str] = None,
) -> str:
    """Per-tap branch: 'ghost' | 'instantiate'.

    Non-matmul kinds have a forced branch: scale/bias/dw_conv per-sample grads
    are tiny (instantiate); embeddings always use the index-equality ghost
    norm (instantiating a (V, p) gradient per sample is never viable).

    ``override`` is a measured-cost branch from a ``repro.tuner`` ClipPlan:
    it wins over the analytic Eq-(4.1) rule (both branches compute the same
    per-sample norm, so the choice is pure performance), but never over a
    forced kind, and never over the pure reference modes ('ghost',
    'fastgradclip'), whose whole point is a fixed branch everywhere.
    """
    if meta.kind == "embedding":
        return "ghost"
    if meta.kind != "matmul":
        return "instantiate"
    if mode in ("ghost",):
        return "ghost"
    if mode in ("instantiate", "fastgradclip"):
        return "instantiate"
    if mode in ("mixed_ghost", "bk_mixed"):
        if override is not None:
            if override not in ("ghost", "instantiate"):
                raise ValueError(f"invalid branch override {override!r}")
            return override
        if mode == "bk_mixed":
            # book-keeping banks residuals instead of paying a second
            # backward; its branch economics are bank-size driven
            a_elems = None
            if meta.a_shape is not None:
                rows = max(meta.n_stack * meta.batch_size, 1)
                a_elems = math.prod(meta.a_shape) // rows
            return "ghost" if bk_bank_prefers_ghost(
                meta.T, meta.D, meta.p,
                groups=max(meta.n_groups, 1), a_elems=a_elems,
            ) else "instantiate"
        return "ghost" if ghost_is_cheaper(meta.T, meta.D, meta.p, by=by) else "instantiate"
    raise ValueError(f"unknown clipping mode {mode!r}")


def algorithm_cost(
    metas: dict[str, TapMeta], mode: str, *, by: str = "space"
) -> dict[str, float]:
    """Table 2: total per-iteration time/space of a clipping algorithm,
    summing matmul taps (the paper's analysis covers linear/conv layers)."""
    time = 0.0
    space = 0.0
    peak_clip_space = 0.0
    for m in metas.values():
        if m.kind != "matmul":
            continue
        reps = m.n_stack * max(m.n_groups, 1)
        B, T, D, p = m.batch_size, m.T, m.D, m.p
        bp = back_propagation(B, T, D, p)
        if mode == "non_private":
            time += reps * 3 * bp.time / 2  # fwd (~bp/2) + bwd
            space += reps * bp.space
            continue
        if mode == "opacus":
            gi = grad_instantiation(B, T, D, p)
            wg = weighted_grad(B, T, D, p)
            time += reps * (3 * bp.time / 2 + gi.time + wg.time)
            # Opacus holds per-sample grads of ALL layers simultaneously
            space += reps * (bp.space + gi.space)
            continue
        branch = decide(m, mode=mode if mode != "fastgradclip" else "instantiate", by=by)
        mod = ghost_norm(B, T, D, p) if branch == "ghost" else grad_instantiation(B, T, D, p)
        if mode == "bk_mixed":
            # no second backward; instead every tap banks residuals until the
            # clip factors are known, then pays the weighted contraction.
            # Ghost-branch taps replay the full (a, g) book (2BTDp); the
            # instantiate branch already paid the psg einsum inside
            # grad_instantiation, leaving only the Table-1 col-4 C_i sum.
            if branch == "ghost":
                wg_time = 2 * B * T * D * p
                bank = B * T * (D + p)
            else:
                wg_time = weighted_grad(B, T, D, p).time
                bank = B * p * D
            time += reps * (3 * bp.time / 2 + mod.time + wg_time)
            space += reps * (bp.space + bank)
        else:
            time += reps * (3 * bp.time / 2 + mod.time + bp.time)
            space += reps * bp.space
            peak_clip_space = max(peak_clip_space, reps * mod.space)
    return {"time": time, "space": space + peak_clip_space}
