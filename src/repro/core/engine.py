"""PrivacyEngine: the paper's Appendix-E API, adapted to functional JAX.

PyTorch version:                          This framework:

    engine = PrivacyEngine(model, ...)    engine = PrivacyEngine(loss_fn, ...)
    engine.attach(optimizer)              grad_fn = engine.clipped_grad_fn()
    optimizer.step(loss=loss)             loss, g, aux = grad_fn(params, batch)
    optimizer.virtual_step(loss=loss)     g_sum += g   (gradient accumulation)
                                          noisy = engine.privatize(g_sum, key)

``privatize`` adds sigma*R*N(0, I) once per *logical* batch and divides by the
logical batch size — exactly the paper's virtual-step semantics, which is what
makes large-batch DP training (the regime where DP accuracy lives) affordable
on fixed-memory hardware.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.accountant import RDPAccountant, compute_epsilon, find_noise_multiplier
from repro.core.clipping import (
    ClipConfig,
    discover_meta,
    dp_value_and_clipped_grad,
    validate_coverage,
)
from repro.core.noise import add_dp_noise
from repro.utils.logging import get_logger

log = get_logger("engine")


@dataclasses.dataclass
class PrivacyEngine:
    loss_with_ctx: Callable  # (params, batch, ctx) -> (B,) per-sample losses
    batch_size: int  # logical batch size (samples per optimizer step)
    sample_size: int  # dataset size N
    max_grad_norm: float  # clipping norm R
    epochs: Optional[float] = None
    steps: Optional[int] = None
    target_epsilon: Optional[float] = None
    target_delta: Optional[float] = None
    noise_multiplier: Optional[float] = None
    mode: str = "mixed_ghost"  # paper: 'ghost-mixed'
    clip_fn: str = "abadi"
    frozen_prefixes: tuple[str, ...] = ()
    # measured-cost branch plan (repro.tuner.ClipPlan); set directly, via
    # use_plan(), or produced in place by tune()
    plan: Optional[Any] = None
    # clipping policy (repro.policies.ClipPolicy).  None -> the fixed flat-R
    # policy built from (max_grad_norm, clip_fn) — the paper's mechanism.
    # A policy with a per-step release (quantile) is composed into every
    # epsilon this engine reports, including the target-epsilon bisection.
    clip_policy: Optional[Any] = None

    def __post_init__(self):
        self.sampling_rate = self.batch_size / self.sample_size
        if self.steps is None:
            if self.epochs is None:
                raise ValueError("need epochs or steps")
            self.steps = int(self.epochs * self.sample_size / self.batch_size)
        if self.target_delta is None:
            self.target_delta = 1.0 / (2 * self.sample_size)
        if self.clip_policy is None:
            from repro.policies.fixed import FixedPolicy

            self.clip_policy = FixedPolicy(
                clip_norm=self.max_grad_norm, clip_fn=self.clip_fn
            )
        if self.noise_multiplier is None:
            if self.target_epsilon is None:
                raise ValueError("need target_epsilon or noise_multiplier")
            self.noise_multiplier = find_noise_multiplier(
                target_epsilon=self.target_epsilon,
                q=self.sampling_rate,
                steps=self.steps,
                delta=self.target_delta,
                release_sigmas=self._release_sigmas(),
            )
        self.accountant = RDPAccountant()
        self._clip_cfg = ClipConfig(
            mode=self.mode,
            clip_norm=self.max_grad_norm,
            clip_fn=self.clip_fn,
            frozen_prefixes=self.frozen_prefixes,
            plan=self.plan,
            policy=self.clip_policy,
        )

    def _release_sigmas(self) -> tuple[float, ...]:
        """Noise multipliers of the policy's per-step side releases."""
        ev = self.clip_policy.release_event()
        return (ev.release_sigma,) if ev.spends else ()

    def init_policy_state(self) -> Any:
        """The policy-state pytree the first train step should receive."""
        return self.clip_policy.init_state()

    # -- measured-cost autotuning -----------------------------------------
    def use_plan(self, plan: Any) -> None:
        """Adopt a tuner ClipPlan; subsequent clipped_grad_fn() calls use it."""
        self.plan = plan
        self._clip_cfg = dataclasses.replace(self._clip_cfg, plan=plan)

    def recertify_max_batch(
        self, params: Any, batch: Any, *, hi_cap: int = 4096
    ) -> Optional[Any]:
        """Re-run the max-batch search for the engine's CURRENT mode + plan.

        The physical-batch certificate is only as good as the graph it was
        compiled from: adopting a different mode (book-keeping banks
        residuals the searched graph never allocated) or flipping branches
        after a re-measure both invalidate it.  Returns the plan with a
        refreshed ``physical_batch`` (adopted via use_plan), the unchanged
        plan when the certificate still holds, or ``None`` when nothing fits
        the stored budget under the current configuration — the caller must
        then fall back rather than train uncertified.

        On a multi-host fleet, pass a ``batch`` probe already sliced to the
        per-host share (parallel.sharding.per_host_batch): the certificate
        describes one host's HBM, and compiling it at the global batch
        would certify memory no single device ever holds.
        """
        plan = self.plan
        if plan is None or not getattr(plan, "budget_bytes", None):
            return plan
        from repro.tuner import max_batch as _mb

        mp, method = _mb.certify_max_batch(
            self.clipped_grad_fn(), params, batch,
            budget_bytes=plan.budget_bytes, hi_cap=hi_cap,
            reserved_bytes=_mb.resident_state_bytes(params),
        )
        if mp <= 0:
            return None
        if mp != plan.physical_batch:
            _, steps = _mb.derive_accumulation(self.batch_size, mp)
            log.info("re-certified max physical batch under %s by %s: %d "
                     "(was %s)", self.mode, method, mp, plan.physical_batch)
            plan = plan.replace_batch(
                physical_batch=mp, logical_batch=self.batch_size,
                accumulation_steps=steps, budget_bytes=plan.budget_bytes,
            )
            self.use_plan(plan)
        return plan

    def tune(
        self,
        params: Any,
        batch: Any,
        *,
        arch: Optional[str] = None,
        measure: Optional[Any] = None,
        search_max_batch: bool = True,
        budget_bytes: Optional[int] = None,
        hi_cap: int = 4096,
        plan_path: Optional[str] = "auto",
        use_cache: bool = True,
        remeasure_at_physical: bool = True,
        consensus: bool = False,
        gather_fn: Optional[Callable] = None,
    ) -> Any:
        """Profile the three-way branch decision per tap on this device,
        search the max physical microbatch, adopt and (by default) cache the
        ClipPlan.

        Each matmul tap is timed on {ghost norm, instantiated norm,
        book-keeping ghost-bank, book-keeping psg-bank, second-backward
        share}; the plan carries a branch map per tuned mode plus a measured
        ``recommended_mode``.  After the max-batch search settles,
        ``remeasure_at_physical`` re-times the branches at the tuned
        physical batch and only then finalizes the plan (timings scale
        ~linearly in B, so flips are rare — re-measuring removes the
        assumption).

        A valid cached plan for this (arch, device, tap shapes) is adopted
        without re-profiling (``use_cache=False`` forces a fresh measure).
        ``plan_path="auto"`` writes to the tuner cache dir; ``None`` skips
        writing.  Returns the plan.  The clipped gradients under the plan are
        bit-compatible with the analytic decision — only the branch (cost)
        changes, never the math.

        ``consensus=True`` makes tuning fleet-safe (repro.tuner.consensus):
        only the elected leader of each device kind measures; every rank
        then adopts the byte-identical fleet-agreed plan (or raises
        ``PlanConsensusError`` before anything is traced).  On a single
        process this is a cheap no-op agreement that stamps the plan's
        consensus provenance.  ``gather_fn`` injects the all-gather
        primitive (tests simulate fleets without ``jax.distributed``).
        On multi-host fleets, pass a ``batch`` already sliced to the
        per-host share (parallel.sharding.per_host_batch) so the max-batch
        certificate describes one host's HBM, not the global batch.
        """
        import os

        from repro.tuner import max_batch as _mb
        from repro.tuner.measure import (
            MeasureConfig,
            build_plan,
            close_physical_batch_loop,
        )
        from repro.tuner.plan import ClipPlan, default_plan_path, load_cached_plan

        budget = _mb.DEFAULT_BUDGET_BYTES if budget_bytes is None else budget_bytes
        meta = discover_meta(self.loss_with_ctx, params, batch)
        policy_fp = self.clip_policy.fingerprint()

        def stamp(p):
            # plans are policy-stamped so a fleet cannot certify one plan
            # across ranks running different clipping policies.  Re-stamping
            # a plan agreed under another policy voids that agreement claim
            # (the measurements stay valid — branch decisions are
            # policy-independent); the consensus path below re-agrees and
            # re-stamps provenance honestly.
            if p is None or p.policy_fingerprint == policy_fp:
                return p
            cleared = {} if p.agreed_hash is None else {
                "agreed_hash": None, "agreed_ranks": None,
            }
            return dataclasses.replace(
                p, policy_fingerprint=policy_fp, **cleared
            )

        def agree_and_save(measured):
            # one agreement path for every consensus branch below: submit
            # this rank's measurement (None on non-leaders), persist what
            # the fleet adopted — never the rank-local measurement
            from repro.tuner import consensus as _cons

            adopted = _cons.fleet_agree(
                stamp(measured), meta, gather_fn=gather_fn,
                policy_fingerprint=policy_fp,
            )
            if plan_path is not None:
                adopted.save(
                    default_plan_path(arch, adopted.fingerprint)
                    if plan_path == "auto" else plan_path
                )
            return adopted

        if consensus:
            from repro.tuner import consensus as _cons

            roles = _cons.fleet_roles(gather_fn=gather_fn)
            if not roles.is_leader:
                # one measurement per device kind: non-leaders skip straight
                # to the agreement and adopt (and cache) the leader's plan
                adopted = agree_and_save(None)
                self.use_plan(adopted)
                return adopted
        if use_cache:
            cached = None
            if plan_path == "auto":
                cached = load_cached_plan(arch, meta)
            elif plan_path is not None and os.path.exists(plan_path):
                try:
                    cached = ClipPlan.load(plan_path)
                except (ValueError, KeyError) as e:
                    log.warning("ignoring unreadable plan %s (%s); re-tuning",
                                plan_path, e)
            # a cached max batch is only valid for the budget it was searched
            # under; branch overrides alone don't depend on the budget
            budget_ok = not search_max_batch or (
                cached is not None and cached.budget_bytes == budget
            )
            from repro.tuner.plan import device_string as _device_string

            if consensus and cached is not None and cached.device != _device_string():
                # a cached plan this kind merely RATIFIED (measured by a
                # different kind in an earlier fleet) is not a measurement
                # of this hardware: submitting it would let a device kind
                # dodge profiling forever — re-measure instead
                log.info("cached plan was measured on %s, not this %s; "
                         "re-measuring for the fleet agreement",
                         cached.device, _device_string())
                cached = None
            if cached is not None and budget_ok and cached.matches(meta):
                cached = stamp(cached)
                if consensus:
                    cached = agree_and_save(cached)
                self.use_plan(cached)
                return cached
        measure_cfg = measure or MeasureConfig()
        plan = stamp(build_plan(meta, measure=measure_cfg, arch=arch))
        if search_max_batch:
            grad_fn = dp_value_and_clipped_grad(
                self.loss_with_ctx, dataclasses.replace(self._clip_cfg, plan=plan)
            )
            mp, method = _mb.certify_max_batch(
                grad_fn, params, batch, budget_bytes=budget, hi_cap=hi_cap,
                reserved_bytes=_mb.resident_state_bytes(params),
            )
            if mp > 0:
                log.info("max physical batch certified by %s: %d", method, mp)
                _, steps = _mb.derive_accumulation(self.batch_size, mp)
                plan = plan.replace_batch(
                    physical_batch=mp,
                    logical_batch=self.batch_size,
                    accumulation_steps=steps,
                    budget_bytes=budget,
                )
                if remeasure_at_physical:
                    # close the loop: the step will run at the tuned batch,
                    # so the branch decision must be measured there too —
                    # and flips change per-tap clipping memory, so the batch
                    # certificate and the branch maps must converge together
                    def _search(p):
                        grad_fn = dp_value_and_clipped_grad(
                            self.loss_with_ctx,
                            dataclasses.replace(self._clip_cfg, plan=p),
                        )
                        return _mb.certify_max_batch(
                            grad_fn, params, batch, budget_bytes=budget,
                            hi_cap=hi_cap,
                            reserved_bytes=_mb.resident_state_bytes(params),
                        )[0]

                    plan = close_physical_batch_loop(
                        plan, meta, _search, self.batch_size, budget,
                        measure_cfg,
                    )
        if consensus:
            # leader rank: the fleet-adopted plan (possibly another kind's,
            # under the mixed-kind tie-break) is what gets cached and used
            plan = agree_and_save(plan)
        elif plan_path is not None:
            plan.save(
                default_plan_path(arch, plan.fingerprint)
                if plan_path == "auto" else plan_path
            )
        self.use_plan(plan)
        return plan

    def plan_event_fields(self) -> dict:
        """The ``plan_adopted`` event payload for this engine's clipping.

        Everything the post-mortem reader needs to reconstruct what was
        actually traced: the per-tap branch decision for the running mode,
        the kernel winners per (tap, op), and the batch certificate.  With
        no plan adopted the decision is the analytic rule — reported as
        such so "no tuning happened" is an explicit record, not a missing
        one.  Plain JSON-able scalars/dicts only.
        """
        out = {
            "mode": self.mode,
            "policy": self.clip_policy.fingerprint(),
            "clip_norm": float(self.max_grad_norm),
            "noise_multiplier": float(self.noise_multiplier),
        }
        plan = self.plan
        if plan is None:
            out["source"] = "analytic"
            return out
        out.update(
            source="plan",
            branches=plan.branch_map(self.mode),
            kernels=plan.kernel_map(),
            recommended_mode=plan.recommended_mode(),
            physical_batch=plan.physical_batch,
            accumulation_steps=plan.accumulation_steps,
            plan_device=plan.device,
            consensus_hash=plan.consensus_hash(),
            agreed_hash=plan.agreed_hash,
            agreed_ranks=plan.agreed_ranks,
        )
        return out

    # -- validation -------------------------------------------------------
    def validate(self, params: Any, batch: Any) -> None:
        """Raise if any trainable parameter escapes per-sample clipping."""
        meta = discover_meta(self.loss_with_ctx, params, batch)
        missing = validate_coverage(meta, params, self.frozen_prefixes)
        if missing:
            raise ValueError(
                "parameters not covered by per-sample clipping (freeze them or "
                f"add taps): {missing[:10]}{'...' if len(missing) > 10 else ''}"
            )

    # -- the two halves of the mechanism ----------------------------------
    def clipped_grad_fn(self) -> Callable:
        """(params, batch) -> (mean_loss, sum_i C_i g_i, aux). jit/pjit-safe."""
        return dp_value_and_clipped_grad(self.loss_with_ctx, self._clip_cfg)

    def privatize(
        self, grad_sum: Any, key: jax.Array, policy_state: Any = None
    ) -> Any:
        """Add noise once per logical batch; normalize by batch size.

        The noise std is ``sigma * policy.sensitivity(state)`` — for the
        fixed policy that is ``sigma * R`` exactly as before; the quantile
        policy's adapted R and the automatic policy's unit bound flow from
        the same call.  ``policy_state=None`` uses the policy's init state
        (correct for stateless policies).
        """
        pstate = (
            policy_state if policy_state is not None
            else self.clip_policy.init_state()
        )
        std = self.noise_multiplier * self.clip_policy.sensitivity(pstate)
        noisy = add_dp_noise(grad_sum, key, std)
        return jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) / self.batch_size).astype(g.dtype), noisy
        )

    # -- accounting --------------------------------------------------------
    def record_step(self, n: int = 1) -> None:
        """Compose n steps: the gradient mechanism + any policy release.

        Composed one step at a time, gradient-then-release, so a resume
        that replays ``record_step(start_step)`` performs the identical
        float additions (same order) as the uninterrupted run — the
        accountant's epsilon is bit-exact across restarts.
        """
        for _ in range(n):
            self.accountant.step(
                q=self.sampling_rate, sigma=self.noise_multiplier, steps=1
            )
            for rs in self._release_sigmas():
                self.accountant.step(q=self.sampling_rate, sigma=rs, steps=1)

    def check_epsilon_alarm(
        self, fraction: float, step: Optional[int] = None
    ) -> bool:
        """One-shot budget alarm: emit ``epsilon_budget_crossed`` once the
        accountant's spend passes ``fraction * target_epsilon``.

        Returns True iff the alarm fired on THIS call — the latch guarantees
        at most one event per engine, so drivers may call this after every
        ``record_step`` without flooding the stream.  A no-op when the run
        has no ``target_epsilon`` (noise-multiplier-specified runs) or
        ``fraction <= 0``.
        """
        if (
            getattr(self, "_eps_alarm_fired", False)
            or self.target_epsilon is None
            or fraction <= 0
        ):
            return False
        eps, delta = self.privacy_spent()
        if eps < fraction * self.target_epsilon:
            return False
        self._eps_alarm_fired = True
        from repro.obs import events as obs

        obs.emit_event(
            "epsilon_budget_crossed",
            step=step,
            epsilon=float(eps),
            delta=float(delta),
            target_epsilon=float(self.target_epsilon),
            fraction=float(fraction),
        )
        log.warning(
            "privacy budget alarm: epsilon %.4f passed %.0f%% of target %.4f",
            eps, 100 * fraction, self.target_epsilon,
        )
        return True

    def privacy_spent(self, steps: Optional[int] = None) -> tuple[float, float]:
        if steps is not None:
            eps = compute_epsilon(
                q=self.sampling_rate,
                sigma=self.noise_multiplier,
                steps=steps,
                delta=self.target_delta,
                release_sigmas=self._release_sigmas(),
            )
        else:
            eps = self.accountant.get_epsilon(self.target_delta)
        return eps, self.target_delta
