"""Per-tap per-sample gradient norms and (BK mode) weighted gradients.

Given a tap's recorded activation ``a``, its cotangent ``g = dL/ds`` from the
first backward pass, and the static ``TapMeta``, this module computes the
per-sample squared gradient norm on the branch the layerwise decision picked
(Alg. 1), and — for the book-keeping mode — the weighted gradient
``sum_i C_i g_i`` directly as an einsum, skipping the second backward pass.

Canonical layouts (stack dims folded into the row dim N):
- matmul:     a (N, T, D), g (N, T, p); N = prod(stack) * B * G
- embedding:  ids (N, T),  g (N, T, p)
- scale:      a, g (N, T, p)          grad = sum_T g*a
- bias:       g (N, T, p)             grad = sum_T g
- dw_conv:    a (N, T, k, d), g (N, T, d)
- scale_grouped: a, g (N, T, h*dh), param (h,)
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.decision import decide
from repro.core.taps import TapMeta
from repro.kernels.ghost_norm import ops as gops
from repro.nn.conv import unfold2d


def _fold(meta: TapMeta, x: jax.Array, trailing: tuple[int, ...]) -> jax.Array:
    """Reshape (stack..., B, <middle>) -> (L, B*G?, ...) canonical row-major."""
    lead = math.prod(meta.stack_dims) if meta.stack_dims else 1
    return x.reshape((lead, meta.batch_size) + trailing)


def _per_sample(meta: TapMeta, row_vals: jax.Array) -> jax.Array:
    """(L*B*G,) row norms -> (B,) per-sample sums (over stack and groups)."""
    lead = math.prod(meta.stack_dims) if meta.stack_dims else 1
    v = row_vals.reshape(lead, meta.batch_size, max(meta.n_groups, 1))
    return jnp.sum(v, axis=(0, 2))


def _canonical_ag(meta: TapMeta, a: jax.Array, g: jax.Array):
    """Return a (N, T, D), g (N, T, p) with N = L*B*G."""
    lead = math.prod(meta.stack_dims) if meta.stack_dims else 1
    gg = g.reshape(lead * meta.batch_size * max(meta.n_groups, 1), meta.T, meta.p)
    if meta.conv is not None:
        # a is raw (lead*B, H, W, d): unfold lazily to (N, T, D)
        a4 = a.reshape((lead * meta.batch_size,) + a.shape[-3:])
        aa = unfold2d(a4, meta.conv)
    else:
        aa = a.reshape(lead * meta.batch_size * max(meta.n_groups, 1), meta.T, meta.D)
    return aa, gg


def tap_norm_sq(
    meta: TapMeta,
    a: Optional[jax.Array],
    g: jax.Array,
    *,
    mode: str = "mixed_ghost",
    decision_by: str = "space",
    ghost_block: int = 512,
    inst_block_d: int = 8192,
    override: Optional[str] = None,
) -> jax.Array:
    """Per-sample squared norm contributions: (B,) fp32 (weight + bias).

    ``override`` forces the matmul branch (tuner ClipPlan); both branches
    compute the same norm, so it changes cost only, never the result.
    """
    g = g.astype(jnp.float32)
    total = jnp.zeros((meta.batch_size,), jnp.float32)

    if meta.kind == "matmul":
        branch = decide(meta, mode=mode, by=decision_by, override=override)
        aa, gg = _canonical_ag(meta, a, g)
        if branch == "ghost":
            rows = gops.ghost_norm_sq(aa, gg, block=ghost_block)
        else:
            rows = gops.instantiated_norm_sq(aa, gg, block_d=inst_block_d)
        total = total + _per_sample(meta, rows)
    elif meta.kind == "embedding":
        lead = math.prod(meta.stack_dims) if meta.stack_dims else 1
        ids = a.reshape(lead * meta.batch_size, meta.T)
        gg = g.reshape(lead * meta.batch_size, meta.T, meta.p)
        rows = gops.embedding_ghost_norm_sq(ids, gg)
        total = total + _per_sample(meta, rows)
    elif meta.kind == "scale":
        af = _fold(meta, a.astype(jnp.float32), (meta.T, meta.p))
        gf = _fold(meta, g, (meta.T, meta.p))
        grad = jnp.sum(gf * af, axis=-2)  # (L, B, p)
        total = total + jnp.sum(grad * grad, axis=(0, 2))
    elif meta.kind == "bias":
        gf = _fold(meta, g, (meta.T, meta.p))
        grad = jnp.sum(gf, axis=-2)
        total = total + jnp.sum(grad * grad, axis=(0, 2))
    elif meta.kind == "scale_grouped":
        h, dh = meta.p, meta.D
        af = _fold(meta, a.astype(jnp.float32), (meta.T, h, dh))
        gf = _fold(meta, g, (meta.T, h, dh))
        grad = jnp.einsum("lbthd,lbthd->lbh", gf, af)
        total = total + jnp.sum(grad * grad, axis=(0, 2))
    elif meta.kind == "dw_conv":
        k = meta.D
        af = _fold(meta, a.astype(jnp.float32), (meta.T, k, meta.p))
        gf = _fold(meta, g, (meta.T, meta.p))
        grad = jnp.einsum("lbtkd,lbtd->lbkd", af, gf)
        total = total + jnp.sum(grad * grad, axis=(0, 2, 3))
    else:
        raise ValueError(f"unknown tap kind {meta.kind!r}")

    if meta.bias_path is not None:
        lead = math.prod(meta.stack_dims) if meta.stack_dims else 1
        gf = g.reshape(lead, meta.batch_size, -1, meta.p)  # (L, B, G*T, p)
        bias_grad = jnp.sum(gf, axis=2)  # (L, B, p)
        total = total + jnp.sum(bias_grad * bias_grad, axis=(0, 2))
    return total


def tap_weighted_grads(
    meta: TapMeta,
    a: Optional[jax.Array],
    g: jax.Array,
    clip: jax.Array,  # (B,) clip factors C_i
    param_shape: tuple[int, ...],
) -> dict[str, jax.Array]:
    """BK mode: weighted gradients sum_i C_i g_i as direct einsums.

    Returns {param_path: grad, [bias_path: grad]} shaped like the params.
    """
    out: dict[str, jax.Array] = {}
    lead = math.prod(meta.stack_dims) if meta.stack_dims else 1
    gdim = max(meta.n_groups, 1)
    cw = clip.astype(jnp.float32)

    if meta.kind in ("matmul", "embedding", "scale", "bias"):
        gw = g.astype(jnp.float32).reshape(lead, meta.batch_size, gdim, meta.T, meta.p)
        gw = gw * cw[None, :, None, None, None]

    if meta.kind == "matmul":
        if a is None:
            raise ValueError(f"matmul tap {meta.param_path} has no recorded activation")
        if meta.conv is not None:
            a4 = a.reshape((lead * meta.batch_size,) + a.shape[-3:])
            aa = unfold2d(a4, meta.conv).reshape(
                lead, meta.batch_size, gdim, meta.T, meta.D
            )
        else:
            aa = a.reshape(lead, meta.batch_size, gdim, meta.T, meta.D)
        w = jnp.einsum("lbgtd,lbgtp->lgdp", aa.astype(jnp.float32), gw)
        if meta.conv is not None:
            # unfold ordering is channel-major: (D=d*kh*kw, p) -> (d, kh, kw, p)
            kh, kw = meta.conv.kernel
            d_in = meta.D // (kh * kw)
            w = w.reshape(lead, d_in, kh, kw, meta.p).transpose(0, 2, 3, 1, 4)
            w = w.reshape(param_shape)
        else:
            w = w.reshape(param_shape)
        out[meta.param_path] = w
    elif meta.kind == "embedding":
        ids = a.reshape(-1)
        flat_g = gw.reshape(-1, meta.p)
        w = jnp.zeros(param_shape, jnp.float32).at[ids].add(flat_g)
        out[meta.param_path] = w
    elif meta.kind == "scale":
        af = a.astype(jnp.float32).reshape(lead, meta.batch_size, gdim, meta.T, meta.p)
        out[meta.param_path] = jnp.einsum("lbgtp,lbgtp->lp", af, gw).reshape(param_shape)
    elif meta.kind == "bias":
        out[meta.param_path] = jnp.einsum("lbgtp->lp", gw).reshape(param_shape)
    elif meta.kind == "scale_grouped":
        h, dh = meta.p, meta.D
        af = a.astype(jnp.float32).reshape(lead, meta.batch_size, meta.T, h, dh)
        gg = g.astype(jnp.float32).reshape(lead, meta.batch_size, meta.T, h, dh)
        gg = gg * cw[None, :, None, None, None]
        out[meta.param_path] = jnp.einsum("lbthd,lbthd->lh", af, gg).reshape(param_shape)
    elif meta.kind == "dw_conv":
        k = meta.D
        af = a.astype(jnp.float32).reshape(lead, meta.batch_size, meta.T, k, meta.p)
        gg = g.astype(jnp.float32).reshape(lead, meta.batch_size, meta.T, meta.p)
        gg = gg * cw[None, :, None, None]
        out[meta.param_path] = jnp.einsum("lbtkd,lbtd->lkd", af, gg).reshape(param_shape)
    else:
        raise ValueError(f"unknown tap kind {meta.kind!r}")

    if meta.bias_path is not None:
        gb = g.astype(jnp.float32).reshape(lead, meta.batch_size, -1, meta.p)
        gb = gb * cw[None, :, None, None]
        out[meta.bias_path] = jnp.einsum("lbtp->lp", gb).reshape(
            meta.stack_dims + (meta.p,) if meta.stack_dims else (meta.p,)
        )
    return out
