"""Per-tap per-sample gradient norms, book-keeping banks, weighted gradients.

Given a tap's recorded activation ``a``, its cotangent ``g = dL/ds`` from the
first backward pass, and the static ``TapMeta``, this module computes the
per-sample squared gradient norm on the branch the layerwise decision picked
(Alg. 1), and — for the book-keeping mode — the weighted gradient
``sum_i C_i g_i`` directly as an einsum, skipping the second backward pass.

Three call sites:
- ``tap_norm_sq``        per-sample norm^2 from explicit (a, g) pairs; used
                         by the reference ``*_taps`` engine and the fused
                         probes of the second-backward modes.
- ``tap_bank``           runs INSIDE the fused probe's backward rule: returns
                         the side-channel payload for one tap — always the
                         per-sample norm^2 ``n``, plus (book-keeping mode) the
                         residuals the weighted-grad stage needs (banked
                         per-sample gradients ``psg``/``psg_b``, or the
                         ``(a, g)`` book for ghost-banked taps).
- ``bank_weighted_grads``  the fused gradient stage: ``sum_i C_i g_i`` from a
                         tap's bank once the clip factors are known.
- ``tap_weighted_grads``   same, from explicit (a, g) (reference engine and
                         late taps whose activation only exists post-scan).

Canonical layouts (stack dims folded into the row dim N):
- matmul:     a (N, T, D), g (N, T, p); N = prod(stack) * B * G
- embedding:  ids (N, T),  g (N, T, p)
- scale:      a, g (N, T, p)          grad = sum_T g*a
- bias:       g (N, T, p)             grad = sum_T g
- dw_conv:    a (N, T, k, d), g (N, T, d)
- scale_grouped: a, g (N, T, h*dh), param (h,)
"""
from __future__ import annotations

import math
from typing import Mapping, Optional

import jax
import jax.numpy as jnp

from repro.core.decision import decide
from repro.core.taps import TapMeta
from repro.kernels import dispatch
from repro.kernels.ghost_norm import ops as gops
from repro.nn.conv import unfold2d

# Largest integer float32 represents exactly: the fused engine sends
# embedding ids through the bank side channel as fp32 (cotangent pytrees
# are float), so vocabs at/above this silently corrupt high token ids.
MAX_EXACT_FP32_ID = 1 << 24

# ``kernels`` arguments below: an optional per-tap {op: impl} map from a
# tuner ClipPlan ("pallas" | "xla" per dispatch op); None defers to
# repro.kernels.dispatch's backend default (pallas on TPU, xla elsewhere).
KernelChoices = Optional[Mapping[str, str]]


def _check_embedding_vocab(meta: TapMeta, where: str) -> None:
    """Trace-time guard: oversized vocabs must not cross the fp32 channel.

    ``meta.D`` is the vocab size for embedding taps (nn.module.Embedding
    registers D=vocab).  Raising at trace time — before any id is cast —
    beats silently training on corrupted indices >= 2^24.
    """
    if meta.D >= MAX_EXACT_FP32_ID:
        raise ValueError(
            f"embedding tap {meta.param_path!r} has vocab size {meta.D} >= "
            f"2^24 ({MAX_EXACT_FP32_ID}): {where} carries token ids as "
            "float32, which cannot represent ids that large exactly, so "
            "high vocab indices would be silently corrupted. Run this model "
            "on the explicit *_taps engine (ids stay integer) or shard the "
            "embedding below 2^24 rows per tap."
        )


def _fold(meta: TapMeta, x: jax.Array, trailing: tuple[int, ...]) -> jax.Array:
    """Reshape (stack..., B, <middle>) -> (L, B*G?, ...) canonical row-major."""
    lead = math.prod(meta.stack_dims) if meta.stack_dims else 1
    return x.reshape((lead, meta.batch_size) + trailing)


def _per_sample(meta: TapMeta, row_vals: jax.Array) -> jax.Array:
    """(L*B*G,) row norms -> (B,) per-sample sums (over stack and groups)."""
    lead = math.prod(meta.stack_dims) if meta.stack_dims else 1
    v = row_vals.reshape(lead, meta.batch_size, max(meta.n_groups, 1))
    return jnp.sum(v, axis=(0, 2))


def _canonical_ag(meta: TapMeta, a: jax.Array, g: jax.Array):
    """Return a (N, T, D), g (N, T, p) with N = L*B*G."""
    lead = math.prod(meta.stack_dims) if meta.stack_dims else 1
    gg = g.reshape(lead * meta.batch_size * max(meta.n_groups, 1), meta.T, meta.p)
    if meta.conv is not None:
        # a is raw (lead*B, H, W, d): unfold lazily to (N, T, D)
        a4 = a.reshape((lead * meta.batch_size,) + a.shape[-3:])
        aa = unfold2d(a4, meta.conv)
    else:
        aa = a.reshape(lead * meta.batch_size * max(meta.n_groups, 1), meta.T, meta.D)
    return aa, gg


def tap_norm_sq(
    meta: TapMeta,
    a: Optional[jax.Array],
    g: jax.Array,
    *,
    mode: str = "mixed_ghost",
    decision_by: str = "space",
    ghost_block: int = 512,
    inst_block_d: int = 8192,
    override: Optional[str] = None,
    include_bias: bool = True,
    kernels: KernelChoices = None,
) -> jax.Array:
    """Per-sample squared norm contributions: (B,) fp32 (weight + bias).

    ``override`` forces the matmul branch (tuner ClipPlan); both branches
    compute the same norm, so it changes cost only, never the result.
    ``include_bias=False`` skips the bias term (book-keeping banks it
    separately as ``psg_b`` and adds its norm from the bank).  ``kernels``
    picks the Pallas-vs-XLA impl per dispatch op (also cost-only).
    """
    g = g.astype(jnp.float32)
    total = jnp.zeros((meta.batch_size,), jnp.float32)

    if meta.kind == "matmul":
        branch = decide(meta, mode=mode, by=decision_by, override=override)
        aa, gg = _canonical_ag(meta, a, g)
        if branch == "ghost":
            rows = dispatch.ghost_norm_sq(
                aa, gg, block=ghost_block,
                impl=dispatch.kernels_arg(kernels, "ghost_norm"),
            )
        else:
            rows = gops.instantiated_norm_sq(aa, gg, block_d=inst_block_d)
        total = total + _per_sample(meta, rows)
    elif meta.kind == "embedding":
        if jnp.issubdtype(a.dtype, jnp.floating):
            # fused engine: ids arrived through the fp32 side channel
            _check_embedding_vocab(meta, "the per-sample norm stage")
        lead = math.prod(meta.stack_dims) if meta.stack_dims else 1
        ids = a.reshape(lead * meta.batch_size, meta.T)
        gg = g.reshape(lead * meta.batch_size, meta.T, meta.p)
        rows = dispatch.embedding_ghost_norm_sq(
            ids, gg, impl=dispatch.kernels_arg(kernels, "embedding_ghost_norm")
        )
        total = total + _per_sample(meta, rows)
    elif meta.kind == "scale":
        af = _fold(meta, a.astype(jnp.float32), (meta.T, meta.p))
        gf = _fold(meta, g, (meta.T, meta.p))
        grad = jnp.sum(gf * af, axis=-2)  # (L, B, p)
        total = total + jnp.sum(grad * grad, axis=(0, 2))
    elif meta.kind == "bias":
        gf = _fold(meta, g, (meta.T, meta.p))
        grad = jnp.sum(gf, axis=-2)
        total = total + jnp.sum(grad * grad, axis=(0, 2))
    elif meta.kind == "scale_grouped":
        h, dh = meta.p, meta.D
        af = _fold(meta, a.astype(jnp.float32), (meta.T, h, dh))
        gf = _fold(meta, g, (meta.T, h, dh))
        grad = jnp.einsum("lbthd,lbthd->lbh", gf, af)
        total = total + jnp.sum(grad * grad, axis=(0, 2))
    elif meta.kind == "dw_conv":
        k = meta.D
        af = _fold(meta, a.astype(jnp.float32), (meta.T, k, meta.p))
        gf = _fold(meta, g, (meta.T, meta.p))
        grad = jnp.einsum("lbtkd,lbtd->lbkd", af, gf)
        total = total + jnp.sum(grad * grad, axis=(0, 2, 3))
    else:
        raise ValueError(f"unknown tap kind {meta.kind!r}")

    if meta.bias_path is not None and include_bias:
        lead = math.prod(meta.stack_dims) if meta.stack_dims else 1
        gf = g.reshape(lead, meta.batch_size, -1, meta.p)  # (L, B, G*T, p)
        bias_grad = jnp.sum(gf, axis=2)  # (L, B, p)
        total = total + jnp.sum(bias_grad * bias_grad, axis=(0, 2))
    return total


def psg_param_shape(meta: TapMeta) -> tuple[int, ...]:
    """Per-layer shape of one sample's banked gradient = the param's layout.

    matmul (D, p) / grouped (G, D, p) / conv kernel+(d, p) | scale (p,) |
    scale_grouped (h,) | dw_conv (k, d) | bias (p,).
    """
    if meta.kind == "matmul":
        if meta.conv is not None:
            d_in = meta.D // math.prod(meta.conv.kernel)
            return tuple(meta.conv.kernel) + (d_in, meta.p)
        if meta.n_groups > 1:
            return (meta.n_groups, meta.D, meta.p)
        return (meta.D, meta.p)
    if meta.kind == "dw_conv":
        return (meta.D, meta.p)
    if meta.kind in ("scale", "scale_grouped", "bias"):
        return (meta.p,)
    raise ValueError(f"no banked per-sample gradient for tap kind {meta.kind!r}")


def _matmul_psg(meta: TapMeta, a: jax.Array, g: jax.Array) -> jax.Array:
    """Per-layer per-sample weight gradients (B,) + psg_param_shape(meta).

    Convolutions go through a vmapped vjp of the conv op itself — the
    per-sample dW lowers to a conv kernel and the (B, T, D) im2col patches
    are never materialized (the explicit unfold is the single largest temp
    of the instantiate branch on CNNs).
    """
    b = meta.batch_size
    g32 = g.astype(jnp.float32)
    if meta.conv is not None:
        info = meta.conv
        a4 = a.reshape((b,) + tuple(a.shape[-3:])).astype(jnp.float32)
        go = g32.reshape((b,) + tuple(meta.s_shape[-3:]))
        w0 = jnp.zeros(psg_param_shape(meta), jnp.float32)

        def one(ab, gb):
            _, pullb = jax.vjp(
                lambda w: jax.lax.conv_general_dilated(
                    ab[None], w, info.strides, info.padding,
                    rhs_dilation=info.rhs_dilation,
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                    feature_group_count=info.feature_group_count,
                ),
                w0,
            )
            (dw,) = pullb(gb[None])
            return dw

        return jax.vmap(one)(a4, go)
    gdim = max(meta.n_groups, 1)
    aa = a.astype(jnp.float32).reshape(b * gdim, meta.T, meta.D)
    gg = g32.reshape(b * gdim, meta.T, meta.p)
    psg = jnp.einsum("ntd,ntp->ndp", aa, gg)
    return psg.reshape((b,) + psg_param_shape(meta))


def _small_psg(meta: TapMeta, a: jax.Array, g: jax.Array) -> jax.Array:
    """Per-layer per-sample gradients for the tiny forced-instantiate kinds.

    Shapes (B = batch, per layer instance, no stack dims):
    scale (B, p) | scale_grouped (B, h) | dw_conv (B, k, d) | bias (B, p).
    """
    b = meta.batch_size
    if meta.kind == "scale":
        af = a.astype(jnp.float32).reshape(b, meta.T, meta.p)
        gf = g.reshape(b, meta.T, meta.p)
        return jnp.sum(gf * af, axis=1)
    if meta.kind == "scale_grouped":
        h, dh = meta.p, meta.D
        af = a.astype(jnp.float32).reshape(b, meta.T, h, dh)
        gf = g.reshape(b, meta.T, h, dh)
        return jnp.einsum("bthd,bthd->bh", gf, af)
    if meta.kind == "dw_conv":
        k = meta.D
        af = a.astype(jnp.float32).reshape(b, meta.T, k, meta.p)
        gf = g.reshape(b, meta.T, meta.p)
        return jnp.einsum("btkd,btd->bkd", af, gf)
    if meta.kind == "bias":
        return jnp.sum(g.reshape(b, meta.T, meta.p), axis=1)
    raise ValueError(f"no small per-sample gradient for tap kind {meta.kind!r}")


def tap_bank(
    meta: TapMeta,
    a: Optional[jax.Array],
    g: jax.Array,
    *,
    mode: str = "mixed_ghost",
    decision_by: str = "space",
    ghost_block: int = 512,
    inst_block_d: int = 8192,
    override: Optional[str] = None,
    kernels: KernelChoices = None,
) -> dict[str, jax.Array]:
    """The fused probe's backward payload for one tap (per layer instance).

    Every bank carries ``n`` — the tap's total per-sample squared norm (B,).
    Outside book-keeping mode that is the whole bank (today's side channel).
    In ``bk_mixed`` the bank additionally carries what the weighted-grad
    stage needs once the clip factors exist:

    - forced-instantiate kinds and instantiate-branch matmuls: the per-sample
      gradients ``psg`` (+ ``psg_b`` for the bias) — the norm falls out of
      them for free, and nothing activation- or cotangent-sized survives;
    - ghost-branch matmuls and embeddings: the ``(a, g)`` book (smaller than
      pD per sample exactly when the branch rule banked it), from which both
      the ghost norm (here) and the weighted einsum (later) are formed.
    """
    if mode != "bk_mixed":
        return {
            "n": tap_norm_sq(
                meta, a, g, mode=mode, decision_by=decision_by,
                ghost_block=ghost_block, inst_block_d=inst_block_d,
                override=override, kernels=kernels,
            )
        }

    b = meta.batch_size
    g32 = g.astype(jnp.float32)
    bank: dict[str, jax.Array] = {}
    n = jnp.zeros((b,), jnp.float32)

    if meta.kind == "matmul":
        branch = decide(meta, mode="bk_mixed", by=decision_by, override=override)
        if branch == "instantiate":
            psg = _matmul_psg(meta, a, g32)
            bank["psg"] = psg
            n = n + jnp.sum(jnp.square(psg).reshape(b, -1), axis=-1)
        else:
            bank["a"], bank["g"] = a, g
            n = n + tap_norm_sq(
                meta, a, g, mode="ghost", decision_by=decision_by,
                ghost_block=ghost_block, inst_block_d=inst_block_d,
                include_bias=False, kernels=kernels,
            )
    elif meta.kind == "embedding":
        # a is the fp32-cast ids (taps.Ctx casts before probing): exact for
        # vocab indices below 2^24 — guarded at trace time, since anything
        # larger would silently corrupt high token ids in the bank
        _check_embedding_vocab(meta, "the book-keeping bank")
        bank["a"], bank["g"] = a, g
        n = n + tap_norm_sq(
            meta, a, g, mode=mode, decision_by=decision_by,
            ghost_block=ghost_block, inst_block_d=inst_block_d,
            include_bias=False, kernels=kernels,
        )
    else:
        psg = _small_psg(meta, a, g32)
        bank["psg"] = psg
        n = n + jnp.sum(jnp.square(psg).reshape(b, -1), axis=-1)

    if meta.bias_path is not None:
        if "g" in bank:
            # the book already reconstructs the bias grad; only the norm term
            # is still owed (tap_norm_sq above ran with include_bias=False)
            gf = g32.reshape(b, -1, meta.p)
            bias_grad = jnp.sum(gf, axis=1)
            n = n + jnp.sum(bias_grad * bias_grad, axis=-1)
        else:
            psg_b = jnp.sum(g32.reshape(b, -1, meta.p), axis=1)
            bank["psg_b"] = psg_b
            n = n + jnp.sum(psg_b * psg_b, axis=-1)
    bank["n"] = n
    return bank


def _finish_matmul_grad(
    meta: TapMeta, w: jax.Array, param_shape: tuple[int, ...]
) -> jax.Array:
    """Weighted matmul grad (L, G, D, p) -> the parameter's own layout.

    Convolution weights live as (kh, kw, d, p) while the unfolded fan-in is
    channel-major (D = d*kh*kw), so the conv path un-permutes before the
    final reshape.
    """
    lead = math.prod(meta.stack_dims) if meta.stack_dims else 1
    if meta.conv is not None:
        # unfold ordering is channel-major: (D=d*kh*kw, p) -> (d, kh, kw, p)
        kh, kw = meta.conv.kernel
        d_in = meta.D // (kh * kw)
        w = w.reshape(lead, d_in, kh, kw, meta.p).transpose(0, 2, 3, 1, 4)
    return w.reshape(param_shape)


def tap_weighted_grads(
    meta: TapMeta,
    a: Optional[jax.Array],
    g: jax.Array,
    clip: jax.Array,  # (B,) clip factors C_i
    param_shape: tuple[int, ...],
    kernels: KernelChoices = None,
) -> dict[str, jax.Array]:
    """BK mode: weighted gradients sum_i C_i g_i, contracted directly.

    Matmul taps run the fused clip-and-contract stage through
    ``dispatch.book_weighted_grad`` (the Pallas kernel on TPU scales
    cotangent tiles in VMEM, so the ``C_i * g_i`` temporary never reaches
    HBM; the XLA path is a single three-operand einsum).  Returns
    {param_path: grad, [bias_path: grad]} shaped like the params.
    """
    out: dict[str, jax.Array] = {}
    lead = math.prod(meta.stack_dims) if meta.stack_dims else 1
    gdim = max(meta.n_groups, 1)
    b = meta.batch_size
    cw = clip.astype(jnp.float32)

    if meta.kind in ("embedding", "scale", "bias"):
        gw = g.astype(jnp.float32).reshape(lead, b, gdim, meta.T, meta.p)
        gw = gw * cw[None, :, None, None, None]

    if meta.kind == "matmul":
        if a is None:
            raise ValueError(f"matmul tap {meta.param_path} has no recorded activation")
        if meta.conv is not None:
            a4 = a.reshape((lead * b,) + a.shape[-3:])
            aa = unfold2d(a4, meta.conv).reshape(lead, b, gdim, meta.T, meta.D)
        else:
            aa = a.reshape(lead, b, gdim, meta.T, meta.D)
        gg = g.reshape(lead, b, gdim, meta.T, meta.p)
        # canonical (M, R, .) book: rows = (B, T) folded, one row weight per
        # (sample, position); layer/group instances ride the leading dim
        a2 = aa.transpose(0, 2, 1, 3, 4).reshape(lead * gdim, b * meta.T, meta.D)
        g2 = gg.transpose(0, 2, 1, 3, 4).reshape(lead * gdim, b * meta.T, meta.p)
        w2 = jnp.broadcast_to(
            jnp.broadcast_to(cw[:, None], (b, meta.T)).reshape(1, b * meta.T),
            (lead * gdim, b * meta.T),
        )
        w = dispatch.book_weighted_grad(
            a2, g2, w2, impl=dispatch.kernels_arg(kernels, "psg_contract")
        ).reshape(lead, gdim, meta.D, meta.p)
        out[meta.param_path] = _finish_matmul_grad(meta, w, param_shape)
    elif meta.kind == "embedding":
        ids = a.reshape(-1)
        flat_g = gw.reshape(-1, meta.p)
        w = jnp.zeros(param_shape, jnp.float32).at[ids].add(flat_g)
        out[meta.param_path] = w
    elif meta.kind == "scale":
        af = a.astype(jnp.float32).reshape(lead, meta.batch_size, gdim, meta.T, meta.p)
        out[meta.param_path] = jnp.einsum("lbgtp,lbgtp->lp", af, gw).reshape(param_shape)
    elif meta.kind == "bias":
        out[meta.param_path] = jnp.einsum("lbgtp->lp", gw).reshape(param_shape)
    elif meta.kind == "scale_grouped":
        h, dh = meta.p, meta.D
        af = a.astype(jnp.float32).reshape(lead, meta.batch_size, meta.T, h, dh)
        gg = g.astype(jnp.float32).reshape(lead, meta.batch_size, meta.T, h, dh)
        gg = gg * cw[None, :, None, None, None]
        out[meta.param_path] = jnp.einsum("lbthd,lbthd->lh", af, gg).reshape(param_shape)
    elif meta.kind == "dw_conv":
        k = meta.D
        af = a.astype(jnp.float32).reshape(lead, meta.batch_size, meta.T, k, meta.p)
        gg = g.astype(jnp.float32).reshape(lead, meta.batch_size, meta.T, meta.p)
        gg = gg * cw[None, :, None, None]
        out[meta.param_path] = jnp.einsum("lbtkd,lbtd->lkd", af, gg).reshape(param_shape)
    else:
        raise ValueError(f"unknown tap kind {meta.kind!r}")

    if meta.bias_path is not None:
        gb = g.astype(jnp.float32).reshape(lead, meta.batch_size, -1, meta.p)
        gb = gb * cw[None, :, None, None]
        out[meta.bias_path] = jnp.einsum("lbtp->lp", gb).reshape(
            meta.stack_dims + (meta.p,) if meta.stack_dims else (meta.p,)
        )
    return out


def bank_weighted_grads(
    meta: TapMeta,
    bank: dict[str, jax.Array],
    clip: jax.Array,  # (B,) clip factors C_i
    param_shape: tuple[int, ...],
    kernels: KernelChoices = None,
) -> dict[str, jax.Array]:
    """Fused book-keeping gradient stage: sum_i C_i g_i from a probe bank.

    ``bank`` arrives with stack dims prepended by the scan (the probes emit
    per-layer payloads; ``lax.scan`` stacks them).  Ghost-banked taps replay
    the weighted book contraction from the banked (a, g) pair; psg-banked
    taps contract the banked per-sample gradients with the clip factors
    directly — both through ``repro.kernels.dispatch``.
    """
    if "g" in bank:
        a = bank["a"]
        if meta.kind == "embedding":
            # ids crossed the side channel as fp32 (see tap_bank); exactness
            # of the round-trip is guarded at trace time
            _check_embedding_vocab(meta, "the banked-id round-trip")
            a = jnp.round(a).astype(jnp.int32)
        return tap_weighted_grads(
            meta, a, bank["g"], clip, param_shape, kernels=kernels
        )

    out: dict[str, jax.Array] = {}
    lead = math.prod(meta.stack_dims) if meta.stack_dims else 1
    b = meta.batch_size
    cw = clip.astype(jnp.float32)
    impl = dispatch.kernels_arg(kernels, "psg_contract")
    # banked per-sample grads are already in the param's own layout:
    # (L..., B, *param) -> contract the batch dim against the clip factors
    psg = bank["psg"].reshape((lead, b) + psg_param_shape(meta))
    w = dispatch.psg_contract(psg, cw, axis=1, impl=impl)
    out[meta.param_path] = w.reshape(param_shape)

    if "psg_b" in bank:
        psg_b = bank["psg_b"].reshape(lead, b, meta.p)
        out[meta.bias_path] = dispatch.psg_contract(
            psg_b, cw, axis=1, impl=impl
        ).reshape(
            meta.stack_dims + (meta.p,) if meta.stack_dims else (meta.p,)
        )
    return out
