"""Per-sample gradient clipping engines (the paper's Algorithm 1 and rivals).

The model exposes ``loss_with_ctx(params, batch, ctx) -> per_sample_losses``;
everything else happens here.  Modes:

- ``vmap``        Opacus analogue: materialize per-sample grads via
                  vmap(grad), clip, sum.  O(B x |params|) memory.
- ``ghost``       ghost norm everywhere + second backward pass.
- ``fastgradclip``  instantiation norms + second backward pass.
- ``mixed_ghost`` the paper's Algorithm 1: Eq-(4.1) layerwise decision
                  between ghost norm and instantiation + second backward.
- ``bk_mixed``    beyond-paper: mixed norms + weighted gradient as direct
                  einsums (book-keeping, arXiv:2210.00038) — no second
                  backward; DP cost ~= non-private cost.

All modes produce bit-identical clipped gradients (tested): the paper's claim
that the implementation "does not affect the mathematics".

Flow for the ghost family (1 forward + 2 backward, Fig. 1 right):

    (losses, acts), pullback = vjp(f, params, taps)   # taps = zeros
    _, gs      = pullback(ones)     # dL/ds per tap; dW einsums DCE'd by XLA
    norms2     = sum_tap tap_norm_sq(acts, gs)        # ghost / instantiate
    C          = clip_fn(sqrt(norms2), R) * mask
    grads, _   = pullback(C)        # == grad of sum_i C_i L_i  (2nd backward)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import ghost
from repro.core.functions import get_clip_fn
from repro.core.taps import ClipRuntime, Ctx, TapMeta, make_zero_taps
from repro.utils.tree import flatten_dict, unflatten_dict

LossFn = Callable[..., jax.Array]  # (params, batch, ctx) -> (B,) losses

# fused engine: ghost | fastgradclip | mixed_ghost (probe-based, default)
# explicit-tap engine: bk_mixed (book-keeping) and *_taps reference variants
MODES = (
    "vmap", "ghost", "fastgradclip", "mixed_ghost",
    "ghost_taps", "fastgradclip_taps", "mixed_ghost_taps",
    "bk_mixed", "non_private",
)


@dataclasses.dataclass(frozen=True)
class ClipConfig:
    mode: str = "mixed_ghost"
    clip_norm: float = 1.0
    clip_fn: str = "abadi"
    decision_by: str = "space"  # Eq 4.1 (space) or Remark 4.1 (time)
    ghost_block: int = 512
    inst_block_d: int = 8192
    # taps whose params are frozen (no clipping/noise/coverage requirement)
    frozen_prefixes: tuple[str, ...] = ()
    # measured-cost branch plan (repro.tuner.ClipPlan, duck-typed to keep
    # core free of tuner imports).  Consulted before the analytic Eq-(4.1)
    # rule; a plan whose device/shape fingerprint does not match the model
    # is rejected at trace time and the analytic rule applies.
    plan: Optional[Any] = None


def _plan_overrides(plan: Optional[Any], meta: dict[str, TapMeta]) -> dict[str, str]:
    """Validated per-tap branch overrides from a tuner plan ({} if stale)."""
    if plan is None:
        return {}
    return plan.overrides_for(meta)


def discover_meta(
    loss_with_ctx: LossFn, params: Any, batch: Any, clip: Optional[ClipRuntime] = None
) -> dict[str, TapMeta]:
    """Trace once abstractly to enumerate taps."""
    meta: dict[str, TapMeta] = {}

    def probe(p, b):
        ctx = Ctx(taps=None, meta=meta, clip=clip)
        return loss_with_ctx(p, b, ctx)

    jax.eval_shape(probe, params, batch)
    return meta


def validate_coverage(
    meta: dict[str, TapMeta], params: Any, frozen_prefixes: tuple[str, ...] = ()
) -> list[str]:
    """Every trainable param leaf must be covered by exactly one tap.

    Uncovered parameters would silently escape clipping — a privacy bug —
    so callers should raise unless the leaf is declared frozen.
    """
    flat = flatten_dict(params)
    covered = set()
    for m in meta.values():
        covered.add(m.param_path)
        if m.bias_path:
            covered.add(m.bias_path)
    missing = []
    for path in flat:
        if path in covered:
            continue
        if any(path.startswith(p) for p in frozen_prefixes):
            continue
        missing.append(path)
    return sorted(missing)


def _batch_mask(batch: Any) -> Optional[jax.Array]:
    if isinstance(batch, dict):
        return batch.get("mask")
    return None


def dp_value_and_clipped_grad(
    loss_with_ctx: LossFn,
    cfg: ClipConfig = ClipConfig(),
) -> Callable[[Any, Any], tuple[jax.Array, Any, dict]]:
    """Returns fn(params, batch) -> (mean_loss, clipped_grad_sum, aux).

    ``clipped_grad_sum`` is sum_i C_i g_i (noise is added by the optimizer /
    privacy engine; keeping it separate lets benchmarks isolate clipping).
    aux = {"per_sample_norms": (B,), "clip_factors": (B,)}.
    """
    clip_fn = get_clip_fn(cfg.clip_fn)

    if cfg.mode == "non_private":

        def np_fn(params, batch):
            def mean_loss(p):
                losses = loss_with_ctx(p, batch, Ctx.disabled())
                return jnp.sum(losses), losses

            (total, losses), grads = jax.value_and_grad(mean_loss, has_aux=True)(params)
            b = losses.shape[0]
            aux = {
                "per_sample_norms": jnp.zeros((b,), jnp.float32),
                "clip_factors": jnp.ones((b,), jnp.float32),
            }
            return total / b, grads, aux

        return np_fn

    if cfg.mode == "vmap":

        def vmap_fn(params, batch):
            mask = _batch_mask(batch)

            def single(p, ex):
                losses = loss_with_ctx(p, ex, Ctx.disabled())
                return losses[0]

            # add a singleton batch dim per sample
            per_ex = jax.tree_util.tree_map(lambda x: x[:, None], batch)
            losses, grads = jax.vmap(
                lambda ex: jax.value_and_grad(single, argnums=0)(params, ex)
            )(per_ex)
            flat, tdef = jax.tree_util.tree_flatten(grads)
            norms2 = sum(
                jnp.sum(
                    jnp.square(g.astype(jnp.float32)).reshape(g.shape[0], -1), axis=-1
                )
                for g in flat
            )
            norms = jnp.sqrt(norms2)
            c = clip_fn(norms, cfg.clip_norm)
            if mask is not None:
                c = c * mask.astype(c.dtype)
            clipped = jax.tree_util.tree_map(
                lambda g: jnp.einsum(
                    "b...,b->...", g.astype(jnp.float32), c
                ).astype(g.dtype),
                grads,
            )
            b = losses.shape[0]
            aux = {"per_sample_norms": norms, "clip_factors": c}
            return jnp.sum(losses) / b, clipped, aux

        return vmap_fn

    # --- fused ghost family (default): norms inside the backward pass -----
    if cfg.mode in ("ghost", "fastgradclip", "mixed_ghost"):
        base_runtime = ClipRuntime(
            mode=cfg.mode, decision_by=cfg.decision_by,
            ghost_block=cfg.ghost_block, inst_block_d=cfg.inst_block_d,
        )

        def fused_fn(params, batch):
            mask = _batch_mask(batch)
            meta = discover_meta(loss_with_ctx, params, batch, clip=base_runtime)
            overrides = _plan_overrides(cfg.plan, meta)
            runtime = dataclasses.replace(
                base_runtime, overrides=tuple(sorted(overrides.items()))
            )
            zs0 = {
                name: jnp.zeros(m.stack_dims + (m.batch_size,), jnp.float32)
                for name, m in meta.items() if m.fused
            }
            taps0 = {
                name: jnp.zeros(m.s_shape, m.s_dtype)
                for name, m in meta.items() if not m.fused
            }

            def f(p, zs, taps):
                ctx = Ctx(taps=taps, zs=zs, meta={}, clip=runtime)
                losses = loss_with_ctx(p, batch, ctx)
                return losses, ctx.acts

            losses, pull, acts = jax.vjp(f, params, zs0, taps0, has_aux=True)
            b = losses.shape[0]
            ones = jnp.ones_like(losses)
            _, z_cots, gs_late = pull(ones)  # param grads DCE'd

            norms2 = jnp.zeros((b,), jnp.float32)
            for name, m in meta.items():
                if m.fused:
                    zc = z_cots[name].astype(jnp.float32)
                    norms2 = norms2 + zc.reshape(-1, b).sum(axis=0)
                else:
                    norms2 = norms2 + ghost.tap_norm_sq(
                        m, acts.get(name), gs_late[name],
                        mode=cfg.mode, decision_by=cfg.decision_by,
                        ghost_block=cfg.ghost_block, inst_block_d=cfg.inst_block_d,
                        override=overrides.get(name),
                    )
            norms = jnp.sqrt(norms2)
            c = clip_fn(norms, cfg.clip_norm)
            if mask is not None:
                c = c * mask.astype(c.dtype)
            c = jax.lax.stop_gradient(c)
            clipped, _, _ = pull(c.astype(losses.dtype))  # second backward
            aux = {"per_sample_norms": norms, "clip_factors": c}
            return jnp.sum(losses) / b, clipped, aux

        return fused_fn

    # --- explicit-tap engine: bk_mixed and *_taps reference variants -------
    branch_mode = cfg.mode.replace("_taps", "")

    def ghost_fn(params, batch):
        mask = _batch_mask(batch)
        meta = discover_meta(loss_with_ctx, params, batch)
        overrides = _plan_overrides(cfg.plan, meta)
        taps0 = make_zero_taps(meta)

        def f(p, taps):
            ctx = Ctx(taps=taps, meta={})
            losses = loss_with_ctx(p, batch, ctx)
            return losses, ctx.acts

        losses, pull, acts = jax.vjp(f, params, taps0, has_aux=True)
        b = losses.shape[0]
        ones = jnp.ones_like(losses)
        _, gs = pull(ones)  # first backward; unused param grads are DCE'd

        norms2 = jnp.zeros((b,), jnp.float32)
        for name, m in meta.items():
            norms2 = norms2 + ghost.tap_norm_sq(
                m,
                acts.get(name),
                gs[name],
                mode=branch_mode,
                decision_by=cfg.decision_by,
                ghost_block=cfg.ghost_block,
                inst_block_d=cfg.inst_block_d,
                override=overrides.get(name),
            )
        norms = jnp.sqrt(norms2)
        c = clip_fn(norms, cfg.clip_norm)
        if mask is not None:
            c = c * mask.astype(c.dtype)
        c = jax.lax.stop_gradient(c)

        if cfg.mode == "bk_mixed":
            flat_params = flatten_dict(params)
            flat_grads: dict[str, jax.Array] = {}
            for name, m in meta.items():
                ws = ghost.tap_weighted_grads(
                    m, acts.get(name), gs[name], c, flat_params[m.param_path].shape
                )
                for path, val in ws.items():
                    flat_grads[path] = (
                        flat_grads[path] + val if path in flat_grads else val
                    )
            for path, leaf in flat_params.items():
                if path not in flat_grads:
                    flat_grads[path] = jnp.zeros_like(leaf)
                else:
                    flat_grads[path] = flat_grads[path].astype(leaf.dtype)
            clipped = unflatten_dict(flat_grads)
        else:
            clipped, _ = pull(c.astype(losses.dtype))  # second backward

        aux = {"per_sample_norms": norms, "clip_factors": c}
        return jnp.sum(losses) / b, clipped, aux

    return ghost_fn
