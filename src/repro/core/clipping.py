"""Per-sample gradient clipping engines (the paper's Algorithm 1 and rivals).

The model exposes ``loss_with_ctx(params, batch, ctx) -> per_sample_losses``;
everything else happens here.  Every mode is a ``ClipExecutor`` — one shared
three-stage pipeline

    norms stage    -> per-sample squared norms (mode-specific machinery)
    factor stage   -> C_i = clip_fn(||g_i||, R) * mask     (shared)
    gradient stage -> sum_i C_i g_i                        (mode-specific)

The factor stage is delegated to a **ClipPolicy** (``repro.policies``):
``fixed`` (the paper's flat R, the default), ``automatic`` (AUTO-S/AUTO-V
normalization, no R), ``quantile`` (DP-adaptive R tracking a norm quantile,
paying for its release in the accountant), and ``per_layer`` (per-tap-group
thresholds).  Policies may carry state — pass it as the executor's third
argument and thread the updated state through the train step
(``launch.steps.make_train_step``).

Modes
-----
- ``vmap``        Opacus analogue: materialize per-sample grads via
                  vmap(grad), clip, sum.  O(B x |params|) memory.
- ``ghost``       ghost norm everywhere + second backward pass.
- ``fastgradclip``  instantiation norms + second backward pass.
- ``mixed_ghost`` the paper's Algorithm 1: Eq-(4.1) layerwise decision
                  between ghost norm and instantiation + second backward.
- ``bk_mixed``    beyond-paper: book-keeping (arXiv:2210.00038) — the fused
                  probes bank per-sample gradients (or the (a, g) book) during
                  the single backward pass and the gradient stage is a direct
                  einsum against the clip factors.  No second backward; DP
                  cost ~= non-private cost.
- ``*_taps``      thin reference executors on the explicit-tap engine
                  (zero taps + activation dict); the exactness oracle for the
                  fused engine and the fallback for experimentation.
- ``non_private`` no clipping (C_i = 1); the baseline every overhead claim is
                  measured against.

All modes produce bit-identical clipped gradients (tested): the paper's claim
that the implementation "does not affect the mathematics".

Mode selection guide
--------------------
Which engine wins depends on {memory budget, architecture, device}:

- **Tight memory budget** (the paper's ≤10%-overhead regime — large CNNs or
  long sequences on small devices): ``mixed_ghost``.  The fused probes keep
  per-layer cotangents inside the backward scan, the Eq-(4.1) decision never
  materializes a large branch, and the second backward reuses residuals
  instead of banking anything.
- **Throughput-bound training with headroom** (fine-tuning, mid-size models,
  accelerators with spare HBM): ``bk_mixed``.  It trades the whole second
  backward for per-tap banks (per-sample grads where pD is small, the (a, g)
  book where it is not); per-step time approaches ``non_private`` while peak
  memory stays within ~10% of it on conv nets (see BENCH_modes.json).
- **Unknown hardware**: run ``repro.tuner`` — it times ghost / instantiate /
  book-keeping per tap on the device and writes a ClipPlan whose
  ``recommended_mode()`` settles the question with measurements; ``launch.train
  --tune --mode auto`` adopts it end to end.
- **Debugging / cross-checking**: ``vmap`` (the oracle, tiny models only) and
  the ``*_taps`` reference executors.

Flow for the fused second-backward family (1 forward + 2 backward, Fig. 1
right)::

    (losses, acts), pullback = vjp(f, params, banks)  # banks = dummy zeros
    _, nb, gs  = pullback(ones)     # per-tap banks {"n": norms^2} via probes
    norms2     = sum_tap nb[tap]["n"]
    C          = clip_fn(sqrt(norms2), R) * mask
    grads, _   = pullback(C)        # == grad of sum_i C_i L_i  (2nd backward)

``bk_mixed`` runs the same pipeline but its banks also carry the weighted-
gradient residuals, and the gradient stage is ``bank_weighted_grads`` —
no tap-sized zeros, no activation dict, no second backward.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import fused as fused_mod
from repro.core import ghost
from repro.core.taps import ClipRuntime, Ctx, TapMeta, make_zero_taps
from repro.utils.tree import flatten_dict, unflatten_dict

LossFn = Callable[..., jax.Array]  # (params, batch, ctx) -> (B,) losses

# fused engine: ghost | fastgradclip | mixed_ghost | bk_mixed (probe-based)
# explicit-tap engine: *_taps reference variants
MODES = (
    "vmap", "ghost", "fastgradclip", "mixed_ghost", "bk_mixed",
    "ghost_taps", "fastgradclip_taps", "mixed_ghost_taps", "bk_mixed_taps",
    "non_private",
)


@dataclasses.dataclass(frozen=True)
class ClipConfig:
    mode: str = "mixed_ghost"
    clip_norm: float = 1.0
    clip_fn: str = "abadi"
    decision_by: str = "space"  # Eq 4.1 (space) or Remark 4.1 (time)
    ghost_block: int = 512
    inst_block_d: int = 8192
    # taps whose params are frozen (no clipping/noise/coverage requirement)
    frozen_prefixes: tuple[str, ...] = ()
    # measured-cost branch plan (repro.tuner.ClipPlan, duck-typed to keep
    # core free of tuner imports).  Consulted before the analytic branch
    # rule; a plan whose device/shape fingerprint does not match the model
    # is rejected at trace time and the analytic rule applies.
    plan: Optional[Any] = None
    # clipping policy (repro.policies.ClipPolicy).  None builds the fixed
    # flat-R policy from (clip_norm, clip_fn) — exactly the pre-policy
    # behavior.  Stateful policies (quantile R) receive their state as the
    # executor's third argument.
    policy: Optional[Any] = None


def _plan_overrides(
    plan: Optional[Any], meta: dict[str, TapMeta], mode: str
) -> dict[str, str]:
    """Validated per-tap branch overrides from a tuner plan ({} if stale).

    Plans are mode-specific: the book-keeping branch trades bank size, not
    norm cost, so ``bk_mixed`` consumes a different branch map than
    ``mixed_ghost``.  ``plan.overrides_for`` must dispatch on the mode —
    a mode-blind plan object would silently drive bank-size decisions with
    norm-cost winners, so there is deliberately no fallback signature.
    """
    if plan is None:
        return {}
    return plan.overrides_for(meta, mode=mode)


def _plan_kernels(
    plan: Optional[Any], meta: dict[str, TapMeta]
) -> dict[str, dict[str, str]]:
    """Validated per-tap kernel-impl choices from a tuner plan ({} if stale).

    ``{tap: {op: "pallas" | "xla"}}`` routed to ``repro.kernels.dispatch``
    through the executors; plans predating v5 (no ``kernels_for``) and
    stale plans fall back to the dispatch backend default.
    """
    if plan is None:
        return {}
    fn = getattr(plan, "kernels_for", None)
    return fn(meta) if fn is not None else {}


def discover_meta(
    loss_with_ctx: LossFn, params: Any, batch: Any, clip: Optional[ClipRuntime] = None
) -> dict[str, TapMeta]:
    """Trace once abstractly to enumerate taps."""
    meta: dict[str, TapMeta] = {}

    def probe(p, b):
        ctx = Ctx(taps=None, meta=meta, clip=clip)
        return loss_with_ctx(p, b, ctx)

    jax.eval_shape(probe, params, batch)
    return meta


def validate_coverage(
    meta: dict[str, TapMeta], params: Any, frozen_prefixes: tuple[str, ...] = ()
) -> list[str]:
    """Every trainable param leaf must be covered by exactly one tap.

    Uncovered parameters would silently escape clipping — a privacy bug —
    so callers should raise unless the leaf is declared frozen.  Duplicate
    coverage (two taps claiming the same param leaf) would silently
    double-count that leaf's per-sample norm, inflating ||g_i|| and
    over-clipping — also a correctness bug — so it raises here directly,
    naming the offending taps.  Returns the sorted list of uncovered paths.
    """
    flat = flatten_dict(params)
    claimed: dict[str, list[str]] = {}
    for name, m in meta.items():
        claimed.setdefault(m.param_path, []).append(name)
        if m.bias_path:
            claimed.setdefault(m.bias_path, []).append(name)
    duplicates = {
        path: names for path, names in claimed.items() if len(names) > 1
    }
    if duplicates:
        detail = "; ".join(
            f"{path} <- taps {sorted(names)}" for path, names in sorted(duplicates.items())
        )
        raise ValueError(
            "duplicate per-sample clipping coverage (norms would be "
            f"double-counted): {detail}"
        )
    missing = []
    for path in flat:
        if path in claimed:
            continue
        if any(path.startswith(p) for p in frozen_prefixes):
            continue
        missing.append(path)
    return sorted(missing)


def _batch_mask(batch: Any) -> Optional[jax.Array]:
    if isinstance(batch, dict):
        return batch.get("mask")
    return None


def _assemble_bk_grads(
    meta: dict[str, TapMeta], params: Any, ws_fn: Callable
) -> Any:
    """Shared book-keeping gradient assembly (fused and reference engines).

    ``ws_fn(name, m, param_shape)`` yields one tap's {path: weighted grad};
    uncovered leaves (frozen params) are zero-filled and everything is cast
    back to the leaf dtype.  Contributions to the same leaf are summed
    defensively, but two taps on one param leaf is a coverage bug —
    ``validate_coverage`` raises on it because the summed per-tap squared
    norms would drop the cross term.
    """
    flat_params = flatten_dict(params)
    flat_grads: dict[str, jax.Array] = {}
    for name, m in meta.items():
        ws = ws_fn(name, m, flat_params[m.param_path].shape)
        for path, val in ws.items():
            flat_grads[path] = (
                flat_grads[path] + val if path in flat_grads else val
            )
    for path, leaf in flat_params.items():
        if path not in flat_grads:
            flat_grads[path] = jnp.zeros_like(leaf)
        else:
            flat_grads[path] = flat_grads[path].astype(leaf.dtype)
    return unflatten_dict(flat_grads)


def _grouped_second_backward(st: "_NormState", c: Any, params: Any) -> Any:
    """Second-backward gradient stage under per-layer-group clip factors.

    The pullback cotangent is per-*sample* — one scalar weight per loss —
    so a factor that differs per layer group cannot ride a single second
    backward.  Run one pullback per group and keep each group's own leaves:
    correct for any G, at G x the second-backward cost.  The book-keeping
    engines do this for free (per-tap einsums); prefer them when G is large.
    """
    out: dict[str, jax.Array] = {}
    for gi in range(len(c.groups)):
        clipped = st.pull(c.factors[gi].astype(st.losses.dtype))[0]
        for path, val in flatten_dict(clipped).items():
            if c.group_index(path) == gi:
                out[path] = val
    return unflatten_dict(out)


@dataclasses.dataclass
class _NormState:
    """What the norms stage hands the gradient stage (one step's plumbing)."""

    losses: jax.Array
    norms2: jax.Array
    pull: Optional[Callable] = None  # vjp pullback (second-backward modes)
    banks: Optional[dict] = None  # per-tap probe cotangents (fused engine)
    acts: Optional[dict] = None  # explicit activations (taps engine / late)
    gs: Optional[dict] = None  # explicit tap cotangents
    meta: Optional[dict] = None
    # per-tap kernel-impl choices from the plan ({} = dispatch defaults)
    kernels: Optional[dict] = None
    per_sample_grads: Optional[Any] = None  # vmap oracle only
    # per-param-path squared norm contributions (grouped policies only):
    # {param_path: (B,)}, summing to norms2
    path_norms2: Optional[dict[str, jax.Array]] = None


class ClipExecutor:
    """Template for every clipping mode: norms -> clip factors -> gradients.

    Subclasses implement ``_norm_state`` and ``_weighted_grads``; the factor
    stage (delegated to the ClipPolicy) and the (loss, grads, aux) contract
    are shared.  Instances are plain callables: ``fn(params, batch,
    policy_state=None) -> (mean_loss, clipped_grad_sum, aux)`` with aux =
    {"per_sample_norms": (B,), "clip_factors": (B,)} — jit/pjit-safe, noise
    added downstream by the privacy engine.  ``policy_state`` is the pytree
    a stateful policy carries between steps (``policy.init_state()`` when
    omitted — correct for stateless policies, a fresh default otherwise).
    """

    def __init__(self, loss_with_ctx: LossFn, cfg: ClipConfig):
        self.loss = loss_with_ctx
        self.cfg = cfg
        if cfg.policy is not None:
            self.policy = cfg.policy
        else:
            from repro.policies.fixed import FixedPolicy

            self.policy = FixedPolicy(
                clip_norm=cfg.clip_norm, clip_fn=cfg.clip_fn
            )
        self.grouped = bool(getattr(self.policy, "grouped", False))

    # -- stage 1: mode-specific -------------------------------------------
    def _norm_state(self, params, batch) -> _NormState:
        raise NotImplementedError

    # -- stage 2: shared (policy-delegated) --------------------------------
    def _clip_factors(self, norms: jax.Array, mask, st: _NormState, pstate):
        c = self.policy.clip_factors(norms, pstate, path_norms2=st.path_norms2)
        if hasattr(c, "factors"):  # GroupedFactors
            f = c.factors
            if mask is not None:
                f = f * mask.astype(f.dtype)[None, :]
            return dataclasses.replace(c, factors=jax.lax.stop_gradient(f))
        if mask is not None:
            c = c * mask.astype(c.dtype)
        return jax.lax.stop_gradient(c)

    # -- stage 3: mode-specific -------------------------------------------
    def _weighted_grads(self, st: _NormState, c, params) -> Any:
        raise NotImplementedError

    def _validate_groups(self, meta: dict[str, TapMeta]) -> None:
        """A group boundary must not split a tap's (weight, bias) pair —
        their per-sample norm is computed jointly."""
        for name, m in meta.items():
            if m.bias_path is None:
                continue
            if self.policy.group_of(m.param_path) != self.policy.group_of(
                m.bias_path
            ):
                raise ValueError(
                    f"layer groups split tap {name!r}: weight "
                    f"{m.param_path!r} and bias {m.bias_path!r} land in "
                    "different groups but share one per-sample norm"
                )

    def __call__(self, params, batch, policy_state=None):
        mask = _batch_mask(batch)
        st = self._norm_state(params, batch)
        norms = jnp.sqrt(st.norms2)
        pstate = policy_state if policy_state is not None else self.policy.init_state()
        c = self._clip_factors(norms, mask, st, pstate)
        grads = self._weighted_grads(st, c, params)
        b = st.losses.shape[0]
        rep = c.representative if hasattr(c, "representative") else c
        aux = {"per_sample_norms": norms, "clip_factors": rep}
        return jnp.sum(st.losses) / b, grads, aux


class NonPrivateExecutor(ClipExecutor):
    """C_i = 1 for all i: plain summed gradients through the same skeleton."""

    def _norm_state(self, params, batch) -> _NormState:
        losses, pull = jax.vjp(
            lambda p: self.loss(p, batch, Ctx.disabled()), params
        )
        return _NormState(
            losses=losses,
            norms2=jnp.zeros((losses.shape[0],), jnp.float32),
            pull=pull,
        )

    def _clip_factors(self, norms, mask, st, pstate):
        return jnp.ones_like(norms)

    def _weighted_grads(self, st, c, params):
        (grads,) = st.pull(c.astype(st.losses.dtype))
        return grads


class VmapExecutor(ClipExecutor):
    """Opacus analogue and correctness oracle: vmap(grad) per sample."""

    def _norm_state(self, params, batch) -> _NormState:
        def single(p, ex):
            losses = self.loss(p, ex, Ctx.disabled())
            return losses[0]

        # add a singleton batch dim per sample
        per_ex = jax.tree_util.tree_map(lambda x: x[:, None], batch)
        losses, grads = jax.vmap(
            lambda ex: jax.value_and_grad(single, argnums=0)(params, ex)
        )(per_ex)
        path_norms2 = None
        if self.grouped:
            # same trace-time gate as the tap engines: a group boundary
            # through a tap's (weight, bias) pair would give this oracle
            # semantics no other executor can reproduce
            self._validate_groups(discover_meta(self.loss, params, batch))
            # exact per-leaf contributions: grouped policies sum them per
            # group, and weight/bias leaves fall into the same group as the
            # tap engines assign them (validated above)
            path_norms2 = {
                path: jnp.sum(
                    jnp.square(g.astype(jnp.float32)).reshape(g.shape[0], -1),
                    axis=-1,
                )
                for path, g in flatten_dict(grads).items()
            }
            norms2 = sum(path_norms2.values())
        else:
            flat, _ = jax.tree_util.tree_flatten(grads)
            norms2 = sum(
                jnp.sum(
                    jnp.square(g.astype(jnp.float32)).reshape(g.shape[0], -1),
                    axis=-1,
                )
                for g in flat
            )
        return _NormState(
            losses=losses, norms2=norms2, per_sample_grads=grads,
            path_norms2=path_norms2,
        )

    def _weighted_grads(self, st, c, params):
        if hasattr(c, "for_path"):  # GroupedFactors: per-leaf group factors
            flat = flatten_dict(st.per_sample_grads)
            out = {
                path: jnp.einsum(
                    "b...,b->...", g.astype(jnp.float32), c.for_path(path)
                ).astype(g.dtype)
                for path, g in flat.items()
            }
            return unflatten_dict(out)
        return jax.tree_util.tree_map(
            lambda g: jnp.einsum(
                "b...,b->...", g.astype(jnp.float32), c
            ).astype(g.dtype),
            st.per_sample_grads,
        )


def _fold_bank_norm(n: jax.Array, b: int) -> jax.Array:
    """Stacked (L..., B) per-sample norm cotangents -> (B,) sums."""
    return n.astype(jnp.float32).reshape(-1, b).sum(axis=0)


class FusedExecutor(ClipExecutor):
    """Probe engine: norms (and bk banks) computed inside the backward pass.

    Covers ghost / fastgradclip / mixed_ghost (gradient stage = second
    backward over the shared pullback) and bk_mixed (gradient stage = bank
    einsums; the single backward is all the backpropagation there is).
    Taps registered with ``late=True`` (recurrent weights whose activation
    only exists after the time scan) fall back to the explicit-tap channel
    within the same pipeline.
    """

    def __init__(self, loss_with_ctx: LossFn, cfg: ClipConfig):
        super().__init__(loss_with_ctx, cfg)
        self.base_runtime = ClipRuntime(
            mode=cfg.mode, decision_by=cfg.decision_by,
            ghost_block=cfg.ghost_block, inst_block_d=cfg.inst_block_d,
        )

    @property
    def is_bk(self) -> bool:
        return self.cfg.mode == "bk_mixed"

    def _norm_state(self, params, batch) -> _NormState:
        cfg = self.cfg
        meta = discover_meta(self.loss, params, batch, clip=self.base_runtime)
        overrides = _plan_overrides(cfg.plan, meta, cfg.mode)
        kernel_map = _plan_kernels(cfg.plan, meta)
        runtime = dataclasses.replace(
            self.base_runtime,
            overrides=tuple(sorted(overrides.items())),
            kernels=tuple(
                (name, tuple(sorted(ks.items())))
                for name, ks in sorted(kernel_map.items())
            ),
        )
        zs0 = {
            name: fused_mod.make_bank_zeros(
                fused_mod.bank_struct(
                    m, mode=cfg.mode, decision_by=cfg.decision_by,
                    override=overrides.get(name),
                )
            )
            for name, m in meta.items() if m.fused
        }
        taps0 = make_zero_taps({n: m for n, m in meta.items() if not m.fused})

        def f(p, zs, taps):
            ctx = Ctx(taps=taps, zs=zs, meta={}, clip=runtime)
            losses = self.loss(p, batch, ctx)
            return losses, ctx.acts

        losses, pull, acts = jax.vjp(f, params, zs0, taps0, has_aux=True)
        b = losses.shape[0]
        ones = jnp.ones_like(losses)
        _, banks, gs_late = pull(ones)  # param grads DCE'd

        if self.grouped:
            self._validate_groups(meta)
        norms2 = jnp.zeros((b,), jnp.float32)
        path_norms2: Optional[dict[str, jax.Array]] = {} if self.grouped else None
        for name, m in meta.items():
            if m.fused:
                n = _fold_bank_norm(banks[name]["n"], b)
            else:
                n = ghost.tap_norm_sq(
                    m, acts.get(name), gs_late[name],
                    mode=cfg.mode, decision_by=cfg.decision_by,
                    ghost_block=cfg.ghost_block, inst_block_d=cfg.inst_block_d,
                    override=overrides.get(name),
                    kernels=kernel_map.get(name),
                )
            norms2 = norms2 + n
            if path_norms2 is not None:
                path_norms2[m.param_path] = (
                    path_norms2[m.param_path] + n
                    if m.param_path in path_norms2 else n
                )
        return _NormState(
            losses=losses, norms2=norms2, pull=pull, banks=banks,
            acts=acts, gs=gs_late, meta=meta, path_norms2=path_norms2,
            kernels=kernel_map,
        )

    def _weighted_grads(self, st, c, params):
        grouped = hasattr(c, "for_path")
        if not self.is_bk:
            if grouped:
                return _grouped_second_backward(st, c, params)
            clipped, _, _ = st.pull(c.astype(st.losses.dtype))  # 2nd backward
            return clipped

        # book-keeping: direct einsums from the banks; nothing re-propagates.
        # Grouped policies are free here — each tap contracts against its own
        # group's factors.
        def ws_fn(name, m, param_shape):
            cw = c.for_path(m.param_path) if grouped else c
            kernels = (st.kernels or {}).get(name)
            if m.fused:
                return ghost.bank_weighted_grads(
                    m, st.banks[name], cw, param_shape, kernels=kernels
                )
            return ghost.tap_weighted_grads(
                m, st.acts.get(name), st.gs[name], cw, param_shape,
                kernels=kernels,
            )

        return _assemble_bk_grads(st.meta, params, ws_fn)


class TapsExecutor(ClipExecutor):
    """Reference explicit-tap engine (``*_taps`` modes).

    Materializes zero taps and an activation dict — the memory-hungry but
    transparent formulation the fused engine is tested against.
    """

    def __init__(self, loss_with_ctx: LossFn, cfg: ClipConfig):
        super().__init__(loss_with_ctx, cfg)
        self.branch_mode = cfg.mode.replace("_taps", "")

    def _norm_state(self, params, batch) -> _NormState:
        cfg = self.cfg
        meta = discover_meta(self.loss, params, batch)
        overrides = _plan_overrides(cfg.plan, meta, self.branch_mode)
        kernel_map = _plan_kernels(cfg.plan, meta)
        taps0 = make_zero_taps(meta)

        def f(p, taps):
            ctx = Ctx(taps=taps, meta={})
            losses = self.loss(p, batch, ctx)
            return losses, ctx.acts

        losses, pull, acts = jax.vjp(f, params, taps0, has_aux=True)
        b = losses.shape[0]
        ones = jnp.ones_like(losses)
        _, gs = pull(ones)  # first backward; unused param grads are DCE'd

        if self.grouped:
            self._validate_groups(meta)
        norms2 = jnp.zeros((b,), jnp.float32)
        path_norms2: Optional[dict[str, jax.Array]] = {} if self.grouped else None
        for name, m in meta.items():
            n = ghost.tap_norm_sq(
                m, acts.get(name), gs[name],
                mode=self.branch_mode, decision_by=cfg.decision_by,
                ghost_block=cfg.ghost_block, inst_block_d=cfg.inst_block_d,
                override=overrides.get(name),
                kernels=kernel_map.get(name),
            )
            norms2 = norms2 + n
            if path_norms2 is not None:
                path_norms2[m.param_path] = (
                    path_norms2[m.param_path] + n
                    if m.param_path in path_norms2 else n
                )
        return _NormState(
            losses=losses, norms2=norms2, pull=pull, acts=acts, gs=gs,
            meta=meta, path_norms2=path_norms2, kernels=kernel_map,
        )

    def _weighted_grads(self, st, c, params):
        grouped = hasattr(c, "for_path")
        if self.branch_mode != "bk_mixed":
            if grouped:
                return _grouped_second_backward(st, c, params)
            clipped, _ = st.pull(c.astype(st.losses.dtype))  # second backward
            return clipped
        return _assemble_bk_grads(
            st.meta, params,
            lambda name, m, shape: ghost.tap_weighted_grads(
                m, st.acts.get(name), st.gs[name],
                c.for_path(m.param_path) if grouped else c, shape,
                kernels=(st.kernels or {}).get(name),
            ),
        )


_EXECUTORS = {
    "non_private": NonPrivateExecutor,
    "vmap": VmapExecutor,
    "ghost": FusedExecutor,
    "fastgradclip": FusedExecutor,
    "mixed_ghost": FusedExecutor,
    "bk_mixed": FusedExecutor,
    "ghost_taps": TapsExecutor,
    "fastgradclip_taps": TapsExecutor,
    "mixed_ghost_taps": TapsExecutor,
    "bk_mixed_taps": TapsExecutor,
}


def dp_value_and_clipped_grad(
    loss_with_ctx: LossFn,
    cfg: ClipConfig = ClipConfig(),
) -> Callable[..., tuple[jax.Array, Any, dict]]:
    """Returns fn(params, batch, policy_state=None) -> (mean_loss,
    clipped_grad_sum, aux).

    ``clipped_grad_sum`` is sum_i C_i g_i (noise is added by the optimizer /
    privacy engine; keeping it separate lets benchmarks isolate clipping).
    aux = {"per_sample_norms": (B,), "clip_factors": (B,)}.  The optional
    ``policy_state`` feeds a stateful ClipPolicy (``cfg.policy``); the
    policy's *update* runs outside this function (once per logical batch,
    see ``launch.steps``), so the executor stays a pure clipping map.
    """
    try:
        executor_cls = _EXECUTORS[cfg.mode]
    except KeyError:
        raise ValueError(f"unknown clipping mode {cfg.mode!r}; have {MODES}") from None
    return executor_cls(loss_with_ctx, cfg)
