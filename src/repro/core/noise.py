"""Gaussian noise addition for the DP mechanism (Eq. 2.1, second term).

Noise is generated per parameter leaf with an independent fold_in of the step
key, in fp32, then cast to the gradient dtype.  Under pjit the normal draws
are partitioned by GSPMD along the parameter sharding, so no shard ever
materializes another shard's noise — the generation is fully parallel and
deterministic in (key, leaf index).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def add_dp_noise(grad_sum: Any, key: jax.Array, noise_std: float) -> Any:
    """grad_sum + noise_std * N(0, I), leafwise independent."""
    leaves, treedef = jax.tree_util.tree_flatten(grad_sum)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        g + (noise_std * jax.random.normal(k, g.shape, jnp.float32)).astype(g.dtype)
        for g, k in zip(leaves, keys)
    ]
    return jax.tree_util.tree_unflatten(treedef, noisy)
