"""The zero-tap mechanism: JAX's answer to PyTorch backward hooks.

The paper's algorithm needs, for every parameterized linear op
``s = U(a) @ W + b``, the pair ``(a_i, dL/ds_i)`` per sample.  PyTorch gets these
with forward/backward hooks.  In JAX we instead make every pre-activation an
explicit function of a zeros-valued *tap*::

    s = op(a, W) + b + tap[name]    # tap == 0, so forward is unchanged

and take one ``jax.vjp`` of the per-sample-loss function w.r.t. ``(params, taps)``.
The tap cotangents are exactly ``dL/ds`` per layer; activations are returned as
auxiliary outputs.  Pulling the same vjp back a *second* time with the clip
factors ``C_i`` as the cotangent of the per-sample losses yields the weighted
gradient ``sum_i C_i g_i`` — the paper's "second back-propagation" — while
reusing the forward residuals (1 forward + 2 backward total).

Tap kinds and their per-sample gradient semantics
-------------------------------------------------
- ``matmul``     s = a @ W (+ b);  a: (B, [G,] T, D), s: (B, [G,] T, p).
                 Per-sample grad ``g_i = a_i^T gs_i`` (D, p): ghost norm
                 (paper Eq. 2.7) or instantiation, per the layerwise decision.
                 G is an optional group dim (MoE experts, attention heads for
                 per-head mats); norms are summed over G.  Convolutions record
                 the *raw* input plus unfold info; the engine unfolds lazily
                 (im2col) so the forward stays on the fused conv op.
- ``bias``       handled as a flag on a host tap: per-sample grad = sum_T gs_i.
- ``scale``      s = x_hat * gamma (+ beta) (norm scales, SSM A/D vectors).
                 Per-sample grad = sum_T gs_i * x_hat_i  (elementwise).
- ``embedding``  s = E[ids].  Ghost norm via the index-equality Gram
                 (never materializes the (V, p) per-sample gradient).

Stacked layers (``ScannedStack``) register the same tap names with a leading
stack dimension; the engine folds stack dims into the layer-norm reduction
(per-sample norms sum over layers, Alg. 1 line "sum_l").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

TapKind = str  # "matmul" | "scale" | "embedding"


@dataclasses.dataclass(frozen=True)
class ConvInfo:
    """Unfold (im2col) parameters for convolution taps."""

    kernel: tuple[int, ...]  # spatial kernel dims, e.g. (kh, kw) or (k,)
    strides: tuple[int, ...]
    padding: Any  # str or tuple of (lo, hi) pairs
    feature_group_count: int = 1
    rhs_dilation: tuple[int, ...] | None = None


@dataclasses.dataclass(frozen=True)
class TapMeta:
    """Static metadata for one tap (trace-time only, hashable)."""

    kind: TapKind
    # Dimension parameters of the paper's complexity model (per layer instance):
    T: int  # positions per sample (H_out*W_out for conv, seq len for dense)
    D: int  # fan-in = d * prod(kernel)
    p: int  # fan-out
    s_shape: tuple[int, ...]  # full shape of the tapped pre-activation
    s_dtype: Any
    param_path: str  # param-tree path ("a/b/w") of the weight for this tap
    bias_path: Optional[str] = None  # set when the op has a bias param
    n_groups: int = 1  # group dim between B and T (MoE experts); norms sum over it
    stack_dims: tuple[int, ...] = ()  # leading dims added by ScannedStack
    conv: Optional[ConvInfo] = None
    batch_size: int = 0
    # fused taps compute their norm (and, in book-keeping mode, the residuals
    # the weighted-grad einsum needs) inside the backward pass (core/fused.py)
    # and expose them as the cotangents of a dummy "bank" input
    fused: bool = False
    # shape/dtype of the recorded activation as the probe receives it
    # (embedding ids are fp32-cast before probing); None for late taps
    a_shape: Optional[tuple[int, ...]] = None
    a_dtype: Any = None

    def with_stack(self, n: int) -> "TapMeta":
        return dataclasses.replace(
            self,
            stack_dims=(n,) + self.stack_dims,
            s_shape=(n,) + tuple(self.s_shape),
            a_shape=(n,) + tuple(self.a_shape) if self.a_shape is not None else None,
        )

    @property
    def n_stack(self) -> int:
        out = 1
        for s in self.stack_dims:
            out *= s
        return out

    @property
    def batch_axis(self) -> int:
        """Axis of ``s_shape``/``a_shape`` carrying the batch dimension.

        0 for plain taps; ScannedStack prepends one stack dim per level, so
        stacked taps carry the batch right after them.  The static auditor
        (``repro.analysis``) uses this to locate each tap's sample axis in
        the traced jaxpr."""
        return len(self.stack_dims)


@dataclasses.dataclass(frozen=True)
class ClipRuntime:
    """Static knobs the fused probes need at trace time."""

    mode: str = "mixed_ghost"
    decision_by: str = "space"
    ghost_block: int = 512
    inst_block_d: int = 8192
    # measured-cost branch overrides from a tuner ClipPlan, as sorted
    # (tap_name, branch) pairs (tuple: ClipRuntime must stay hashable)
    overrides: tuple[tuple[str, str], ...] = ()
    # measured kernel-impl choices from a tuner ClipPlan, as sorted
    # (tap_name, ((op, impl), ...)) pairs routed to repro.kernels.dispatch;
    # empty = the dispatch backend default (pallas on TPU, xla elsewhere)
    kernels: tuple[tuple[str, tuple[tuple[str, str], ...]], ...] = ()

    def override_for(self, name: str) -> Optional[str]:
        for tap_name, branch in self.overrides:
            if tap_name == name:
                return branch
        return None

    def kernels_for(self, name: str) -> tuple[tuple[str, str], ...]:
        for tap_name, choices in self.kernels:
            if tap_name == name:
                return choices
        return ()


class Ctx:
    """Per-apply context threading taps in and activations out.

    Two engines:
    - fused (``clip`` set): each tap routes through a custom-vjp probe whose
      dummy *bank* input's cotangent carries the per-sample norm^2 — and, in
      book-keeping mode, the weighted-gradient residuals (core/fused.py).
      Nothing tap-sized ever escapes the backward pass except what the
      algorithm itself must bank.
    - explicit (``clip`` None): pre-activations get zero taps added and
      activations recorded; dL/ds comes back as tap cotangents (the
      ``*_taps`` reference/testing engines and late taps).

    ``taps=None``/``zs=None`` means discovery mode (meta only).
    ``collect=False`` disables DP bookkeeping entirely (serving path).
    """

    __slots__ = ("taps", "zs", "acts", "meta", "path", "collect", "clip")

    def __init__(
        self,
        taps: Optional[dict[str, jax.Array]] = None,
        acts: Optional[dict[str, Any]] = None,
        meta: Optional[dict[str, TapMeta]] = None,
        path: str = "",
        collect: bool = True,
        zs: Optional[dict[str, jax.Array]] = None,
        clip: Optional[ClipRuntime] = None,
    ):
        self.taps = taps
        self.zs = zs
        self.acts = {} if acts is None else acts
        self.meta = {} if meta is None else meta
        self.path = path
        self.collect = collect
        self.clip = clip

    # -- scoping ---------------------------------------------------------
    def scope(self, name: str) -> "Ctx":
        return Ctx(self.taps, self.acts, self.meta, self._join(name),
                   self.collect, self.zs, self.clip)

    def _join(self, name: str) -> str:
        return f"{self.path}/{name}" if self.path else name

    # -- tap registration ------------------------------------------------
    def tap(
        self,
        name: str,
        s: jax.Array,
        *,
        kind: TapKind,
        a: Optional[jax.Array] = None,
        T: int,
        D: int,
        p: int,
        param_path: str,
        bias_path: Optional[str] = None,
        n_groups: int = 1,
        conv: Optional[ConvInfo] = None,
        late: bool = False,
    ) -> jax.Array:
        """Register pre-activation ``s`` with recorded input ``a``.

        ``late=True`` forces the explicit-tap path even under the fused
        engine (recurrent weights whose activation only exists after the
        scan — see record_act).
        """
        if not self.collect:
            return s
        full = self._join(name)
        fused = self.clip is not None and not late
        meta = TapMeta(
            kind=kind,
            T=T,
            D=D,
            p=p,
            s_shape=tuple(int(d) for d in s.shape),
            s_dtype=s.dtype,
            param_path=self._join(param_path),
            bias_path=self._join(bias_path) if bias_path else None,
            n_groups=n_groups,
            conv=conv,
            batch_size=int(s.shape[0]),
            fused=fused,
            a_shape=tuple(int(d) for d in a.shape) if a is not None else None,
            a_dtype=(jnp.float32 if kind == "embedding" else a.dtype)
            if a is not None else None,
        )
        self.meta[full] = meta
        if fused:
            if self.zs is not None and full in self.zs:
                from repro.core.fused import ProbeSpec, make_probe

                a_p = a.astype(jnp.float32) if kind == "embedding" else a
                probe = make_probe(
                    ProbeSpec(
                        meta=meta,
                        branch_mode=self.clip.mode,
                        decision_by=self.clip.decision_by,
                        ghost_block=self.clip.ghost_block,
                        inst_block_d=self.clip.inst_block_d,
                        override=self.clip.override_for(full),
                        kernels=self.clip.kernels_for(full),
                    )
                )
                s = probe(s, a_p, self.zs[full])
            return s
        if a is not None:
            self.acts[full] = a
        if self.taps is not None:
            tap = self.taps.get(full)
            if tap is not None:
                s = s + tap.astype(s.dtype)
        return s

    def record_act(self, name: str, a: jax.Array) -> None:
        """Late activation recording for taps registered with ``a=None``.

        Used for recurrent weights: the tap is added to the *input stream* of a
        time scan (addition commutes into the scan, so the tap cotangent is
        still dL/ds_t), while the recorded activation (h_{t-1}, emitted by the
        scan) only exists afterwards.
        """
        if self.collect:
            self.acts[self._join(name)] = a

    @staticmethod
    def disabled() -> "Ctx":
        return Ctx(taps=None, collect=False)


def make_zero_taps(meta: dict[str, TapMeta]) -> dict[str, jax.Array]:
    """Build the zeros tap pytree from discovered metadata."""
    return {name: jnp.zeros(m.s_shape, m.s_dtype) for name, m in meta.items()}


def tap_specs(meta: dict[str, TapMeta]) -> dict[str, jax.ShapeDtypeStruct]:
    return {
        name: jax.ShapeDtypeStruct(m.s_shape, m.s_dtype) for name, m in meta.items()
    }
