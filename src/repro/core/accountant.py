"""RDP accountant for the Poisson-subsampled Gaussian mechanism.

Implements Mironov et al. 2019 ("Renyi Differential Privacy of the Sampled
Gaussian Mechanism") for integer orders, composition over steps, and the
improved RDP->(eps, delta) conversion used by Opacus/TF-Privacy.  Pure numpy —
this runs on the host, never inside jit.

The paper's engine (Appendix E) exposes ``target_epsilon`` -> ``sigma``; we
recover sigma by bisection on the accountant.
"""
from __future__ import annotations

import math
from typing import Sequence

import numpy as np
from scipy import special

DEFAULT_ALPHAS = tuple(range(2, 64)) + tuple(range(64, 513, 8))


def rdp_gaussian(sigma: float, alphas: Sequence[int]) -> np.ndarray:
    """RDP of the (unsubsampled) Gaussian mechanism: alpha / (2 sigma^2)."""
    a = np.asarray(alphas, dtype=np.float64)
    return a / (2.0 * sigma**2)


def rdp_subsampled_gaussian(
    q: float, sigma: float, alphas: Sequence[int]
) -> np.ndarray:
    """Per-step RDP at integer orders for Poisson sampling rate q.

    RDP(a) = 1/(a-1) * log sum_{k=0}^{a} C(a,k) (1-q)^{a-k} q^k e^{k(k-1)/2s^2}
    """
    if q == 0.0:
        return np.zeros(len(alphas))
    if q >= 1.0:
        return rdp_gaussian(sigma, alphas)
    out = []
    log_q = math.log(q)
    log_1q = math.log1p(-q)
    for a in alphas:
        a = int(a)
        ks = np.arange(a + 1, dtype=np.float64)
        log_terms = (
            special.gammaln(a + 1)
            - special.gammaln(ks + 1)
            - special.gammaln(a - ks + 1)
            + (a - ks) * log_1q
            + ks * log_q
            + ks * (ks - 1) / (2.0 * sigma**2)
        )
        out.append(special.logsumexp(log_terms) / (a - 1))
    return np.asarray(out)


def eps_from_rdp(
    rdp: np.ndarray, alphas: Sequence[int], delta: float
) -> tuple[float, int]:
    """Improved conversion (Balle et al. 2020): returns (eps, best_alpha)."""
    a = np.asarray(alphas, dtype=np.float64)
    eps = rdp + np.log((a - 1) / a) - (np.log(delta) + np.log(a)) / (a - 1)
    eps = np.where(eps < 0, np.inf, eps)
    i = int(np.argmin(eps))
    return float(eps[i]), int(a[i])


class RDPAccountant:
    """Tracks composed RDP over heterogeneous (q, sigma, steps) phases."""

    def __init__(self, alphas: Sequence[int] = DEFAULT_ALPHAS):
        self.alphas = tuple(alphas)
        self._rdp = np.zeros(len(self.alphas))

    def step(self, *, q: float, sigma: float, steps: int = 1) -> None:
        # compose one step at a time, not as `steps * rdp`: float addition is
        # not distributive over that multiply, and bit-exact resume (a crash
        # at step k replays `step(steps=k)` and must land on EXACTLY the
        # epsilon trajectory of the uninterrupted run) depends on replaying
        # the same additions in the same order
        r = rdp_subsampled_gaussian(q, sigma, self.alphas)
        for _ in range(steps):
            self._rdp = self._rdp + r

    def get_epsilon(self, delta: float) -> float:
        eps, _ = eps_from_rdp(self._rdp, self.alphas, delta)
        return eps


def compute_epsilon(
    *, q: float, sigma: float, steps: int, delta: float,
    alphas: Sequence[int] = DEFAULT_ALPHAS,
    release_sigmas: Sequence[float] = (),
) -> float:
    """Epsilon after ``steps`` compositions of the gradient mechanism plus
    any per-step side releases.

    ``release_sigmas`` are the noise multipliers of additional sensitivity-1
    queries the pipeline makes against the *same* Poisson-sampled batch each
    step — e.g. the quantile clipping policy's noised indicator count
    (``repro.policies.quantile``).  Each composes as its own subsampled
    Gaussian mechanism at rate ``q``; ignoring them would under-report the
    spend, so every epsilon the engine reports flows through here.
    """
    rdp = steps * rdp_subsampled_gaussian(q, sigma, alphas)
    for rs in release_sigmas:
        rdp = rdp + steps * rdp_subsampled_gaussian(q, rs, alphas)
    return eps_from_rdp(rdp, alphas, delta)[0]


def find_noise_multiplier(
    *, target_epsilon: float, q: float, steps: int, delta: float,
    sigma_min: float = 0.3, sigma_max: float = 1e4, tol: float = 1e-4,
    release_sigmas: Sequence[float] = (),
) -> float:
    """Smallest sigma achieving eps(sigma) <= target_epsilon (bisection).

    ``release_sigmas`` (fixed per-step side releases, e.g. the quantile
    policy's indicator) are composed inside the bisection, so the returned
    sigma lands the *total* spend on the target — no hand-tuned headroom.
    """

    def eps(s: float) -> float:
        return compute_epsilon(
            q=q, sigma=s, steps=steps, delta=delta,
            release_sigmas=release_sigmas,
        )

    if eps(sigma_max) > target_epsilon:
        raise ValueError(
            "target epsilon unreachable even at sigma_max"
            + (" (the per-step policy releases alone may exceed it)"
               if release_sigmas else "")
        )
    lo, hi = sigma_min, sigma_max
    if eps(lo) <= target_epsilon:
        return lo
    while hi / lo > 1 + tol:
        mid = math.sqrt(lo * hi)
        if eps(mid) <= target_epsilon:
            hi = mid
        else:
            lo = mid
    return hi
