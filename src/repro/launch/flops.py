"""Useful-FLOPs model: MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference),
with N = active parameters (MoE counts top-k of E experts + shared paths).
"""
from __future__ import annotations

import jax

from repro.configs.base import ArchConfig, ShapeConfig
from repro.utils.tree import flatten_dict


def count_params(model, cfg: ArchConfig) -> tuple[int, int]:
    """(total_params, active_params) from the abstract param tree."""
    abstract = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    flat = flatten_dict(abstract)
    total = 0
    active = 0
    for path, leaf in flat.items():
        n = 1
        for d in leaf.shape:
            n *= int(d)
        total += n
        if cfg.moe_experts and ("moe/wg" in path or "moe/wu" in path or "moe/wo" in path):
            active += n * cfg.moe_top_k // cfg.moe_experts
        else:
            active += n
    return total, active


def model_flops(model, cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Global useful FLOPs for one step of this (arch, shape) cell."""
    _, active = count_params(model, cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per sequence; embedding/lm_head still touched per token
    return 2.0 * active * shape.global_batch
