"""Serving driver: continuous-batching engine + per-request metrics.

Token-prompt decoder LMs route through ``repro.serving.Engine`` — request
queue, SLO-aware admission, paged KV pool, per-step slot recycling.  The
encoder-frontend families (audio, vlm) still decode as one fixed wave, but
with honest token accounting: generation and counting stop at EOS.

CPU quickstart:
    python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --slots 4 --requests 8 --prompt-len 16 --max-new 12
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import build_model, get_arch
from repro.launch.steps import make_decode_step
from repro.obs import events as obs
from repro.serving import Engine, aggregate_metrics
from repro.utils.logging import get_logger, reconfigure

log = get_logger("serve")


def _serve_engine(model, cfg, params, args) -> int:
    engine = Engine(
        model, params,
        n_slots=args.slots,
        page_size=args.page,
        max_len=args.prompt_len + args.max_new,
        eos_id=args.eos,
    )
    key = jax.random.PRNGKey(1)
    for _ in range(args.requests):
        key, sub = jax.random.split(key)
        # 1 + ... keeps random prompts off the EOS id
        prompt = (1 + jax.random.randint(
            sub, (args.prompt_len,), 0, cfg.vocab - 1, dtype=jnp.int32
        )).tolist()
        rid, admitted = engine.submit(
            prompt, max_new=args.max_new, slo_ttft_ms=args.slo_ttft_ms)
        if not admitted:
            log.info("request %d shed at admission (projected TTFT > SLO)", rid)
    completions = engine.drain()
    m = aggregate_metrics(completions)
    log.info(
        "%d requests (%d shed): %d tokens, %.1f tok/s | TTFT p50 %.1fms "
        "p95 %.1fms | per-token p50 %.1fms p95 %.1fms",
        int(m["requests"]), int(m["shed"]), int(m["tokens"]), m["tok_per_s"],
        m["ttft_p50_ms"], m["ttft_p95_ms"],
        m["per_token_p50_ms"], m["per_token_p95_ms"],
    )
    for rid in sorted(completions)[:2]:
        c = completions[rid]
        log.info("request %d [%s]: %s", rid, c.finish, c.tokens)
    return 0


def _serve_wave(model, cfg, params, args) -> int:
    """Legacy fixed-wave decode for the encoder-frontend families."""
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.slots, args.prompt_len), 0, cfg.vocab, dtype=jnp.int32)}
    if cfg.family == "vlm":
        batch["prefix"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.slots, cfg.prefix_tokens, cfg.prefix_dim)
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (args.slots, cfg.encoder_seq, cfg.d_model)
        ).astype(jnp.dtype(cfg.dtype))

    max_len = args.prompt_len + args.max_new + (cfg.prefix_tokens or 0)
    state = model.init_state(args.slots, max_len)

    t0 = time.time()
    logits, state = jax.jit(model.prefill)(params, batch, state)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    decode = jax.jit(make_decode_step(model))
    done = tok[:, 0] == args.eos
    outputs = [tok]
    t0 = time.time()
    for _ in range(args.max_new - 1):
        if bool(jnp.all(done)):
            break
        tok, _, state = decode(params, tok, state)
        # finished lanes keep stepping (fixed wave) but emit nothing:
        # -1 marks dead rows so they never reach the output or the count
        outputs.append(jnp.where(done[:, None], -1, tok))
        done = done | (tok[:, 0] == args.eos)
    t_decode = time.time() - t0
    gen = jnp.concatenate(outputs, axis=1)
    n_tok = int(jnp.sum(gen != -1))
    log.info("prefill %.3fs; decode %d tokens in %.3fs (%.1f tok/s)",
             t_prefill, n_tok, t_decode, n_tok / max(t_decode, 1e-9))
    for i in range(min(args.slots, 2)):
        row = [t for t in gen[i].tolist() if t != -1]
        log.info("request %d: %s", i, row)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", "--batch", dest="slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page", type=int, default=16)
    ap.add_argument("--eos", type=int, default=0)
    ap.add_argument("--slo-ttft-ms", type=float, default=None)
    ap.add_argument("--obs-dir", default=None,
                    help="directory for the observability streams "
                         "(events.jsonl/metrics.jsonl); request_shed events "
                         "and per-step queue stats land here")
    args = ap.parse_args(argv)
    reconfigure()

    obs.configure_run(args.obs_dir)
    obs.emit_event(
        "run_started", arch=args.arch, reduced=bool(args.reduced),
        slots=args.slots, requests=args.requests, max_new=args.max_new,
        slo_ttft_ms=args.slo_ttft_ms,
    )
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if cfg.family == "audio" or cfg.prefix_tokens:
        rc = _serve_wave(model, cfg, params, args)
    else:
        rc = _serve_engine(model, cfg, params, args)
    obs.emit_event("run_finished", exit_code=rc)
    return rc


if __name__ == "__main__":
    sys.exit(main())
