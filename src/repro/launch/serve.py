"""Batched serving driver: prefill + decode with per-request completion.

CPU quickstart:
    python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --batch 4 --prompt-len 16 --max-new 12
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import build_model, get_arch
from repro.launch.steps import make_decode_step
from repro.utils.logging import get_logger

log = get_logger("serve")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--eos", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab, dtype=jnp.int32)}
    if cfg.family == "vlm":
        batch["prefix"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.prefix_tokens, cfg.prefix_dim)
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (args.batch, cfg.encoder_seq, cfg.d_model)
        ).astype(jnp.dtype(cfg.dtype))

    max_len = args.prompt_len + args.max_new + (cfg.prefix_tokens or 0)
    state = model.init_state(args.batch, max_len)

    t0 = time.time()
    logits, state = jax.jit(model.prefill)(params, batch, state)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    decode = jax.jit(make_decode_step(model))
    done = jnp.zeros((args.batch,), bool)
    outputs = [tok]
    t0 = time.time()
    for _ in range(args.max_new - 1):
        tok, _, state = decode(params, tok, state)
        done = done | (tok[:, 0] == args.eos)
        outputs.append(tok)
        if bool(jnp.all(done)):
            break
    t_decode = time.time() - t0
    gen = jnp.concatenate(outputs, axis=1)
    n_tok = int(gen.shape[0] * gen.shape[1])
    log.info("prefill %.3fs; decode %d tokens in %.3fs (%.1f tok/s)",
             t_prefill, n_tok, t_decode, n_tok / max(t_decode, 1e-9))
    for i in range(min(args.batch, 2)):
        log.info("request %d: %s", i, gen[i].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
