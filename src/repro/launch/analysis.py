"""Roofline-term extraction from compiled XLA artifacts.

Sources (per §Roofline):
- ``compiled.cost_analysis()``  -> per-device HLO FLOPs and bytes accessed
- ``compiled.as_text()``        -> post-SPMD HLO; collective bytes are summed
  from the operand/output sizes of every all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  Effective wire bytes per collective use the standard
ring-algorithm factors with the participant count parsed from replica_groups.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link (per direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(sig: str) -> int:
    """Total bytes of all array shapes in an HLO type signature string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]<=[total]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    raw_bytes: dict[str, float]  # per-device output bytes by op kind
    wire_bytes: float  # ring-model effective bytes over the ICI link

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def parse_collectives(hlo_text: str, default_group: int = 2) -> CollectiveStats:
    counts: dict[str, int] = {}
    raw: dict[str, float] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        kind = None
        for c in _COLLECTIVES:
            # match "  %x = TYPE all-gather(" or fused variants like all-gather-start
            if re.search(rf"\s{c}(-start)?\(", s):
                kind = c
                break
        if kind is None:
            continue
        lhs = s.split("=", 1)[1]
        out_bytes = _shape_bytes(lhs.split("(", 1)[0])
        n = max(_group_size(s, default_group), 2)
        counts[kind] = counts.get(kind, 0) + 1
        raw[kind] = raw.get(kind, 0.0) + out_bytes
        if kind == "all-reduce":
            wire += 2.0 * (n - 1) / n * out_bytes
        elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
            wire += (n - 1) / n * out_bytes
        else:  # collective-permute
            wire += out_bytes
    return CollectiveStats(counts=counts, raw_bytes=raw, wire_bytes=wire)


@dataclasses.dataclass
class RooflineTerms:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float  # 6*N*D useful flops (global)
    useful_flops_ratio: float  # model_flops / (HLO flops * n_devices)
    memory_stats: dict
    collectives: dict

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(
    compiled,
    *,
    n_devices: int,
    flops_global: float,
    bytes_per_device: float,
    wire_bytes_per_device: float,
    model_flops: float = 0.0,
) -> RooflineTerms:
    flops = flops_global / n_devices
    byts = bytes_per_device
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes_estimate": int(
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes
        ),
    }
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = wire_bytes_per_device / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    total_hlo = flops * n_devices
    return RooflineTerms(
        flops_per_device=flops,
        bytes_per_device=byts,
        wire_bytes_per_device=wire_bytes_per_device,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / total_hlo) if total_hlo else 0.0,
        memory_stats=mem,
        collectives={},
    )
