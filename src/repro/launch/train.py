"""DP training driver.

Full production loop: data pipeline -> mixed-ghost clipped grads (with
gradient accumulation / virtual steps) -> Gaussian noise -> optimizer ->
checkpoint manager -> privacy accountant, with straggler watchdog,
preemption-to-checkpoint, and an ``--auto-restart`` supervision loop that
resumes from the latest checkpoint after a crash (fault injection for tests
via ``--fail-at-step``).

CPU quickstart (reduced config):
    python -m repro.launch.train --arch qwen2-72b --reduced --steps 20 \
        --batch 4 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import build_model, get_arch
from repro.core.engine import PrivacyEngine
from repro.data.pipeline import DataPipeline
from repro.data.poisson import poisson_sample_mask
from repro.data.synthetic import SyntheticLMConfig, synthetic_lm_batch
from repro.checkpoint.manager import CheckpointManager
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import DPTrainConfig, make_train_state, make_train_step
from repro.optim import adam, warmup_cosine
from repro.parallel.reshard import use_reshard_rules
from repro.parallel.sharding import batch_shardings, state_shardings
from repro.runtime.fault import PreemptionHandler, StepWatchdog
from repro.utils.logging import get_logger

log = get_logger("train")


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mode", default="mixed_ghost")
    ap.add_argument("--clip-norm", type=float, default=1.0)
    ap.add_argument("--target-epsilon", type=float, default=None)
    ap.add_argument("--noise-multiplier", type=float, default=1.0)
    ap.add_argument("--sample-size", type=int, default=50000)
    ap.add_argument("--poisson", action="store_true",
                    help="Poisson subsampling masks (DP accounting assumption)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--auto-restart", type=int, default=0,
                    help="supervise and restart up to N times on failure")
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="fault injection: raise at this step (tests)")
    ap.add_argument("--log-every", type=int, default=10)
    return ap.parse_args(argv)


def run_once(args) -> int:
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = make_host_mesh()

    # privacy engine: sigma from target epsilon (or given), accountant attached
    engine = PrivacyEngine(
        loss_with_ctx=model.loss_with_ctx,
        batch_size=args.batch,
        sample_size=args.sample_size,
        steps=args.steps,
        max_grad_norm=args.clip_norm,
        target_epsilon=args.target_epsilon,
        noise_multiplier=None if args.target_epsilon else args.noise_multiplier,
        mode=args.mode,
    )
    log.info("noise multiplier sigma=%.4f (q=%.5f)", engine.noise_multiplier,
             engine.sampling_rate)

    optimizer = adam(state_dtype=jnp.dtype(cfg.opt_state_dtype))
    schedule = warmup_cosine(args.lr, max(args.steps // 20, 1), args.steps)
    dp = DPTrainConfig(
        clipping_mode=args.mode,
        clip_norm=args.clip_norm,
        noise_multiplier=engine.noise_multiplier,
        logical_batch=args.batch,
    )
    step_fn = make_train_step(model, optimizer, schedule, dp)

    state = make_train_state(model, jax.random.PRNGKey(0), optimizer)
    st_sh = state_shardings(model, mesh, cfg, jax.eval_shape(lambda: state))
    state = jax.tree_util.tree_map(jax.device_put, state, st_sh)

    # data
    seq = args.seq if args.reduced else 4096
    text_len = seq - (cfg.prefix_tokens or 0)
    lm_cfg = SyntheticLMConfig(vocab=cfg.vocab, seq_len=text_len, batch=args.batch)

    def batch_fn(step, shard):
        b = synthetic_lm_batch(lm_cfg, step, shard)
        if args.poisson:
            key = jax.random.fold_in(jax.random.PRNGKey(4242), step)
            b["mask"] = poisson_sample_mask(key, args.batch, engine.sampling_rate)
        if cfg.family == "vlm":
            key = jax.random.fold_in(jax.random.PRNGKey(77), step)
            b["prefix"] = jax.random.normal(
                key, (args.batch, cfg.prefix_tokens, cfg.prefix_dim), jnp.float32
            ).astype(jnp.dtype(cfg.dtype))
        if cfg.family == "audio":
            key = jax.random.fold_in(jax.random.PRNGKey(78), step)
            b["frames"] = jax.random.normal(
                key, (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32
            ).astype(jnp.dtype(cfg.dtype))
        return b

    start_step = 0
    manager = None
    if args.ckpt_dir:
        manager = CheckpointManager(args.ckpt_dir, save_every=args.ckpt_every)
        if args.resume and manager.latest() is not None:
            start_step, state = manager.restore(shardings=st_sh)
            log.info("resumed from step %d", start_step)
            engine.record_step(start_step)

    pipeline = DataPipeline(batch_fn, start_step=start_step).start()
    b_sh = batch_shardings(
        jax.eval_shape(lambda: batch_fn(0, 0)), mesh, cfg
    )
    with use_reshard_rules(mesh, cfg):
        jit_step = jax.jit(
            step_fn, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None),
            donate_argnums=(0,),
        ).lower(jax.eval_shape(lambda: state),
                jax.eval_shape(lambda: batch_fn(0, 0))).compile()

    watchdog = StepWatchdog()
    preempt = PreemptionHandler().install()

    step = start_step
    try:
        while step < args.steps:
            step_idx, batch = pipeline.next()
            watchdog.start_step()
            if args.fail_at_step is not None and step_idx == args.fail_at_step:
                raise RuntimeError(f"injected fault at step {step_idx}")
            state, metrics = jit_step(state, batch)
            engine.record_step()
            dt = watchdog.end_step(step_idx)
            step = step_idx + 1
            if step % args.log_every == 0 or step == args.steps:
                eps, delta = engine.privacy_spent()
                log.info(
                    "step %d loss=%.4f lr=%.2e clip_frac=%.2f eps=%.3f (%.2fs/step)",
                    step, float(metrics["loss"]), float(metrics["lr"]),
                    float(metrics["clip_frac"]), eps, dt,
                )
            if manager is not None:
                if preempt.preempted():
                    manager.save(step, state, force=True)
                    manager.wait()
                    log.warning("preempted: checkpointed step %d, exiting", step)
                    return 0
                manager.save(step, state)
    finally:
        pipeline.stop()
        if manager is not None:
            manager.save(step, state, force=True)
            manager.wait()
    eps, delta = engine.privacy_spent()
    log.info("done: %d steps, privacy spent (eps=%.3f, delta=%.1e)", step, eps, delta)
    return 0


def main(argv=None) -> int:
    args = parse_args(argv)
    if args.auto_restart <= 0:
        return run_once(args)
    attempts = 0
    while True:
        try:
            return run_once(args)
        except Exception as e:  # noqa: BLE001 — supervision loop
            attempts += 1
            if attempts > args.auto_restart:
                log.error("giving up after %d restarts", attempts - 1)
                raise
            log.warning("run failed (%s); auto-restart %d/%d from latest checkpoint",
                        e, attempts, args.auto_restart)
            args = dataclasses.replace(args) if dataclasses.is_dataclass(args) else args
            args.resume = True
            args.fail_at_step = None  # injected fault only fires once
            time.sleep(0.5)


if __name__ == "__main__":
    sys.exit(main())
