"""DP training driver.

Full production loop: data pipeline -> mixed-ghost clipped grads (with
gradient accumulation / virtual steps) -> Gaussian noise -> optimizer ->
checkpoint manager -> privacy accountant, with straggler watchdog,
preemption-to-checkpoint, and an ``--auto-restart`` supervision loop that
resumes from the latest checkpoint after a crash (fault injection for tests
via ``--fail-at-step``).

CPU quickstart (reduced config):
    python -m repro.launch.train --arch qwen2-72b --reduced --steps 20 \
        --batch 4 --seq 64 --ckpt-dir /tmp/ckpt

Measured-cost autotuning (repro.tuner): ``--tune`` profiles the three-way
branch decision per tap on this device — ghost / instantiate norms for the
second-backward modes and the book-keeping banks for ``bk_mixed`` — and
binary-searches the max physical microbatch; ``--plan plan.json`` reuses a
cached ClipPlan.  ``--mode auto`` adopts the plan's measured
``recommended_mode`` (mixed_ghost vs bk_mixed).  When the tuned physical
batch is smaller than ``--batch`` (the logical batch), the loop
automatically switches to gradient accumulation with the derived number of
microsteps (the paper's virtual-step pattern).

Multi-host fleets add ``--consensus`` (repro.tuner.consensus): tuning
elects one leader per device kind, every rank adopts the byte-identical
fleet-agreed plan (GSPMD requires all ranks to trace the same branch per
tap), memory certificates compile at the per-host batch share, and a stale
``--plan`` import fails loudly instead of silently falling back to the
analytic rule on one rank while its peers trace the plan.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import build_model, get_arch
from repro.core.engine import PrivacyEngine
from repro.data.pipeline import DataPipeline
from repro.data.poisson import poisson_sample_mask
from repro.data.synthetic import synthetic_arch_batch
from repro.checkpoint.manager import CheckpointManager
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (
    DPTrainConfig,
    make_accum_finalize,
    make_accum_init,
    make_accum_microstep,
    make_clipped_microstep,
    make_train_state,
    make_train_step,
)
from repro.obs import events as obs
from repro.obs.profile import ProfileWindow
from repro.optim import adam, warmup_cosine
from repro.parallel.reshard import use_reshard_rules
from repro.parallel.sharding import batch_shardings, state_shardings
from repro.runtime.elastic import current_data_shards, elastic_plan
from repro.runtime.fault import PreemptionHandler, StepWatchdog
from repro.runtime.inject import InjectionPlan
from repro.utils.logging import get_logger, reconfigure

log = get_logger("train")


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mode", default="mixed_ghost",
                    help="clipping mode (see core.clipping.MODES), or 'auto' "
                         "to adopt the tuned plan's recommended_mode")
    ap.add_argument("--clip-norm", type=float, default=1.0)
    ap.add_argument("--clip-policy", default="fixed",
                    choices=["fixed", "automatic", "quantile", "per_layer"],
                    help="clipping policy (repro.policies): fixed flat R, "
                         "automatic AUTO-S normalization (no R), quantile "
                         "DP-adaptive R, or per_layer group thresholds")
    ap.add_argument("--clip-quantile", type=float, default=0.5,
                    help="quantile policy: target norm quantile for R")
    ap.add_argument("--quantile-lr", type=float, default=0.2,
                    help="quantile policy: geometric update rate for R")
    ap.add_argument("--quantile-sigma", type=float, default=1.0,
                    help="quantile policy: noise multiplier of the "
                         "indicator release (composed into the accountant; "
                         "0 disables the release and its DP guarantee)")
    ap.add_argument("--auto-gamma", type=float, default=0.01,
                    help="automatic policy: stability constant (0 = AUTO-V)")
    ap.add_argument("--layer-groups", default="",
                    help="per_layer policy: comma-separated param-path "
                         "prefixes, one threshold per group (a catch-all "
                         "group is added automatically)")
    ap.add_argument("--target-epsilon", type=float, default=None)
    ap.add_argument("--epsilon-alarm-frac", type=float, default=0.9,
                    help="emit a one-shot epsilon_budget_crossed event when "
                         "the accountant passes this fraction of "
                         "--target-epsilon (<=0 disables)")
    ap.add_argument("--noise-multiplier", type=float, default=1.0)
    ap.add_argument("--sample-size", type=int, default=50000)
    ap.add_argument("--poisson", action="store_true",
                    help="Poisson subsampling masks (DP accounting assumption)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--auto-restart", type=int, default=0,
                    help="supervise and restart up to N times on failure")
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="fault injection: raise at this step (tests); "
                         "shorthand for --inject crash@STEP")
    ap.add_argument("--inject", default=None,
                    help="deterministic fault injection spec "
                         "(runtime.inject), e.g. 'crash@5,torn@4' or "
                         "'shrink@5:1'; merged with $REPRO_FAULT_INJECT")
    ap.add_argument("--data-shards", type=int, default=0,
                    help="data-parallel degree of the fleet (0 = "
                         "$REPRO_ELASTIC_SHARDS, else 1); the elastic "
                         "replan keeps the logical batch across resizes")
    ap.add_argument("--elastic-max-per-shard", type=int, default=0,
                    help="per-shard microbatch cap for the elastic replan "
                         "(0 = the tuned/physical microbatch)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--obs-dir", default=None,
                    help="directory for the observability streams "
                         "(events.jsonl/metrics.jsonl; default: --ckpt-dir). "
                         "Read back with `python -m repro.obs DIR`")
    ap.add_argument("--profile-steps", default=None, metavar="N[:M]",
                    help="capture a jax.profiler trace around the inclusive "
                         "step window [N, M] into <obs-dir>/profile "
                         "(repro.obs.timeline extracts per-step wall times)")
    ap.add_argument("--tune", action="store_true",
                    help="profile ghost-vs-instantiate per tap and search the "
                         "max physical microbatch before training")
    ap.add_argument("--consensus", action="store_true",
                    help="fleet-safe tuning/plan adoption: one measurement "
                         "per device kind, every rank adopts the "
                         "byte-identical agreed ClipPlan; with --plan, a "
                         "stale import fails loudly instead of silently "
                         "falling back (which would diverge across ranks)")
    ap.add_argument("--plan", default=None,
                    help="ClipPlan JSON to load (or, with --tune, to write)")
    ap.add_argument("--tune-budget-gb", type=float, default=16.0,
                    help="memory budget for the --tune max-batch search")
    ap.add_argument("--tune-hi-cap", type=int, default=4096)
    return ap.parse_args(argv)


def _injection_for(args) -> InjectionPlan:
    """One InjectionPlan per process: ``--inject`` + env, with the legacy
    ``--fail-at-step N`` folded in as a ``crash@N`` injector.  Injectors are
    one-shot, so in-process ``--auto-restart`` attempts share the plan and a
    fault that already fired does not re-fire after the restart."""
    plan = InjectionPlan.from_spec(args.inject)
    if args.fail_at_step is not None:
        plan.add_crash(args.fail_at_step)
    return plan


def _write_summary(ckpt_dir: str, **fields) -> None:
    """Machine-readable run outcome next to the checkpoints (tests compare
    the privacy spend of interrupted vs uninterrupted runs through this)."""
    path = pathlib.Path(ckpt_dir) / "summary.json"
    tmp = path.with_name(".tmp_summary.json")
    tmp.write_text(json.dumps(fields, sort_keys=True))
    tmp.replace(path)


def run_once(args, injection: Optional[InjectionPlan] = None) -> int:
    if injection is None:
        injection = _injection_for(args)
    # observability streams live next to the checkpoints unless redirected;
    # configure_run(None) resets any sinks a previous in-process run left
    # installed, and re-configuring the SAME dir keeps appending (so every
    # --auto-restart attempt lands in one events.jsonl timeline)
    run_dir = args.obs_dir or args.ckpt_dir
    obs.configure_run(run_dir)
    obs.emit_event(
        "run_started", arch=args.arch, reduced=bool(args.reduced),
        steps=args.steps, logical_batch=args.batch, seq_len=args.seq,
        mode=args.mode, policy=args.clip_policy, resume=bool(args.resume),
        ckpt_dir=args.ckpt_dir,
    )
    profile = None
    if args.profile_steps:
        if run_dir is None:
            log.warning("--profile-steps needs --obs-dir or --ckpt-dir for "
                        "the trace output; skipping profiling")
        else:
            profile = ProfileWindow.from_spec(args.profile_steps, run_dir)
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = make_host_mesh()

    # clipping policy (repro.policies): make_policy filters the kwarg union
    # down to what the chosen policy's __init__ actually takes
    from repro.policies import make_policy

    policy = make_policy(
        args.clip_policy,
        clip_norm=args.clip_norm,
        init_clip_norm=args.clip_norm,
        gamma=args.auto_gamma,
        target_quantile=args.clip_quantile,
        lr=args.quantile_lr,
        release_sigma=args.quantile_sigma,
        groups=tuple(g for g in args.layer_groups.split(",") if g),
    )
    if args.clip_policy != "fixed":
        log.info("clipping policy: %s", policy.fingerprint())

    # privacy engine: sigma from target epsilon (or given), accountant
    # attached.  With --target-epsilon the bisection composes the policy's
    # per-step release (quantile indicator) so the TOTAL spend hits the
    # target — no hand-picked sigma, no silent under-accounting.
    def make_engine(batch_size: int, mode: str) -> PrivacyEngine:
        return PrivacyEngine(
            loss_with_ctx=model.loss_with_ctx,
            batch_size=batch_size,
            sample_size=args.sample_size,
            steps=args.steps,
            max_grad_norm=args.clip_norm,
            target_epsilon=args.target_epsilon,
            noise_multiplier=None if args.target_epsilon else args.noise_multiplier,
            mode=mode,
            clip_policy=policy,
        )

    # '--mode auto' is resolved from the tuned plan below; tune/search under
    # the paper default in the meantime
    clip_mode = "mixed_ghost" if args.mode == "auto" else args.mode
    engine = make_engine(args.batch, clip_mode)
    log.info("noise multiplier sigma=%.4f (q=%.5f)", engine.noise_multiplier,
             engine.sampling_rate)

    optimizer = adam(state_dtype=jnp.dtype(cfg.opt_state_dtype))
    schedule = warmup_cosine(args.lr, max(args.steps // 20, 1), args.steps)

    state = make_train_state(model, jax.random.PRNGKey(0), optimizer, policy)

    # measured-cost autotuning: load a cached ClipPlan or profile one now.
    # Memory certificates (max-batch search / re-certification) compile at
    # the PER-HOST share of the batch: on a fleet, one host's HBM never
    # holds the global batch.  Single host: probe_batch == args.batch.
    from repro.parallel.sharding import per_host_batch

    seq = args.seq if args.reduced else 4096
    probe_batch = per_host_batch(args.batch, mesh, cfg)
    if probe_batch != args.batch:
        log.info("multi-host fleet: memory certificates compile at the "
                 "per-host batch share %d (global %d)", probe_batch, args.batch)
    plan = None
    if args.plan and not args.tune:
        from repro.core.clipping import discover_meta
        from repro.tuner import ClipPlan

        probe = synthetic_arch_batch(cfg, batch=probe_batch, seq=seq)
        metas = discover_meta(model.loss_with_ctx, state["params"], probe)
        if args.consensus:
            # fleet import: a stale plan on one rank means that rank would
            # trace different branches than its peers — abort, loudly,
            # before anything is traced.  verify_adopted is rank-local
            # (fingerprint/ratification/hash integrity); the certify phase
            # then cross-checks that every rank imported the SAME bytes
            # (e.g. one host left holding yesterday's re-exported artifact)
            from repro.tuner.consensus import certify_fleet_hash, verify_adopted

            plan = ClipPlan.load(args.plan)
            verify_adopted(
                plan, metas, policy_fingerprint=policy.fingerprint()
            )
            certify_fleet_hash(plan)
        else:
            try:
                plan = ClipPlan.load(args.plan)
            except (ValueError, KeyError) as e:
                # e.g. a pre-three-way (v1) artifact: unreadable == stale
                log.warning("unreadable ClipPlan %s (%s); falling back to the "
                            "analytic decision", args.plan, e)
                plan = None
            if plan is not None and not plan.matches(metas):
                # a stale plan must not drive anything — neither the branch
                # overrides nor the microbatch geometry it measured elsewhere
                log.warning("ClipPlan %s is stale for this arch/device; "
                            "falling back to the analytic decision", args.plan)
                plan = None
        if plan is not None:
            engine.use_plan(plan)
            log.info("loaded ClipPlan %s (device %s, %d branch overrides%s)",
                     args.plan, plan.device, len(plan.branches),
                     f", agreed by {plan.agreed_ranks} rank(s)"
                     if plan.agreed_ranks else "")
    elif args.tune:
        probe = synthetic_arch_batch(cfg, batch=probe_batch, seq=seq)
        plan = engine.tune(
            state["params"], probe, arch=cfg.name,
            budget_bytes=int(args.tune_budget_gb * 1024**3),
            hi_cap=args.tune_hi_cap,
            plan_path=args.plan if args.plan else "auto",
            consensus=args.consensus,
        )
        log.info("tuned %d taps; max physical batch=%s", len(plan.branches),
                 plan.physical_batch)

    if args.mode == "auto":
        if plan is not None:
            clip_mode = plan.recommended_mode()
            log.info("--mode auto: measured recommendation is %s "
                     "(mixed_ghost=%.1fus bk_mixed=%.1fus per step)",
                     clip_mode, plan.mode_cost_us("mixed_ghost"),
                     plan.mode_cost_us("bk_mixed"))
        else:
            log.warning("--mode auto without a usable plan; staying on %s "
                        "(pass --tune or a valid --plan)", clip_mode)
        if clip_mode != engine.mode:
            # the max-batch certificate was compiled under the tuning mode;
            # book-keeping banks residuals the searched graph never
            # allocated, so re-certify under the adopted mode before
            # committing to it
            candidate = make_engine(args.batch, clip_mode)
            if plan is not None:
                candidate.use_plan(plan)
                if plan.physical_batch and plan.budget_bytes:
                    replan = candidate.recertify_max_batch(
                        state["params"], probe, hi_cap=args.tune_hi_cap
                    )
                    if args.consensus:
                        # the re-certification compiled on THIS rank's kind;
                        # the fleet adopts the mode only if every rank fits
                        # it, at the minimum batch any rank certified
                        from repro.tuner.consensus import (
                            reconcile_recertification,
                        )

                        fits, fleet_mb = reconcile_recertification(
                            replan is not None,
                            replan.physical_batch if replan is not None
                            else None,
                        )
                        if not fits:
                            replan = None
                        elif fleet_mb and fleet_mb != replan.physical_batch:
                            log.info("fleet minimum re-certified batch %d "
                                     "(this rank fit %d)", fleet_mb,
                                     replan.physical_batch)
                            replan = replan.replace_batch(
                                physical_batch=fleet_mb,
                                logical_batch=replan.logical_batch,
                                accumulation_steps=None,
                                budget_bytes=replan.budget_bytes,
                            )
                            candidate.use_plan(replan)
                    if replan is None:
                        log.warning(
                            "no batch fits the budget under %s; staying on "
                            "the certified tuning mode %s", clip_mode,
                            engine.mode,
                        )
                        clip_mode = engine.mode
                        candidate = None
                    else:
                        plan = replan
            if candidate is not None:
                engine = candidate

    physical, accum = args.batch, 1
    if plan is not None and plan.physical_batch:
        from repro.tuner import derive_accumulation

        # plan.physical_batch certifies ONE host's capacity (the probe was
        # sliced to the per-host share above); the cap on the *global*
        # microbatch scales back by the same factor — on a single host the
        # scale is 1 and this is the PR-2 behaviour unchanged
        host_scale = max(1, args.batch // probe_batch)
        physical, accum = derive_accumulation(
            args.batch, plan.physical_batch * host_scale
        )
    logical_eff = physical * accum
    if accum > 1:
        log.info(
            "tuned physical batch=%d (max %d): logical %d -> %d accumulation "
            "steps (effective logical %d)", physical, plan.physical_batch,
            args.batch, accum, logical_eff,
        )
    if logical_eff != args.batch:
        # accumulation rounding changed the per-step sample count: rebuild
        # the engine so the accountant's sampling rate (and sigma, when
        # derived from a target epsilon) match what actually runs
        log.info("effective logical batch %d != requested %d; re-deriving "
                 "privacy accounting", logical_eff, args.batch)
        engine = make_engine(logical_eff, clip_mode)
        if plan is not None:
            engine.use_plan(plan)

    # elastic fleet layout (runtime.elastic): recomputed on EVERY start —
    # including every --auto-restart attempt — from the shard count the
    # fleet actually has now ($REPRO_ELASTIC_SHARDS is the restart-time
    # seam; a scheduler or a shrink@step injector updates it between
    # attempts).  The logical batch (and with it the sampling rate q the
    # accountant composes) never changes; lost parallelism becomes extra
    # accumulation microsteps of the SAME per-shard microbatch, so a resumed
    # run replays the identical microbatch stream bit for bit.
    data_shards = current_data_shards(args.data_shards)
    if data_shards > 1 or args.elastic_max_per_shard:
        eplan = elastic_plan(
            logical_batch=logical_eff,
            data_shards=data_shards,
            max_per_shard=args.elastic_max_per_shard or physical,
        )
        physical, accum = eplan.execution(jax.process_count())
        log.info(
            "elastic layout: %d shard(s) x per-shard %d (accum %d) -> "
            "microbatch %d, %d microstep(s) per logical batch of %d",
            eplan.data_shards, eplan.per_shard_batch,
            eplan.accumulation_steps, physical, accum, logical_eff,
        )

    if args.consensus:
        # decisions derived rank-locally AFTER plan adoption — the --mode
        # auto re-certification (which can fall back per rank when nothing
        # fits) and the accumulation split — must also agree fleet-wide, or
        # ranks would trace different modes/microstep counts past the plan
        # consensus gate
        from repro.tuner.consensus import certify_fleet_value

        certify_fleet_value(
            "adopted mode/batch/policy",
            f"{clip_mode}:{physical}:{accum}:{policy.fingerprint()}:"
            f"{plan.consensus_hash() if plan is not None else '-'}",
        )

    # the adopted configuration, as actually traced: per-tap branch map +
    # kernel winners from the plan (or the analytic rule), plus the executed
    # batch layout (which elastic resharding may have reshaped past the
    # plan's own certificate)
    plan_fields = engine.plan_event_fields()
    plan_fields.update(
        mode=clip_mode, physical_batch=physical, accumulation_steps=accum,
        logical_batch=logical_eff, data_shards=data_shards,
    )
    obs.emit_event("plan_adopted", **plan_fields)

    dp = DPTrainConfig(
        clipping_mode=clip_mode,
        clip_norm=args.clip_norm,
        noise_multiplier=engine.noise_multiplier,
        logical_batch=logical_eff,
        accumulation_steps=accum,
        plan=plan,
        policy=policy,
    )
    step_fn = make_train_step(model, optimizer, schedule, dp)

    st_sh = state_shardings(model, mesh, cfg, jax.eval_shape(lambda: state))
    state = jax.tree_util.tree_map(jax.device_put, state, st_sh)

    # data (microbatches of the tuned physical size)
    def batch_fn(step, shard):
        b = synthetic_arch_batch(cfg, batch=physical, seq=seq, step=step, shard=shard)
        if args.poisson:
            key = jax.random.fold_in(jax.random.PRNGKey(4242), step)
            b["mask"] = poisson_sample_mask(key, physical, engine.sampling_rate)
        return b

    start_step = 0
    manager = None
    if args.ckpt_dir:
        manager = CheckpointManager(
            args.ckpt_dir, save_every=args.ckpt_every,
            on_saved=injection.on_checkpoint_saved if injection else None,
        )
        if args.resume and manager.latest() is not None:
            # restore to host first: a pre-policy checkpoint lacks the
            # state["policy"] subtree the sharding tree now carries, so
            # fill it with the init state before re-sharding
            start_step, rstate = manager.restore()
            if "policy" not in rstate:
                log.info("pre-policy checkpoint: starting the %s policy "
                         "state fresh", policy.name)
                rstate["policy"] = policy.init_state()
            state = jax.tree_util.tree_map(jax.device_put, rstate, st_sh)
            log.info("resumed from step %d", start_step)
            engine.record_step(start_step)

    pipeline = DataPipeline(batch_fn, start_step=start_step * accum).start()
    b_sh = batch_shardings(
        jax.eval_shape(lambda: batch_fn(0, 0)), mesh, cfg
    )
    with use_reshard_rules(mesh, cfg):
        if accum == 1:
            jit_step = jax.jit(
                step_fn, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None),
                donate_argnums=(0,),
            ).lower(jax.eval_shape(lambda: state),
                    jax.eval_shape(lambda: batch_fn(0, 0))).compile()
        else:
            # virtual-step pattern: accumulate clipped grad sums over
            # physical microbatches, then noise + update once per logical
            # step.  AOT-compile INSIDE the reshard context (like the
            # accum==1 path): a lazy jit would trace at first call, outside
            # it, silently dropping every sharding constraint.
            #
            # The accumulator is a device-resident pytree DONATED through
            # every microstep and into the finalize: the fold runs inside
            # the jitted program (bank reductions overlap the accumulator
            # update), the buffers alias in place instead of
            # double-buffering per microstep, and the host loop performs no
            # sync until the logical-batch boundary.
            st_spec = jax.eval_shape(lambda: state)
            b_spec = jax.eval_shape(lambda: batch_fn(0, 0))
            micro_raw = make_clipped_microstep(model, dp)
            p_spec = st_spec["policy"]
            g_spec = jax.eval_shape(micro_raw, st_spec["params"], b_spec, p_spec)[1]
            # the policy update runs once per LOGICAL batch, over the
            # per-sample norms (and Poisson mask) of every microstep,
            # scattered into the accumulator's flat (physical*accum,)
            # buffers — one quantile release per noise addition
            acc_init = make_accum_init(g_spec, physical * accum)
            acc_spec = jax.eval_shape(acc_init)
            acc_sh = {
                "grads": st_sh["params"], "loss": None, "clip_hits": None,
                "norms": None, "mask": None,
            }
            idx_spec = jax.ShapeDtypeStruct((), jnp.int32)
            init_fn = jax.jit(
                acc_init, out_shardings=acc_sh,
            ).lower().compile()
            micro_fn = jax.jit(
                make_accum_microstep(model, dp),
                in_shardings=(
                    st_sh["params"], st_sh["policy"], acc_sh, b_sh, None,
                ),
                out_shardings=acc_sh,
                donate_argnums=(2,),
            ).lower(
                st_spec["params"], p_spec, acc_spec, b_spec, idx_spec
            ).compile()
            # state is donated (params/opt alias into the update); the
            # accumulator is NOT — its leaves are temps inside the finalize
            # (noise-add, optimizer) with no matching output to alias, so
            # donating them only triggers the unusable-donation warning
            fin_fn = jax.jit(
                make_accum_finalize(optimizer, schedule, dp),
                in_shardings=(st_sh, acc_sh), out_shardings=(st_sh, None),
                donate_argnums=(0,),
            ).lower(st_spec, acc_spec).compile()
            # microstep indices as device scalars, built once: the loop
            # body transfers nothing and never blocks mid-logical-batch
            idx_dev = [jnp.asarray(i, jnp.int32) for i in range(accum)]

    watchdog = StepWatchdog()
    preempt = PreemptionHandler().install()

    step = start_step
    try:
        while step < args.steps:
            if accum == 1:
                step_idx, batch = pipeline.next()
                watchdog.start_step()
                injection.on_step(step_idx)
                if profile is not None:
                    profile.before_step(step_idx)
                state, metrics = jit_step(state, batch)
            else:
                watchdog.start_step()
                step_idx = step
                injection.on_step(step_idx)
                if profile is not None:
                    profile.before_step(step_idx)
                # every microstep is async dispatch into the donated
                # accumulator; nothing on the host reads a device value, so
                # the bank reductions of microstep i overlap the dispatch
                # (and compute) of microstep i+1
                acc = init_fn()
                for i in range(accum):
                    _, batch = pipeline.next()
                    acc = micro_fn(
                        state["params"], state["policy"], acc, batch, idx_dev[i]
                    )
                state, metrics = fin_fn(state, acc)
                # the ONE host sync per logical batch: bounds the dispatch
                # queue and makes the watchdog time executed work.  The step
                # metrics ride the SAME sync, so the record below reads
                # already-materialized buffers — instrumentation adds no
                # second block_until_ready (test-asserted)
                jax.block_until_ready((state["step"], metrics))
            engine.record_step()
            engine.check_epsilon_alarm(args.epsilon_alarm_frac, step=step_idx + 1)
            dt = watchdog.end_step(step_idx)
            step = step_idx + 1
            if profile is not None:
                profile.after_step(step_idx)
            if obs.metrics_active():
                eps_m, delta_m = engine.privacy_spent()
                obs.emit_metrics(
                    {
                        "kind": "train_step",
                        "loss": float(metrics["loss"]),
                        "lr": float(metrics["lr"]),
                        "clip_frac": float(metrics["clip_frac"]),
                        "norm_mean": float(metrics["norm_mean"]),
                        "norm_max": float(metrics["norm_max"]),
                        "epsilon": eps_m,
                        "delta": delta_m,
                        "step_s": dt,
                        "examples_per_s": logical_eff / dt if dt > 0 else None,
                        "physical_batch": physical,
                        "accumulation_steps": accum,
                        "mode": clip_mode,
                    },
                    step=step,
                )
            if step % args.log_every == 0 or step == args.steps:
                eps, delta = engine.privacy_spent()
                log.info(
                    "step %d loss=%.4f lr=%.2e clip_frac=%.2f eps=%.3f (%.2fs/step)",
                    step, float(metrics["loss"]), float(metrics["lr"]),
                    float(metrics["clip_frac"]), eps, dt,
                )
            if manager is not None:
                if preempt.preempted():
                    manager.save(step, state, force=True)
                    manager.wait()
                    log.warning("preempted: checkpointed step %d, exiting", step)
                    obs.emit_event("preemption", step=step, checkpointed=True)
                    return 0
                manager.save(step, state)
    finally:
        pipeline.stop()
        preempt.uninstall()
        if profile is not None:
            profile.stop(step=step)
        if manager is not None:
            manager.save(step, state, force=True)
            manager.wait()
    eps, delta = engine.privacy_spent()
    log.info("done: %d steps, privacy spent (eps=%.3f, delta=%.1e)", step, eps, delta)
    obs.emit_event("run_finished", step=step, epsilon=eps, delta=delta)
    if args.ckpt_dir:
        _write_summary(
            args.ckpt_dir, step=step, epsilon=eps, delta=delta,
            logical_batch=logical_eff, microbatch=physical,
            accumulation_steps=accum, data_shards=data_shards,
        )
    return 0


# Deterministic failure classes: a config/shape/assertion error fails
# identically on every attempt, so restarting it only burns the budget a
# real transient (preempted host, flaky storage, injected crash) needs.
_NON_RETRYABLE = (
    AssertionError,
    ValueError,
    TypeError,
    KeyError,
    AttributeError,
    ImportError,
    NotImplementedError,
)


def is_retryable_failure(exc: BaseException) -> bool:
    """Should the --auto-restart supervisor retry after ``exc``?

    Consensus failures are deterministic fleet-configuration divergence
    (every restart re-derives the same mismatch), so they are classified
    non-retryable alongside the stdlib config-error types above.
    """
    try:
        from repro.tuner.consensus import PlanConsensusError
    except ImportError:  # pragma: no cover - tuner always ships
        PlanConsensusError = ()
    if isinstance(exc, PlanConsensusError):
        return False
    return not isinstance(exc, _NON_RETRYABLE)


def main(argv=None) -> int:
    args = parse_args(argv)
    reconfigure()  # re-apply $REPRO_LOG_LEVEL to module-level loggers
    # ONE injection plan for the whole supervision loop: injectors are
    # one-shot, so a crash that already fired does not re-fire after the
    # in-process restart (no args surgery needed)
    injection = _injection_for(args)
    if args.auto_restart <= 0:
        return run_once(args, injection)
    attempts = 0
    while True:
        try:
            return run_once(args, injection)
        except Exception as e:  # noqa: BLE001 — supervision loop
            if not is_retryable_failure(e):
                log.error(
                    "non-retryable failure (%s: %s): a deterministic "
                    "config/assertion error would fail every attempt — not "
                    "burning the %d-restart budget",
                    type(e).__name__, e, args.auto_restart,
                )
                raise
            attempts += 1
            if attempts > args.auto_restart:
                log.error("giving up after %d restarts", attempts - 1)
                raise
            log.warning("run failed (%s); auto-restart %d/%d from latest checkpoint",
                        e, attempts, args.auto_restart)
            # the crashed attempt's sinks are still installed (configure_run
            # keeps them for the same dir), so this lands in the same stream
            obs.emit_event(
                "restart_attempt", attempt=attempts,
                max_attempts=args.auto_restart,
                error=f"{type(e).__name__}: {e}",
            )
            # an actual copy: the previous `dataclasses.replace(args) if
            # is_dataclass(args) else args` was a no-op on an
            # argparse.Namespace, silently mutating the caller's args
            args = argparse.Namespace(**vars(args))
            args.resume = True
            time.sleep(0.5)


if __name__ == "__main__":
    sys.exit(main())
