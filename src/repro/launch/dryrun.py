from repro.launch.env import apply_env

# full harness (allocator, markers, preallocate-off) + the 512-device
# host platform this dry run lowers against — BEFORE jax initializes
apply_env(host_devices=512)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent without hardware: the SPMD
partitioner must accept every sharding, the compiled program's memory
analysis must fit the chip, and the collective schedule feeds the roofline.

Roofline methodology (EXPERIMENTS.md):
- compute term: ANALYTIC flops (launch/analytic.py) — XLA's cost_analysis
  counts while-loop bodies once, undercounting every lax.scan.
- memory + collective terms: HLO-parsed, with the layer-scan undercount
  corrected by depth extrapolation: lower 1-period and 2-period variants of
  the arch, take the per-period delta, extrapolate to full depth.
- memory FIT: compiled.memory_analysis() of the full-depth program (exact).

Usage:
    python -m repro.launch.dryrun [--arch qwen2-72b] [--shape train_4k]
        [--mesh single|multi|both] [--mode mixed_ghost] [--out results/dryrun]
        [--no-calibrate]
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.configs.registry import ARCHS, SHAPES, build_model, get_arch, get_shape
from repro.core.clipping import discover_meta
from repro.core.taps import ClipRuntime
from repro.launch import analysis
from repro.launch.analytic import cell_flops, extra_fwd_flops, serve_matmul_flops
from repro.launch.flops import model_flops
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    decode_token_specs,
    prefill_batch_specs,
    serve_state_specs,
    train_batch_specs,
)
from repro.launch.steps import (
    DPTrainConfig,
    abstract_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.optim import adam, warmup_cosine
from repro.parallel.reshard import use_reshard_rules
from repro.parallel.sharding import (
    batch_shardings,
    param_shardings,
    serve_state_shardings,
    state_shardings,
)
from repro.utils.logging import get_logger

log = get_logger("dryrun")


def _lower(cfg: ArchConfig, shape: ShapeConfig, mode: str, mesh):
    """Build the step for one cell and AOT-compile it.

    The explicit FSDP gather plan (reshard_param) is a TRAIN optimization:
    at decode/prefill the activations are small and GSPMD's native plan
    (keep weights sharded, replicate/reduce small activations) wins —
    measured 2-4x on jamba/mixtral serve cells, so serving lowers without
    the reshard context.
    """
    if shape.kind == "train":
        with use_reshard_rules(mesh, cfg):
            return _lower_inner(cfg, shape, mode, mesh)
    return _lower_inner(cfg, shape, mode, mesh)


def _lower_inner(cfg: ArchConfig, shape: ShapeConfig, mode: str, mesh):
    model = build_model(cfg)
    if shape.kind == "train":
        optimizer = adam(state_dtype=jnp.dtype(cfg.opt_state_dtype))
        dp = DPTrainConfig(
            clipping_mode=mode, clip_norm=1.0, noise_multiplier=1.0,
            logical_batch=shape.global_batch,
        )
        step = make_train_step(model, optimizer, warmup_cosine(1e-3, 100, 10000), dp)
        state_spec = abstract_train_state(model, optimizer)
        batch_spec = train_batch_specs(cfg, shape, shape.global_batch)
        st_sh = state_shardings(model, mesh, cfg, state_spec)
        b_sh = batch_shardings(batch_spec, mesh, cfg)
        lowered = jax.jit(
            step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None),
            donate_argnums=(0,),
        ).lower(state_spec, batch_spec)
    elif shape.kind == "prefill":
        step = make_prefill_step(model)
        p_sh = param_shardings(model, mesh, cfg)
        params_spec = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        batch_spec = prefill_batch_specs(cfg, shape, shape.global_batch)
        state_spec = serve_state_specs(model, cfg, shape, shape.global_batch)
        b_sh = batch_shardings(batch_spec, mesh, cfg)
        s_sh = serve_state_shardings(mesh, cfg, state_spec, shape.global_batch)
        lowered = jax.jit(
            step, in_shardings=(p_sh, b_sh, s_sh), out_shardings=(None, s_sh),
            donate_argnums=(2,),
        ).lower(params_spec, batch_spec, state_spec)
    else:  # decode
        step = make_decode_step(model)
        p_sh = param_shardings(model, mesh, cfg)
        params_spec = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        tok_spec = decode_token_specs(shape.global_batch)
        state_spec = serve_state_specs(model, cfg, shape, shape.global_batch)
        s_sh = serve_state_shardings(mesh, cfg, state_spec, shape.global_batch)
        t_sh = batch_shardings(tok_spec, mesh, cfg)
        lowered = jax.jit(
            step, in_shardings=(p_sh, t_sh, s_sh),
            out_shardings=(t_sh, None, s_sh), donate_argnums=(2,),
        ).lower(params_spec, tok_spec, state_spec)
    return lowered.compile()


def _hlo_stats(compiled):
    cost = compiled.cost_analysis()
    byts = float(cost.get("bytes accessed", 0.0))
    colls = analysis.parse_collectives(compiled.as_text())
    return byts, colls.wire_bytes, colls.to_dict()


def _period_len(cfg: ArchConfig) -> int:
    if cfg.block_pattern:
        return len(cfg.block_pattern)
    return cfg.moe_every if cfg.moe_experts else 1


def _depth_variant(cfg: ArchConfig, periods: int) -> ArchConfig:
    p_len = _period_len(cfg)
    kw = {"n_layers": periods * p_len}
    if cfg.encoder_layers:
        kw["encoder_layers"] = periods
        kw["n_layers"] = periods
    return dataclasses.replace(cfg, **kw)


def analytic_flops(cfg: ArchConfig, shape: ShapeConfig, mode: str) -> dict:
    model = build_model(cfg)
    if shape.kind == "train":
        runtime = ClipRuntime(mode=mode)
        state_spec = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        batch_spec = train_batch_specs(cfg, shape, shape.global_batch)
        meta = discover_meta(model.loss_with_ctx, state_spec, batch_spec, clip=runtime)
        return cell_flops(meta, cfg, shape, mode).to_dict()
    fwd = serve_matmul_flops(model, cfg, shape) + extra_fwd_flops(cfg, shape)
    return {"fwd": fwd, "total": fwd, "norms": 0.0}


def lower_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
               mode: str = "mixed_ghost", calibrate: bool = True):
    """Lower+compile one cell; returns (compiled, meta dict)."""
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    if not cfg.supports(shape):
        return None, {"status": "skipped",
                      "arch": arch_name, "shape": shape_name,
                      "mesh": "2x16x16" if multi_pod else "16x16",
                      "reason": "full-attention arch: long_500k not runnable "
                                "(noted in DESIGN.md §Arch-applicability)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.size

    compiled = _lower(cfg, shape, mode, mesh)
    raw_bytes, raw_wire, coll_detail = _hlo_stats(compiled)

    # depth extrapolation for scan-undercounted bytes/collectives
    n_periods = cfg.n_layers // _period_len(cfg)
    if cfg.encoder_layers:
        n_periods = cfg.n_layers
    if calibrate and n_periods >= 2:
        c1 = _lower(_depth_variant(cfg, 1), shape, mode, mesh)
        b1, w1, _ = _hlo_stats(c1)
        del c1
        c2 = _lower(_depth_variant(cfg, 2), shape, mode, mesh)
        b2, w2, _ = _hlo_stats(c2)
        del c2
        # per-period deltas can be slightly negative when fixed costs dominate
        # (partitioner noise between depth variants): clamp at zero
        bytes_corr = b1 + (n_periods - 1) * max(b2 - b1, 0.0)
        wire_corr = w1 + (n_periods - 1) * max(w2 - w1, 0.0)
    else:
        bytes_corr, wire_corr = raw_bytes, raw_wire

    flops = analytic_flops(cfg, shape, mode)
    mflops = model_flops(build_model(cfg), cfg, shape)

    terms = analysis.roofline_terms(
        compiled,
        n_devices=n_devices,
        flops_global=flops["total"],
        bytes_per_device=bytes_corr,
        wire_bytes_per_device=wire_corr,
        model_flops=mflops,
    )
    meta = {
        "status": "ok",
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_devices,
        "kind": shape.kind,
        "clipping_mode": mode if shape.kind == "train" else None,
        "analytic_flops": flops,
        "hlo_raw": {"bytes": raw_bytes, "wire_bytes": raw_wire,
                    "collectives": coll_detail},
        "roofline": terms.to_dict(),
    }
    return compiled, meta


def run_cell(arch_name, shape_name, *, multi_pod, mode, out_dir,
             resume=True, calibrate=True):
    tag = f"{'multi' if multi_pod else 'single'}/{arch_name}__{shape_name}"
    prior = (pathlib.Path(out_dir) / ("multi" if multi_pod else "single")
             / f"{arch_name}__{shape_name}.json")
    if resume and prior.exists():
        meta = json.loads(prior.read_text())
        if meta.get("status") in ("ok", "skipped"):
            meta.setdefault("mesh", "2x16x16" if multi_pod else "16x16")
            meta.setdefault("arch", arch_name)
            meta.setdefault("shape", shape_name)
            log.info("%s: cached %s", tag, meta["status"])
            return meta
    t0 = time.time()
    try:
        compiled, meta = lower_cell(
            arch_name, shape_name, multi_pod=multi_pod, mode=mode,
            calibrate=calibrate,
        )
        if compiled is not None:
            print(f"[{tag}] memory_analysis:", compiled.memory_analysis())
            cost = compiled.cost_analysis()
            print(f"[{tag}] cost_analysis: flops={cost.get('flops', 0):.3e} "
                  f"bytes={cost.get('bytes accessed', 0):.3e}")
    except Exception as e:  # noqa: BLE001 — any failure is a recorded bug
        meta = {
            "status": "error",
            "arch": arch_name,
            "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    meta["elapsed_s"] = round(time.time() - t0, 1)
    out = pathlib.Path(out_dir) / ("multi" if multi_pod else "single")
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{arch_name}__{shape_name}.json").write_text(json.dumps(meta, indent=2))
    status = meta["status"]
    extra = meta.get("error", "")[:140] if status == "error" else (
        meta.get("roofline", {}).get("bottleneck", "") if status == "ok" else
        meta.get("reason", ""))
    log.info("%s: %s (%.1fs) %s", tag, status, meta["elapsed_s"], extra)
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--mode", default="mixed_ghost")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    summary = []
    for multi in meshes:
        for a in archs:
            for s in shapes:
                meta = run_cell(a, s, multi_pod=multi, mode=args.mode,
                                out_dir=args.out, resume=not args.no_resume,
                                calibrate=not args.no_calibrate)
                summary.append((a, s, meta["mesh"], meta["status"]))
    n_ok = sum(1 for *_, st in summary if st == "ok")
    n_skip = sum(1 for *_, st in summary if st == "skipped")
    n_err = len(summary) - n_ok - n_skip
    print(f"\nDRY-RUN SUMMARY: {n_ok} ok / {n_skip} skipped / {n_err} errors "
          f"of {len(summary)} cells")
    for a, s, m, st in summary:
        if st == "error":
            print(f"  ERROR {m} {a} {s}")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
