"""Analytic FLOPs model for the roofline compute term.

Why not XLA's cost_analysis?  It counts `while`-loop bodies ONCE (verified in
EXPERIMENTS.md §Methodology), so every lax.scan — layers, flash-attention
tiles, SSM chunks, ghost-norm tiles — is undercounted by its trip count.

Instead we build the exact matmul inventory from the DP tap metadata (every
parameterized matmul in the model registers a tap with its true (stack,
groups, B, T, D, p) — including MoE capacity and scan depth) and add the
parameter-free terms (attention scores, SSM scans, softmax/CE) per family.

Cost conventions: matmul (m,k)x(k,n) = 2mkn flops; backward = 2x forward;
remat adds one forward recompute per backward pass; the DP second backward
adds another backward; per-sample norms cost their branch's einsum.
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.decision import decide
from repro.core.taps import TapMeta


def matmul_fwd_flops(meta: dict[str, TapMeta]) -> float:
    total = 0.0
    for m in meta.values():
        if m.kind != "matmul":
            continue
        reps = m.n_stack * max(m.n_groups, 1)
        total += 2.0 * reps * m.batch_size * m.T * m.D * m.p
    return total


def norm_flops(meta: dict[str, TapMeta], mode: str, decision_by: str = "space") -> float:
    """Per-sample gradient-norm flops (the clipping module, Table 1)."""
    total = 0.0
    for m in meta.values():
        reps = m.n_stack * max(m.n_groups, 1)
        b = m.batch_size
        if m.kind == "matmul":
            branch = decide(m, mode=mode if not mode.endswith("_taps") else mode[:-5],
                            by=decision_by)
            if branch == "ghost":
                total += reps * b * (2.0 * m.T * m.T * (m.D + m.p))
            else:
                total += reps * b * (2.0 * m.T * m.D * m.p)
        elif m.kind == "embedding":
            total += reps * b * (2.0 * m.T * m.T * (1 + m.p))
        else:  # scale/bias/dw_conv: one elementwise pass
            total += reps * b * 2.0 * m.T * m.p
    return total


def attention_extra_flops(cfg: ArchConfig, shape: ShapeConfig, *, n_attn_layers: int) -> float:
    """Scores + AV matmuls (the XLA path computes the full causal square)."""
    b = shape.global_batch
    hd = cfg.resolved_head_dim
    h = cfg.n_heads
    if shape.kind == "decode":
        s_kv = min(shape.seq_len, cfg.window or shape.seq_len)
        per_layer = 2.0 * b * 1 * s_kv * h * hd * 2
    else:
        s = shape.seq_len
        s_kv = min(s, cfg.window or s)
        per_layer = 2.0 * b * s * s_kv * h * hd * 2
    return n_attn_layers * per_layer


def ssm_extra_flops(cfg: ArchConfig, shape: ShapeConfig, *, n_ssm_layers: int,
                    d_inner: int, d_state: int, head_dim: int) -> float:
    b = shape.global_batch
    heads = d_inner // head_dim
    if shape.kind == "decode":
        # state update + readout: 2*B*H*dk*dv * 2
        return n_ssm_layers * 4.0 * b * heads * d_state * head_dim
    s = shape.seq_len
    chunk = cfg.ssm_chunk
    intra = 2.0 * b * s * chunk * heads * (d_state + head_dim)
    inter = 4.0 * b * s * heads * d_state * head_dim
    return n_ssm_layers * (intra + inter)


def _layer_census(cfg: ArchConfig) -> dict[str, int]:
    if cfg.block_pattern:
        period = cfg.block_pattern
        n_periods = cfg.n_layers // len(period)
        return {
            "attn": n_periods * sum(1 for k in period if k == "attn"),
            "mamba": n_periods * sum(1 for k in period if k == "mamba"),
            "mlstm": n_periods * sum(1 for k in period if k == "mlstm"),
            "slstm": n_periods * sum(1 for k in period if k == "slstm"),
        }
    return {"attn": cfg.n_layers + cfg.encoder_layers
            + (cfg.n_layers if cfg.family == "audio" else 0),  # cross-attn
            "mamba": 0, "mlstm": 0, "slstm": 0}


def extra_fwd_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    census = _layer_census(cfg)
    total = 0.0
    if census["attn"]:
        total += attention_extra_flops(cfg, shape, n_attn_layers=census["attn"])
    if census["mamba"]:
        total += ssm_extra_flops(
            cfg, shape, n_ssm_layers=census["mamba"],
            d_inner=2 * cfg.d_model, d_state=cfg.ssm_d_state, head_dim=cfg.ssm_head_dim,
        )
    if census["mlstm"]:
        total += ssm_extra_flops(
            cfg, shape, n_ssm_layers=census["mlstm"],
            d_inner=2 * cfg.d_model, d_state=2 * cfg.d_model // cfg.n_heads,
            head_dim=2 * cfg.d_model // cfg.n_heads,
        )
    if census["slstm"]:
        b = shape.global_batch
        s = 1 if shape.kind == "decode" else shape.seq_len
        total += census["slstm"] * 10.0 * b * s * cfg.d_model  # elementwise cell
    # CE / softmax over vocab
    b = shape.global_batch
    s = 1 if shape.kind == "decode" else shape.seq_len
    total += 3.0 * b * s * max(cfg.vocab, 1)
    return total


@dataclasses.dataclass(frozen=True)
class CellFlops:
    fwd: float
    total: float  # full step (train: fwd + backwards + norms [+ remat])
    norms: float

    def to_dict(self):
        return dataclasses.asdict(self)


def cell_flops(
    meta: dict[str, TapMeta], cfg: ArchConfig, shape: ShapeConfig, mode: str,
) -> CellFlops:
    fwd = matmul_fwd_flops(meta) + extra_fwd_flops(cfg, shape)
    if shape.kind != "train":
        return CellFlops(fwd=fwd, total=fwd, norms=0.0)
    norms = norm_flops(meta, mode) if mode not in ("non_private", "vmap") else 0.0
    remat = fwd if cfg.remat else 0.0
    if mode == "non_private":
        total = fwd + remat + 2.0 * fwd
    elif mode == "vmap":
        total = fwd + remat + 2.0 * fwd  # same flops; memory differs
    elif mode == "bk_mixed":
        # one backward; weighted grads replace the dW einsums (same cost)
        total = fwd + remat + 2.0 * fwd + norms
    else:
        # ghost family: bwd1 = dX chain (~fwd) + norms; bwd2 = full backward
        total = fwd + (remat + fwd + norms) + (remat + 2.0 * fwd)
    return CellFlops(fwd=fwd, total=total, norms=norms)


def serve_matmul_flops(model, cfg: ArchConfig, shape: ShapeConfig) -> float:
    """2 * tokens * active-matmul-params (embedding gathers excluded)."""
    import jax

    from repro.utils.tree import flatten_dict

    abstract = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    flat = flatten_dict(abstract)
    active = 0.0
    for path, leaf in flat.items():
        n = float(math.prod(leaf.shape))
        base = path.rsplit("/", 1)[0]
        if base.endswith("embed") or base.endswith("enc_pos") or base.endswith("pos_embed"):
            continue
        if cfg.moe_experts and ("moe/wg" in path or "moe/wu" in path or "moe/wo" in path):
            active += n * cfg.moe_top_k * cfg.capacity_factor / cfg.moe_experts
        else:
            active += n
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    return 2.0 * tokens * active
