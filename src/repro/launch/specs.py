"""Input specs per (architecture x shape): ShapeDtypeStruct stand-ins.

``input_specs`` returns abstract shapes for the dry-run (no allocation);
``materialize`` instantiates concrete arrays for smoke tests / real runs.
Modality frontends are STUBS per the assignment: whisper gets precomputed
frame embeddings, phi-3-vision gets precomputed CLIP patch embeddings.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig


def per_device_batch(shape: ShapeConfig, n_data_shards: int) -> int:
    assert shape.global_batch % n_data_shards == 0 or n_data_shards % shape.global_batch == 0
    return max(1, shape.global_batch // n_data_shards)


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig, batch: int) -> dict:
    s = shape.seq_len
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    i32 = jnp.int32
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
        )
        specs["tokens"] = jax.ShapeDtypeStruct((batch, s), i32)
        specs["labels"] = jax.ShapeDtypeStruct((batch, s), i32)
    elif cfg.family == "vlm":
        text = s - cfg.prefix_tokens
        specs["prefix"] = jax.ShapeDtypeStruct(
            (batch, cfg.prefix_tokens, cfg.prefix_dim), jnp.dtype(cfg.dtype)
        )
        specs["tokens"] = jax.ShapeDtypeStruct((batch, text), i32)
        specs["labels"] = jax.ShapeDtypeStruct((batch, text), i32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((batch, s), i32)
        specs["labels"] = jax.ShapeDtypeStruct((batch, s), i32)
    specs["mask"] = jax.ShapeDtypeStruct((batch,), jnp.float32)
    return specs


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig, batch: int) -> dict:
    s = shape.seq_len
    i32 = jnp.int32
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
        )
        specs["tokens"] = jax.ShapeDtypeStruct((batch, s), i32)
    elif cfg.family == "vlm":
        specs["prefix"] = jax.ShapeDtypeStruct(
            (batch, cfg.prefix_tokens, cfg.prefix_dim), jnp.dtype(cfg.dtype)
        )
        specs["tokens"] = jax.ShapeDtypeStruct((batch, s - cfg.prefix_tokens), i32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((batch, s), i32)
    return specs


def decode_token_specs(batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, 1), jnp.int32)


def serve_state_specs(model, cfg: ArchConfig, shape: ShapeConfig, batch: int):
    """Abstract serve state (KV caches / SSM states) for shape ``shape``."""
    return jax.eval_shape(lambda: model.init_state(batch, shape.seq_len))


def materialize(specs: Any, key: jax.Array, vocab: int = 128) -> Any:
    """Concrete batch from specs (tokens uniform in vocab, floats ~N(0,1))."""
    leaves, treedef = jax.tree_util.tree_flatten(specs)
    keys = jax.random.split(key, len(leaves))
    out = []
    for sp, k in zip(leaves, keys):
        if jnp.issubdtype(sp.dtype, jnp.integer):
            out.append(jax.random.randint(k, sp.shape, 0, max(vocab, 2), dtype=sp.dtype))
        else:
            if len(sp.shape) == 1:  # sample mask
                out.append(jnp.ones(sp.shape, sp.dtype))
            else:
                out.append(jax.random.normal(k, sp.shape, jnp.float32).astype(sp.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
