"""Step builders: DP train step, prefill step, decode step.

These are the functions the launcher jit/pjit-lowers.  The train step is the
paper's full mechanism: mixed-ghost per-sample clipping + Gaussian noise +
(DP-)optimizer update, in one compiled program.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.clipping import ClipConfig, dp_value_and_clipped_grad
from repro.core.noise import add_dp_noise
from repro.optim.optimizers import Optimizer, apply_updates


@dataclasses.dataclass(frozen=True)
class DPTrainConfig:
    clipping_mode: str = "mixed_ghost"
    clip_norm: float = 1.0
    clip_fn: str = "abadi"
    noise_multiplier: float = 1.0
    logical_batch: int = 256  # denominator for the privatized mean
    accumulation_steps: int = 1
    # measured-cost branch plan (repro.tuner.ClipPlan); threaded into the
    # clipping config so jitted steps pick the profiled branch per tap
    plan: Optional[Any] = None


def make_train_state(model, key: jax.Array, optimizer: Optimizer) -> dict:
    params = model.init(key)
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
        "rng": jax.random.PRNGKey(0),
    }


def abstract_train_state(model, optimizer: Optimizer) -> Any:
    return jax.eval_shape(
        lambda: make_train_state(model, jax.random.PRNGKey(0), optimizer)
    )


def make_train_step(
    model,
    optimizer: Optimizer,
    schedule: Callable,
    dp: DPTrainConfig,
) -> Callable:
    """Full DP step: clip (mixed ghost) -> noise -> optimizer update."""
    clip_cfg = ClipConfig(
        mode=dp.clipping_mode, clip_norm=dp.clip_norm, clip_fn=dp.clip_fn,
        plan=dp.plan,
    )
    grad_fn = dp_value_and_clipped_grad(model.loss_with_ctx, clip_cfg)

    def train_step(state: dict, batch: Any) -> tuple[dict, dict]:
        loss, grad_sum, aux = grad_fn(state["params"], batch)
        rng, noise_key = jax.random.split(state["rng"])
        if dp.clipping_mode == "non_private":
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grad_sum
            )
        else:
            std = dp.noise_multiplier * dp.clip_norm
            noisy = add_dp_noise(grad_sum, noise_key, std)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32) / dp.logical_batch, noisy
            )
        lr = schedule(state["step"])
        updates, opt_state = optimizer.update(
            grads, state["opt"], state["params"], state["step"], lr
        )
        params = apply_updates(state["params"], updates)
        new_state = {
            "params": params,
            "opt": opt_state,
            "step": state["step"] + 1,
            "rng": rng,
        }
        metrics = {
            "loss": loss,
            "lr": lr,
            "grad_norm_mean": jnp.mean(aux["per_sample_norms"]),
            "clip_frac": jnp.mean((aux["clip_factors"] < 1.0).astype(jnp.float32)),
        }
        return new_state, metrics

    return train_step


def make_clipped_microstep(model, dp: DPTrainConfig) -> Callable:
    """Gradient-accumulation half: returns (loss, clipped grad SUM, aux).

    The caller sums across microbatches and finalizes with
    ``make_noise_finalize`` — the paper's virtual_step pattern.
    """
    clip_cfg = ClipConfig(
        mode=dp.clipping_mode, clip_norm=dp.clip_norm, clip_fn=dp.clip_fn,
        plan=dp.plan,
    )
    return dp_value_and_clipped_grad(model.loss_with_ctx, clip_cfg)


def make_noise_finalize(optimizer: Optimizer, schedule: Callable, dp: DPTrainConfig):
    def finalize(state: dict, grad_sum: Any) -> dict:
        rng, noise_key = jax.random.split(state["rng"])
        if dp.clipping_mode == "non_private":
            # mirror make_train_step: no noise, no logical-batch division
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grad_sum
            )
        else:
            std = dp.noise_multiplier * dp.clip_norm
            noisy = add_dp_noise(grad_sum, noise_key, std)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32) / dp.logical_batch, noisy
            )
        lr = schedule(state["step"])
        updates, opt_state = optimizer.update(
            grads, state["opt"], state["params"], state["step"], lr
        )
        params = apply_updates(state["params"], updates)
        return {
            "params": params,
            "opt": opt_state,
            "step": state["step"] + 1,
            "rng": rng,
        }

    return finalize


def make_prefill_step(model) -> Callable:
    def prefill_step(params, batch, state):
        return model.prefill(params, batch, state)

    return prefill_step


def make_decode_step(model) -> Callable:
    def decode_step(params, tokens, state):
        logits, state = model.decode_step(params, tokens, state)
        next_tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tokens, logits, state

    return decode_step
