"""Step builders: DP train step, prefill step, decode step.

These are the functions the launcher jit/pjit-lowers.  The train step is the
paper's full mechanism: mixed-ghost per-sample clipping + Gaussian noise +
(DP-)optimizer update, in one compiled program.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.clipping import ClipConfig, _batch_mask, dp_value_and_clipped_grad
from repro.core.noise import add_dp_noise
from repro.optim.optimizers import Optimizer, apply_updates


@dataclasses.dataclass(frozen=True)
class DPTrainConfig:
    clipping_mode: str = "mixed_ghost"
    clip_norm: float = 1.0
    clip_fn: str = "abadi"
    noise_multiplier: float = 1.0
    logical_batch: int = 256  # denominator for the privatized mean
    accumulation_steps: int = 1
    # measured-cost branch plan (repro.tuner.ClipPlan); threaded into the
    # clipping config so jitted steps pick the profiled branch per tap
    plan: Optional[Any] = None
    # clipping policy (repro.policies.ClipPolicy); None builds the fixed
    # flat-R policy from (clip_norm, clip_fn).  Stateful policies carry
    # their pytree in state["policy"], updated once per logical batch.
    policy: Optional[Any] = None


def _policy_for(dp: DPTrainConfig):
    if dp.policy is not None:
        return dp.policy
    from repro.policies.fixed import FixedPolicy

    return FixedPolicy(clip_norm=dp.clip_norm, clip_fn=dp.clip_fn)


def make_train_state(
    model, key: jax.Array, optimizer: Optimizer, policy: Any = None
) -> dict:
    if policy is None:
        from repro.policies.fixed import FixedPolicy

        policy = FixedPolicy()
    params = model.init(key)
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
        "rng": jax.random.PRNGKey(0),
        # clipping-policy state (quantile R, per-layer thresholds, ...):
        # lives in the train state so it is checkpointed and restored with
        # everything else — adaptation survives preemption bit-identically
        "policy": policy.init_state(),
    }


def abstract_train_state(model, optimizer: Optimizer, policy: Any = None) -> Any:
    return jax.eval_shape(
        lambda: make_train_state(model, jax.random.PRNGKey(0), optimizer, policy)
    )


def make_train_step(
    model,
    optimizer: Optimizer,
    schedule: Callable,
    dp: DPTrainConfig,
) -> Callable:
    """Full DP step: clip (policy factors) -> noise -> optimizer update.

    The clip factors are computed under the *current* policy state; the
    noise std uses the same pre-update state (``policy.sensitivity``), and
    only then does the policy update run — so a quantile release never
    retroactively rescales the step that produced it.
    """
    policy = _policy_for(dp)
    # the RESOLVED policy goes into the clip config: the factor stage and
    # the noise/update below must share one object, not two equivalently-
    # constructed defaults that could drift apart
    clip_cfg = ClipConfig(
        mode=dp.clipping_mode, clip_norm=dp.clip_norm, clip_fn=dp.clip_fn,
        plan=dp.plan, policy=policy,
    )
    grad_fn = dp_value_and_clipped_grad(model.loss_with_ctx, clip_cfg)

    def train_step(state: dict, batch: Any) -> tuple[dict, dict]:
        # legacy states (pre-policy checkpoints, hand-built test states)
        # may lack the "policy" entry; run them on the init state, and only
        # write the updated state back when the slot exists
        pstate = state.get("policy", policy.init_state())
        loss, grad_sum, aux = grad_fn(state["params"], batch, pstate)
        rng, noise_key, policy_key = jax.random.split(state["rng"], 3)
        if dp.clipping_mode == "non_private":
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grad_sum
            )
            new_pstate = pstate
        else:
            std = dp.noise_multiplier * policy.sensitivity(pstate)
            noisy = add_dp_noise(grad_sum, noise_key, std)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32) / dp.logical_batch, noisy
            )
            new_pstate, _ = policy.update(
                pstate, aux["per_sample_norms"], key=policy_key,
                mask=_batch_mask(batch),
            )
        lr = schedule(state["step"])
        updates, opt_state = optimizer.update(
            grads, state["opt"], state["params"], state["step"], lr
        )
        params = apply_updates(state["params"], updates)
        new_state = {
            "params": params,
            "opt": opt_state,
            "step": state["step"] + 1,
            "rng": rng,
        }
        if "policy" in state:
            new_state["policy"] = new_pstate
        metrics = {
            "loss": loss,
            "lr": lr,
            "grad_norm_mean": jnp.mean(aux["per_sample_norms"]),
            "norm_mean": jnp.mean(aux["per_sample_norms"]),
            "norm_max": jnp.max(aux["per_sample_norms"]),
            "clip_frac": jnp.mean((aux["clip_factors"] < 1.0).astype(jnp.float32)),
            # the policy's current sensitivity bound (== R for fixed/quantile)
            "clip_norm": policy.sensitivity(pstate) * jnp.ones(()),
        }
        return new_state, metrics

    return train_step


def make_clipped_microstep(model, dp: DPTrainConfig) -> Callable:
    """Gradient-accumulation half: (params, batch, policy_state) ->
    (loss, clipped grad SUM, aux).

    The caller sums across microbatches — every microstep under the SAME
    policy state — and finalizes with ``make_noise_finalize`` (which also
    runs the one policy update per logical batch): the paper's virtual_step
    pattern, policy-aware.
    """
    clip_cfg = ClipConfig(
        mode=dp.clipping_mode, clip_norm=dp.clip_norm, clip_fn=dp.clip_fn,
        plan=dp.plan, policy=_policy_for(dp),
    )
    return dp_value_and_clipped_grad(model.loss_with_ctx, clip_cfg)


def make_accum_init(grad_spec: Any, n_samples: int) -> Callable:
    """Zero accumulator for one logical batch: () -> acc pytree.

    ``grads`` mirrors the clipped-grad pytree (``grad_spec`` from an
    ``eval_shape`` of the microstep); ``norms``/``mask`` are flat
    ``(n_samples,)`` buffers the microsteps scatter into, so the policy
    update sees the whole logical batch without a host-side concatenate.
    The accumulator is DONATED through every jitted microstep — one
    resident buffer set per logical batch, not a double-buffered copy per
    microstep.
    """

    def init() -> dict:
        return {
            "grads": jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), grad_spec
            ),
            "loss": jnp.zeros((), jnp.float32),
            "clip_hits": jnp.zeros((), jnp.float32),
            "norms": jnp.zeros((n_samples,), jnp.float32),
            "mask": jnp.zeros((n_samples,), jnp.float32),
        }

    return init


def make_accum_microstep(model, dp: DPTrainConfig) -> Callable:
    """Accumulating microstep: (params, policy_state, acc, batch, idx) -> acc.

    One jitted program per microbatch that clips AND folds into the
    logical-batch accumulator — grad sum, loss sum, clip-hit count, and the
    per-sample norms/Poisson mask scattered at microstep ``idx``'s offset.
    Keeping the fold inside the program (instead of a host-side
    ``tree_map(add)``) lets XLA schedule the per-tap bank reductions and
    the accumulator update together, and donating ``acc`` aliases the
    output into the input buffers: no double-buffered accumulator, no host
    sync per microstep.  ``idx`` is a traced scalar so every microstep runs
    the same compiled program.
    """
    grad_fn = make_clipped_microstep(model, dp)

    def micro(params, policy_state, acc: dict, batch: Any, idx) -> dict:
        loss, g, aux = grad_fn(params, batch, policy_state)
        norms = aux["per_sample_norms"].astype(jnp.float32)
        physical = norms.shape[0]
        m = _batch_mask(batch)
        mask = (
            jnp.ones((physical,), jnp.float32) if m is None
            else m.astype(jnp.float32)
        )
        off = (idx * physical,)
        return {
            "grads": jax.tree_util.tree_map(jnp.add, acc["grads"], g),
            "loss": acc["loss"] + loss.astype(jnp.float32),
            "clip_hits": acc["clip_hits"]
            + jnp.sum((aux["clip_factors"] < 1.0).astype(jnp.float32)),
            "norms": jax.lax.dynamic_update_slice(acc["norms"], norms, off),
            "mask": jax.lax.dynamic_update_slice(acc["mask"], mask, off),
        }

    return micro


def make_accum_finalize(
    optimizer: Optimizer, schedule: Callable, dp: DPTrainConfig
) -> Callable:
    """Logical-batch finalize over the donated accumulator:
    (state, acc) -> (state, metrics).

    Thin jit target around ``make_noise_finalize`` that also derives the
    step metrics on device — the host loop touches no per-microstep values,
    so a logging ``float()`` only ever syncs at a logical-batch boundary.
    """
    base = make_noise_finalize(optimizer, schedule, dp)
    n_samples = dp.logical_batch

    def finalize(state: dict, acc: dict) -> tuple[dict, dict]:
        metrics = {
            "loss": acc["loss"] / dp.accumulation_steps,
            "lr": schedule(state["step"]),
            "clip_frac": acc["clip_hits"] / n_samples,
            # whole-logical-batch norm summary from the scattered buffers —
            # computed on device, synced only at the logical-batch boundary
            "norm_mean": jnp.mean(acc["norms"]),
            "norm_max": jnp.max(acc["norms"]),
        }
        new_state = base(state, acc["grads"], acc["norms"], acc["mask"])
        return new_state, metrics

    return finalize


def make_noise_finalize(optimizer: Optimizer, schedule: Callable, dp: DPTrainConfig):
    """Noise + update once per logical batch.

    ``norms``/``mask`` are the concatenated per-sample norms (and Poisson
    mask) of the whole logical batch, collected across microsteps; they
    feed the policy update — one release per *noise addition*, so the
    quantile policy spends exactly once per accounted step.  Pass
    ``norms=None`` to skip the update (legacy callers, fixed policies).
    """
    policy = _policy_for(dp)

    def finalize(
        state: dict, grad_sum: Any, norms: Any = None, mask: Any = None
    ) -> dict:
        pstate = state.get("policy", policy.init_state())
        rng, noise_key, policy_key = jax.random.split(state["rng"], 3)
        if dp.clipping_mode == "non_private":
            # mirror make_train_step: no noise, no logical-batch division
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grad_sum
            )
            new_pstate = pstate
        else:
            std = dp.noise_multiplier * policy.sensitivity(pstate)
            noisy = add_dp_noise(grad_sum, noise_key, std)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32) / dp.logical_batch, noisy
            )
            if norms is not None:
                new_pstate, _ = policy.update(
                    pstate, norms, key=policy_key, mask=mask
                )
            else:
                new_pstate = pstate
        lr = schedule(state["step"])
        updates, opt_state = optimizer.update(
            grads, state["opt"], state["params"], state["step"], lr
        )
        params = apply_updates(state["params"], updates)
        new_state = {
            "params": params,
            "opt": opt_state,
            "step": state["step"] + 1,
            "rng": rng,
        }
        if "policy" in state:
            new_state["policy"] = new_pstate
        return new_state

    return finalize


def make_prefill_step(model) -> Callable:
    def prefill_step(params, batch, state):
        return model.prefill(params, batch, state)

    return prefill_step


def make_decode_step(model) -> Callable:
    def decode_step(params, tokens, state):
        logits, state = model.decode_step(params, tokens, state)
        next_tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tokens, logits, state

    return decode_step
