"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device initialization.  The dry-run entrypoint
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get placeholder devices; smoke tests and benches see 1 real device.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh() -> Mesh:
    """Whatever devices exist, as a (data, model=1) mesh (CPU smoke runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
