"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device initialization.  The dry-run entrypoint
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get placeholder devices; smoke tests and benches see 1 real device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.4.38; older versions have neither AxisType nor axis_types=
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever devices exist, as a (data, model=1) mesh (CPU smoke runs)."""
    n = len(jax.devices())
    return _make_mesh((n, 1), ("data", "model"))


def mesh_host_count(mesh: Mesh) -> int:
    """Number of distinct processes owning devices of this mesh.

    The denominator for per-host batch shares (parallel.sharding
    .per_host_batch): memory certificates — the tuner's max-batch search and
    the PR-2 mode re-certification — must be compiled at the slice of the
    batch one host actually materializes, not the global batch no single
    HBM ever holds.
    """
    return len({d.process_index for d in mesh.devices.flat})


def mesh_device_kinds(mesh: Mesh) -> tuple[str, ...]:
    """Sorted distinct ``platform:device_kind`` strings across the mesh.

    More than one entry means a heterogeneous fleet: the clipping autotuner
    then needs the mixed-kind consensus tie-break (repro.tuner.consensus)
    before any rank may trace a tuned branch map.
    """
    return tuple(sorted({
        f"{d.platform}:{d.device_kind}" for d in mesh.devices.flat
    }))
