"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device initialization.  The dry-run entrypoint
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get placeholder devices; smoke tests and benches see 1 real device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.4.38; older versions have neither AxisType nor axis_types=
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever devices exist, as a (data, model=1) mesh (CPU smoke runs)."""
    n = len(jax.devices())
    return _make_mesh((n, 1), ("data", "model"))
