"""Launch environment harness: allocator + XLA flags, applied BEFORE jax.

Step timings are only comparable when the process environment is pinned:
the allocator (tcmalloc vs glibc malloc changes host-staging cost), XLA's
logging noise, whether the backend preallocates its arena (the OOM-trial
ladder in ``repro.tuner.max_batch`` needs it OFF so a failed trial's blocks
actually return), and the step markers profilers key on.  This module is
the Python half of that contract — ``scripts/launch_env.sh`` is the shell
half (it additionally LD_PRELOADs tcmalloc, which a running interpreter
cannot) — and both set the same variables, defaulting but never clobbering:
anything the user already exported wins.

Import-order matters: XLA reads these at backend init, so call
``apply_env()`` before the first ``import jax`` (``benchmarks/run.py`` and
``repro.launch.dryrun`` do).  This module therefore must not import jax.
"""
from __future__ import annotations

import os
import platform
import sys
import warnings

# flag -> default value; merged into XLA_FLAGS only when the flag is absent
XLA_FLAG_DEFAULTS: dict[str, str] = {}

# TPU-only flags: the CPU/GPU wheels' env-flag parser does not know these
# DebugOptions and ABORTS the process on unknown flags (parse_flags_from_env
# check-fails), so they must never reach a non-TPU run
TPU_XLA_FLAG_DEFAULTS = {
    # 1 = mark steps at the outer while loop (0 marks program entry):
    # profilers and the step-time gate then bracket exactly one logical
    # step per marker (HomebrewNLP run.sh uses the same setting)
    "--xla_step_marker_location": "1",
}

ENV_DEFAULTS = {
    # let the OOM-trial retry ladder actually reclaim a failed trial's
    # arena instead of probing a preallocated (and thus opaque) pool
    "XLA_PYTHON_CLIENT_PREALLOCATE": "false",
    # silence libtf/XLA info chatter that skews wall-clock on slow ttys
    "TF_CPP_MIN_LOG_LEVEL": "4",
    # tcmalloc (when preloaded by scripts/launch_env.sh): only report
    # truly pathological single allocations, not every large weight buffer
    "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
}


def merge_xla_flags(flags: dict[str, str]) -> str:
    """Fold ``flags`` into ``XLA_FLAGS``, keeping any user-set values.

    A flag already present in the env (with any value) is left alone —
    the merge only appends missing ones.  Returns the merged string (also
    written back to ``os.environ``).
    """
    current = os.environ.get("XLA_FLAGS", "")
    parts = current.split()
    for flag, value in flags.items():
        if not any(p == flag or p.startswith(flag + "=") for p in parts):
            parts.append(f"{flag}={value}" if value is not None else flag)
    merged = " ".join(parts)
    os.environ["XLA_FLAGS"] = merged
    return merged


def apply_env(host_devices: int | None = None) -> None:
    """Pin the launch environment (idempotent; user-set values win).

    ``host_devices`` adds ``--xla_force_host_platform_device_count`` for
    multi-device dry runs on a single host.  Warns (but proceeds) when jax
    is already imported — the backend has then read its config and most of
    these settings are inert for this process.
    """
    if "jax" in sys.modules:
        warnings.warn(
            "repro.launch.env.apply_env() called after jax was imported; "
            "XLA flags set now will not reach the already-initialized "
            "backend", stacklevel=2,
        )
    for key, value in ENV_DEFAULTS.items():
        os.environ.setdefault(key, value)
    flags = dict(XLA_FLAG_DEFAULTS)
    if _backend() == "tpu":
        flags.update(TPU_XLA_FLAG_DEFAULTS)
    if host_devices is not None:
        flags["--xla_force_host_platform_device_count"] = str(host_devices)
    merge_xla_flags(flags)


def _backend() -> str:
    """The backend this process will target, without importing jax."""
    return os.environ.get("JAX_PLATFORMS", "cpu").split(",")[0] or "cpu"


def host_fingerprint() -> str:
    """Coarse same-host-class tag stamped into bench rows.

    ``machine-cpucount-backend`` (e.g. ``x86_64-8-cpu``): two rows with
    equal fingerprints were produced on comparable hosts, so the step-time
    gate may compare them; rows from different classes never pair.  The
    backend component comes from ``JAX_PLATFORMS`` when set (cheap, no jax
    import) and defaults to ``cpu`` — matching the tier-1 harness.
    """
    return f"{platform.machine()}-{os.cpu_count()}-{_backend()}"
