"""Paged KV cache: fixed-size pages + per-slot page tables.

The model's serving state (``model.init_state(1, view_len)``) stores each
attention layer's KV cache as a contiguous ``(stack, 1, L, K, hd)`` buffer.
Allocating that buffer per decode slot means every slot pays for ``max_len``
even when its request is 20 tokens long.  This module splits the sequence
axis of every KV leaf into fixed-size **pages** held in one shared pool:

    pool leaf   (stack, n_pages, page, K, hd)      one slab per kv leaf
    page table  (n_slots, max_pages) int32         shared by every layer/leaf

Slot ``s``'s logical row ``j`` lives at ``pool[:, table[s, j // page],
j % page]`` — long and short requests draw from the same pool, and a slot's
pages return to the free list the step its request finishes.

Page id 0 is the reserved **null page**: unused page-table entries point at
it, so scatters from idle slots land in a sacrificial slab and gathers from
it produce junk that the position mask (``pos == -1``) already excludes.

Everything device-side here is pure and jit-friendly (the engine traces
``gather_views`` / ``scatter_prefill`` / ``scatter_rows`` into its step
functions); the free-list bookkeeping (``PageAllocator``) is host-side
Python between steps.  On CPU/GPU the gather materializes the per-slot
views (correctness-first — the memory win is in the *persistent* pool);
a Pallas paged-attention kernel that consumes the page table directly in
VMEM is the TPU follow-on, same HBM argument as the psg contraction.

Cache-tree layout notes: a KV-cache node is any dict with exactly the
``make_kv_cache`` keys ``{k, v, pos, idx}``; its ``k``/``v`` leaves are
paged, while ``pos``/``idx`` (tiny) stay in the dense per-slot state.  Any
other cache entry (Mamba conv/ssm states, xLSTM registers) has no sequence
axis and stays dense too.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

KV_KEYS = frozenset({"k", "v", "pos", "idx"})

NULL_PAGE = 0


def is_kv_node(node: Any) -> bool:
    """True for an attention KV-cache dict (the ``make_kv_cache`` layout)."""
    return isinstance(node, dict) and set(node.keys()) == KV_KEYS


def kv_paths(tree: Any, _path: tuple = ()) -> list[tuple]:
    """Paths (key tuples) of every KV-cache node inside a nested-dict tree."""
    if is_kv_node(tree):
        return [_path]
    if isinstance(tree, dict):
        out = []
        for key in sorted(tree):
            out.extend(kv_paths(tree[key], _path + (key,)))
        return out
    return []


def get_at(tree: Any, path: tuple) -> Any:
    for key in path:
        tree = tree[key]
    return tree


def set_at(tree: Any, path: tuple, value: Any) -> Any:
    """Functional deep-set for nested dicts (returns a new tree)."""
    if not path:
        return value
    out = dict(tree)
    out[path[0]] = set_at(tree[path[0]], path[1:], value)
    return out


def strip_kv(state: Any) -> Any:
    """The dense remainder: KV nodes keep only their ``pos``/``idx`` leaves."""
    if is_kv_node(state):
        return {"pos": state["pos"], "idx": state["idx"]}
    if isinstance(state, dict):
        return {k: strip_kv(v) for k, v in state.items()}
    return state


def extract_kv(state: Any) -> dict[tuple, dict]:
    """{path: {"k": leaf, "v": leaf}} for every KV node in ``state``."""
    return {
        p: {"k": get_at(state, p)["k"], "v": get_at(state, p)["v"]}
        for p in kv_paths(state)
    }


def merge_kv(dense: Any, views: dict[tuple, dict]) -> Any:
    """Reassemble a full model state from the dense remainder + KV views."""
    out = dense
    for path, kv in views.items():
        node = dict(get_at(out, path))
        node["k"] = kv["k"]
        node["v"] = kv["v"]
        out = set_at(out, path, node)
    return out


# -- device-side paging ops (pure; traced into the engine step fns) --------
def make_pools(template_state: Any, n_pages: int, page: int) -> dict[tuple, dict]:
    """Zeroed page pools for every KV leaf of a per-slot template state.

    ``template_state`` is ``model.init_state(1, view_len)``; every KV leaf
    must be ``(stack, 1, view_len, K, hd)`` with one shared ``view_len``
    (asserted — ring-sized caches shorter than the view are rejected by the
    engine before we get here).
    """
    pools: dict[tuple, dict] = {}
    for path in kv_paths(template_state):
        node = get_at(template_state, path)
        pools[path] = {}
        for name in ("k", "v"):
            leaf = node[name]
            assert leaf.ndim == 5 and leaf.shape[1] == 1, (
                f"KV leaf at {path} has shape {leaf.shape}; expected "
                "(stack, 1, L, K, hd)"
            )
            assert leaf.shape[2] % page == 0, (
                f"view length {leaf.shape[2]} not a multiple of page {page}"
            )
            stack, _, _, kh, hd = leaf.shape
            pools[path][name] = jnp.zeros(
                (stack, n_pages, page, kh, hd), leaf.dtype
            )
    return pools


def gather_views(pools: dict[tuple, dict], table: jax.Array) -> dict[tuple, dict]:
    """Materialize per-slot contiguous KV views from the pools.

    ``table``: (n_slots, max_pages) int32.  Returns {path: {"k"/"v":
    (n_slots, stack, 1, max_pages*page, K, hd)}} — the stacked per-lane
    layout the vmapped decode step consumes.
    """
    n_slots, max_pages = table.shape

    def one(pool: jax.Array) -> jax.Array:
        stack, _, page, kh, hd = pool.shape
        g = jnp.take(pool, table, axis=1)  # (stack, n_slots, max_pages, page, K, hd)
        g = jnp.moveaxis(g, 1, 0)
        return g.reshape(n_slots, stack, 1, max_pages * page, kh, hd)

    return {
        path: {"k": one(kv["k"]), "v": one(kv["v"])}
        for path, kv in pools.items()
    }


def scatter_prefill(
    pools: dict[tuple, dict], kv_state: dict[tuple, dict], table_row: jax.Array
) -> dict[tuple, dict]:
    """Write one freshly prefilled slot's full KV view into its pages.

    ``kv_state``: {path: {"k"/"v": (stack, 1, L, K, hd)}} from the per-slot
    prefill; ``table_row``: (max_pages,) page ids (unused entries point at
    the null page — their writes are junk rows landing in the sacrificial
    slab).
    """
    out: dict[tuple, dict] = {}
    for path, kv in pools.items():
        out[path] = {}
        for name in ("k", "v"):
            pool = kv[name]
            stack, _, page, kh, hd = pool.shape
            leaf = kv_state[path][name]
            max_pages = leaf.shape[2] // page
            r = leaf.reshape(stack, max_pages, page, kh, hd)
            out[path][name] = pool.at[:, table_row].set(r)
    return out


def scatter_rows(
    pools: dict[tuple, dict],
    rows: dict[tuple, dict],
    page_ids: jax.Array,
    offsets: jax.Array,
) -> dict[tuple, dict]:
    """Write one decode step's newly produced KV row per slot.

    ``rows``: {path: {"k"/"v": (n_slots, stack, K, hd)}}; ``page_ids`` /
    ``offsets``: (n_slots,) target page and in-page row per slot.  Slots
    whose page-table row is null all write page 0 — sacrificial, masked on
    read.
    """
    out: dict[tuple, dict] = {}
    for path, kv in pools.items():
        out[path] = {}
        for name in ("k", "v"):
            pool = kv[name]
            r = jnp.moveaxis(rows[path][name], 0, 1)  # (stack, n_slots, K, hd)
            out[path][name] = pool.at[:, page_ids, offsets].set(r)
    return out


# -- host-side allocation ---------------------------------------------------
@dataclasses.dataclass
class PageAllocator:
    """Free-list page allocator (host side; page 0 is never handed out).

    Reservation-based: a request's worst case ``ceil((prompt + max_new) /
    page)`` pages are claimed at admission, so an admitted request can never
    hit mid-flight pool exhaustion (the SLO contract — admission is the only
    shedding point).  Pages free as one batch when the request finishes.
    """

    n_pages: int
    page: int

    def __post_init__(self) -> None:
        self._free = list(range(self.n_pages - 1, 0, -1))  # pop() -> low ids

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_needed(self, total_tokens: int) -> int:
        return max(1, math.ceil(total_tokens / self.page))

    def reserve(self, total_tokens: int) -> Optional[list[int]]:
        """Claim pages for ``total_tokens`` cache rows, or None if the pool
        cannot cover them right now (caller leaves the request queued)."""
        need = self.pages_needed(total_tokens)
        if need > len(self._free):
            return None
        return [self._free.pop() for _ in range(need)]

    def release(self, pages: list[int]) -> None:
        for p in pages:
            assert p != NULL_PAGE
            self._free.append(p)
