"""Slot scheduler: continuous-batching occupancy bookkeeping.

The decode batch is a fixed set of ``n_slots`` lanes; the scheduler owns
which request occupies which lane, each lane's page-table row, and the
per-lane progress counters.  The continuous-batching contract: the step a
request finishes, its slot and pages are freed and the *next* queued
request can prefill into that slot before the following decode step — no
wave barriers, the other lanes never stop decoding.

All state here is host-side (numpy page table, python counters); the
device-side state this mirrors lives in the engine's dense/pool pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.serving.kv_pages import NULL_PAGE, PageAllocator
from repro.serving.queue import Completion, Request


@dataclasses.dataclass
class Slot:
    """One decode lane's occupancy state."""

    index: int
    request: Optional[Request] = None
    completion: Optional[Completion] = None
    pages: list[int] = dataclasses.field(default_factory=list)
    # cache rows written so far (prompt + decode inputs); mirrors the
    # device-side per-lane cache idx
    length: int = 0
    generated: int = 0
    last_token: int = 0

    @property
    def active(self) -> bool:
        return self.request is not None

    @property
    def remaining(self) -> int:
        return 0 if self.request is None else self.request.max_new - self.generated


class SlotScheduler:
    """Assigns queued requests to freed slots and reserves their pages."""

    def __init__(self, n_slots: int, allocator: PageAllocator, max_pages: int):
        self.slots = [Slot(i) for i in range(n_slots)]
        self.allocator = allocator
        self.max_pages = max_pages
        # shared across every layer's KV leaves; row i belongs to slot i
        self.table = np.full((n_slots, max_pages), NULL_PAGE, np.int32)

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    def free_slots(self) -> list[Slot]:
        return [s for s in self.slots if not s.active]

    def active_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.active]

    def active_remaining(self) -> list[int]:
        return [s.remaining for s in self.active_slots()]

    def assign(self, req: Request, completion: Completion) -> Optional[Slot]:
        """Bind ``req`` to a free slot, reserving its worst-case pages.

        Returns the slot, or None when no slot is free or the pool cannot
        cover the request right now (it stays queued — admission already
        accepted it, so it waits rather than sheds).
        """
        free = self.free_slots()
        if not free:
            return None
        # worst-case cache rows: the prompt plus every decode input (the
        # final generated token is never written back)
        pages = self.allocator.reserve(req.prompt_len + max(req.max_new - 1, 0))
        if pages is None:
            return None
        slot = free[0]
        slot.request = req
        slot.completion = completion
        slot.pages = pages
        slot.length = req.prompt_len
        slot.generated = 0
        row = np.full((self.max_pages,), NULL_PAGE, np.int32)
        row[: len(pages)] = pages
        self.table[slot.index] = row
        return slot

    def release(self, slot: Slot) -> None:
        """Recycle a finished slot: pages back to the pool, row nulled so
        the lane's idle decode writes land in the sacrificial page."""
        self.allocator.release(slot.pages)
        self.table[slot.index] = NULL_PAGE
        slot.request = None
        slot.completion = None
        slot.pages = []
        slot.length = 0
        slot.generated = 0
        slot.last_token = 0
