"""repro.serving — continuous-batching decode service.

Layer map:

- ``engine``     — ``Engine``: submit/step/drain orchestrator, jitted
  batched decode (vmap over B=1 lanes) + per-slot prefill install.
- ``scheduler``  — ``SlotScheduler``: lane occupancy, per-slot page tables,
  next-step slot recycling.
- ``queue``      — ``RequestQueue`` + ``LatencyModel``: SLO-aware admission
  (shed when projected TTFT blows the deadline).
- ``kv_pages``   — paged KV pool: fixed-size pages, shared page table,
  gather/scatter ops traced into the engine's step functions.
- ``reference``  — ``sequential_decode``: the bit-exactness oracle.
"""
from repro.serving.engine import Engine, aggregate_metrics
from repro.serving.kv_pages import PageAllocator
from repro.serving.queue import Completion, LatencyModel, Request, RequestQueue
from repro.serving.reference import sequential_decode
from repro.serving.scheduler import SlotScheduler

__all__ = [
    "Engine",
    "aggregate_metrics",
    "PageAllocator",
    "Completion",
    "LatencyModel",
    "Request",
    "RequestQueue",
    "sequential_decode",
    "SlotScheduler",
]
