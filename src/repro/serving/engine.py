"""Continuous-batching decode engine: ``submit() / step() / drain()``.

One ``Engine`` owns a fixed decode batch of ``n_slots`` lanes over a shared
paged KV pool.  Each ``step()``:

1. **Admit** — while a slot is free and the queue has work, the newcomer is
   prefilled (its own jitted call, B=1 — prefill/decode disaggregation) and
   its KV view scattered into freshly reserved pages; its first token comes
   out of the prefill logits.  A slot freed by an EOS in the *previous*
   step is refilled here, before the next decode — no wave barrier.
2. **Decode** — one batched decode step for every lane at once: gather the
   per-slot KV views from the page pool, run the model's decode step vmapped
   over lanes, scatter each lane's newly written KV row back to its page.

The decode step is ``jax.vmap`` of the **B=1** step over lanes, not a
jointly batched B=n call — deliberately: per-lane semantics (MoE expert
capacity, per-slot RoPE positions, per-slot cache fill) are then *exactly*
the sequential one-request-at-a-time semantics, which is what makes greedy
outputs bit-identical to sequential decode (tested) while the lanes still
share every weight fetch.

Latency metrics per request (TTFT, per-token, end-to-end) feed the SLO
admission model in ``repro.serving.queue``; aggregate percentiles come from
``aggregate_metrics`` (the decode benchmark's rows).
"""
from __future__ import annotations

import math
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import make_decode_step
from repro.obs.events import emit_metrics, metrics_active
from repro.serving.kv_pages import (
    PageAllocator,
    extract_kv,
    gather_views,
    kv_paths,
    make_pools,
    merge_kv,
    scatter_prefill,
    scatter_rows,
    strip_kv,
)
from repro.serving.queue import Completion, LatencyModel, Request, RequestQueue
from repro.serving.scheduler import SlotScheduler
from repro.utils.logging import get_logger

log = get_logger("serving")


def _make_batched_decode(model, page: int) -> Callable:
    """(params, toks, dense, pools, table) -> (toks', dense', pools').

    ``toks`` (n_slots, 1, 1) are each lane's last emitted token; ``dense``
    is the slot-stacked non-KV state; the KV views are gathered from the
    pools, the vmapped B=1 decode runs, and only each lane's newly written
    row goes back to its page (idle lanes write the sacrificial null page).
    """
    decode = make_decode_step(model)

    def step(params, toks, dense, pools, table):
        write_pos = dense["pos"]  # (n_slots,) cache rows about to be written
        views = gather_views(pools, table)
        state = dict(dense)
        state["cache"] = merge_kv(dense["cache"], views)
        tok, _, new_state = jax.vmap(decode, in_axes=(None, 0, 0))(
            params, toks, state
        )
        new_dense = strip_kv(new_state)

        def take_row(leaf):  # (ns, stack, 1, L, K, hd) -> (ns, stack, K, hd)
            def one(lf, p):
                return jax.lax.dynamic_slice_in_dim(lf, p, 1, axis=2)[:, 0, 0]

            return jax.vmap(one)(leaf, write_pos)

        rows = {
            path: {name: take_row(kv[name]) for name in ("k", "v")}
            for path, kv in extract_kv(new_state["cache"]).items()
        }
        page_slot = jnp.clip(write_pos // page, 0, table.shape[1] - 1)
        page_ids = jnp.take_along_axis(table, page_slot[:, None], axis=1)[:, 0]
        new_pools = scatter_rows(pools, rows, page_ids, write_pos % page)
        return tok, new_dense, new_pools

    return step


def _install(dense, pools, pstate, table_row, slot):
    """Write one freshly prefilled per-slot state into lane ``slot``."""
    new_dense = jax.tree_util.tree_map(
        lambda d, s: d.at[slot].set(s), dense, strip_kv(pstate)
    )
    new_pools = scatter_prefill(pools, extract_kv(pstate["cache"]), table_row)
    return new_dense, new_pools


class Engine:
    """Continuous-batching decode service for one (model, params) pair."""

    def __init__(
        self,
        model: Any,
        params: Any,
        *,
        n_slots: int = 4,
        page_size: int = 16,
        max_len: int = 128,
        pool_pages: Optional[int] = None,
        eos_id: Optional[int] = None,
        queue: Optional[RequestQueue] = None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        cfg = model.cfg
        if cfg.family == "audio" or cfg.prefix_tokens:
            raise NotImplementedError(
                "serving engine covers token-prompt decoder LMs; encoder "
                "frontends (audio/vlm prefixes) are a follow-on"
            )
        self.model = model
        self.params = params
        self.eos_id = eos_id
        self.clock = clock
        self.page = page_size
        self.max_pages = math.ceil(max_len / page_size)
        self.view_len = self.max_pages * page_size
        if cfg.window is not None and cfg.window < self.view_len:
            raise ValueError(
                f"view length {self.view_len} exceeds the sliding window "
                f"{cfg.window}: ring-sized KV caches are not pageable yet "
                "(cap max_len at the window)"
            )
        # default pool: full provisioning (every slot can hold view_len).
        # The paging win is handing the engine *less* than that when the
        # offered mix is mostly short requests.
        if pool_pages is None:
            pool_pages = n_slots * self.max_pages + 1
        self.queue = queue or RequestQueue()
        self.latency: LatencyModel = self.queue.model
        self.scheduler = SlotScheduler(
            n_slots, PageAllocator(pool_pages, page_size), self.max_pages
        )

        # per-slot template state; also the fresh state every prefill starts
        # from (immutable arrays — reused, never mutated)
        self._template = model.init_state(1, self.view_len)
        if not kv_paths(self._template["cache"]):
            # pure-SSM stacks have no KV leaves; the pool machinery is a
            # no-op but the slot-stacked dense state still recycles lanes
            log.info("no KV-cache leaves found (SSM-only stack); paging idle")
        self.pools = make_pools(self._template["cache"], pool_pages, page_size)
        self.dense = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (n_slots,) + x.shape),
            strip_kv(self._template),
        )

        self._prefill = jax.jit(model.prefill)  # compiles per prompt length
        self._decode = jax.jit(_make_batched_decode(model, page_size))
        self._install = jax.jit(_install)

        self.completions: dict[int, Completion] = {}
        self._rid = 0
        self.steps = 0

    # -- API ----------------------------------------------------------------
    def submit(
        self,
        tokens: list[int],
        *,
        max_new: int = 16,
        slo_ttft_ms: Optional[float] = None,
        rid: Optional[int] = None,
    ) -> tuple[int, bool]:
        """Queue one request. Returns (rid, admitted); a shed request gets a
        ``Completion`` with ``finish="shed"`` and no tokens."""
        if rid is None:
            rid = self._rid
        self._rid = max(self._rid, rid) + 1
        if not tokens:
            raise ValueError("empty prompt")
        if len(tokens) + max(max_new - 1, 0) > self.view_len:
            raise ValueError(
                f"prompt {len(tokens)} + max_new {max_new} exceeds the "
                f"engine view length {self.view_len}"
            )
        req = Request(rid=rid, tokens=list(tokens), max_new=max_new,
                      slo_ttft_ms=slo_ttft_ms)
        admitted = self.queue.offer(
            req,
            free_slots=len(self.scheduler.free_slots()),
            active_remaining=self.scheduler.active_remaining(),
        )
        if not admitted:
            self.completions[rid] = Completion(
                rid=rid, prompt_len=req.prompt_len, tokens=[], finish="shed",
                submit_t=self.clock(),
            )
            return rid, False
        self._submit_times = getattr(self, "_submit_times", {})
        self._submit_times[rid] = self.clock()
        return rid, True

    def step(self) -> list[tuple[int, int]]:
        """Admit newcomers into free slots, then run one decode step.

        Returns the (rid, token) pairs emitted this step (prefill first
        tokens + decode tokens), in slot order.
        """
        emitted: list[tuple[int, int]] = []
        # 1. slot recycling: fill every free slot from the queue *now*, so a
        # request finishing at step t has its slot re-prefilled before the
        # step-t+1 decode
        while self.queue.peek() is not None:
            req = self.queue.peek()
            comp = Completion(
                rid=req.rid, prompt_len=req.prompt_len, tokens=[],
                finish="length",
                submit_t=self._submit_times.get(req.rid, self.clock()),
            )
            slot = self.scheduler.assign(req, comp)
            if slot is None:
                break  # no free slot / pool can't cover it yet — stays queued
            self.queue.pop()
            emitted.extend(self._admit(slot))

        # 2. one decode step for every lane (idle lanes compute masked junk)
        if self.scheduler.active_slots():
            emitted.extend(self._decode_once())
        self.steps += 1
        if metrics_active():
            emit_metrics(
                dict(
                    kind="serving_step",
                    active_slots=len(self.scheduler.active_slots()),
                    free_slots=len(self.scheduler.free_slots()),
                    emitted=len(emitted),
                    **self.queue.stats(
                        free_slots=len(self.scheduler.free_slots()),
                        active_remaining=self.scheduler.active_remaining(),
                    ),
                ),
                step=self.steps,
            )
        return emitted

    def drain(self, max_steps: Optional[int] = None) -> dict[int, Completion]:
        """Step until the queue and every slot are empty; return completions."""
        n = 0
        while len(self.queue) or self.scheduler.active_slots():
            self.step()
            n += 1
            if max_steps is not None and n >= max_steps:
                raise RuntimeError(f"drain exceeded {max_steps} steps")
        return dict(self.completions)

    # -- internals ----------------------------------------------------------
    def _admit(self, slot) -> list[tuple[int, int]]:
        req = slot.request
        t0 = self.clock()
        toks = jnp.asarray(req.tokens, jnp.int32)[None]
        logits, pstate = self._prefill(self.params, {"tokens": toks},
                                       self._template)
        tok0 = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        self.dense, self.pools = self._install(
            self.dense, self.pools, pstate,
            jnp.asarray(self.scheduler.table[slot.index]), slot.index,
        )
        first = int(jax.block_until_ready(tok0)[0, 0])
        now = self.clock()
        self.latency.observe_prefill(req.prompt_len, now - t0)

        comp = slot.completion
        comp.first_token_t = now
        comp.tokens.append(first)
        comp.token_times.append(now)
        slot.last_token = first
        slot.generated = 1
        self._finish_if_done(slot, first, now)
        return [(req.rid, first)]

    def _decode_once(self) -> list[tuple[int, int]]:
        sched = self.scheduler
        t0 = self.clock()
        toks = jnp.asarray(
            [[[s.last_token]] for s in sched.slots], jnp.int32
        )
        tok, self.dense, self.pools = self._decode(
            self.params, toks, self.dense, self.pools,
            jnp.asarray(sched.table),
        )
        host = np.asarray(jax.block_until_ready(tok))[:, 0, 0]
        now = self.clock()
        self.latency.observe_step(now - t0)

        emitted = []
        for slot in sched.active_slots():
            t = int(host[slot.index])
            slot.length += 1  # the decode wrote last_token's KV row
            slot.generated += 1
            slot.last_token = t
            comp = slot.completion
            comp.tokens.append(t)
            comp.token_times.append(now)
            emitted.append((slot.request.rid, t))
            self._finish_if_done(slot, t, now)
        return emitted

    def _finish_if_done(self, slot, token: int, now: float) -> None:
        req = slot.request
        comp = slot.completion
        done_eos = self.eos_id is not None and token == self.eos_id
        done_len = slot.generated >= req.max_new
        if not (done_eos or done_len):
            return
        # post-EOS tokens are never generated, never counted: the slot frees
        # here and the next queued request takes the lane
        comp.finish = "eos" if done_eos else "length"
        comp.end_t = now
        self.completions[req.rid] = comp
        self.scheduler.release(slot)


def _percentile(xs: list[float], q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    i = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
    return s[i]


def aggregate_metrics(completions: dict[int, Completion]) -> dict[str, float]:
    """Fold per-request completions into the benchmark's summary row.

    Token counts include only emitted tokens (generation stops at EOS, so
    padding a finished request to ``max_new`` can never inflate tok/s).
    """
    done = [c for c in completions.values() if c.finish in ("eos", "length")]
    shed = [c for c in completions.values() if c.finish == "shed"]
    ttfts = [c.ttft_s for c in done if c.ttft_s is not None]
    per_tok = [d for c in done for d in c.per_token_s]
    n_tokens = sum(len(c.tokens) for c in done)
    t_start = min((c.submit_t for c in done), default=0.0)
    t_end = max((c.end_t for c in done if c.end_t), default=t_start)
    elapsed = max(t_end - t_start, 1e-9)
    return {
        "requests": float(len(done)),
        "shed": float(len(shed)),
        "tokens": float(n_tokens),
        "tok_per_s": n_tokens / elapsed,
        "ttft_p50_ms": _percentile(ttfts, 0.50) * 1e3,
        "ttft_p95_ms": _percentile(ttfts, 0.95) * 1e3,
        "per_token_p50_ms": _percentile(per_tok, 0.50) * 1e3,
        "per_token_p95_ms": _percentile(per_tok, 0.95) * 1e3,
    }
