"""Request queue with SLO-aware admission for the decode engine.

A ``Request`` carries its prompt, a generation budget (``max_new``), and an
optional time-to-first-token SLO.  Admission happens once, at ``submit``:
the engine projects the request's TTFT from its measured latency model
(``LatencyModel`` — EMAs of prefill and decode-step cost observed on this
host) and the current backlog; a request whose projection blows its SLO is
**shed immediately** instead of rotting in the queue past its deadline.
Admitted requests are never dropped — page reservation at slot-assignment
time guarantees an admitted request can run to completion.

The projection model is deliberately simple and deterministic (tests drive
it with injected observations):

    wait  = 0                                  if a slot is free for us
          = steps_until_a_slot_frees * step_s  otherwise (k-th smallest
            remaining budget among active slots, k = our queue position)
    TTFT ~= wait + prompt_len * prefill_s_per_token

Cold start (nothing observed yet) projects 0 and admits — the model only
starts shedding once it has real measurements to shed on.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

from repro.obs.events import emit_event


@dataclasses.dataclass
class Request:
    """One decode request. ``tokens`` is the prompt (token ids)."""

    rid: int
    tokens: list[int]
    max_new: int = 16
    slo_ttft_ms: Optional[float] = None  # None = no deadline, never shed

    @property
    def prompt_len(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass
class Completion:
    """Per-request outcome + latency metrics (seconds, engine clock)."""

    rid: int
    prompt_len: int
    tokens: list[int]  # generated ids, truncated at (and including) EOS
    finish: str  # "eos" | "length" | "shed"
    submit_t: float = 0.0
    first_token_t: Optional[float] = None
    end_t: Optional[float] = None
    token_times: list[float] = dataclasses.field(default_factory=list)

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def e2e_s(self) -> Optional[float]:
        if self.end_t is None:
            return None
        return self.end_t - self.submit_t

    @property
    def per_token_s(self) -> list[float]:
        """Inter-token latencies (decode steps; excludes the prefill token)."""
        ts = self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]


class LatencyModel:
    """EMAs of prefill cost (per prompt token) and decode-step cost."""

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self.prefill_s_per_token: Optional[float] = None
        self.step_s: Optional[float] = None

    def _ema(self, old: Optional[float], new: float) -> float:
        return new if old is None else (1 - self.alpha) * old + self.alpha * new

    def observe_prefill(self, n_tokens: int, seconds: float) -> None:
        self.prefill_s_per_token = self._ema(
            self.prefill_s_per_token, seconds / max(n_tokens, 1)
        )

    def observe_step(self, seconds: float) -> None:
        self.step_s = self._ema(self.step_s, seconds)

    def projected_ttft_s(
        self,
        prompt_len: int,
        queue_position: int,
        free_slots: int,
        active_remaining: list[int],
    ) -> float:
        """Projected TTFT for a request entering at ``queue_position``
        (0 = front) given current occupancy. 0.0 until observations exist."""
        prefill = (self.prefill_s_per_token or 0.0) * prompt_len
        if self.step_s is None:
            return prefill
        ahead = queue_position - free_slots
        if ahead < 0:
            return prefill  # a slot is free for us right now
        if not active_remaining:
            return prefill
        rem = sorted(active_remaining)
        steps = rem[min(ahead, len(rem) - 1)]
        return steps * self.step_s + prefill


class RequestQueue:
    """FIFO of admitted-but-not-yet-scheduled requests + shed decisions."""

    def __init__(self, model: Optional[LatencyModel] = None):
        self.model = model or LatencyModel()
        self._pending: deque[Request] = deque()
        self.shed: list[Request] = []

    def __len__(self) -> int:
        return len(self._pending)

    def offer(
        self, req: Request, free_slots: int, active_remaining: list[int]
    ) -> bool:
        """Admit or shed ``req``; True iff admitted (now queued)."""
        if req.slo_ttft_ms is not None:
            projected = self.model.projected_ttft_s(
                req.prompt_len, len(self._pending), free_slots, active_remaining
            )
            if projected * 1e3 > req.slo_ttft_ms:
                self.shed.append(req)
                emit_event(
                    "request_shed", rid=req.rid, prompt_len=req.prompt_len,
                    slo_ttft_ms=req.slo_ttft_ms,
                    projected_ttft_ms=projected * 1e3,
                    queue_depth=len(self._pending), free_slots=free_slots,
                )
                return False
        self._pending.append(req)
        return True

    def stats(
        self, free_slots: int = 0, active_remaining: Optional[list[int]] = None
    ) -> dict:
        """Snapshot of the admission state: depth, sheds, latency EMAs.

        ``free_slots``/``active_remaining`` (the engine's current occupancy)
        extend the snapshot with the projected TTFT a request arriving at
        the back of the queue would see — the number admission actually
        compares against SLOs.  All values are host floats; callers may
        JSON-serialize the dict as-is.
        """
        out = {
            "queue_depth": len(self._pending),
            "shed_total": len(self.shed),
            "prefill_s_per_token": self.model.prefill_s_per_token,
            "step_s": self.model.step_s,
        }
        if active_remaining is not None:
            out["projected_wait_s"] = self.model.projected_ttft_s(
                0, len(self._pending), free_slots, active_remaining
            )
        return out

    def peek(self) -> Optional[Request]:
        return self._pending[0] if self._pending else None

    def pop(self) -> Request:
        return self._pending.popleft()

    def requeue_front(self, req: Request) -> None:
        self._pending.appendleft(req)
