"""Sequential one-request-at-a-time greedy decode — the exactness oracle.

This is the semantics the continuous-batching engine must reproduce
bit-identically: each prompt gets a fresh dense cache of the same view
length, an exact-length prefill, then single-token greedy decode until EOS
or the budget runs out.  Tests and the decode benchmark compare
``Engine.drain()`` token streams against this.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.launch.steps import make_decode_step


def sequential_decode(
    model: Any,
    params: Any,
    prompts: list[list[int]],
    *,
    max_new: int = 16,
    view_len: int = 128,
    eos_id: Optional[int] = None,
) -> list[list[int]]:
    """Greedy-decode each prompt independently; returns generated ids
    (EOS included when hit, like the engine's completions)."""
    prefill = jax.jit(model.prefill)
    decode = jax.jit(make_decode_step(model))
    out: list[list[int]] = []
    for prompt in prompts:
        state = model.init_state(1, view_len)
        toks = jnp.asarray(prompt, jnp.int32)[None]
        logits, state = prefill(params, {"tokens": toks}, state)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        gen = [int(tok[0, 0])]
        while len(gen) < max_new and (eos_id is None or gen[-1] != eos_id):
            tok, _, state = decode(params, tok, state)
            gen.append(int(tok[0, 0]))
        out.append(gen)
    return out
