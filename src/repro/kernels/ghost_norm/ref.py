"""Pure-jnp oracles for per-sample gradient norms (paper Eq. 2.7).

These materialize the full (T, T) Grams / (D, p) gradients — correct but
memory-hungry; the chunked ops and the Pallas kernel are checked against them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ghost_norm_sq_ref(a: jax.Array, g: jax.Array) -> jax.Array:
    """vec(a a^T) . vec(g g^T) per row.  a: (N, T, D), g: (N, T, p) -> (N,)."""
    a = a.astype(jnp.float32)
    g = g.astype(jnp.float32)
    gram_a = jnp.einsum("ntd,nsd->nts", a, a)
    gram_g = jnp.einsum("ntp,nsp->nts", g, g)
    return jnp.einsum("nts,nts->n", gram_a, gram_g)


def instantiated_norm_sq_ref(a: jax.Array, g: jax.Array) -> jax.Array:
    """|| a^T g ||_F^2 per row (per-sample gradient instantiation)."""
    a = a.astype(jnp.float32)
    g = g.astype(jnp.float32)
    grads = jnp.einsum("ntd,ntp->ndp", a, g)
    return jnp.sum(grads * grads, axis=(1, 2))


def embedding_ghost_norm_sq_ref(ids: jax.Array, g: jax.Array) -> jax.Array:
    """Index-equality ghost norm: sum_{t,t'} [id_t == id_t'] (g_t . g_t').

    ids: (N, T) int, g: (N, T, p) -> (N,).  Equals the Frobenius norm of the
    per-sample embedding gradient (scatter-add of g rows by token id).
    """
    g = g.astype(jnp.float32)
    eq = (ids[:, :, None] == ids[:, None, :]).astype(jnp.float32)
    gram_g = jnp.einsum("ntp,nsp->nts", g, g)
    return jnp.einsum("nts,nts->n", eq, gram_g)
