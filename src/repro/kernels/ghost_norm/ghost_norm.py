"""Pallas TPU kernel: fused per-sample gradient ghost norm (paper Eq. 2.7).

Computes, per sample n:

    out[n] = sum_{t,t'} (a_t . a_t') * (g_t . g_t')

without ever materializing the (T, T) Gram matrices in HBM.  This is the
paper's hot spot re-thought for the TPU memory hierarchy: on GPU the authors
lean on cuBLAS batched GEMMs producing full B x T x T Grams in HBM; on TPU we
tile the (T, T) plane into (bt, bt) blocks, build *both* Gram tiles in VMEM
scratch with MXU matmuls chunked over the feature dims, fuse their
elementwise product + reduction in registers, and emit a single scalar
accumulation per sample.  HBM traffic drops from O(T^2) per sample to
O(T*(D+p)) — inputs are read once per tile row; Gram tiles never leave VMEM.

Grid: (N, nb_i, nb_j, nc), nc = feature chunks (max over the a and g widths).
The (i, j) upper triangle is skipped; off-diagonal tiles are weighted 2x
(Gram symmetry) — half the MXU work of the naive double loop.

VMEM budget per step: 4 operand tiles (bt x bf) + 2 scratch Grams
(bt x bt f32); defaults (bt=256, bf=512) ~3.5 MiB.

``embedding_ghost_norm_sq_pallas`` is the index-equality variant: the
activation Gram is replaced by an equality mask built in registers from two
(bt,) id tiles, so only the cotangent Gram needs MXU work and the (T, T)
plane still never reaches HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pad(x, axis, mult):
    p = (-x.shape[axis]) % mult
    if p == 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, p)
    return jnp.pad(x, w)


@functools.partial(jax.jit, static_argnames=("block_t", "block_f", "interpret"))
def ghost_norm_sq_pallas(
    a: jax.Array,  # (N, T, D)
    g: jax.Array,  # (N, T, p)
    *,
    block_t: int = 256,
    block_f: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Per-sample squared gradient norm: (N,) float32."""
    n, t, _ = a.shape
    a = _pad(_pad(a, 1, block_t), 2, block_f)
    g = _pad(_pad(g, 1, block_t), 2, block_f)
    nb = a.shape[1] // block_t
    ca = a.shape[2] // block_f
    cg = g.shape[2] // block_f
    nc = max(ca, cg)

    def row_i(ni, i, j, c):
        return (ni, i, jnp.minimum(c, ca - 1))

    def row_j(ni, i, j, c):
        return (ni, j, jnp.minimum(c, ca - 1))

    def grow_i(ni, i, j, c):
        return (ni, i, jnp.minimum(c, cg - 1))

    def grow_j(ni, i, j, c):
        return (ni, j, jnp.minimum(c, cg - 1))

    def kernel(ai_ref, aj_ref, gi_ref, gj_ref, o_ref, ga_acc, gg_acc):
        i = pl.program_id(1)
        j = pl.program_id(2)
        c = pl.program_id(3)
        live = j <= i  # upper triangle skipped (symmetry)

        @pl.when(jnp.logical_and(c == 0, live))
        def _init():
            ga_acc[...] = jnp.zeros_like(ga_acc)
            gg_acc[...] = jnp.zeros_like(gg_acc)

        @pl.when(jnp.logical_and(c < ca, live))
        def _acc_a():
            ga_acc[...] += jax.lax.dot_general(
                ai_ref[0], aj_ref[0], (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

        @pl.when(jnp.logical_and(c < cg, live))
        def _acc_g():
            gg_acc[...] += jax.lax.dot_general(
                gi_ref[0], gj_ref[0], (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

        @pl.when(jnp.logical_and(c == nc - 1, live))
        def _finalize():
            weight = jnp.where(i == j, 1.0, 2.0).astype(jnp.float32)
            contrib = weight * jnp.sum(ga_acc[...] * gg_acc[...])

            @pl.when(jnp.logical_and(i == 0, j == 0))
            def _first():
                o_ref[0] = contrib

            @pl.when(jnp.logical_or(i != 0, j != 0))
            def _rest():
                o_ref[0] += contrib

    return pl.pallas_call(
        kernel,
        grid=(n, nb, nb, nc),
        in_specs=[
            pl.BlockSpec((1, block_t, block_f), row_i),
            pl.BlockSpec((1, block_t, block_f), row_j),
            pl.BlockSpec((1, block_t, block_f), grow_i),
            pl.BlockSpec((1, block_t, block_f), grow_j),
        ],
        out_specs=pl.BlockSpec((1,), lambda ni, i, j, c: (ni,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_t, block_t), jnp.float32),
            pltpu.VMEM((block_t, block_t), jnp.float32),
        ],
        interpret=interpret,
    )(a, a, g, g)


@functools.partial(jax.jit, static_argnames=("block_t", "block_f", "interpret"))
def embedding_ghost_norm_sq_pallas(
    ids: jax.Array,  # (N, T) token ids (int, or fp32-cast ids < 2^24)
    g: jax.Array,  # (N, T, p)
    *,
    block_t: int = 256,
    block_f: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Index-equality ghost norm: out[n] = sum_{t,t'} [id_t == id_t'] (g_t . g_t').

    Same (T, T)-tile structure as ``ghost_norm_sq_pallas`` with the
    activation Gram replaced by an equality mask computed in registers from
    the id tiles.  The two id operands are padded with *different* sentinels
    (-1 / -2), so pad positions never match anything — real ids, the other
    pad, or each other — and correctness does not ride on ``g``'s zero
    padding.
    """
    n, t = ids.shape
    from repro.kernels.ghost_norm.ops import pad_ids_pair

    ids_i, ids_j = pad_ids_pair(ids, block_t)
    g = _pad(_pad(g, 1, block_t), 2, block_f)
    nb = g.shape[1] // block_t
    nc = g.shape[2] // block_f

    def kernel(idi_ref, idj_ref, gi_ref, gj_ref, o_ref, gg_acc):
        i = pl.program_id(1)
        j = pl.program_id(2)
        c = pl.program_id(3)
        live = j <= i  # upper triangle skipped (symmetry)

        @pl.when(jnp.logical_and(c == 0, live))
        def _init():
            gg_acc[...] = jnp.zeros_like(gg_acc)

        @pl.when(live)
        def _acc_g():
            gg_acc[...] += jax.lax.dot_general(
                gi_ref[0].astype(jnp.float32), gj_ref[0].astype(jnp.float32),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

        @pl.when(jnp.logical_and(c == nc - 1, live))
        def _finalize():
            eq = (
                idi_ref[...].reshape(block_t, 1)
                == idj_ref[...].reshape(1, block_t)
            ).astype(jnp.float32)
            weight = jnp.where(i == j, 1.0, 2.0).astype(jnp.float32)
            contrib = weight * jnp.sum(eq * gg_acc[...])

            @pl.when(jnp.logical_and(i == 0, j == 0))
            def _first():
                o_ref[0] = contrib

            @pl.when(jnp.logical_or(i != 0, j != 0))
            def _rest():
                o_ref[0] += contrib

    return pl.pallas_call(
        kernel,
        grid=(n, nb, nb, nc),
        in_specs=[
            pl.BlockSpec((1, block_t), lambda ni, i, j, c: (ni, i)),
            pl.BlockSpec((1, block_t), lambda ni, i, j, c: (ni, j)),
            pl.BlockSpec((1, block_t, block_f), lambda ni, i, j, c: (ni, i, c)),
            pl.BlockSpec((1, block_t, block_f), lambda ni, i, j, c: (ni, j, c)),
        ],
        out_specs=pl.BlockSpec((1,), lambda ni, i, j, c: (ni,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_t, block_t), jnp.float32),
        ],
        interpret=interpret,
    )(ids_i, ids_j, g, g)
