"""Chunked per-sample gradient-norm ops (jit-ready wrappers).

The ghost norm sum_{t,t'} (a_t . a_t')(g_t . g_t') is computed over (T x T)
*tiles*: a pair of block Grams is formed in registers/VMEM, their elementwise
product is reduced immediately, and the (T, T) matrices never exist in HBM.
Symmetry halves the work: total = sum_i w_ii + 2 sum_{i<j} w_ij.

The instantiate branch streams over fan-in blocks of the (D, p) per-sample
gradient the same way.

These are the portable XLA paths: they lower to plain ``lax.scan`` on every
backend and are what the multi-pod dry-run uses.  Whether the training hot
path runs them or the Pallas TPU kernels (``ghost_norm.py``) is decided by
``repro.kernels.dispatch`` — pallas on TPU by default, measured per tap by
the tuner, recorded in the ClipPlan — NOT by anything in this module.
Calling these functions directly always runs the XLA path.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

_DIRECT_T = 1024  # below this, a direct einsum beats the scan machinery


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pad_ids_pair(
    ids: jax.Array, block: int
) -> tuple[jax.Array, jax.Array]:
    """Pad the two id operands of an index-equality Gram to a block multiple.

    The left and right operands get *different* sentinel ids (-1 and -2), so
    a pad position can never match a real id (vocab ids are non-negative),
    the other operand's pad, or — on diagonal tiles — its own mirror.  This
    makes the equality mask exactly zero at every padded position without
    assuming anything about how the cotangent is padded.

    Returns ``(ids_i, ids_j)``; when ``T`` is already a multiple of
    ``block`` both are the input unchanged.
    """
    pad = (-ids.shape[1]) % block
    if pad == 0:
        return ids, ids
    widths = ((0, 0), (0, pad))
    return (
        jnp.pad(ids, widths, constant_values=-1),
        jnp.pad(ids, widths, constant_values=-2),
    )


def ghost_norm_sq(a: jax.Array, g: jax.Array, *, block: int = 512) -> jax.Array:
    """Ghost norm (Eq. 2.7), chunked XLA path. a: (N, T, D), g: (N, T, p) -> (N,) fp32.

    Inputs stay in their storage dtype; slices are upcast per tile — an
    upfront fp32 copy of both operands would stay live through the whole
    pair scan (9+ GB on qwen2-72b's lm_head tap).
    """
    n, t, _ = a.shape
    if t <= max(block, _DIRECT_T):
        af = a.astype(jnp.float32)
        gf = g.astype(jnp.float32)
        gram_a = jnp.einsum("ntd,nsd->nts", af, af)
        gram_g = jnp.einsum("ntp,nsp->nts", gf, gf)
        return jnp.einsum("nts,nts->n", gram_a, gram_g)

    a = _pad_axis(a, 1, block)
    g = _pad_axis(g, 1, block)
    nb = a.shape[1] // block
    ij = jnp.array([(i, j) for i in range(nb) for j in range(i + 1)], jnp.int32)
    wts = jnp.array([1.0 if i == j else 2.0 for i in range(nb) for j in range(i + 1)])

    def body(acc, pair):
        (i, j), w = pair
        a_i = lax.dynamic_slice_in_dim(a, i * block, block, 1).astype(jnp.float32)
        a_j = lax.dynamic_slice_in_dim(a, j * block, block, 1).astype(jnp.float32)
        g_i = lax.dynamic_slice_in_dim(g, i * block, block, 1).astype(jnp.float32)
        g_j = lax.dynamic_slice_in_dim(g, j * block, block, 1).astype(jnp.float32)
        gram_a = jnp.einsum("ntd,nsd->nts", a_i, a_j)
        gram_g = jnp.einsum("ntp,nsp->nts", g_i, g_j)
        return acc + w * jnp.einsum("nts,nts->n", gram_a, gram_g), None

    acc, _ = lax.scan(body, jnp.zeros((n,), jnp.float32), (ij, wts))
    return acc


def instantiated_norm_sq(a: jax.Array, g: jax.Array, *, block_d: int = 4096) -> jax.Array:
    """|| a^T g ||_F^2 per row, streaming over fan-in blocks.

    a: (N, T, D), g: (N, T, p) -> (N,) fp32.
    """
    n, t, d = a.shape
    if d <= block_d:
        grads = jnp.einsum("ntd,ntp->ndp", a.astype(jnp.float32), g.astype(jnp.float32))
        return jnp.sum(grads * grads, axis=(1, 2))
    a = _pad_axis(a, 2, block_d)
    g = g.astype(jnp.float32)
    nb = a.shape[2] // block_d

    def body(acc, i):
        a_i = lax.dynamic_slice_in_dim(a, i * block_d, block_d, 2).astype(jnp.float32)
        part = jnp.einsum("ntd,ntp->ndp", a_i, g)
        return acc + jnp.sum(part * part, axis=(1, 2)), None

    acc, _ = lax.scan(body, jnp.zeros((n,), jnp.float32), jnp.arange(nb))
    return acc


def embedding_ghost_norm_sq(ids: jax.Array, g: jax.Array, *, block: int = 1024) -> jax.Array:
    """Index-equality ghost norm, chunked XLA path. ids: (N, T), g: (N, T, p) -> (N,)."""
    n, t, _ = g.shape
    if t <= max(block, _DIRECT_T):
        gf = g.astype(jnp.float32)
        eq = (ids[:, :, None] == ids[:, None, :]).astype(jnp.float32)
        gram_g = jnp.einsum("ntp,nsp->nts", gf, gf)
        return jnp.einsum("nts,nts->n", eq, gram_g)

    # Two *different* sentinel ids per operand (pad_ids_pair): pad positions
    # match nothing — not real ids, not the other pad — so the equality mask
    # is exactly zero there and correctness does not depend on g's zero
    # padding (g is still zero-padded, but only as a don't-care).
    ids_i, ids_j = pad_ids_pair(ids, block)
    if ids_i.shape[1] != t:
        g = jnp.pad(g, ((0, 0), (0, ids_i.shape[1] - t), (0, 0)))
    nb = ids_i.shape[1] // block
    ij = jnp.array([(i, j) for i in range(nb) for j in range(i + 1)], jnp.int32)
    wts = jnp.array([1.0 if i == j else 2.0 for i in range(nb) for j in range(i + 1)])

    def body(acc, pair):
        (i, j), w = pair
        id_i = lax.dynamic_slice_in_dim(ids_i, i * block, block, 1)
        id_j = lax.dynamic_slice_in_dim(ids_j, j * block, block, 1)
        g_i = lax.dynamic_slice_in_dim(g, i * block, block, 1).astype(jnp.float32)
        g_j = lax.dynamic_slice_in_dim(g, j * block, block, 1).astype(jnp.float32)
        eq = (id_i[:, :, None] == id_j[:, None, :]).astype(jnp.float32)
        gram_g = jnp.einsum("ntp,nsp->nts", g_i, g_j)
        return acc + w * jnp.sum(eq * gram_g, axis=(1, 2)), None

    acc, _ = lax.scan(body, jnp.zeros((n,), jnp.float32), (ij, wts))
    return acc
