"""Pallas TPU kernel: blocked online-softmax attention (forward).

MXU-tiled FlashAttention-2 forward for the serving/prefill paths: the score
matrix lives only as (block_q, block_kv) VMEM tiles; running (m, l, acc)
statistics are VMEM scratch carried across the kv grid dimension.  Fully
masked tiles (causal future, outside the sliding window) are skipped with
``pl.when`` — the causal prefill does half the MXU work of the dense loop.

The training path uses the custom-VJP XLA implementation in ``ops.py``
(identical math, differentiable); ``ref.py`` is the oracle for both.

Layout: q (B, H, Sq, hd), k/v (B, H, Skv, hd) — GQA callers broadcast KV
heads (the wrapper in ops dispatches).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pad(x, axis, mult):
    p = (-x.shape[axis]) % mult
    if p == 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, p)
    return jnp.pad(x, w)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_kv", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, H, Skv, hd)
    v: jax.Array,  # (B, H, Skv, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, hd = q.shape
    skv = k.shape[2]
    scale = hd**-0.5
    in_dtype = q.dtype

    q = _pad(q, 2, block_q)
    k = _pad(k, 2, block_kv)
    v = _pad(v, 2, block_kv)
    sq_p, skv_p = q.shape[2], k.shape[2]
    nq, nkv = sq_p // block_q, skv_p // block_kv

    def q_index(bh, i, j):
        return (bh // h, bh % h, i, 0)

    def kv_index(bh, i, j):
        return (bh // h, bh % h, j, 0)

    def kernel(q_ref, k_ref, v_ref, o_ref, m_acc, l_acc, acc):
        i = pl.program_id(1)
        j = pl.program_id(2)

        qpos0 = q_offset + i * block_q
        kpos0 = j * block_kv
        # tile-level skip: fully masked tiles do no work
        live = jnp.asarray(True)
        if causal:
            live = jnp.logical_and(live, kpos0 <= qpos0 + block_q - 1)
        if window is not None:
            live = jnp.logical_and(
                live, (qpos0 - (kpos0 + block_kv - 1)) < window
            )

        @pl.when(j == 0)
        def _init():
            m_acc[...] = jnp.full_like(m_acc, NEG_INF)
            l_acc[...] = jnp.zeros_like(l_acc)
            acc[...] = jnp.zeros_like(acc)

        @pl.when(live)
        def _tile():
            qf = q_ref[0, 0].astype(jnp.float32)
            kf = k_ref[0, 0].astype(jnp.float32)
            s = jax.lax.dot_general(
                qf, kf, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            ) * scale  # (bq, bkv)
            qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            mask = kpos < skv  # padding
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                mask &= (qpos - kpos) < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m_acc[...], jnp.max(s, axis=1))
            p = jnp.exp(s - m_new[:, None])
            alpha = jnp.exp(m_acc[...] - m_new)
            l_acc[...] = l_acc[...] * alpha + jnp.sum(p, axis=1)
            acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
                p.astype(v_ref.dtype), v_ref[0, 0],
                (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
            )
            m_acc[...] = m_new

        @pl.when(j == nkv - 1)
        def _finalize():
            denom = jnp.maximum(l_acc[...], 1e-30)
            o_ref[0, 0] = (acc[...] / denom[:, None]).astype(o_ref.dtype)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), q_index),
            pl.BlockSpec((1, 1, block_kv, hd), kv_index),
            pl.BlockSpec((1, 1, block_kv, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), q_index),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, hd), in_dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]
