"""Blocked online-softmax attention with a custom VJP (FlashAttention-2 style).

This is the XLA path: a two-level ``lax.scan`` (outer: query blocks, inner: KV
blocks) that never materializes the (Sq, Skv) score matrix.  Forward saves only
(q, k, v, o, lse); backward recomputes probabilities blockwise.  The Pallas TPU
kernel in ``flash_attention.py`` implements the same tiling for the MXU; this
function is its lowering fallback and its semantics oracle is ``ref.py``.

Supports GQA (H query heads over K kv heads), causal masking, sliding windows
(Mixtral SWA), decode offsets, and partially-filled KV caches.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _block_mask(qi: jax.Array, kj: jax.Array, *, causal, window, kv_valid_len,
                require_nonneg=False):
    """(bq, bkv) boolean mask from absolute q positions qi and kv positions kj."""
    m = jnp.ones((qi.shape[0], kj.shape[0]), dtype=bool)
    qi = qi[:, None]
    kj = kj[None, :]
    if causal:
        m &= kj <= qi
    if window is not None:
        m &= (qi - kj) < window
    if kv_valid_len is not None:
        m &= kj < kv_valid_len
    if require_nonneg:
        m &= kj >= 0
    return m


def _pad_to(x: jax.Array, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9)
)
def _flash(q, k, v, causal, window, q_offset, block_q, block_kv, scale, kv_valid_is_none):
    # Precision boundary INSIDE the custom vjp: inputs/outputs stay in the
    # model dtype so attention cotangents (and their TP all-reduces) are
    # bf16; the softmax math runs fp32 internally.
    out, _ = _flash_fwd_impl(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        None, causal, window, q_offset, block_q, block_kv, scale,
    )
    return out.astype(q.dtype)


def _flash_fwd_impl(
    q, k, v, kv_valid_len, causal, window, q_offset, block_q, block_kv, scale,
    kv_positions=None,
):
    """q: (B, Sq, K, g, hd) f32; k/v: (B, Skv, K, hd) f32.

    ``kv_positions`` (Skv,) gives the absolute position of each cache slot
    (ring buffers store positions out of order; negative marks unwritten
    slots, which the causal mask then excludes).  Returns out and lse.
    """
    b, sq, kh, g, hd = q.shape
    skv = k.shape[1]
    nq = sq // block_q
    nkv = skv // block_kv

    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(skv) if kv_positions is None else kv_positions

    def q_block(carry, qb):
        q_i, qpos_i = qb  # (B, bq, K, g, hd), (bq,)

        def kv_block(acc, kb):
            o, m, l = acc
            k_j, v_j, kpos_j = kb
            s = jnp.einsum("bqkgd,bskd->bqkgs", q_i, k_j) * scale  # (B,bq,K,g,bkv)
            msk = _block_mask(
                qpos_i, kpos_j, causal=causal, window=window,
                kv_valid_len=None if kv_positions is not None else kv_valid_len,
                require_nonneg=kv_positions is not None,
            )
            s = jnp.where(msk[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            o = o * alpha[..., None] + jnp.einsum("bqkgs,bskd->bqkgd", p, v_j)
            return (o, m_new, l), None

        o0 = jnp.zeros((b, block_q, kh, g, hd), jnp.float32)
        m0 = jnp.full((b, block_q, kh, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, block_q, kh, g), jnp.float32)
        ks = k.reshape(b, nkv, block_kv, kh, hd).swapaxes(0, 1)
        vs = v.reshape(b, nkv, block_kv, kh, hd).swapaxes(0, 1)
        kps = kpos.reshape(nkv, block_kv)
        (o, m, l), _ = lax.scan(kv_block, (o0, m0, l0), (ks, vs, kps))
        l = jnp.maximum(l, 1e-30)
        out_i = o / l[..., None]
        lse_i = m + jnp.log(l)
        return carry, (out_i, lse_i)

    qs = q.reshape(b, nq, block_q, kh, g, hd).swapaxes(0, 1)
    qps = qpos.reshape(nq, block_q)
    _, (outs, lses) = lax.scan(q_block, None, (qs, qps))
    out = outs.swapaxes(0, 1).reshape(b, sq, kh, g, hd)
    lse = lses.swapaxes(0, 1).reshape(b, sq, kh, g)
    return out, lse


def _flash_fwd(q, k, v, causal, window, q_offset, block_q, block_kv, scale, kv_valid_is_none):
    out, lse = _flash_fwd_impl(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        None, causal, window, q_offset, block_q, block_kv, scale,
    )
    out = out.astype(q.dtype)
    # residuals kept in the model dtype (halves flash residual memory)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_offset, block_q, block_kv, scale, kv_valid_is_none, res, do):
    q, k, v, out, lse = res
    in_dtype = q.dtype
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    out = out.astype(jnp.float32)
    b, sq, kh, g, hd = q.shape
    skv = k.shape[1]
    nq = sq // block_q
    nkv = skv // block_kv
    do = do.astype(jnp.float32)
    delta = jnp.sum(do * out, axis=-1)  # (B, Sq, K, g)

    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(skv)

    ks = k.reshape(b, nkv, block_kv, kh, hd).swapaxes(0, 1)
    vs = v.reshape(b, nkv, block_kv, kh, hd).swapaxes(0, 1)
    kps = kpos.reshape(nkv, block_kv)

    def q_block(carry, qb):
        dk_acc, dv_acc = carry
        q_i, do_i, lse_i, delta_i, qpos_i = qb

        def kv_block(acc, kb):
            dq_i, dk_a, dv_a = acc
            k_j, v_j, kpos_j, idx = kb
            s = jnp.einsum("bqkgd,bskd->bqkgs", q_i, k_j) * scale
            msk = _block_mask(qpos_i, kpos_j, causal=causal, window=window, kv_valid_len=None)
            s = jnp.where(msk[None, :, None, None, :], s, NEG_INF)
            p = jnp.exp(s - lse_i[..., None])  # (B,bq,K,g,bkv)
            dp = jnp.einsum("bqkgd,bskd->bqkgs", do_i, v_j)
            ds = p * (dp - delta_i[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bqkgs,bskd->bqkgd", ds, k_j)
            dk_j = jnp.einsum("bqkgs,bqkgd->bskd", ds, q_i)
            dv_j = jnp.einsum("bqkgs,bqkgd->bskd", p, do_i)
            dk_a = lax.dynamic_update_index_in_dim(
                dk_a, lax.dynamic_index_in_dim(dk_a, idx, 0, keepdims=False) + dk_j, idx, 0
            )
            dv_a = lax.dynamic_update_index_in_dim(
                dv_a, lax.dynamic_index_in_dim(dv_a, idx, 0, keepdims=False) + dv_j, idx, 0
            )
            return (dq_i, dk_a, dv_a), None

        dq0 = jnp.zeros_like(q_i)
        (dq_i, dk_acc, dv_acc), _ = lax.scan(
            kv_block, (dq0, dk_acc, dv_acc), (ks, vs, kps, jnp.arange(nkv))
        )
        return (dk_acc, dv_acc), dq_i

    qs = q.reshape(b, nq, block_q, kh, g, hd).swapaxes(0, 1)
    dos = do.reshape(b, nq, block_q, kh, g, hd).swapaxes(0, 1)
    lses = lse.reshape(b, nq, block_q, kh, g).swapaxes(0, 1)
    deltas = delta.reshape(b, nq, block_q, kh, g).swapaxes(0, 1)
    qps = qpos.reshape(nq, block_q)

    dk0 = jnp.zeros((nkv, b, block_kv, kh, hd), jnp.float32)
    dv0 = jnp.zeros((nkv, b, block_kv, kh, hd), jnp.float32)
    (dk_b, dv_b), dqs = lax.scan(q_block, (dk0, dv0), (qs, dos, lses, deltas, qps))
    dq = dqs.swapaxes(0, 1).reshape(b, sq, kh, g, hd).astype(in_dtype)
    dk = dk_b.swapaxes(0, 1).reshape(b, skv, kh, hd).astype(in_dtype)
    dv = dv_b.swapaxes(0, 1).reshape(b, skv, kh, hd).astype(in_dtype)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, K, hd)
    v: jax.Array,  # (B, Skv, K, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    kv_valid_len: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    block_q: int = 512,
    block_kv: int = 512,
    scale: Optional[float] = None,
) -> jax.Array:
    """Public entry point. Returns (B, Sq, H, hd) in q.dtype.

    ``kv_valid_len`` (dynamic cache fill level) is handled on the
    non-differentiable path (serving); training uses static masks.
    """
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = scale if scale is not None else hd**-0.5
    in_dtype = q.dtype

    bq = min(block_q, max(sq, 1))
    bkv = min(block_kv, max(k.shape[1], 1))

    qf = q.reshape(b, sq, kh, g, hd)
    kf = k
    vf = v

    qf, sq0 = _pad_to(qf, 1, bq)
    kf, skv0 = _pad_to(kf, 1, bkv)
    vf, _ = _pad_to(vf, 1, bkv)
    if kv_positions is not None and kf.shape[1] != skv0:
        kv_positions = jnp.pad(kv_positions, (0, kf.shape[1] - skv0), constant_values=-1)
    # Padded kv positions must be masked out.
    if kf.shape[1] != skv0 and kv_valid_len is None and kv_positions is None:
        kv_valid_len = jnp.asarray(skv0)

    if kv_valid_len is None and kv_positions is None:
        out = _flash(qf, kf, vf, causal, window, q_offset, bq, bkv, scale, True)
    else:
        # Serving path: dynamic valid length / ring positions, no grad needed.
        out, _ = _flash_fwd_impl(
            qf.astype(jnp.float32), kf.astype(jnp.float32), vf.astype(jnp.float32),
            kv_valid_len, causal, window, q_offset, bq, bkv, scale,
            kv_positions=kv_positions,
        )
    out = out[:, :sq] if out.shape[1] != sq else out
    return out.reshape(b, sq, h, hd).astype(in_dtype)
