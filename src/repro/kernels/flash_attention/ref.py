"""Pure-jnp oracle for flash attention (naive, materializes scores)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_mask(
    q_len: int,
    kv_len: int,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    kv_valid_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Boolean (q_len, kv_len) mask; True = attend.

    ``q_offset``: absolute position of q row 0 (decode: cache fill level).
    ``window``: sliding-window size W — attend iff 0 <= i - j < W.
    ``kv_valid_len``: scalar; positions >= it are padding (unfilled cache).
    """
    qi = q_offset + jnp.arange(q_len)[:, None]
    kj = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= (qi - kj) < window
    if kv_valid_len is not None:
        mask &= kj < kv_valid_len
    return mask


def mha_reference(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, K, hd)
    v: jax.Array,  # (B, Skv, K, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    kv_valid_len: Optional[jax.Array] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Grouped-query attention, full-score reference. Returns (B, Sq, H, hd)."""
    b, sq, h, hd = q.shape
    kheads = k.shape[2]
    g = h // kheads
    scale = scale if scale is not None else hd**-0.5
    qf = q.astype(jnp.float32).reshape(b, sq, kheads, g, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) * scale
    mask = attention_mask(
        sq, k.shape[1], causal=causal, window=window, q_offset=q_offset,
        kv_valid_len=kv_valid_len,
    )
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, vf)
    return out.reshape(b, sq, h, hd).astype(q.dtype)
