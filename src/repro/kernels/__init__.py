# Custom-kernel layer for the paper's compute hot spots.  Each op lives in
# its own package with <name>.py (Pallas TPU kernel), ops.py (portable
# chunked-XLA path), and ref.py (pure-jnp oracle both are tested against):
# ghost_norm/ (Eq. 2.7 ghost norms, dense + index-equality), psg_contract/
# (book-keeping's fused clip-and-contract stage), flash_attention/.
# dispatch.py routes the clipping hot ops between the Pallas and XLA
# implementations — backend default or per-tap measured ClipPlan choice;
# call sites never pick an implementation themselves.
