"""Portable XLA paths for the psg bank-contraction stage (jit-ready).

These are the everywhere-else counterparts of the Pallas kernels in
``psg_contract.py``; ``repro.kernels.dispatch`` routes between the two.
The book contraction is handed to XLA as a single three-operand einsum so
the contraction order is the compiler's choice — on most backends that
still materializes the weighted cotangent ``g * c`` (the temporary the
Pallas kernel exists to avoid); the complexity is identical, only the HBM
traffic differs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def book_weighted_grad(a: jax.Array, g: jax.Array, w: jax.Array) -> jax.Array:
    """sum_r w[m,r] a[m,r]^T g[m,r].  a: (M,R,D), g: (M,R,p), w: (M,R) -> (M,D,p)."""
    return jnp.einsum(
        "mrd,mrp,mr->mdp",
        a.astype(jnp.float32), g.astype(jnp.float32), w.astype(jnp.float32),
    )


def psg_contract(psg: jax.Array, c: jax.Array) -> jax.Array:
    """sum_n c[n] * psg[n].  psg: (N, F), c: (N,) -> (F,) float32."""
    return jnp.einsum(
        "nf,n->f", psg.astype(jnp.float32), c.astype(jnp.float32)
    )
