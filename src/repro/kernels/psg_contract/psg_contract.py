"""Pallas TPU kernels: the fused clip-and-contract stage of book-keeping.

Book-keeping (arXiv:2210.00038) ends every step with two contractions
against the clip factors C (one scalar per sample):

- **psg bank**:   out = sum_n C_n * psg_n          psg: (N, F) -> (F,)
- **(a, g) book**: out = sum_n C_n * a_n^T g_n     a: (M, R, D), g: (M, R, p)

The XLA formulation of the book contraction (core/ghost.py before this
kernel existed) scales the cotangent first — ``g * C`` — which materializes
a cotangent-sized temporary in HBM, reads it back for the einsum, and only
then reduces.  Here the scale-and-contract is fused per VMEM tile: a
``(block_r, block_p)`` slab of ``g`` is scaled by its row weights in
registers and immediately fed to the MXU against the matching ``a`` tile;
the weighted cotangent never exists outside VMEM.  HBM traffic drops from
``2*M*R*p`` extra elements (write + read of the temp) to zero.

The psg contraction is a rank-1 batch reduction (no MXU-sized reuse), so
its kernel is a plain tiled weighted sum — it exists so the whole bank
stage can run under one dispatch decision (repro.kernels.dispatch) and be
timed as one unit by the tuner.

Grids iterate the reduction dim innermost; output blocks are revisited
across it and accumulated in place (same pattern as the ghost-norm
kernel's per-sample scalar).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pad(x, axis, mult):
    p = (-x.shape[axis]) % mult
    if p == 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, p)
    return jnp.pad(x, w)


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_d", "block_p", "interpret")
)
def book_weighted_grad_pallas(
    a: jax.Array,  # (M, R, D)
    g: jax.Array,  # (M, R, p)
    w: jax.Array,  # (M, R) per-row weights (clip factors fanned out over T)
    *,
    block_r: int = 256,
    block_d: int = 512,
    block_p: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused weighted-book contraction: out[m] = sum_r w[m,r] a[m,r]^T g[m,r].

    Returns (M, D, p) float32.  The ``w``-scaled cotangent tile lives only
    in VMEM; rows padded up to ``block_r`` carry zero weight and contribute
    nothing regardless of the operand padding.
    """
    m, r, d = a.shape
    p = g.shape[-1]
    a = _pad(_pad(a, 1, block_r), 2, block_d)
    g = _pad(_pad(g, 1, block_r), 2, block_p)
    w = _pad(w, 1, block_r).astype(jnp.float32)
    nr = a.shape[1] // block_r
    nd = a.shape[2] // block_d
    np_ = g.shape[2] // block_p

    def kernel(a_ref, g_ref, w_ref, o_ref):
        ri = pl.program_id(3)
        gw = g_ref[0].astype(jnp.float32) * w_ref[0][:, None]
        contrib = jax.lax.dot_general(
            a_ref[0].astype(jnp.float32), gw,
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

        @pl.when(ri == 0)
        def _first():
            o_ref[0] = contrib

        @pl.when(ri != 0)
        def _rest():
            o_ref[0] += contrib

    out = pl.pallas_call(
        kernel,
        grid=(m, nd, np_, nr),
        in_specs=[
            pl.BlockSpec((1, block_r, block_d), lambda mi, i, j, ri: (mi, ri, i)),
            pl.BlockSpec((1, block_r, block_p), lambda mi, i, j, ri: (mi, ri, j)),
            pl.BlockSpec((1, block_r), lambda mi, i, j, ri: (mi, ri)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_d, block_p), lambda mi, i, j, ri: (mi, i, j)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (m, nd * block_d, np_ * block_p), jnp.float32
        ),
        interpret=interpret,
    )(a, g, w)
    return out[:, :d, :p]


@functools.partial(jax.jit, static_argnames=("block_n", "block_f", "interpret"))
def psg_contract_pallas(
    psg: jax.Array,  # (N, F) banked per-sample gradients, flattened
    c: jax.Array,  # (N,) clip factors
    *,
    block_n: int = 256,
    block_f: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Weighted bank sum: out = sum_n c[n] * psg[n].  Returns (F,) float32.

    Samples padded up to ``block_n`` carry zero weight, so the operand
    padding never leaks into the sum.
    """
    n, f = psg.shape
    psg = _pad(_pad(psg, 0, block_n), 1, block_f)
    c2 = _pad(c.astype(jnp.float32).reshape(1, n), 1, block_n)
    nn = psg.shape[0] // block_n
    nf = psg.shape[1] // block_f

    def kernel(p_ref, c_ref, o_ref):
        ni = pl.program_id(1)
        contrib = jax.lax.dot_general(
            c_ref[...], p_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[0]

        @pl.when(ni == 0)
        def _first():
            o_ref[...] = contrib

        @pl.when(ni != 0)
        def _rest():
            o_ref[...] += contrib

    out = pl.pallas_call(
        kernel,
        grid=(nf, nn),
        in_specs=[
            pl.BlockSpec((block_n, block_f), lambda i, ni: (ni, i)),
            pl.BlockSpec((1, block_n), lambda i, ni: (0, ni)),
        ],
        out_specs=pl.BlockSpec((block_f,), lambda i, ni: (i,)),
        out_shape=jax.ShapeDtypeStruct((nf * block_f,), jnp.float32),
        interpret=interpret,
    )(psg, c2)
    return out[:f]
