"""Pure-jnp oracles for the psg bank contraction (book-keeping stage).

These deliberately materialize the weighted cotangent — the memory-hungry
formulation the fused kernel avoids — and are what the chunked XLA ops and
the Pallas kernels are checked against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def book_weighted_grad_ref(a: jax.Array, g: jax.Array, w: jax.Array) -> jax.Array:
    """Scale-then-contract: (g * w) materialized, then the (a, g) einsum."""
    gw = g.astype(jnp.float32) * w.astype(jnp.float32)[..., None]
    return jnp.einsum("mrd,mrp->mdp", a.astype(jnp.float32), gw)


def psg_contract_ref(psg: jax.Array, c: jax.Array) -> jax.Array:
    """Row-scaled bank summed over samples."""
    scaled = psg.astype(jnp.float32) * c.astype(jnp.float32)[:, None]
    return jnp.sum(scaled, axis=0)
