"""Backend-aware kernel dispatch for the three clipping hot ops.

The Pallas TPU kernels (``ghost_norm/ghost_norm.py``,
``psg_contract/psg_contract.py``) and the portable chunked-XLA ops
(``ghost_norm/ops.py``, ``psg_contract/ops.py``) compute identical values;
which one the training step traces is a pure performance decision.  This
module is the single place that decision is made:

    op               pallas impl                      xla impl
    ---------------  -------------------------------  ------------------------
    ghost_norm       ghost_norm_sq_pallas             gops.ghost_norm_sq
    embedding_ghost_norm
                     embedding_ghost_norm_sq_pallas   gops.embedding_ghost_norm_sq
    psg_contract     book_weighted_grad_pallas /      cops.book_weighted_grad /
                     psg_contract_pallas              cops.psg_contract
    flash_attention  flash_attention_pallas           fops.flash_attention
                     (static masks only; dynamic cache args fall back)

Resolution order, per call:

1. an explicit ``impl=`` argument — threaded from a tuner ``ClipPlan``'s
   per-tap ``kernels`` map through ``ClipRuntime``/``ProbeSpec`` (the
   measured choice, consensus-hash-covered on fleets);
2. a ``force_impl`` context override (tests flip the choice both ways);
3. the backend default: ``pallas`` on TPU, ``xla`` everywhere else.

Requesting ``pallas`` off-TPU runs the kernel in interpreter mode — exact
but slow, which is precisely what the parity tests and the flipped-choice
exactness oracle want; it can never happen in production because the
backend default is ``xla`` there and a plan's kernel map is only applied
by the device kind that *measured* it (``ClipPlan.kernels_for`` — merely
ratifying a fleet agreement is not enough, unlike branch overrides).
Both impls of every op compute the same sums over the same tiles; only
scheduling and HBM traffic differ, so a flipped choice moves cost, never
results (tested).
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fops
from repro.kernels.ghost_norm import ops as gops
from repro.kernels.psg_contract import ops as cops

OPS = ("ghost_norm", "embedding_ghost_norm", "psg_contract", "flash_attention")
IMPLS = ("pallas", "xla")

# force_impl() state: {op: impl}; consulted at trace time, tests only
_forced: dict[str, str] = {}


def backend() -> str:
    """The platform jax will place this trace on (``tpu``/``gpu``/``cpu``)."""
    return jax.default_backend()


def available_impls() -> tuple[str, ...]:
    """Impls worth *measuring* here: both on TPU, xla-only elsewhere.

    (``pallas`` still *runs* off-TPU via the interpreter when explicitly
    requested — it is excluded here because an interpreted kernel can never
    win a timing comparison and must not be offered to the tuner.)
    """
    return IMPLS if backend() == "tpu" else ("xla",)


def default_impl(op: str) -> str:
    """The unmeasured default: the Pallas kernel on TPU, XLA elsewhere."""
    if op not in OPS:
        raise ValueError(f"unknown kernel op {op!r}; have {OPS}")
    return "pallas" if backend() == "tpu" else "xla"


def resolve(op: str, impl: Optional[str] = None) -> str:
    """Pick the impl for one op: explicit > forced > backend default."""
    if impl is None:
        impl = _forced.get(op)
    if impl is None:
        return default_impl(op)
    if op not in OPS:
        raise ValueError(f"unknown kernel op {op!r}; have {OPS}")
    if impl not in IMPLS:
        raise ValueError(f"unknown kernel impl {impl!r} for {op}; have {IMPLS}")
    return impl


@contextlib.contextmanager
def force_impl(
    impl: Optional[str] = None, **per_op: str
) -> Iterator[None]:
    """Context override for tests: force all ops to ``impl`` or per-op kwargs.

    ``force_impl("pallas")`` routes every op through the Pallas kernels
    (interpreted off-TPU); ``force_impl(psg_contract="xla")`` pins one op.
    Overrides apply at trace time — build and jit the function under test
    inside the context.
    """
    if impl is not None and impl not in IMPLS:
        raise ValueError(f"unknown kernel impl {impl!r}; have {IMPLS}")
    for op, i in per_op.items():
        if op not in OPS:
            raise ValueError(f"unknown kernel op {op!r}; have {OPS}")
        if i not in IMPLS:
            raise ValueError(f"unknown kernel impl {i!r} for {op}; have {IMPLS}")
    saved = dict(_forced)
    try:
        if impl is not None:
            _forced.update({op: impl for op in OPS})
        _forced.update(per_op)
        yield
    finally:
        _forced.clear()
        _forced.update(saved)


def _interpret() -> bool:
    return backend() != "tpu"


def kernels_arg(kernels: Optional[Mapping[str, str]], op: str) -> Optional[str]:
    """The per-tap plan choice for ``op`` (None = no recorded choice)."""
    return None if kernels is None else kernels.get(op)


# -- the dispatched ops ----------------------------------------------------
def ghost_norm_sq(
    a: jax.Array,
    g: jax.Array,
    *,
    block: int = 512,
    impl: Optional[str] = None,
) -> jax.Array:
    """Ghost norm (Eq. 2.7): a (N,T,D), g (N,T,p) -> (N,) fp32."""
    if resolve("ghost_norm", impl) == "pallas":
        from repro.kernels.ghost_norm.ghost_norm import ghost_norm_sq_pallas

        return ghost_norm_sq_pallas(a, g, interpret=_interpret())
    return gops.ghost_norm_sq(a, g, block=block)


def embedding_ghost_norm_sq(
    ids: jax.Array,
    g: jax.Array,
    *,
    block: int = 1024,
    impl: Optional[str] = None,
) -> jax.Array:
    """Index-equality ghost norm: ids (N,T), g (N,T,p) -> (N,) fp32."""
    if resolve("embedding_ghost_norm", impl) == "pallas":
        from repro.kernels.ghost_norm.ghost_norm import (
            embedding_ghost_norm_sq_pallas,
        )

        return embedding_ghost_norm_sq_pallas(ids, g, interpret=_interpret())
    return gops.embedding_ghost_norm_sq(ids, g, block=block)


def book_weighted_grad(
    a: jax.Array,
    g: jax.Array,
    w: jax.Array,
    *,
    impl: Optional[str] = None,
) -> jax.Array:
    """Weighted (a,g)-book contraction: sum_r w[m,r] a[m,r]^T g[m,r].

    a (M,R,D), g (M,R,p), w (M,R) -> (M,D,p) fp32.  The Pallas impl scales
    cotangent tiles in VMEM so the ``g * w`` temporary never reaches HBM.
    """
    if resolve("psg_contract", impl) == "pallas":
        from repro.kernels.psg_contract.psg_contract import (
            book_weighted_grad_pallas,
        )

        return book_weighted_grad_pallas(a, g, w, interpret=_interpret())
    return cops.book_weighted_grad(a, g, w)


def psg_contract(
    psg: jax.Array,
    c: jax.Array,
    *,
    axis: int = 0,
    impl: Optional[str] = None,
) -> jax.Array:
    """Weighted bank sum over the sample axis: sum_n c[n] * psg[..n..].

    ``psg`` has the batch on ``axis`` (the probe banks carry it *after* the
    stack dims); the result drops that axis, keeping the remaining dims in
    order, fp32.
    """
    if resolve("psg_contract", impl) == "pallas":
        from repro.kernels.psg_contract.psg_contract import psg_contract_pallas

        moved = jnp.moveaxis(psg, axis, 0)
        out_shape = moved.shape[1:]
        flat = moved.reshape(moved.shape[0], -1)
        return psg_contract_pallas(flat, c, interpret=_interpret()).reshape(
            out_shape
        )
    return jnp.tensordot(
        c.astype(jnp.float32), psg.astype(jnp.float32), axes=(0, axis)
    )


def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, K, hd)
    v: jax.Array,  # (B, Skv, K, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    kv_valid_len: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    block_q: int = 512,
    block_kv: int = 512,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
) -> jax.Array:
    """Serving attention (B, Sq, H, hd layout), forward only.

    The Pallas kernel covers the static-mask cases (causal/window with an
    int ``q_offset``).  Dynamic cache shapes — a traced ``q_offset``, ring
    ``kv_positions``, or a ``kv_valid_len`` fill level — fall back to the
    XLA path regardless of the resolved impl: the kernel has no scalar-
    prefetch story for them yet (the paged-attention follow-on).  Training
    never routes through here (it needs the custom VJP in
    ``flash_attention.ops``); this wrapper is for cache-serving traces.
    """
    pallas_ok = (
        kv_positions is None
        and kv_valid_len is None
        and scale is None
        and isinstance(q_offset, int)
    )
    if resolve("flash_attention", impl) == "pallas" and pallas_ok:
        from repro.kernels.flash_attention.flash_attention import (
            flash_attention_pallas,
        )

        h, kh = q.shape[2], k.shape[2]
        qt = jnp.moveaxis(q, 1, 2)  # (B, H, Sq, hd)
        kt = jnp.moveaxis(k, 1, 2)
        vt = jnp.moveaxis(v, 1, 2)
        if kh != h:
            # GQA: query head h reads kv head h // g (matches the XLA
            # (B, S, K, g, hd) grouping)
            kt = jnp.repeat(kt, h // kh, axis=1)
            vt = jnp.repeat(vt, h // kh, axis=1)
        out = flash_attention_pallas(
            qt, kt, vt, causal=causal, window=window, q_offset=q_offset,
            block_q=min(block_q, 128), block_kv=min(block_kv, 128),
            interpret=_interpret(),
        )
        return jnp.moveaxis(out, 1, 2)
    return fops.flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        kv_valid_len=kv_valid_len, kv_positions=kv_positions,
        block_q=block_q, block_kv=block_kv, scale=scale,
    )
