"""Elastic scaling: recompute the run layout when the fleet size changes.

Checkpoints store logical arrays (see checkpoint/), so a restart on a
different mesh only needs (a) new shardings, (b) a data layout that keeps the
*logical* batch (and therefore the DP sampling rate q — the privacy
accounting is unchanged) while re-splitting it across the surviving hosts.

The launcher (``launch/train.py``) calls ``elastic_plan`` on every start —
including every ``--auto-restart`` attempt — with the shard count of the
fleet it actually has (``current_data_shards``: ``--data-shards`` or the
``REPRO_ELASTIC_SHARDS`` environment the scheduler sets).  A shrink never
changes the logical batch: lost parallelism becomes extra gradient
accumulation, so the microbatch stream (per-shard batch, order) is
preserved and a resumed run is bit-identical to an uninterrupted one.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

from repro.utils.logging import get_logger

log = get_logger("elastic")

ENV_SHARDS = "REPRO_ELASTIC_SHARDS"


def current_data_shards(cli_value: Optional[int] = None) -> int:
    """The data-parallel degree of the fleet this process launched into.

    Precedence: an explicit CLI value, then ``$REPRO_ELASTIC_SHARDS`` (the
    restart-time seam — the scheduler, or a ``shrink@step`` fault injector,
    updates it between attempts), then 1.
    """
    if cli_value:
        return int(cli_value)
    env = os.environ.get(ENV_SHARDS, "").strip()
    return int(env) if env else 1


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data_shards: int
    per_shard_batch: int
    accumulation_steps: int
    note: str

    def execution(self, n_processes: int = 1) -> tuple[int, int]:
        """Map the fleet plan onto ``n_processes`` as (microbatch, accum).

        With one process per shard the global physical microbatch is
        ``per_shard_batch * data_shards`` (the mesh shards it over the data
        axis).  With FEWER processes than shards — always, in single-host
        tests simulating a fleet — each process serializes its share of the
        shards into extra accumulation microsteps: the per-shard microbatch
        programs and their order are unchanged, which is exactly what makes
        a shrunk-fleet resume bit-identical to the uninterrupted run.
        """
        if n_processes < 1:
            raise ValueError(f"n_processes must be >= 1, got {n_processes}")
        par = min(self.data_shards, n_processes)
        if self.data_shards % par != 0:
            raise ValueError(
                f"data_shards={self.data_shards} does not divide over "
                f"{n_processes} process(es); choose a shard count that is a "
                "multiple of the process count"
            )
        serial = self.data_shards // par
        return self.per_shard_batch * par, self.accumulation_steps * serial


def elastic_plan(
    *, logical_batch: int, data_shards: int, max_per_shard: int
) -> ElasticPlan:
    """Keep the logical batch constant; grow accumulation when shards shrink.

    DP invariant: sampling rate q = logical_batch / N must not change across
    restarts, else the accountant's composition is wrong.  So the logical
    batch is held fixed and the lost throughput is absorbed by gradient
    accumulation (the paper's virtual-step machinery).

    Raises ``ValueError`` on impossible layouts (non-dividing shard counts)
    — a *config* error the ``--auto-restart`` supervisor classifies as
    non-retryable, since retrying a deterministic misconfiguration only
    burns the restart budget.
    """
    if data_shards < 1:
        raise ValueError(f"data_shards must be >= 1, got {data_shards}")
    if max_per_shard < 1:
        raise ValueError(f"max_per_shard must be >= 1, got {max_per_shard}")
    if logical_batch % data_shards != 0:
        raise ValueError(
            f"logical batch {logical_batch} must divide over {data_shards} "
            "shards; choose a shard count that divides it"
        )
    per_shard = logical_batch // data_shards
    accum = 1
    while per_shard > max_per_shard:
        if per_shard % 2 != 0:
            raise ValueError(
                f"per-shard batch {per_shard} exceeds max_per_shard="
                f"{max_per_shard} and is odd — cannot halve into equal "
                "accumulation microsteps; adjust the logical batch or cap"
            )
        accum *= 2
        per_shard //= 2
    plan = ElasticPlan(
        data_shards=data_shards,
        per_shard_batch=per_shard,
        accumulation_steps=accum,
        note=f"logical batch {logical_batch} preserved; q unchanged",
    )
    log.info("elastic plan: %s", plan)
    return plan
