"""Elastic scaling: recompute the run layout when the fleet size changes.

Checkpoints store logical arrays (see checkpoint/), so a restart on a
different mesh only needs (a) new shardings, (b) a data layout that keeps the
*logical* batch (and therefore the DP sampling rate q — the privacy
accounting is unchanged) while re-splitting it across the surviving hosts.
"""
from __future__ import annotations

import dataclasses

from repro.utils.logging import get_logger

log = get_logger("elastic")


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data_shards: int
    per_shard_batch: int
    accumulation_steps: int
    note: str


def elastic_plan(
    *, logical_batch: int, data_shards: int, max_per_shard: int
) -> ElasticPlan:
    """Keep the logical batch constant; grow accumulation when shards shrink.

    DP invariant: sampling rate q = logical_batch / N must not change across
    restarts, else the accountant's composition is wrong.  So the logical
    batch is held fixed and the lost throughput is absorbed by gradient
    accumulation (the paper's virtual-step machinery).
    """
    assert logical_batch % data_shards == 0, (
        f"logical batch {logical_batch} must divide over {data_shards} shards; "
        "choose a shard count that divides it"
    )
    per_shard = logical_batch // data_shards
    accum = 1
    while per_shard > max_per_shard:
        accum *= 2
        assert per_shard % 2 == 0
        per_shard //= 2
    plan = ElasticPlan(
        data_shards=data_shards,
        per_shard_batch=per_shard,
        accumulation_steps=accum,
        note=f"logical batch {logical_batch} preserved; q unchanged",
    )
    log.info("elastic plan: %s", plan)
    return plan
