"""Deterministic fault injection for the fleet runtime.

The elastic/auto-restart machinery is only trustworthy if its failure paths
are *executed*, not reasoned about — and executed the same way in a unit
test, a CLI subprocess, and CI.  This module is that seam: a small set of
injectors (crash, SIGTERM, slow step, torn/corrupt checkpoint, fleet
shrink) parsed from one spec string that can arrive via ``--inject`` or the
``REPRO_FAULT_INJECT`` environment variable, so a subprocess under test
exhibits the fault without any monkeypatching.

Spec grammar (comma-separated, each injector fires at most once)::

    crash@S         raise InjectedCrash at the start of step S (retryable)
    sigterm@S       deliver SIGTERM to this process at the start of step S
                    (exercises PreemptionHandler -> checkpoint -> exit 0)
    slow@S:SECS     sleep SECS seconds inside step S (trips StepWatchdog)
    torn@S          truncate the step-S checkpoint right after it is written
                    (a torn write: restore must fall back to an older step)
    corrupt@S       overwrite the step-S checkpoint with garbage bytes
    shrink@S:K      set REPRO_ELASTIC_SHARDS=K, then crash at step S — the
                    restart sees a smaller fleet and must replan via
                    ``runtime.elastic.elastic_plan``

The launcher builds ONE ``InjectionPlan`` per process (``--fail-at-step N``
is folded in as ``crash@N``) and threads it through every ``--auto-restart``
attempt, so an injector that fired before the crash does not re-fire after
the in-process restart — exactly like a real transient fault.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Optional

from repro.obs.events import emit_event
from repro.utils.logging import get_logger

log = get_logger("inject")

ENV_SPEC = "REPRO_FAULT_INJECT"

_STEP_KINDS = ("crash", "sigterm", "slow", "shrink")
_CKPT_KINDS = ("torn", "corrupt")


class InjectedCrash(RuntimeError):
    """A deliberately injected, *retryable* failure (tests/CI)."""


@dataclasses.dataclass
class Injector:
    kind: str
    step: int
    value: Optional[float] = None  # slow: seconds; shrink: new shard count
    fired: bool = False

    def spec(self) -> str:
        v = "" if self.value is None else f":{self.value:g}"
        return f"{self.kind}@{self.step}{v}"


def _parse_one(item: str) -> Injector:
    item = item.strip()
    if "@" not in item:
        raise ValueError(
            f"bad fault spec {item!r}: expected kind@step[:value] "
            f"(kinds: {', '.join(_STEP_KINDS + _CKPT_KINDS)})"
        )
    kind, _, rest = item.partition("@")
    kind = kind.strip()
    if kind not in _STEP_KINDS + _CKPT_KINDS:
        raise ValueError(
            f"unknown fault kind {kind!r} in {item!r} "
            f"(kinds: {', '.join(_STEP_KINDS + _CKPT_KINDS)})"
        )
    step_s, _, value_s = rest.partition(":")
    step = int(step_s)
    value = float(value_s) if value_s else None
    if kind == "slow" and value is None:
        raise ValueError(f"slow injector needs a duration: slow@{step}:SECS")
    if kind == "shrink" and (value is None or value < 1 or value != int(value)):
        raise ValueError(
            f"shrink injector needs an integer shard count: shrink@{step}:K"
        )
    return Injector(kind=kind, step=step, value=value)


class InjectionPlan:
    """One process's fault schedule; hooks called from the train loop."""

    def __init__(self, injectors: Optional[list[Injector]] = None):
        self.injectors = injectors or []

    @classmethod
    def from_spec(
        cls, spec: Optional[str] = None, *, env: Optional[str] = None
    ) -> "InjectionPlan":
        """Parse ``--inject`` and/or ``$REPRO_FAULT_INJECT`` (both may be
        set; CLI items come first).  ``env=None`` reads the real environment
        — pass ``env=""`` to ignore it."""
        if env is None:
            env = os.environ.get(ENV_SPEC, "")
        items = [s for src in (spec or "", env) for s in src.split(",") if s.strip()]
        return cls([_parse_one(s) for s in items])

    def add_crash(self, step: int) -> None:
        self.injectors.append(Injector(kind="crash", step=step))

    def __bool__(self) -> bool:
        return bool(self.injectors)

    # -- hooks -------------------------------------------------------------
    def on_step(self, step: int) -> None:
        """Called at the start of every (logical) train step."""
        for inj in self.injectors:
            if inj.fired or inj.kind not in _STEP_KINDS or inj.step != step:
                continue
            inj.fired = True
            log.warning("fault injection: %s firing at step %d", inj.spec(), step)
            emit_event("fault_injected", step=step, spec=inj.spec(),
                       fault_kind=inj.kind)
            if inj.kind == "crash":
                raise InjectedCrash(f"injected fault at step {step}")
            if inj.kind == "shrink":
                # a shrink is a crash whose restart sees fewer hosts: mutate
                # the env the elastic replan reads, then die
                os.environ["REPRO_ELASTIC_SHARDS"] = str(int(inj.value))
                raise InjectedCrash(
                    f"injected fleet shrink to {int(inj.value)} shard(s) "
                    f"at step {step}"
                )
            if inj.kind == "sigterm":
                os.kill(os.getpid(), signal.SIGTERM)
            elif inj.kind == "slow":
                time.sleep(float(inj.value))

    def on_checkpoint_saved(self, step: int, path) -> None:
        """Called after a checkpoint file is durably written (and rotated).

        Runs on the async writer thread in production configs — torn-write
        injection therefore also exercises the manager's thread-safety.
        """
        for inj in self.injectors:
            if inj.fired or inj.kind not in _CKPT_KINDS or inj.step != step:
                continue
            inj.fired = True
            log.warning(
                "fault injection: %s mangling checkpoint %s", inj.spec(), path
            )
            emit_event("fault_injected", step=step, spec=inj.spec(),
                       fault_kind=inj.kind, path=str(path))
            if inj.kind == "torn":
                tear_file(path)
            else:  # corrupt
                path.write_bytes(b"\x00garbage\x00" * 16)


def tear_file(path) -> None:
    """Truncate ``path`` to a strict prefix — a realistic torn write.

    Shared between the ``torn@S`` checkpoint injector and the obs tests
    that prove ``sinks.read_jsonl`` survives a crash-torn final line: both
    need "a prefix of the true bytes", not a missing or zeroed file.
    """
    import pathlib

    path = pathlib.Path(path)
    data = path.read_bytes()
    path.write_bytes(data[: max(1, len(data) // 3)])
