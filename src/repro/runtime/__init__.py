from repro.runtime.fault import StepWatchdog, PreemptionHandler, retry
from repro.runtime.elastic import elastic_plan

__all__ = ["StepWatchdog", "PreemptionHandler", "retry", "elastic_plan"]
