from repro.runtime.fault import StepWatchdog, PreemptionHandler, retry
from repro.runtime.elastic import ElasticPlan, current_data_shards, elastic_plan
from repro.runtime.inject import InjectedCrash, InjectionPlan

__all__ = [
    "StepWatchdog",
    "PreemptionHandler",
    "retry",
    "ElasticPlan",
    "current_data_shards",
    "elastic_plan",
    "InjectedCrash",
    "InjectionPlan",
]
