"""Fault tolerance: straggler watchdog, preemption handling, retry.

At fleet scale the failure modes are (a) slow steps — a straggling host makes
every collective wait; (b) preemption — the scheduler reclaims nodes with a
grace window; (c) transient infra errors.  The mitigations here are the
host-side halves: detect + checkpoint + clean restart (the launcher's
``--auto-restart`` loop re-runs from the latest checkpoint, excluding dead
hosts via a smaller data-parallel degree — see elastic.py).
"""
from __future__ import annotations

import collections
import signal
import threading
import time
from typing import Callable, Optional

from repro.obs.events import emit_event
from repro.utils.logging import get_logger

log = get_logger("fault")


class StepWatchdog:
    """Flags steps slower than ``trip_factor`` x the rolling median.

    On a real fleet the callback reports the straggling host to the control
    plane (to exclude on restart); here it logs and counts.
    """

    def __init__(self, window: int = 50, trip_factor: float = 3.0,
                 on_trip: Optional[Callable[[int, float, float], None]] = None):
        self.times = collections.deque(maxlen=window)
        self.trip_factor = trip_factor
        self.on_trip = on_trip
        self.trips = 0
        self._t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self, step: int) -> float:
        assert self._t0 is not None
        dt = time.monotonic() - self._t0
        if len(self.times) >= 10:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.trip_factor * med:
                self.trips += 1
                log.warning(
                    "straggler tripwire: step %d took %.3fs (median %.3fs)",
                    step, dt, med,
                )
                emit_event("watchdog_trip", step=step, dt_s=dt, median_s=med,
                           trip_factor=self.trip_factor, trips=self.trips)
                if self.on_trip:
                    self.on_trip(step, dt, med)
        self.times.append(dt)
        self._t0 = None
        return dt


class PreemptionHandler:
    """SIGTERM/SIGINT -> set a flag; the train loop checkpoints and exits 0.

    The fleet scheduler interprets a clean exit after preemption as
    "restartable"; the auto-restart wrapper then resumes from the last step.
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._signals = signals
        self._installed = False
        self._previous: dict = {}

    def install(self) -> "PreemptionHandler":
        if not self._installed:
            for sig in self._signals:
                try:
                    self._previous[sig] = signal.signal(sig, self._handle)
                except ValueError:
                    pass  # non-main thread (tests)
            self._installed = True
        return self

    def uninstall(self) -> None:
        """Restore the dispositions ``install`` replaced.

        The train loop calls this on the way out so a later SIGTERM hits
        whatever the host process had installed — not a stale flag on a
        handler whose run already exited (matters for in-process
        ``--auto-restart`` attempts and for test runners).
        """
        if self._installed:
            for sig, prev in self._previous.items():
                try:
                    signal.signal(sig, prev)
                except ValueError:
                    pass
            self._previous = {}
            self._installed = False

    def _handle(self, signum, frame):
        log.warning("received signal %s: requesting graceful stop", signum)
        self._flag.set()

    def preempted(self) -> bool:
        return self._flag.is_set()

    def request_stop(self) -> None:  # testable without real signals
        self._flag.set()


def retry(fn: Callable, *, attempts: int = 3, backoff_s: float = 1.0,
          retriable=(OSError, IOError)):
    """Retry transient host-side failures (checkpoint IO, rendezvous)."""
    last = None
    for i in range(attempts):
        try:
            return fn()
        except retriable as e:  # noqa: PERF203
            last = e
            log.warning("attempt %d/%d failed: %s", i + 1, attempts, e)
            time.sleep(backoff_s * (2**i))
    raise last
