"""xLSTM blocks: mLSTM (parallelizable matrix memory) and sLSTM (sequential).

Faithfulness notes (DESIGN.md §assumptions-changed):
- mLSTM uses a sigmoid input gate folded into k and a logsigmoid forget gate
  as the scalar decay — the bounded-gate variant of the paper's exponential
  gating (removes the running max-stabilizer; numerics stay in (0,1]).
  The normalizer n_t is carried as an extra ones-column of v, and the output
  is num / max(|den|, 1) as in the xLSTM paper.
- sLSTM keeps the exponential input gate WITH the max-stabilizer, and a full
  (not block-diagonal) recurrent matrix R.  The recurrent weight is per-sample
  clipped through a tap on the scan *input stream* (see taps.Ctx.record_act).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.taps import Ctx
from repro.nn.conv import DepthwiseConv1d
from repro.nn.mlp import GatedMLP
from repro.nn.module import Dense, Module, Params, AxesTree, RMSNorm
from repro.nn.ssm_scan import chunked_ssm, ssm_decode_step
from repro.parallel.reshard import reshard_param


class MLSTMBlock(Module):
    """Pre-norm mLSTM block with internal up/down projection (PF=2)."""

    def __init__(
        self,
        name: str,
        d_model: int,
        n_heads: int,
        *,
        expand: int = 2,
        conv_k: int = 4,
        chunk: int = 256,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        dp: bool = True,
    ):
        self.name = name
        self.d_model = d_model
        self.d_inner = expand * d_model
        self.n_heads = n_heads
        assert self.d_inner % n_heads == 0
        self.head_dim = self.d_inner // n_heads
        self.conv_k = conv_k
        self.chunk = chunk
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.dp = dp
        common = dict(dtype=dtype, param_dtype=param_dtype, dp=dp)
        self.norm = RMSNorm(f"{name}.norm", d_model, **common)
        self.in_x = Dense(
            f"{name}.in_x", d_model, self.d_inner, use_bias=False,
            w_axes=("embed", "mlp"), **common,
        )
        self.in_z = Dense(
            f"{name}.in_z", d_model, self.d_inner, use_bias=False,
            w_axes=("embed", "mlp"), **common,
        )
        self.conv = DepthwiseConv1d(f"{name}.conv", self.d_inner, conv_k, **common)
        self.wq = Dense(
            f"{name}.q", self.d_inner, self.d_inner, use_bias=False,
            w_axes=("mlp", "heads"), **common,
        )
        self.wk = Dense(
            f"{name}.k", self.d_inner, self.d_inner, use_bias=False,
            w_axes=("mlp", "heads"), **common,
        )
        self.gates = Dense(
            f"{name}.gates", self.d_inner, 2 * n_heads, use_bias=True,
            w_axes=("mlp", None), **common,
        )
        self.out_norm = RMSNorm(f"{name}.out_norm", self.d_inner, **common)
        self.out_proj = Dense(
            f"{name}.out_proj", self.d_inner, d_model, use_bias=False,
            w_axes=("mlp", "embed"), **common,
        )

    def init(self, key: jax.Array) -> Params:
        ks = jax.random.split(key, 8)
        ks = jax.random.split(ks[0], 9)
        p = {
            "norm": self.norm.init(ks[0]),
            "in_x": self.in_x.init(ks[1]),
            "in_z": self.in_z.init(ks[8]),
            "conv": self.conv.init(ks[2]),
            "q": self.wq.init(ks[3]),
            "k": self.wk.init(ks[4]),
            "gates": self.gates.init(ks[5]),
            "out_norm": self.out_norm.init(ks[6]),
            "out_proj": self.out_proj.init(ks[7]),
        }
        # forget-gate bias init: positive → long memory at init
        p["gates"]["b"] = p["gates"]["b"].at[self.n_heads :].set(3.0)
        return p

    def axes(self) -> AxesTree:
        return {
            "norm": self.norm.axes(),
            "in_x": self.in_x.axes(),
            "in_z": self.in_z.axes(),
            "conv": self.conv.axes(),
            "q": self.wq.axes(),
            "k": self.wk.axes(),
            "gates": self.gates.axes(),
            "out_norm": self.out_norm.axes(),
            "out_proj": self.out_proj.axes(),
        }

    def __call__(
        self,
        params: Params,
        x: jax.Array,
        ctx: Ctx,
        *,
        cache: Optional[dict] = None,
    ) -> tuple[jax.Array, Optional[dict]]:
        bsz, t, _ = x.shape
        h, dh = self.n_heads, self.head_dim
        res = x
        x = self.norm(params["norm"], x, ctx.scope("norm"))
        xi = self.in_x(params["in_x"], x, ctx.scope("in_x"))
        z = self.in_z(params["in_z"], x, ctx.scope("in_z"))

        conv_state = cache["conv"] if cache is not None else None
        xc, new_conv = self.conv(params["conv"], xi, ctx.scope("conv"), state=conv_state)
        xc = jax.nn.silu(xc)

        q = self.wq(params["q"], xc, ctx.scope("q")).reshape(bsz, t, h, dh)
        k = self.wk(params["k"], xc, ctx.scope("k")).reshape(bsz, t, h, dh) * (dh**-0.5)
        v = xi.reshape(bsz, t, h, dh)

        g = self.gates(params["gates"], xc, ctx.scope("gates"))  # (B, T, 2H)
        i_gate = jax.nn.sigmoid(g[..., :h].astype(jnp.float32))
        log_f = jax.nn.log_sigmoid(g[..., h:].astype(jnp.float32))

        k = k * i_gate[..., None].astype(k.dtype)
        ones = jnp.ones((bsz, t, h, 1), v.dtype)
        v_ext = jnp.concatenate([v, ones * i_gate[..., None].astype(v.dtype)], axis=-1)

        if cache is not None and t == 1:
            y_ext, new_ssm = ssm_decode_step(q, k, v_ext, log_f, cache["ssm"])
            y_ext = y_ext[:, None] if y_ext.ndim == 3 else y_ext
        else:
            state0 = cache["ssm"] if cache is not None else None
            y_ext, new_ssm = chunked_ssm(q, k, v_ext, log_f, chunk=self.chunk, state0=state0)
        num = y_ext[..., :dh]
        den = y_ext[..., dh]
        y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        y = y.reshape(bsz, t, self.d_inner)
        y = self.out_norm(params["out_norm"], y, ctx.scope("out_norm"))
        y = y * jax.nn.silu(z)
        out = res + self.out_proj(params["out_proj"], y, ctx.scope("out_proj"))

        new_cache = None
        if cache is not None:
            new_cache = {"conv": new_conv, "ssm": new_ssm}
        return out, new_cache

    def init_cache(self, batch: int, dtype) -> dict:
        return {
            "conv": jnp.zeros((batch, self.conv_k - 1, self.d_inner), dtype),
            "ssm": jnp.zeros(
                (batch, self.n_heads, self.head_dim, self.head_dim + 1), jnp.float32
            ),
        }


class SLSTMBlock(Module):
    """Pre-norm sLSTM with recurrent mixing + post gated FFN (PF=4/3)."""

    def __init__(
        self,
        name: str,
        d_model: int,
        n_heads: int,
        *,
        conv_k: int = 4,
        ffn_factor: float = 4.0 / 3.0,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        dp: bool = True,
    ):
        self.name = name
        self.d_model = d_model
        self.n_heads = n_heads
        self.conv_k = conv_k
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.dp = dp
        # round to a 64-multiple so the "mlp" axis shards evenly on 16-way TP
        d_ff = max(64, int(round(ffn_factor * d_model / 64) * 64))
        common = dict(dtype=dtype, param_dtype=param_dtype, dp=dp)
        self.norm = RMSNorm(f"{name}.norm", d_model, **common)
        self.conv = DepthwiseConv1d(f"{name}.conv", d_model, conv_k, **common)
        self.wx = Dense(
            f"{name}.wx", d_model, 4 * d_model, use_bias=True,
            w_axes=("embed", "mlp"), **common,
        )
        self.wr = Dense(
            f"{name}.wr", d_model, 4 * d_model, use_bias=False,
            w_axes=("embed", "mlp"), **common,
        )
        self.out_norm = RMSNorm(f"{name}.out_norm", d_model, **common)
        self.ffn_norm = RMSNorm(f"{name}.ffn_norm", d_model, **common)
        self.ffn = GatedMLP(f"{name}.ffn", d_model, d_ff, **common)

    def init(self, key: jax.Array) -> Params:
        ks = jax.random.split(key, 7)
        p = {
            "norm": self.norm.init(ks[0]),
            "conv": self.conv.init(ks[1]),
            "wx": self.wx.init(ks[2]),
            "wr": self.wr.init(ks[3]),
            "out_norm": self.out_norm.init(ks[4]),
            "ffn_norm": self.ffn_norm.init(ks[5]),
            "ffn": self.ffn.init(ks[6]),
        }
        d = self.d_model
        # forget gate bias positive
        p["wx"]["b"] = p["wx"]["b"].at[d : 2 * d].set(3.0)
        return p

    def axes(self) -> AxesTree:
        return {
            "norm": self.norm.axes(),
            "conv": self.conv.axes(),
            "wx": self.wx.axes(),
            "wr": self.wr.axes(),
            "out_norm": self.out_norm.axes(),
            "ffn_norm": self.ffn_norm.axes(),
            "ffn": self.ffn.axes(),
        }

    def __call__(
        self,
        params: Params,
        x: jax.Array,
        ctx: Ctx,
        *,
        cache: Optional[dict] = None,
    ) -> tuple[jax.Array, Optional[dict]]:
        bsz, t, d = x.shape
        res = x
        xn = self.norm(params["norm"], x, ctx.scope("norm"))
        conv_state = cache["conv"] if cache is not None else None
        xc, new_conv = self.conv(params["conv"], xn, ctx.scope("conv"), state=conv_state)
        xc = jax.nn.silu(xc)
        # Input-stream preactivations (W path); the recurrent tap rides here.
        pre = self.wx(params["wx"], xc, ctx.scope("wx"))  # (B, T, 4d)
        if self.dp and ctx.collect:
            pre = ctx.tap(
                "wr@out", pre, kind="matmul", a=None, T=t, D=d, p=4 * d,
                param_path="wr/w", late=True,
            )
        wr = reshard_param(params["wr"]["w"].astype(pre.dtype), ("embed", "mlp"))

        if cache is not None:
            h0 = cache["h"]
            c0, n0, m0 = cache["c"], cache["n"], cache["m"]
        else:
            h0 = jnp.zeros((bsz, d), pre.dtype)
            c0 = jnp.zeros((bsz, d), jnp.float32)
            n0 = jnp.zeros((bsz, d), jnp.float32)
            m0 = jnp.full((bsz, d), -1e30, jnp.float32)

        def step(carry, pre_t):
            h, c, n, m = carry
            s = pre_t + h @ wr  # (B, 4d)
            zi, fo, ii, oo = jnp.split(s.astype(jnp.float32), 4, axis=-1)
            z_g = jnp.tanh(zi)
            log_i = ii
            log_f = jax.nn.log_sigmoid(fo)
            o_g = jax.nn.sigmoid(oo)
            m_new = jnp.maximum(log_f + m, log_i)
            i_p = jnp.exp(log_i - m_new)
            f_p = jnp.exp(log_f + m - m_new)
            c = f_p * c + i_p * z_g
            n = f_p * n + i_p
            h_new = (o_g * (c / jnp.maximum(n, 1e-6))).astype(pre_t.dtype)
            return (h_new, c, n, m_new), h

        (h_last, c_l, n_l, m_l), hs = lax.scan(
            step, (h0, c0, n0, m0), pre.swapaxes(0, 1)
        )
        # hs[t] = h_{t-1} (input state at step t) — the recurrent activation.
        h_prev = hs.swapaxes(0, 1)  # (B, T, d)
        if self.dp and ctx.collect:
            ctx.record_act("wr@out", h_prev)
        # outputs h_t: shift: h_1..h_T = hs[1:] + h_last
        y = jnp.concatenate([h_prev[:, 1:], h_last[:, None]], axis=1)
        y = self.out_norm(params["out_norm"], y, ctx.scope("out_norm"))
        x = res + y
        h = self.ffn_norm(params["ffn_norm"], x, ctx.scope("ffn_norm"))
        x = x + self.ffn(params["ffn"], h, ctx.scope("ffn"))

        new_cache = None
        if cache is not None:
            new_cache = {"conv": new_conv, "h": h_last, "c": c_l, "n": n_l, "m": m_l}
        return x, new_cache

    def init_cache(self, batch: int, dtype) -> dict:
        d = self.d_model
        return {
            "conv": jnp.zeros((batch, self.conv_k - 1, d), dtype),
            "h": jnp.zeros((batch, d), dtype),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32),
        }
