"""Mixture-of-Experts with per-sample capacity dispatch.

Two dispatch modes:

- ``per_sample`` (DP training): capacity is allocated *per (sample, expert)*,
  so every expert matmul keeps the batch dimension and sample attribution is
  exact — the DP tap records activations as (B, E, C, d) with ``n_groups=E``
  and the ghost norm sums over experts (Alg. 1 applies per expert matrix).
  This is also what makes per-sample clipping of MoE *possible at all*:
  token-global dispatch would mix samples inside one expert matmul.

- ``global`` (serving): tokens from the whole batch share expert capacity
  (standard GShard-style inference dispatch, better utilization; no DP).

Dispatch is gather-based (argsort-free): slots are assigned by a cumulative
count over token-choice order; over-capacity tokens are dropped (scatter mode
'drop') and their combine weight zeroed.  Expert weights are (E, d, f) —
sharded expert-parallel when E divides the model axis, else tensor-parallel
inside each expert (resolved by ``repro.parallel.sharding``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.taps import Ctx
from repro.nn.module import Dense, Module, Params, AxesTree, normal_init
from repro.parallel.reshard import reshard_param


def _dispatch_one(x, logits, top_k: int, capacity: int, n_experts: int):
    """Single-sample dispatch. x: (T, d), logits: (T, E).

    Returns (xe (E, C, d), combine info (idx, slot, gate, keep)).
    """
    t, _ = x.shape
    gate_logits, idx = jax.lax.top_k(logits, top_k)  # (T, k)
    gates = jax.nn.softmax(gate_logits.astype(jnp.float32), axis=-1)

    flat_e = idx.reshape(-1)  # (T*k,) in token-major, choice-minor order
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)  # (T*k, E)
    slot_flat = jnp.cumsum(onehot, axis=0) - onehot  # occupancy before this entry
    slot_flat = jnp.sum(slot_flat * onehot, axis=-1)  # (T*k,)
    keep_flat = slot_flat < capacity

    token_flat = jnp.repeat(jnp.arange(t), top_k)
    table = jnp.full((n_experts, capacity), t, jnp.int32)  # sentinel = t (OOB)
    table = table.at[flat_e, slot_flat].set(token_flat, mode="drop")

    xe = jnp.take(x, table, axis=0, mode="fill", fill_value=0)  # (E, C, d)
    slot = slot_flat.reshape(t, top_k)
    keep = keep_flat.reshape(t, top_k)
    return xe, (idx, slot, gates, keep)


def _combine_one(ye, info, top_k: int, capacity: int):
    """ye: (E, C, p) -> (T, p) weighted combine."""
    idx, slot, gates, keep = info
    t = idx.shape[0]
    flat_e = idx.reshape(-1)
    flat_s = jnp.clip(slot.reshape(-1), 0, capacity - 1)
    picked = ye[flat_e, flat_s]  # (T*k, p)
    w = (gates * keep.astype(gates.dtype)).reshape(-1)[:, None]
    return jnp.sum((picked * w).reshape(t, top_k, -1), axis=1)


class MoE(Module):
    """Top-k routed experts with fused gate+up projections (SwiGLU experts)."""

    def __init__(
        self,
        name: str,
        d_model: int,
        d_ff: int,
        n_experts: int,
        top_k: int = 2,
        *,
        capacity_factor: float = 1.25,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        dp: bool = True,
    ):
        self.name = name
        self.d_model = d_model
        self.d_ff = d_ff
        self.n_experts = n_experts
        self.top_k = top_k
        self.capacity_factor = capacity_factor
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.dp = dp
        self.router = Dense(
            f"{name}.router", d_model, n_experts, use_bias=False,
            w_axes=("embed", None), dtype=jnp.float32, param_dtype=jnp.float32, dp=dp,
        )

    def init(self, key: jax.Array) -> Params:
        k1, k2, k3, k4 = jax.random.split(key, 4)
        e, d, f = self.n_experts, self.d_model, self.d_ff
        return {
            "router": self.router.init(k1),
            "wg": normal_init(k2, (e, d, f), 1.0 / math.sqrt(d), self.param_dtype),
            "wu": normal_init(k4, (e, d, f), 1.0 / math.sqrt(d), self.param_dtype),
            "wo": normal_init(k3, (e, f, d), 1.0 / math.sqrt(f), self.param_dtype),
        }

    def axes(self) -> AxesTree:
        return {
            "router": self.router.axes(),
            "wg": ("expert", "embed", "moe_mlp"),
            "wu": ("expert", "embed", "moe_mlp"),
            "wo": ("expert", "moe_mlp", "embed"),
        }

    def capacity(self, tokens_per_dispatch: int) -> int:
        cap = int(
            math.ceil(tokens_per_dispatch * self.top_k / self.n_experts * self.capacity_factor)
        )
        return max(cap, self.top_k)

    def __call__(
        self,
        params: Params,
        x: jax.Array,  # (B, T, d)
        ctx: Ctx,
        *,
        dispatch: str = "per_sample",  # "per_sample" (DP train) | "global" (serve)
    ) -> jax.Array:
        b, t, d = x.shape
        orig_b, orig_t = b, t
        if dispatch == "global":
            x = x.reshape(1, b * t, d)
            b, t = 1, b * t

        logits = self.router(params["router"], x, ctx.scope("router"))  # (B, T, E) fp32
        cap = self.capacity(t)

        xe, info = jax.vmap(
            lambda xx, ll: _dispatch_one(xx, ll, self.top_k, cap, self.n_experts)
        )(x, logits)
        # xe: (B, E, C, d)
        wg = reshard_param(params["wg"].astype(self.dtype), ("expert", "embed", "moe_mlp"))
        wu = reshard_param(params["wu"].astype(self.dtype), ("expert", "embed", "moe_mlp"))
        wo = reshard_param(params["wo"].astype(self.dtype), ("expert", "moe_mlp", "embed"))
        xe = xe.astype(self.dtype)
        gate = jnp.einsum("becd,edf->becf", xe, wg)
        up = jnp.einsum("becd,edf->becf", xe, wu)
        if self.dp and ctx.collect:
            gate = ctx.tap(
                "wg@out", gate, kind="matmul", a=xe, T=cap, D=d, p=self.d_ff,
                n_groups=self.n_experts, param_path="wg",
            )
            up = ctx.tap(
                "wu@out", up, kind="matmul", a=xe, T=cap, D=d, p=self.d_ff,
                n_groups=self.n_experts, param_path="wu",
            )
        act = jax.nn.silu(gate) * up
        ye = jnp.einsum("becf,efd->becd", act, wo)
        if self.dp and ctx.collect:
            ye = ctx.tap(
                "wo@out",
                ye,
                kind="matmul",
                a=act,
                T=cap,
                D=self.d_ff,
                p=d,
                n_groups=self.n_experts,
                param_path="wo",
            )
        y = jax.vmap(lambda yy, ii: _combine_one(yy, ii, self.top_k, cap))(ye, info)
        y = y.astype(self.dtype)
        if dispatch == "global":
            y = y.reshape(orig_b, orig_t, d)
        return y
