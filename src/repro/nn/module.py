"""Minimal functional module system (no flax dependency).

A Module is a static (hashable config) object with three methods:

- ``init(key) -> params``      pure parameter construction
- ``axes() -> axes_tree``      logical sharding axes mirroring ``init``
- ``__call__(params, x, ctx, ...)``  pure apply; ``ctx`` threads DP taps

Params are plain nested dicts of arrays so every jax transformation applies
directly.  Logical axis names are resolved to mesh axes by
``repro.parallel.sharding``.
"""
from __future__ import annotations

import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.taps import Ctx
from repro.parallel.reshard import reshard_param

Params = Any
AxesTree = Any


class Module:
    """Base class; subclasses are static configuration holders."""

    name: str

    def init(self, key: jax.Array) -> Params:
        raise NotImplementedError

    def axes(self) -> AxesTree:
        raise NotImplementedError

    def __call__(self, params: Params, x: jax.Array, ctx: Ctx, **kw):
        raise NotImplementedError


def normal_init(key: jax.Array, shape: Sequence[int], scale: float, dtype) -> jax.Array:
    return (scale * jax.random.normal(key, tuple(shape))).astype(dtype)


class Dense(Module):
    """y = x @ W + b with a DP tap on the pre-activation.

    ``x``: (B, ..., d_in) — all middle dims are positions T.
    The recorded activation is ``x`` reshaped to (B, T, d_in).
    """

    def __init__(
        self,
        name: str,
        d_in: int,
        d_out: int,
        *,
        use_bias: bool = True,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        w_axes: tuple = ("embed", "mlp"),
        init_scale: float = 1.0,
        dp: bool = True,
    ):
        self.name = name
        self.d_in = d_in
        self.d_out = d_out
        self.use_bias = use_bias
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.w_axes = w_axes
        self.init_scale = init_scale
        self.dp = dp

    def init(self, key: jax.Array) -> Params:
        scale = self.init_scale / math.sqrt(self.d_in)
        p = {"w": normal_init(key, (self.d_in, self.d_out), scale, self.param_dtype)}
        if self.use_bias:
            p["b"] = jnp.zeros((self.d_out,), self.param_dtype)
        return p

    def axes(self) -> AxesTree:
        a = {"w": self.w_axes}
        if self.use_bias:
            a["b"] = (self.w_axes[-1],)
        return a

    def __call__(self, params: Params, x: jax.Array, ctx: Ctx) -> jax.Array:
        w = reshard_param(params["w"].astype(self.dtype), self.w_axes)
        x = x.astype(self.dtype)
        s = x @ w
        if self.use_bias:
            s = s + params["b"].astype(self.dtype)
        if self.dp and ctx.collect:
            batch = x.shape[0]
            t = int(math.prod(x.shape[1:-1])) if x.ndim > 2 else 1
            a_rec = x.reshape(batch, t, self.d_in) if x.ndim != 3 else x
            s = ctx.tap(
                "out",
                s,
                kind="matmul",
                a=a_rec,
                T=t,
                D=self.d_in,
                p=self.d_out,
                param_path="w",
                bias_path="b" if self.use_bias else None,
            )
        return s


class Embedding(Module):
    """Token embedding with the index-equality ghost-norm tap."""

    def __init__(
        self,
        name: str,
        vocab: int,
        d: int,
        *,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        axes_: tuple = ("vocab", "embed"),
        dp: bool = True,
    ):
        self.name = name
        self.vocab = vocab
        self.d = d
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.axes_ = axes_
        self.dp = dp

    def init(self, key: jax.Array) -> Params:
        return {"e": normal_init(key, (self.vocab, self.d), 0.02, self.param_dtype)}

    def axes(self) -> AxesTree:
        return {"e": self.axes_}

    def __call__(self, params: Params, ids: jax.Array, ctx: Ctx) -> jax.Array:
        e = reshard_param(params["e"].astype(self.dtype), self.axes_)
        s = jnp.take(e, ids, axis=0)
        if self.dp and ctx.collect:
            batch, t = ids.shape[0], int(math.prod(ids.shape[1:]))
            s = ctx.tap(
                "out",
                s,
                kind="embedding",
                a=ids.reshape(batch, t),
                T=t,
                D=self.vocab,
                p=self.d,
                param_path="e",
            )
        return s


class RMSNorm(Module):
    """RMSNorm with a DP "scale" tap on the gamma product."""

    def __init__(
        self,
        name: str,
        d: int,
        *,
        eps: float = 1e-6,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        dp: bool = True,
    ):
        self.name = name
        self.d = d
        self.eps = eps
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.dp = dp

    def init(self, key: jax.Array) -> Params:
        del key
        return {"g": jnp.ones((self.d,), self.param_dtype)}

    def axes(self) -> AxesTree:
        return {"g": (None,)}

    def __call__(self, params: Params, x: jax.Array, ctx: Ctx) -> jax.Array:
        xf = x.astype(jnp.float32)
        x_hat = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + self.eps)
        x_hat = x_hat.astype(self.dtype)
        s = x_hat * params["g"].astype(self.dtype)
        if self.dp and ctx.collect:
            batch = x.shape[0]
            t = int(math.prod(x.shape[1:-1])) if x.ndim > 2 else 1
            s = ctx.tap(
                "out",
                s,
                kind="scale",
                a=x_hat.reshape(batch, t, self.d),
                T=t,
                D=self.d,
                p=self.d,
                param_path="g",
            )
        return s


class LayerNorm(Module):
    """LayerNorm (scale+bias) with a DP "scale" tap."""

    def __init__(
        self,
        name: str,
        d: int,
        *,
        eps: float = 1e-5,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        dp: bool = True,
    ):
        self.name = name
        self.d = d
        self.eps = eps
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.dp = dp

    def init(self, key: jax.Array) -> Params:
        del key
        return {
            "g": jnp.ones((self.d,), self.param_dtype),
            "b": jnp.zeros((self.d,), self.param_dtype),
        }

    def axes(self) -> AxesTree:
        return {"g": (None,), "b": (None,)}

    def __call__(self, params: Params, x: jax.Array, ctx: Ctx) -> jax.Array:
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        x_hat = ((xf - mu) * jax.lax.rsqrt(var + self.eps)).astype(self.dtype)
        s = x_hat * params["g"].astype(self.dtype) + params["b"].astype(self.dtype)
        if self.dp and ctx.collect:
            batch = x.shape[0]
            t = int(math.prod(x.shape[1:-1])) if x.ndim > 2 else 1
            s = ctx.tap(
                "out",
                s,
                kind="scale",
                a=x_hat.reshape(batch, t, self.d),
                T=t,
                D=self.d,
                p=self.d,
                param_path="g",
                bias_path="b",
            )
        return s


class GroupNorm(Module):
    """GroupNorm (the paper swaps BatchNorm for GroupNorm — BN is not DP-safe
    because batch statistics mix samples)."""

    def __init__(
        self,
        name: str,
        d: int,
        *,
        groups: int = 16,
        eps: float = 1e-5,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        dp: bool = True,
    ):
        assert d % groups == 0
        self.name = name
        self.d = d
        self.groups = groups
        self.eps = eps
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.dp = dp

    def init(self, key: jax.Array) -> Params:
        del key
        return {
            "g": jnp.ones((self.d,), self.param_dtype),
            "b": jnp.zeros((self.d,), self.param_dtype),
        }

    def axes(self) -> AxesTree:
        return {"g": (None,), "b": (None,)}

    def __call__(self, params: Params, x: jax.Array, ctx: Ctx) -> jax.Array:
        # x: (B, *spatial, d)
        batch = x.shape[0]
        spatial = x.shape[1:-1]
        xf = x.astype(jnp.float32).reshape(batch, -1, self.groups, self.d // self.groups)
        mu = jnp.mean(xf, axis=(1, 3), keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=(1, 3), keepdims=True)
        x_hat = ((xf - mu) * jax.lax.rsqrt(var + self.eps)).reshape(x.shape)
        x_hat = x_hat.astype(self.dtype)
        s = x_hat * params["g"].astype(self.dtype) + params["b"].astype(self.dtype)
        if self.dp and ctx.collect:
            t = int(math.prod(spatial))
            s = ctx.tap(
                "out",
                s,
                kind="scale",
                a=x_hat.reshape(batch, t, self.d),
                T=t,
                D=self.d,
                p=self.d,
                param_path="g",
                bias_path="b",
            )
        return s
