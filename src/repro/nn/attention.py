"""Grouped-query attention with RoPE, sliding windows, KV cache, cross-attn.

Projections are ``Dense`` modules → each gets a DP tap; the attention math
itself is parameter-free so the mixed-ghost machinery never needs to see it.
The score computation routes through the blocked flash implementation
(``repro.kernels.flash_attention``) so (Sq, Skv) scores are never materialized.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.taps import Ctx
from repro.kernels import dispatch
from repro.kernels.flash_attention.ops import flash_attention
from repro.nn.module import Dense, Module, Params, AxesTree
from repro.nn.rotary import apply_rope


def make_kv_cache(
    batch: int, max_len: int, n_kv: int, head_dim: int, dtype, window=None
) -> dict:
    """KV cache; a ring buffer of size ``window`` when sliding-window attention
    bounds the reachable context (Mixtral SWA at 500k context stores 4k slots).

    ``pos`` tracks the absolute position stored in each slot (-1 = empty);
    attention masks are computed from positions, so ring wraparound is free.
    """
    length = min(max_len, window) if window else max_len
    return {
        "k": jnp.zeros((batch, length, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, length, n_kv, head_dim), dtype),
        "pos": jnp.full((length,), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
    }




def blocked_decode_attention(
    q: jax.Array,  # (B, 1, H, hd)
    k: jax.Array,  # (B, S, K, hd)
    v: jax.Array,  # (B, S, K, hd)
    pos: jax.Array,  # (S,) absolute positions, -1 = empty slot
    qpos: jax.Array,  # scalar absolute position of the query
    *,
    n_blocks: int,
    causal: bool = True,
    window=None,
    scale=None,
) -> jax.Array:
    """Context-parallel decode: per-block partial softmax + tiny combine.

    The KV sequence dim is reshaped into (n_blocks, S/n_blocks); when the
    cache is sharded over the model axis, GSPMD keeps each block's partial
    (o, m, l) local and the combine is an all-reduce of (B, H, hd) —
    context parallelism without shard_map.
    """
    b, _, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    s_len = k.shape[1]
    assert s_len % n_blocks == 0
    blk = s_len // n_blocks
    scale = scale if scale is not None else hd**-0.5

    qf = q.astype(jnp.float32).reshape(b, kh, g, hd)
    kb = k.astype(jnp.float32).reshape(b, n_blocks, blk, kh, hd)
    vb = v.astype(jnp.float32).reshape(b, n_blocks, blk, kh, hd)
    pb = pos.reshape(n_blocks, blk)

    scores = jnp.einsum("bkgd,bnskd->bnkgs", qf, kb) * scale  # (B,nb,K,g,blk)
    mask = pb <= qpos
    mask &= pb >= 0
    if window is not None:
        mask &= (qpos - pb) < window
    scores = jnp.where(mask[None, :, None, None, :], scores, -1e30)

    m_b = jnp.max(scores, axis=-1)  # (B,nb,K,g)
    p = jnp.exp(scores - m_b[..., None])
    l_b = jnp.sum(p, axis=-1)
    o_b = jnp.einsum("bnkgs,bnskd->bnkgd", p, vb)
    # combine across blocks (the only cross-shard reduction)
    m = jnp.max(m_b, axis=1, keepdims=True)
    w = jnp.exp(m_b - m)
    l = jnp.sum(w * l_b, axis=1)
    o = jnp.sum(w[..., None] * o_b, axis=1) / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(b, 1, h, hd).astype(q.dtype)


class Attention(Module):
    def __init__(
        self,
        name: str,
        d_model: int,
        n_heads: int,
        n_kv: int,
        *,
        head_dim: Optional[int] = None,
        qkv_bias: bool = False,
        out_bias: bool = False,
        use_rope: bool = True,
        rope_theta: float = 10000.0,
        causal: bool = True,
        window: Optional[int] = None,
        cross: bool = False,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        block_q: int = 512,
        block_kv: int = 512,
        cp_threshold: int = 65536,
        cp_blocks: int = 64,
        dp: bool = True,
    ):
        self.name = name
        self.d_model = d_model
        self.n_heads = n_heads
        self.n_kv = n_kv
        self.head_dim = head_dim or d_model // n_heads
        self.qkv_bias = qkv_bias
        self.out_bias = out_bias
        self.use_rope = use_rope
        self.rope_theta = rope_theta
        self.causal = causal
        self.window = window
        self.cross = cross
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.block_q = block_q
        self.block_kv = block_kv
        self.cp_threshold = cp_threshold
        self.cp_blocks = cp_blocks
        self.dp = dp
        common = dict(dtype=dtype, param_dtype=param_dtype, dp=dp)
        self.wq = Dense(
            f"{name}.q", d_model, n_heads * self.head_dim,
            use_bias=qkv_bias, w_axes=("embed", "heads"), **common,
        )
        self.wk = Dense(
            f"{name}.k", d_model, n_kv * self.head_dim,
            use_bias=qkv_bias, w_axes=("embed", "kv_heads"), **common,
        )
        self.wv = Dense(
            f"{name}.v", d_model, n_kv * self.head_dim,
            use_bias=qkv_bias, w_axes=("embed", "kv_heads"), **common,
        )
        self.wo = Dense(
            f"{name}.o", n_heads * self.head_dim, d_model,
            use_bias=out_bias, w_axes=("heads", "embed"),
            init_scale=1.0, **common,
        )

    def init(self, key: jax.Array) -> Params:
        ks = jax.random.split(key, 4)
        return {
            "q": self.wq.init(ks[0]),
            "k": self.wk.init(ks[1]),
            "v": self.wv.init(ks[2]),
            "o": self.wo.init(ks[3]),
        }

    def axes(self) -> AxesTree:
        return {
            "q": self.wq.axes(),
            "k": self.wk.axes(),
            "v": self.wv.axes(),
            "o": self.wo.axes(),
        }

    def __call__(
        self,
        params: Params,
        x: jax.Array,  # (B, S, d)
        ctx: Ctx,
        *,
        positions: Optional[jax.Array] = None,  # (S,) or (B, S)
        cache: Optional[dict] = None,
        kv_src: Optional[jax.Array] = None,  # encoder states for cross-attn
    ) -> tuple[jax.Array, Optional[dict]]:
        b, s, _ = x.shape
        q = self.wq(params["q"], x, ctx.scope("q")).reshape(b, s, self.n_heads, self.head_dim)

        if self.cross:
            assert kv_src is not None or cache is not None
            if cache is not None and kv_src is None:
                k, v = cache["k"], cache["v"]  # precomputed encoder projections
                new_cache = cache
            else:
                skv = kv_src.shape[1]
                k = self.wk(params["k"], kv_src, ctx.scope("k"))
                k = k.reshape(b, skv, self.n_kv, self.head_dim)
                v = self.wv(params["v"], kv_src, ctx.scope("v"))
                v = v.reshape(b, skv, self.n_kv, self.head_dim)
                new_cache = {"k": k, "v": v} if cache is not None else None
            # serving (cache present) traces through kernel dispatch; the
            # training path needs the custom-VJP XLA op directly
            fa = dispatch.flash_attention if cache is not None else flash_attention
            out = fa(
                q, k, v, causal=False, block_q=self.block_q, block_kv=self.block_kv,
            )
            y = self.wo(params["o"], out.reshape(b, s, -1), ctx.scope("o"))
            return y, new_cache

        k = self.wk(params["k"], x, ctx.scope("k")).reshape(b, s, self.n_kv, self.head_dim)
        v = self.wv(params["v"], x, ctx.scope("v")).reshape(b, s, self.n_kv, self.head_dim)
        if positions is None:
            positions = jnp.arange(s)
        if self.use_rope:
            q = apply_rope(q, positions, self.rope_theta)
            k = apply_rope(k, positions, self.rope_theta)

        if cache is None:
            out = flash_attention(
                q, k, v, causal=self.causal, window=self.window,
                block_q=self.block_q, block_kv=self.block_kv,
            )
            new_cache = None
        else:
            idx = cache["idx"]
            length = cache["k"].shape[1]
            kc = k.astype(cache["k"].dtype)
            vc = v.astype(cache["v"].dtype)
            if s == 1:
                slot = jnp.mod(idx, length)
                ck = lax.dynamic_update_slice(cache["k"], kc, (0, slot, 0, 0))
                cv = lax.dynamic_update_slice(cache["v"], vc, (0, slot, 0, 0))
                pos = lax.dynamic_update_slice(cache["pos"], idx[None], (slot,))
            elif s <= length:
                # prefill from empty (idx assumed 0)
                ck = lax.dynamic_update_slice(cache["k"], kc, (0, 0, 0, 0))
                cv = lax.dynamic_update_slice(cache["v"], vc, (0, 0, 0, 0))
                pos = lax.dynamic_update_slice(
                    cache["pos"], jnp.arange(s, dtype=jnp.int32), (0,)
                )
            else:
                # ring prefill: attend over the full sequence, but only the
                # last ``length`` slots stay reachable for later decode steps.
                # Slot invariant: slot j holds position p with p % length == j,
                # so later single-token writes (slot = idx % length) line up.
                shift = s % length
                ck = jnp.roll(kc[:, s - length :], shift, axis=1)
                cv = jnp.roll(vc[:, s - length :], shift, axis=1)
                pos = jnp.roll(jnp.arange(s - length, s, dtype=jnp.int32), shift)
                new_cache = {"k": ck, "v": cv, "pos": pos, "idx": idx + s}
                out = dispatch.flash_attention(
                    q, kc, vc, causal=self.causal, window=self.window,
                    block_q=self.block_q, block_kv=self.block_kv,
                )
                y = self.wo(params["o"], out.reshape(b, s, -1), ctx.scope("o"))
                return y, new_cache
            new_cache = {"k": ck, "v": cv, "pos": pos, "idx": idx + s}
            if s == 1 and length >= self.cp_threshold:
                out = blocked_decode_attention(
                    q, ck, cv, pos, idx, n_blocks=self.cp_blocks,
                    causal=self.causal, window=self.window,
                )
            else:
                # traced q_offset + ring kv_positions: dispatch falls back
                # to the XLA path today, but the choice point is here
                out = dispatch.flash_attention(
                    q, ck, cv, causal=self.causal, window=self.window,
                    q_offset=idx, kv_positions=pos,
                    block_q=self.block_q, block_kv=self.block_kv,
                )
        y = self.wo(params["o"], out.reshape(b, s, -1), ctx.scope("o"))
        return y, new_cache
