"""Mamba block (SSD / Mamba-2 style scalar-per-head decay), TPU-native.

Hardware adaptation (documented in DESIGN.md): the original Mamba-1 CUDA
kernel runs a sequential selective scan with per-(channel, state) decays in
SRAM.  On TPU we use the SSD formulation — scalar decay per head per step —
whose chunked form is MXU-friendly matmuls (see ``ssm_scan.chunked_ssm``).

All trainable parameters enter through taps:
- ``in_proj`` / ``out_proj``: matmul taps (Dense)
- ``conv1d``: dw_conv tap
- ``dt_bias``: bias tap on the dt stream
- ``A_log``:  scale tap on the decay stream (d log_a / d A_log = log_a)
- ``D``:      scale tap on the skip stream
so per-sample clipping covers the whole block exactly.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.taps import Ctx
from repro.nn.conv import DepthwiseConv1d
from repro.nn.module import Dense, Module, Params, AxesTree, RMSNorm
from repro.nn.ssm_scan import chunked_ssm, ssm_decode_step
from repro.parallel.reshard import shard_heads


class MambaBlock(Module):
    def __init__(
        self,
        name: str,
        d_model: int,
        *,
        expand: int = 2,
        head_dim: int = 64,
        d_state: int = 64,
        conv_k: int = 4,
        chunk: int = 256,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        dp: bool = True,
    ):
        self.name = name
        self.d_model = d_model
        self.d_inner = expand * d_model
        assert self.d_inner % head_dim == 0
        self.n_heads = self.d_inner // head_dim
        self.head_dim = head_dim
        self.d_state = d_state
        self.conv_k = conv_k
        self.chunk = chunk
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.dp = dp
        # separate projections (a fused one would need sharded-dim splits):
        # z (d_inner), x (d_inner), bcdt (2*d_state + H, replicated — tiny)
        common = dict(dtype=dtype, param_dtype=param_dtype, dp=dp)
        self.in_z = Dense(
            f"{name}.in_z", d_model, self.d_inner, use_bias=False,
            w_axes=("embed", "mlp"), **common,
        )
        self.in_x = Dense(
            f"{name}.in_x", d_model, self.d_inner, use_bias=False,
            w_axes=("embed", "mlp"), **common,
        )
        self.in_bcdt = Dense(
            f"{name}.in_bcdt", d_model, 2 * d_state + self.n_heads, use_bias=False,
            w_axes=("embed", None), **common,
        )
        self.conv = DepthwiseConv1d(
            f"{name}.conv", self.d_inner, conv_k, use_bias=True, **common
        )
        self.norm = RMSNorm(f"{name}.norm", self.d_inner, **common)
        self.out_proj = Dense(
            f"{name}.out_proj", self.d_inner, d_model, use_bias=False,
            w_axes=("mlp", "embed"), **common,
        )

    def init(self, key: jax.Array) -> Params:
        ks = jax.random.split(key, 5)
        h = self.n_heads
        # dt bias: inverse softplus of dt in [1e-3, 1e-1] (mamba default)
        dt = jnp.exp(
            jax.random.uniform(ks[3], (h,)) * (math.log(0.1) - math.log(1e-3))
            + math.log(1e-3)
        )
        dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
        a_init = jnp.log(jnp.linspace(1.0, 16.0, h))
        ks = list(ks) + list(jax.random.split(ks[0], 2))
        return {
            "in_z": self.in_z.init(ks[0]),
            "in_x": self.in_x.init(ks[5]),
            "in_bcdt": self.in_bcdt.init(ks[6]),
            "conv": self.conv.init(ks[1]),
            "out_proj": self.out_proj.init(ks[2]),
            "norm": self.norm.init(ks[4]),
            "dt_bias": dt_bias.astype(self.param_dtype),
            "A_log": a_init.astype(self.param_dtype),
            "D": jnp.ones((h,), self.param_dtype),
        }

    def axes(self) -> AxesTree:
        return {
            "in_z": self.in_z.axes(),
            "in_x": self.in_x.axes(),
            "in_bcdt": self.in_bcdt.axes(),
            "conv": self.conv.axes(),
            "out_proj": self.out_proj.axes(),
            "norm": self.norm.axes(),
            "dt_bias": (None,),
            "A_log": (None,),
            "D": (None,),
        }


    def __call__(
        self,
        params: Params,
        x: jax.Array,  # (B, T, d)
        ctx: Ctx,
        *,
        cache: Optional[dict] = None,
    ) -> tuple[jax.Array, Optional[dict]]:
        bsz, t, _ = x.shape
        h, dh, ds = self.n_heads, self.head_dim, self.d_state

        z = self.in_z(params["in_z"], x, ctx.scope("in_z"))
        xs = self.in_x(params["in_x"], x, ctx.scope("in_x"))
        bcdt = self.in_bcdt(params["in_bcdt"], x, ctx.scope("in_bcdt"))
        ds = self.d_state
        b_in = bcdt[..., :ds]
        c_in = bcdt[..., ds : 2 * ds]
        dt = bcdt[..., 2 * ds :]

        conv_state = cache["conv"] if cache is not None else None
        xs, new_conv_state = self.conv(params["conv"], xs, ctx.scope("conv"), state=conv_state)
        xs = jax.nn.silu(xs)

        # dt stream with bias tap
        dt = dt + params["dt_bias"].astype(dt.dtype)
        if self.dp and ctx.collect:
            dt = ctx.tap(
                "dt_bias@out", dt, kind="bias", T=t, D=1, p=h,
                param_path="dt_bias",
            )
        delta = jax.nn.softplus(dt.astype(jnp.float32))  # (B, T, H)

        # decay stream: log_a = -exp(A_log) * delta ; d(log_a)/d(A_log) = log_a
        log_a = -jnp.exp(params["A_log"].astype(jnp.float32)) * delta
        if self.dp and ctx.collect:
            log_a = ctx.tap(
                "A_log@out", log_a, kind="scale", a=log_a, T=t, D=h, p=h,
                param_path="A_log",
            )

        v = xs.reshape(bsz, t, h, dh) * delta[..., None].astype(xs.dtype)
        q = jnp.broadcast_to(c_in[:, :, None, :], (bsz, t, h, ds))
        k = jnp.broadcast_to(b_in[:, :, None, :], (bsz, t, h, ds))
        if t > 1:  # decode (t=1) tensors are tiny; constraints only add reshards
            v, q, k = shard_heads(v), shard_heads(q), shard_heads(k)
            log_a = shard_heads(log_a, axis=2) if log_a.ndim > 2 else log_a

        if cache is not None and t == 1:
            y, new_ssm = ssm_decode_step(q, k, v, log_a, cache["ssm"])
            y = y.reshape(bsz, t, self.d_inner)
        else:
            state0 = cache["ssm"] if cache is not None else None
            y, new_ssm = chunked_ssm(q, k, v, log_a, chunk=self.chunk, state0=state0)
            y = y.reshape(bsz, t, self.d_inner)

        # D skip: s = D * xs  (scale tap, a = xs per head)
        skip = xs * jnp.repeat(params["D"].astype(xs.dtype), dh)[None, None, :]
        if self.dp and ctx.collect:
            # per-head scale: record per-head-summed jacobian entries
            skip = ctx.tap(
                "D@out", skip, kind="scale_grouped", a=xs, T=t, D=dh, p=h,
                param_path="D",
            )
        y = y + skip
        y = y * jax.nn.silu(z)
        y = self.norm(params["norm"], y, ctx.scope("norm"))
        out = self.out_proj(params["out_proj"], y, ctx.scope("out_proj"))

        new_cache = None
        if cache is not None:
            new_cache = {"conv": new_conv_state, "ssm": new_ssm}
        return out, new_cache

    def init_cache(self, batch: int, dtype) -> dict:
        return {
            "conv": jnp.zeros((batch, self.conv_k - 1, self.d_inner), dtype),
            "ssm": jnp.zeros((batch, self.n_heads, self.d_state, self.head_dim), jnp.float32),
        }
