"""Chunked linear-recurrence scan: the TPU-native SSM primitive.

Computes, per head, the gated linear recurrence

    S_t = a_t * S_{t-1} + k_t v_t^T          (S: (dk, dv), a_t in (0, 1])
    y_t = q_t @ S_t

used by both the Mamba-2/SSD-style blocks (Jamba) and mLSTM (xLSTM).  The
sequence is processed in chunks of length L: within a chunk the contribution
is a masked, decay-weighted score matrix (quadratic in L only); across chunks
a single state tensor is carried.  Memory is O(T*L + (T/L)*dk*dv) instead of
the O(T*dk*dv) a materialized parallel scan would need — this mirrors how the
original CUDA kernel tiles SRAM, re-thought for MXU-sized (128-aligned) chunk
matmuls in VMEM.

Numerics: decays are passed as log_a <= 0; all within-chunk factors are
exp(negative) <= 1 so fp32 accumulation is stable without a max-stabilizer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def chunked_ssm(
    q: jax.Array,  # (B, T, H, dk)
    k: jax.Array,  # (B, T, H, dk)
    v: jax.Array,  # (B, T, H, dv)
    log_a: jax.Array,  # (B, T, H) decay logs, <= 0
    *,
    chunk: int = 256,
    state0: Optional[jax.Array] = None,  # (B, H, dk, dv)
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B, T, H, dv), final_state (B, H, dk, dv))."""
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        zf = lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
        q, k, v, log_a = zf(q), zf(k), zf(v), zf(log_a)
    tp = q.shape[1]
    n = tp // chunk

    # storage dtype through the scan xs; per-chunk slices upcast inside the
    # body (an upfront fp32 copy of q/k/v stays live through the whole scan:
    # 3 x 17 GB on jamba's mamba layers)
    qf = q.reshape(b, n, chunk, h, dk).swapaxes(0, 1)
    kf = k.reshape(b, n, chunk, h, dk).swapaxes(0, 1)
    vf = v.reshape(b, n, chunk, h, dv).swapaxes(0, 1)
    la = log_a.astype(jnp.float32).reshape(b, n, chunk, h).swapaxes(0, 1)

    if state0 is None:
        state0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    def one_chunk(state, xs):
        qc, kc, vc, lac = xs  # (B, L, H, ...)
        qc = qc.astype(jnp.float32)
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        cum = jnp.cumsum(lac, axis=1)  # (B, L, H) inclusive
        total = cum[:, -1]  # (B, H)
        # Inter-chunk: y_t += exp(cum_t) * q_t @ S0
        y_inter = jnp.einsum("blhk,bhkv->blhv", qc * jnp.exp(cum)[..., None], state)
        # Intra-chunk: scores M[t, s] = (q_t . k_s) * exp(cum_t - cum_s), s <= t
        scores = jnp.einsum("blhk,bshk->bhls", qc, kc)
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # (B, L, S, H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        scores = scores * w.transpose(0, 3, 1, 2)
        y_intra = jnp.einsum("bhls,bshv->blhv", scores, vc)
        # State update: S' = exp(total) S0 + sum_s exp(total - cum_s) k_s v_s^T
        kw = kc * jnp.exp(total[:, None] - cum)[..., None]
        state = state * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bshk,bshv->bhkv", kw, vc
        )
        return state, y_inter + y_intra

    state, ys = lax.scan(one_chunk, state0, (qf, kf, vf, la))
    y = ys.swapaxes(0, 1).reshape(b, tp, h, dv)[:, :t]
    return y.astype(v.dtype), state


def ssm_decode_step(
    q: jax.Array,  # (B, 1, H, dk)
    k: jax.Array,
    v: jax.Array,  # (B, 1, H, dv)
    log_a: jax.Array,  # (B, 1, H)
    state: jax.Array,  # (B, H, dk, dv)
) -> tuple[jax.Array, jax.Array]:
    """Single-token recurrent update (serving)."""
    a = jnp.exp(log_a.astype(jnp.float32))[:, 0, :, None, None]  # (B, H, 1, 1)
    kv = jnp.einsum(
        "bhk,bhv->bhkv", k[:, 0].astype(jnp.float32), v[:, 0].astype(jnp.float32)
    )
    new_state = state * a + kv
    y = jnp.einsum("bhk,bhkv->bhv", q[:, 0].astype(jnp.float32), new_state)
    return y[:, None].astype(v.dtype), new_state


def ssm_reference(q, k, v, log_a, state0=None):
    """Sequential oracle (pure scan over time) for tests."""
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    def step(s, xs):
        qt, kt, vt, lat = xs  # (B, H, ...)
        s = s * jnp.exp(lat.astype(jnp.float32))[..., None, None] + jnp.einsum(
            "bhk,bhv->bhkv", kt.astype(jnp.float32), vt.astype(jnp.float32)
        )
        return s, jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32), s)

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1), log_a.swapaxes(0, 1))
    state, ys = lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1).astype(v.dtype), state
