"""Layer stacking via lax.scan with DP-tap stacking.

``ScannedStack`` scans one block definition over stacked parameters (the
MaxText pattern — compile time stays flat in depth).  DP taps inside the block
are threaded as scan xs (per-layer slices of the stacked tap arrays) and the
recorded activations come out as scan ys (stacked).  The parent tap metadata
gains a leading stack dimension; the clipping engine folds it into the
per-sample norm reduction (Alg. 1 sums norms over layers anyway).

``SequentialBlocks`` composes heterogeneous blocks (e.g. Jamba's
[mamba x3, attn, mamba x4] period); a ScannedStack of a SequentialBlocks
period gives interleaved architectures with one compiled block body.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.taps import Ctx, TapMeta
from repro.nn.module import Module, Params, AxesTree
from repro.parallel.reshard import shard_seq


class SequentialBlocks(Module):
    """Apply blocks in order; params/cache keyed by position index.

    ``nested_remat`` checkpoints each sub-block individually (off by default:
    measured no memory win on jamba — XLA already schedules the period
    backward block-by-block — and a ~10% wire regression; §Perf iter 12).
    """

    def __init__(self, name: str, blocks: Sequence[Module], *, nested_remat: bool = False):
        self.name = name
        self.blocks = list(blocks)
        self.nested_remat = nested_remat

    def init(self, key: jax.Array) -> Params:
        ks = jax.random.split(key, len(self.blocks))
        return {str(i): b.init(ks[i]) for i, b in enumerate(self.blocks)}

    def axes(self) -> AxesTree:
        return {str(i): b.axes() for i, b in enumerate(self.blocks)}

    def init_cache(self, batch: int, dtype, **kw) -> dict:
        return {
            str(i): b.init_cache(batch, dtype, **kw) if hasattr(b, "init_cache") else None
            for i, b in enumerate(self.blocks)
        }

    def __call__(self, params, x, ctx, *, cache=None, **kw):
        new_cache = {} if cache is not None else None
        for i, b in enumerate(self.blocks):
            c_i = cache[str(i)] if cache is not None else None

            def run(p_i, x_i, cc, blk=b, sc=str(i)):
                return blk(p_i, x_i, ctx.scope(sc), cache=cc, **kw)

            if self.nested_remat and len(self.blocks) > 1 and ctx.collect:
                run = jax.checkpoint(run)
            x, c_o = run(params[str(i)], x, c_i)
            if cache is not None:
                new_cache[str(i)] = c_o
        return x, new_cache


class ScannedStack(Module):
    """n copies of ``block`` applied via lax.scan over stacked params."""

    def __init__(self, name: str, block: Module, n: int, *, remat: bool = True):
        self.name = name
        self.block = block
        self.n = n
        self.remat = remat

    def init(self, key: jax.Array) -> Params:
        keys = jax.random.split(key, self.n)
        return jax.vmap(self.block.init)(keys)

    def axes(self) -> AxesTree:
        inner = self.block.axes()
        return jax.tree_util.tree_map(
            lambda a: ("stack",) + tuple(a),
            inner,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x
            ),
        )

    def init_cache(self, batch: int, dtype, **kw) -> Any:
        if not hasattr(self.block, "init_cache"):
            return None
        one = self.block.init_cache(batch, dtype, **kw)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (self.n,) + x.shape), one
        )

    def _child_path(self, ctx: Ctx) -> str:
        # ctx is already scoped to this stack's param subtree by the caller
        # (convention: module(params[key], x, ctx.scope(key))).
        return ctx.path

    def _discover(self, params, x, ctx: Ctx, cache, kw) -> dict[str, TapMeta]:
        """Trace the block once abstractly to enumerate tap names/shapes."""
        meta: dict[str, TapMeta] = {}
        child_path = self._child_path(ctx)

        def probe(p_i, x_i, cache_i):
            cctx = Ctx(taps=None, meta=meta, path=child_path, collect=True,
                       clip=ctx.clip)
            y, c = self.block(p_i, x_i, cctx, cache=cache_i, **kw)
            return y, c

        p_spec = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), params
        )
        x_spec = jax.ShapeDtypeStruct(x.shape, x.dtype)
        c_spec = None
        if cache is not None:
            c_spec = jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), cache
            )
        jax.eval_shape(probe, p_spec, x_spec, c_spec)
        return meta

    def __call__(self, params, x, ctx: Ctx, *, cache=None, **kw):
        child_path = self._child_path(ctx)

        if not ctx.collect:
            def body_s(carry, xs):
                p_i, c_i = xs
                y, c_o = self.block(p_i, carry, Ctx.disabled(), cache=c_i, **kw)
                return shard_seq(y), c_o

            if self.remat:
                body_s = jax.checkpoint(body_s)
            y, new_cache = lax.scan(body_s, x, (params, cache))
            return y, (new_cache if cache is not None else None)

        meta = self._discover(params, x, ctx, cache, kw)
        for name, m in meta.items():
            ctx.meta[name] = m.with_stack(self.n)

        has_taps = ctx.taps is not None
        has_zs = ctx.zs is not None
        taps_sliced = None
        zs_sliced = None
        if has_taps:
            taps_sliced = {k: ctx.taps[k] for k in meta if k in ctx.taps}
        if has_zs:
            zs_sliced = {k: ctx.zs[k] for k in meta if k in ctx.zs}

        def body(carry, xs):
            p_i, taps_i, zs_i, c_i = xs
            cctx = Ctx(
                taps=taps_i if has_taps else None,
                zs=zs_i if has_zs else None,
                meta={},
                path=child_path,
                collect=True,
                clip=ctx.clip,
            )
            y, c_o = self.block(p_i, carry, cctx, cache=c_i, **kw)
            return shard_seq(y), (cctx.acts, c_o)

        if self.remat:
            body = jax.checkpoint(body)
        y, (acts, new_cache) = lax.scan(body, x, (params, taps_sliced, zs_sliced, cache))
        for k, v in acts.items():
            ctx.acts[k] = v  # stacked (n, ...)
        return y, (new_cache if cache is not None else None)
