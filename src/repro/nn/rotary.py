"""Rotary position embeddings (RoPE)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Apply RoPE. x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    pos = positions.astype(jnp.float32)
    if pos.ndim == 1:
        pos = pos[None, :]
    ang = pos[..., None] * inv[None, None, :]  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]  # (B, S, 1, hd/2)
    sin = jnp.sin(ang)[:, :, None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)
