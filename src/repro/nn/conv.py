"""Convolution layers with DP taps (the paper's central case).

``Conv2d`` records its *raw* input plus unfold metadata; the DP engine unfolds
lazily (im2col via ``lax.conv_general_dilated_patches``) only on the branch the
layerwise decision selects, so the forward pass stays on the fused conv op.

``DepthwiseConv1d`` (Mamba/xLSTM frontends) records the unfolded input
directly — its kernel is tiny (k*d params) so the instantiate branch always
wins and the unfold is k copies of a (B, T, d) tensor.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.taps import ConvInfo, Ctx
from repro.nn.module import Module, Params, AxesTree, normal_init
from repro.parallel.reshard import reshard_param


def unfold2d(x: jax.Array, info: ConvInfo) -> jax.Array:
    """U(a): (B, H, W, d) -> (B, H_out*W_out, d*kh*kw).

    Feature ordering follows ``conv_general_dilated_patches`` which is
    channel-major: index = c * (kh*kw) + kh_i * kw + kw_i.  Weights reshaped
    as (d, kh, kw, p) -> (d*kh*kw, p) match this ordering.
    """
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=info.kernel,
        window_strides=info.strides,
        padding=info.padding,
        rhs_dilation=info.rhs_dilation,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    b = x.shape[0]
    return patches.reshape(b, -1, patches.shape[-1])


class Conv2d(Module):
    """NHWC conv with a DP "matmul" tap (T = H_out*W_out, D = d*kh*kw)."""

    def __init__(
        self,
        name: str,
        d_in: int,
        d_out: int,
        kernel: tuple[int, int],
        *,
        strides: tuple[int, int] = (1, 1),
        padding="SAME",
        use_bias: bool = True,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        dp: bool = True,
    ):
        self.name = name
        self.d_in = d_in
        self.d_out = d_out
        self.kernel = kernel
        self.strides = strides
        self.padding = padding
        self.use_bias = use_bias
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.dp = dp

    def init(self, key: jax.Array) -> Params:
        fan_in = self.d_in * math.prod(self.kernel)
        p = {
            "w": normal_init(
                key,
                (*self.kernel, self.d_in, self.d_out),
                1.0 / math.sqrt(fan_in),
                self.param_dtype,
            )
        }
        if self.use_bias:
            p["b"] = jnp.zeros((self.d_out,), self.param_dtype)
        return p

    def axes(self) -> AxesTree:
        a = {"w": (None, None, "embed", "mlp")}
        if self.use_bias:
            a["b"] = ("mlp",)
        return a

    def __call__(self, params: Params, x: jax.Array, ctx: Ctx) -> jax.Array:
        w = reshard_param(params["w"].astype(self.dtype), (None, None, "embed", "mlp"))
        x = x.astype(self.dtype)
        s = lax.conv_general_dilated(
            x,
            w,
            window_strides=self.strides,
            padding=self.padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        if self.use_bias:
            s = s + params["b"].astype(self.dtype)
        if self.dp and ctx.collect:
            t = int(math.prod(s.shape[1:-1]))
            big_d = self.d_in * math.prod(self.kernel)
            s = ctx.tap(
                "out",
                s,
                kind="matmul",
                a=x,  # raw input; engine unfolds lazily
                T=t,
                D=big_d,
                p=self.d_out,
                param_path="w",
                bias_path="b" if self.use_bias else None,
                conv=ConvInfo(
                    kernel=tuple(self.kernel),
                    strides=tuple(self.strides),
                    padding=self.padding,
                ),
            )
        return s


class DepthwiseConv1d(Module):
    """Causal depthwise conv1d (Mamba / xLSTM frontend), kernel (k, d).

    s[b, t, c] = sum_j w[j, c] * x[b, t - k + 1 + j, c]  (left-padded).
    Tap kind "dw_conv": recorded act is the unfolded (B, T, k, d).
    """

    def __init__(
        self,
        name: str,
        d: int,
        k: int = 4,
        *,
        use_bias: bool = True,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        dp: bool = True,
    ):
        self.name = name
        self.d = d
        self.k = k
        self.use_bias = use_bias
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.dp = dp

    def init(self, key: jax.Array) -> Params:
        p = {"w": normal_init(key, (self.k, self.d), 1.0 / math.sqrt(self.k), self.param_dtype)}
        if self.use_bias:
            p["b"] = jnp.zeros((self.d,), self.param_dtype)
        return p

    def axes(self) -> AxesTree:
        a = {"w": (None, "mlp")}
        if self.use_bias:
            a["b"] = ("mlp",)
        return a

    def unfold(self, x: jax.Array, state: Optional[jax.Array] = None) -> jax.Array:
        """(B, T, d) -> (B, T, k, d): window ending at each t (causal)."""
        if state is None:
            pad = jnp.zeros((x.shape[0], self.k - 1, self.d), x.dtype)
        else:
            pad = state.astype(x.dtype)  # (B, k-1, d) trailing context
        xp = jnp.concatenate([pad, x], axis=1)  # (B, T+k-1, d)
        cols = [xp[:, j : j + x.shape[1], :] for j in range(self.k)]
        return jnp.stack(cols, axis=2)

    def __call__(
        self,
        params: Params,
        x: jax.Array,
        ctx: Ctx,
        *,
        state: Optional[jax.Array] = None,
    ) -> tuple[jax.Array, jax.Array]:
        """Returns (y, new_state) where state is the last k-1 inputs."""
        x = x.astype(self.dtype)
        unf = self.unfold(x, state)  # (B, T, k, d)
        w = reshard_param(params["w"].astype(self.dtype), (None, "mlp"))
        s = jnp.einsum("btkd,kd->btd", unf, w)
        if self.use_bias:
            s = s + params["b"].astype(self.dtype)
        if self.dp and ctx.collect:
            s = ctx.tap(
                "out",
                s,
                kind="dw_conv",
                a=unf,
                T=int(x.shape[1]),
                D=self.k,
                p=self.d,
                param_path="w",
                bias_path="b" if self.use_bias else None,
            )
        if state is None:
            new_state = x[:, -(self.k - 1) :, :] if x.shape[1] >= self.k - 1 else None
        else:
            joint = jnp.concatenate([state.astype(x.dtype), x], axis=1)
            new_state = joint[:, -(self.k - 1) :, :]
        return s, new_state


def max_pool2d(x: jax.Array, window: int = 2, stride: int = 2) -> jax.Array:
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        (1, window, window, 1),
        (1, stride, stride, 1),
        "VALID",
    )


def avg_pool2d(x: jax.Array, window: int, stride: int = 1, padding="VALID") -> jax.Array:
    summed = lax.reduce_window(
        x, 0.0, lax.add, (1, window, window, 1), (1, stride, stride, 1), padding
    )
    return summed / float(window * window)


def global_avg_pool(x: jax.Array) -> jax.Array:
    return jnp.mean(x, axis=(1, 2))
