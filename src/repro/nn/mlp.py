"""Feed-forward blocks: gated (SwiGLU) and plain (GELU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.taps import Ctx
from repro.nn.module import Dense, Module, Params, AxesTree


class GatedMLP(Module):
    """SwiGLU: down(silu(gate(x)) * up(x)).

    Gate and up are SEPARATE matmuls: a fused (d, 2f) projection must be
    split along the TP-sharded dim afterwards, which GSPMD lowers to
    collective-permute + all-to-all redistributions (measured ~2 GB/layer on
    yi-6b — EXPERIMENTS.md §Perf iteration 2).
    """

    def __init__(
        self,
        name: str,
        d_model: int,
        d_ff: int,
        *,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        dp: bool = True,
    ):
        self.name = name
        self.d_model = d_model
        self.d_ff = d_ff
        common = dict(dtype=dtype, param_dtype=param_dtype, dp=dp, use_bias=False)
        self.wg = Dense(f"{name}.wg", d_model, d_ff, w_axes=("embed", "mlp"), **common)
        self.wu = Dense(f"{name}.wu", d_model, d_ff, w_axes=("embed", "mlp"), **common)
        self.wo = Dense(f"{name}.wo", d_ff, d_model, w_axes=("mlp", "embed"), **common)

    def init(self, key: jax.Array) -> Params:
        k1, k2, k3 = jax.random.split(key, 3)
        return {"wg": self.wg.init(k1), "wu": self.wu.init(k2), "wo": self.wo.init(k3)}

    def axes(self) -> AxesTree:
        return {"wg": self.wg.axes(), "wu": self.wu.axes(), "wo": self.wo.axes()}

    def __call__(self, params: Params, x: jax.Array, ctx: Ctx) -> jax.Array:
        gate = self.wg(params["wg"], x, ctx.scope("wg"))
        up = self.wu(params["wu"], x, ctx.scope("wu"))
        return self.wo(params["wo"], jax.nn.silu(gate) * up, ctx.scope("wo"))


class MLP(Module):
    """Plain transformer FFN with GELU (whisper, ViT, phi-style)."""

    def __init__(
        self,
        name: str,
        d_model: int,
        d_ff: int,
        *,
        use_bias: bool = True,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        dp: bool = True,
    ):
        self.name = name
        common = dict(dtype=dtype, param_dtype=param_dtype, dp=dp, use_bias=use_bias)
        self.wi = Dense(f"{name}.wi", d_model, d_ff, w_axes=("embed", "mlp"), **common)
        self.wo = Dense(f"{name}.wo", d_ff, d_model, w_axes=("mlp", "embed"), **common)

    def init(self, key: jax.Array) -> Params:
        k1, k2 = jax.random.split(key)
        return {"wi": self.wi.init(k1), "wo": self.wo.init(k2)}

    def axes(self) -> AxesTree:
        return {"wi": self.wi.axes(), "wo": self.wo.axes()}

    def __call__(self, params: Params, x: jax.Array, ctx: Ctx) -> jax.Array:
        h = jax.nn.gelu(self.wi(params["wi"], x, ctx.scope("wi")))
        return self.wo(params["wo"], h, ctx.scope("wo"))
