"""Measured per-tap branch costs: ghost norm vs gradient instantiation.

The analytic decision (Eq 4.1) counts multiplies; this module instead times
both branch kernels on the actual device over the tap's real canonical
shapes — a (N, T, D) activation against a (N, T, p) cotangent, exactly what
``ghost.tap_norm_sq`` feeds them at train time — with warmup and
median-of-k.  Convolution taps are timed post-unfold: both branches consume
the unfolded activation, so the (shared) im2col cost cancels out of the
comparison.

Only matmul taps are measured.  Embedding / scale / bias / dw_conv taps have
a single viable branch (decision.decide's forced cases) and are never
overridden.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Optional

import jax
import jax.numpy as jnp

from repro.core.decision import decide
from repro.core.taps import TapMeta
from repro.kernels.ghost_norm import ops as gops
from repro.tuner.plan import (
    ClipPlan,
    TapTiming,
    device_string,
    shape_fingerprint,
    tap_signature,
)
from repro.utils.logging import get_logger

log = get_logger("tuner.measure")


@dataclasses.dataclass(frozen=True)
class MeasureConfig:
    repeats: int = 5  # timed iterations; the median is kept
    warmup: int = 2  # discarded iterations (compile + caches)
    ghost_block: int = 512
    inst_block_d: int = 8192
    # clamp the row dim N = stack*B*groups during profiling; timings scale
    # ~linearly in N, so the *comparison* is preserved while huge-batch taps
    # stay cheap to profile (tuning must never OOM the device it is sizing).
    # None = use the discovered batch as-is.
    max_rows: Optional[int] = 64
    seed: int = 0


def time_us(fn, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall microseconds per call (blocks on outputs)."""
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    samples.sort()
    return samples[len(samples) // 2]


def _tap_rows(meta: TapMeta, max_rows: Optional[int]) -> int:
    n = meta.n_stack * max(meta.batch_size, 1) * max(meta.n_groups, 1)
    if max_rows is not None:
        n = max(1, min(n, max_rows))
    return n


def measure_tap(meta: TapMeta, cfg: MeasureConfig = MeasureConfig()) -> Optional[TapTiming]:
    """Time both branches for one matmul tap; None for forced-branch kinds."""
    if meta.kind != "matmul":
        return None
    n = _tap_rows(meta, cfg.max_rows)
    key = jax.random.PRNGKey(cfg.seed)
    ka, kg = jax.random.split(key)
    dtype = jnp.dtype(meta.s_dtype)
    # match the train-time kernels exactly: activations stay in their
    # storage dtype, but tap_norm_sq upcasts the cotangent to fp32 before
    # either branch runs (core/ghost.py) — time what will actually execute
    a = jax.random.normal(ka, (n, meta.T, meta.D), jnp.float32).astype(dtype)
    g = jax.random.normal(kg, (n, meta.T, meta.p), jnp.float32)

    ghost_fn = jax.jit(lambda x, y: gops.ghost_norm_sq(x, y, block=cfg.ghost_block))
    inst_fn = jax.jit(
        lambda x, y: gops.instantiated_norm_sq(x, y, block_d=cfg.inst_block_d)
    )
    ghost_us = time_us(ghost_fn, a, g, repeats=cfg.repeats, warmup=cfg.warmup)
    inst_us = time_us(inst_fn, a, g, repeats=cfg.repeats, warmup=cfg.warmup)
    return TapTiming(ghost_us=ghost_us, instantiate_us=inst_us)


def _shape_key(name: str, meta: TapMeta) -> tuple:
    sig = tap_signature(name, meta)
    del sig["name"]
    return tuple(sorted((k, tuple(v) if isinstance(v, list) else v)
                        for k, v in sig.items()))


def measure_branches(
    metas: Mapping[str, TapMeta], cfg: MeasureConfig = MeasureConfig()
) -> dict[str, TapTiming]:
    """One timing per *unique shape signature*, fanned out to all taps.

    Identically-shaped layers (every layer of a homogeneous stack) must get
    the same branch: measuring them independently multiplies profiling cost
    and lets timer noise encode jitter as per-layer "hardware truth".
    """
    by_shape: dict[tuple, TapTiming] = {}
    out: dict[str, TapTiming] = {}
    for name in sorted(metas):
        meta = metas[name]
        if meta.kind != "matmul":
            continue
        key = _shape_key(name, meta)
        timing = by_shape.get(key)
        if timing is None:
            timing = measure_tap(meta, cfg)
            by_shape[key] = timing
            analytic = decide(meta, mode="mixed_ghost")
            mark = "" if analytic == timing.winner else "  (!= analytic %s)" % analytic
            log.info(
                "%s: ghost=%.1fus inst=%.1fus -> %s%s",
                name, timing.ghost_us, timing.instantiate_us, timing.winner, mark,
            )
        out[name] = timing
    return out


def build_plan(
    metas: Mapping[str, TapMeta],
    *,
    measure: MeasureConfig = MeasureConfig(),
    arch: Optional[str] = None,
) -> ClipPlan:
    """Profile every matmul tap and assemble the measured-cost ClipPlan."""
    timings = measure_branches(metas, measure)
    return ClipPlan(
        fingerprint=shape_fingerprint(metas),
        device=device_string(),
        branches=tuple((name, t.winner) for name, t in sorted(timings.items())),
        arch=arch,
        timings=tuple(
            (name, t.ghost_us, t.instantiate_us) for name, t in sorted(timings.items())
        ),
    )
