"""Measured per-tap branch costs: the three-way clipping decision.

The analytic decision (Eq 4.1) counts multiplies; this module instead times
the branch kernels on the actual device over the tap's real canonical
shapes — a (N, T, D) activation against a (N, T, p) cotangent, exactly what
``ghost.tap_norm_sq`` feeds them at train time — with warmup and
median-of-k.  Convolution taps are timed post-unfold: both norm branches
consume the unfolded activation, so the (shared) im2col cost cancels out of
the comparison.

Five timings per matmul tap:

- ``ghost_us`` / ``instantiate_us``: the norm kernels (second-backward
  modes pick the cheaper and then pay ``second_bwd_us`` on top);
- ``bk_ghost_us`` / ``bk_instantiate_us``: the full book-keeping pipelines —
  ghost norm + weighted einsum from the (a, g) book, vs per-sample-gradient
  bank (norm falls out free) + clip contraction;
- ``second_bwd_us``: the tap's dW + dX matmuls — its share of the second
  backward pass that book-keeping skips.

This is what makes the tuner *plan-aware across modes*: per tap it can
answer {ghost+2nd-bwd, instantiate+2nd-bwd, book-keeping-einsum} and emit a
branch map per mode (plan.branches / plan.bk_branches) plus a measured
``recommended_mode``.

On TPU each hot op additionally has two *implementations* — the Pallas
kernel and the chunked-XLA lowering (repro.kernels.dispatch) — so before
the branches are timed, ``measure_kernels`` races the impls per tap
(ghost norm + psg bank contraction for matmuls, the index-equality ghost
norm for embeddings) and the branch timings are then taken *under the
winning impls*, which are recorded in the plan's v5 ``kernels`` map.  Off
TPU there is exactly one production impl (xla), recorded without timing.

Only matmul taps get branch timings.  Embedding / scale / bias / dw_conv
taps have a single viable branch (decision.decide's forced cases) and are
never overridden — embeddings still get a kernel-impl measurement.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Optional

import jax
import jax.numpy as jnp

from repro.core.decision import decide
from repro.core.taps import TapMeta
from repro.kernels import dispatch
from repro.kernels.ghost_norm import ops as gops
from repro.tuner.plan import (
    ClipPlan,
    TapTiming,
    device_string,
    shape_fingerprint,
    tap_signature,
)
from repro.utils.logging import get_logger

log = get_logger("tuner.measure")


@dataclasses.dataclass(frozen=True)
class MeasureConfig:
    """Profiling knobs shared by every tuner measurement pass.

    The defaults favour cheap, stable comparisons over absolute accuracy:
    medians over ``repeats`` timed runs absorb scheduler noise, ``warmup``
    burns compilation, and ``max_rows`` clamps the profiled row count so a
    huge-batch model can be tuned without OOMing the device being sized
    (timings scale ~linearly in rows, so the *comparison* survives).
    """

    repeats: int = 5  # timed iterations; the median is kept
    warmup: int = 2  # discarded iterations (compile + caches)
    ghost_block: int = 512
    inst_block_d: int = 8192
    # clamp the row dim N = stack*B*groups during profiling; timings scale
    # ~linearly in N, so the *comparison* is preserved while huge-batch taps
    # stay cheap to profile (tuning must never OOM the device it is sizing).
    # None = use the discovered batch as-is.
    max_rows: Optional[int] = 64
    seed: int = 0


def time_us(fn, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall-clock microseconds per ``fn(*args)`` call.

    Blocks on all outputs (``jax.block_until_ready``) so asynchronous
    dispatch cannot under-report; the first ``warmup`` calls absorb
    compilation and cache effects and are discarded.
    """
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    samples.sort()
    return samples[len(samples) // 2]


def _tap_rows(meta: TapMeta, max_rows: Optional[int]) -> int:
    n = meta.n_stack * max(meta.batch_size, 1) * max(meta.n_groups, 1)
    if max_rows is not None:
        n = max(1, min(n, max_rows))
    return n


# dispatch ops with a measurable impl choice, per tap kind; scale / bias /
# dw_conv taps bank tiny per-sample grads and keep the dispatch default
KERNEL_OPS_BY_KIND = {
    "matmul": ("ghost_norm", "psg_contract"),
    "embedding": ("embedding_ghost_norm",),
}


def _book(x: jax.Array, y: jax.Array, cc: jax.Array, impl: Optional[str]):
    """The fused book contraction as the engine runs it: (n,T,D) x (n,T,p)
    rows folded to one (M=1, R=n*T) book, one row weight per (sample, t)."""
    nn, tt, dd = x.shape
    a2 = x.reshape(1, nn * tt, dd)
    g2 = y.reshape(1, nn * tt, y.shape[-1])
    w2 = jnp.broadcast_to(cc[:, None], (nn, tt)).reshape(1, nn * tt)
    return dispatch.book_weighted_grad(a2, g2, w2, impl=impl)[0]


def measure_tap_kernels(
    meta: TapMeta, cfg: MeasureConfig = MeasureConfig()
) -> dict[str, str]:
    """Race Pallas vs XLA per dispatch op for one tap; return the winners.

    ``{op: impl}`` for every op in ``KERNEL_OPS_BY_KIND[kind]`` ({} for
    kinds with no dispatchable op).  Where only one impl is available
    (everywhere but TPU) it is recorded without timing — the plan then
    states the choice explicitly instead of leaving it to the backend
    default at trace time.
    """
    ops_ = KERNEL_OPS_BY_KIND.get(meta.kind, ())
    if not ops_:
        return {}
    avail = dispatch.available_impls()
    if len(avail) == 1:
        return {op: avail[0] for op in ops_}

    n = _tap_rows(meta, cfg.max_rows)
    key = jax.random.PRNGKey(cfg.seed)
    ka, kg, kc = jax.random.split(key, 3)
    out: dict[str, str] = {}

    def race(op: str, make_fn, *args) -> None:
        per_impl = {}
        for impl in avail:
            per_impl[impl] = time_us(
                jax.jit(make_fn(impl)), *args,
                repeats=cfg.repeats, warmup=cfg.warmup,
            )
        winner = min(sorted(per_impl), key=per_impl.get)
        log.info("%s kernels: %s -> %s", op,
                 " ".join(f"{i}={t:.1f}us" for i, t in sorted(per_impl.items())),
                 winner)
        out[op] = winner

    if meta.kind == "matmul":
        dtype = jnp.dtype(meta.s_dtype)
        a = jax.random.normal(ka, (n, meta.T, meta.D), jnp.float32).astype(dtype)
        g = jax.random.normal(kg, (n, meta.T, meta.p), jnp.float32)
        c = jax.random.uniform(kc, (n,), jnp.float32)
        race(
            "ghost_norm",
            lambda impl: lambda x, y: dispatch.ghost_norm_sq(
                x, y, block=cfg.ghost_block, impl=impl
            ),
            a, g,
        )
        race(
            "psg_contract",
            lambda impl: lambda x, y, cc: _book(x, y, cc, impl),
            a, g, c,
        )
    elif meta.kind == "embedding":
        # the fused engine sends ids through the bank channel as fp32
        # (core/taps.py) — time exactly that
        vocab = min(meta.D, 1 << 24)
        ids = jax.random.randint(ka, (n, meta.T), 0, vocab).astype(jnp.float32)
        g = jax.random.normal(kg, (n, meta.T, meta.p), jnp.float32)
        race(
            "embedding_ghost_norm",
            lambda impl: lambda i, y: dispatch.embedding_ghost_norm_sq(
                i, y, impl=impl
            ),
            ids, g,
        )
    return out


def measure_tap(
    meta: TapMeta,
    cfg: MeasureConfig = MeasureConfig(),
    kernels: Optional[Mapping[str, str]] = None,
) -> Optional[TapTiming]:
    """Time every branch of the three-way decision for one matmul tap.

    Returns a ``TapTiming`` with the five per-tap costs (ghost norm,
    instantiated norm, both book-keeping pipelines, and the tap's share of
    a second backward) measured on synthetic data of the tap's canonical
    shape, or ``None`` for non-matmul kinds, whose branch is forced by
    ``decision.decide`` and never measured.  ``kernels`` pins the
    Pallas-vs-XLA impl per dispatch op (``measure_tap_kernels``'s winners)
    so the branch comparison prices the kernels that will actually trace.
    """
    if meta.kind != "matmul":
        return None
    k_ghost = dispatch.kernels_arg(kernels, "ghost_norm")
    k_psg = dispatch.kernels_arg(kernels, "psg_contract")
    n = _tap_rows(meta, cfg.max_rows)
    key = jax.random.PRNGKey(cfg.seed)
    ka, kg, kw, kc = jax.random.split(key, 4)
    dtype = jnp.dtype(meta.s_dtype)
    # match the train-time kernels exactly: activations stay in their
    # storage dtype, but tap_norm_sq upcasts the cotangent to fp32 before
    # either branch runs (core/ghost.py) — time what will actually execute
    a = jax.random.normal(ka, (n, meta.T, meta.D), jnp.float32).astype(dtype)
    g = jax.random.normal(kg, (n, meta.T, meta.p), jnp.float32)
    w = jax.random.normal(kw, (meta.D, meta.p), jnp.float32)
    c = jax.random.uniform(kc, (n,), jnp.float32)

    # -- second-backward norm branches (both consume unfolded patches at
    # train time, so the shared im2col cost cancels out of THIS comparison)
    ghost_fn = jax.jit(
        lambda x, y: dispatch.ghost_norm_sq(
            x, y, block=cfg.ghost_block, impl=k_ghost
        )
    )
    inst_fn = jax.jit(
        lambda x, y: gops.instantiated_norm_sq(x, y, block_d=cfg.inst_block_d)
    )
    ghost_us = time_us(ghost_fn, a, g, repeats=cfg.repeats, warmup=cfg.warmup)
    inst_us = time_us(inst_fn, a, g, repeats=cfg.repeats, warmup=cfg.warmup)

    # -- book-keeping pipelines (norm + bank + weighted contraction) ------
    # These time the kernels dp_value_and_clipped_grad actually runs, which
    # for convolutions are NOT the im2col einsums: the psg bank goes through
    # the conv op's own vjp on the raw activation (ghost._matmul_psg, no
    # unfold), while the ghost book pays the unfold itself.
    is_conv = meta.conv is not None and meta.a_shape is not None
    if is_conv:
        import dataclasses as _dc

        from repro.core.ghost import _matmul_psg
        from repro.nn.conv import unfold2d

        m1 = _dc.replace(
            meta, batch_size=n, stack_dims=(),
            s_shape=(n,) + tuple(meta.s_shape[-3:]),
            a_shape=(n,) + tuple(meta.a_shape[-3:]),
        )
        a_raw = jax.random.normal(
            ka, (n,) + tuple(meta.a_shape[-3:]), jnp.float32
        ).astype(meta.a_dtype or dtype)
        g_out = g.reshape((n,) + tuple(meta.s_shape[-3:]))

        def bk_ghost(xraw, y, cc):
            aa = unfold2d(xraw, meta.conv).astype(jnp.float32)
            yy = y.reshape(n, meta.T, meta.p)
            norms = dispatch.ghost_norm_sq(
                aa, yy, block=cfg.ghost_block, impl=k_ghost
            )
            wg = _book(aa, yy, cc, k_psg)
            return norms, wg

        def bk_inst(xraw, y, cc):
            psg = _matmul_psg(m1, xraw, y)
            norms = jnp.sum(jnp.square(psg).reshape(n, -1), axis=-1)
            wg = dispatch.psg_contract(psg, cc, impl=k_psg)
            return norms, wg

        bk_ghost_us = time_us(jax.jit(bk_ghost), a_raw, g_out, c,
                              repeats=cfg.repeats, warmup=cfg.warmup)
        bk_inst_us = time_us(jax.jit(bk_inst), a_raw, g_out, c,
                             repeats=cfg.repeats, warmup=cfg.warmup)
    else:
        def bk_ghost(x, y, cc):
            norms = dispatch.ghost_norm_sq(
                x, y, block=cfg.ghost_block, impl=k_ghost
            )
            wg = _book(x.astype(jnp.float32), y, cc, k_psg)
            return norms, wg

        def bk_inst(x, y, cc):
            psg = jnp.einsum("ntd,ntp->ndp", x.astype(jnp.float32), y)
            norms = jnp.sum(jnp.square(psg).reshape(psg.shape[0], -1), axis=-1)
            wg = dispatch.psg_contract(psg, cc, impl=k_psg)
            return norms, wg

        bk_ghost_us = time_us(jax.jit(bk_ghost), a, g, c,
                              repeats=cfg.repeats, warmup=cfg.warmup)
        bk_inst_us = time_us(jax.jit(bk_inst), a, g, c,
                             repeats=cfg.repeats, warmup=cfg.warmup)

    # -- the tap's share of a second backward pass (dW + dX) --------------
    def second_bwd(x, y, ww):
        dw = jnp.einsum("ntd,ntp->dp", x.astype(jnp.float32), y)
        dx = jnp.einsum("ntp,dp->ntd", y, ww)
        return dw, dx

    second_bwd_us = time_us(jax.jit(second_bwd), a, g, w,
                            repeats=cfg.repeats, warmup=cfg.warmup)

    return TapTiming(
        ghost_us=ghost_us, instantiate_us=inst_us,
        bk_ghost_us=bk_ghost_us, bk_instantiate_us=bk_inst_us,
        second_bwd_us=second_bwd_us,
    )


def _shape_key(name: str, meta: TapMeta) -> tuple:
    sig = tap_signature(name, meta)
    del sig["name"]
    return tuple(sorted((k, tuple(v) if isinstance(v, list) else v)
                        for k, v in sig.items()))


def measure_kernels(
    metas: Mapping[str, TapMeta], cfg: MeasureConfig = MeasureConfig()
) -> dict[str, dict[str, str]]:
    """Per-tap kernel-impl winners, one measurement per unique shape.

    Covers every tap whose kind has a dispatchable op (matmul, embedding);
    same shape-signature dedupe as ``measure_branches`` and for the same
    reason — identically-shaped layers must trace identical kernels.
    """
    by_shape: dict[tuple, dict[str, str]] = {}
    out: dict[str, dict[str, str]] = {}
    for name in sorted(metas):
        meta = metas[name]
        if meta.kind not in KERNEL_OPS_BY_KIND:
            continue
        key = _shape_key(name, meta)
        choices = by_shape.get(key)
        if choices is None:
            choices = measure_tap_kernels(meta, cfg)
            by_shape[key] = choices
        if choices:
            out[name] = choices
    return out


def measure_branches(
    metas: Mapping[str, TapMeta],
    cfg: MeasureConfig = MeasureConfig(),
    kernels: Optional[Mapping[str, Mapping[str, str]]] = None,
) -> dict[str, TapTiming]:
    """One timing per *unique shape signature*, fanned out to all taps.

    Identically-shaped layers (every layer of a homogeneous stack) must get
    the same branch: measuring them independently multiplies profiling cost
    and lets timer noise encode jitter as per-layer "hardware truth".
    ``kernels`` (``measure_kernels``'s winners) pins the impl each branch
    timing runs under; None times the dispatch backend default.
    """
    by_shape: dict[tuple, TapTiming] = {}
    out: dict[str, TapTiming] = {}
    for name in sorted(metas):
        meta = metas[name]
        if meta.kind != "matmul":
            continue
        key = _shape_key(name, meta)
        timing = by_shape.get(key)
        if timing is None:
            timing = measure_tap(
                meta, cfg, kernels=None if kernels is None else kernels.get(name)
            )
            by_shape[key] = timing
            analytic = decide(meta, mode="mixed_ghost")
            mark = "" if analytic == timing.winner else "  (!= analytic %s)" % analytic
            log.info(
                "%s: ghost=%.1fus inst=%.1fus bk_ghost=%.1fus bk_inst=%.1fus "
                "2nd_bwd=%.1fus -> %s/%s%s",
                name, timing.ghost_us, timing.instantiate_us,
                timing.bk_ghost_us, timing.bk_instantiate_us,
                timing.second_bwd_us, timing.winner, timing.bk_winner, mark,
            )
        out[name] = timing
    return out


def _plan_fields(timings: Mapping[str, TapTiming]) -> dict:
    return dict(
        branches=tuple((name, t.winner) for name, t in sorted(timings.items())),
        bk_branches=tuple(
            (name, t.bk_winner) for name, t in sorted(timings.items())
        ),
        timings=tuple(t.as_tuple(name) for name, t in sorted(timings.items())),
    )


def _kernel_rows(
    kernels: Mapping[str, Mapping[str, str]]
) -> tuple[tuple[str, str, str], ...]:
    """Flatten {tap: {op: impl}} to the sorted triples ClipPlan stores."""
    return tuple(
        (name, op, impl)
        for name in sorted(kernels)
        for op, impl in sorted(kernels[name].items())
    )


def build_plan(
    metas: Mapping[str, TapMeta],
    *,
    measure: MeasureConfig = MeasureConfig(),
    arch: Optional[str] = None,
) -> ClipPlan:
    """Profile every matmul tap and assemble the measured-cost ClipPlan.

    Kernel impls are raced first (``measure_kernels``); the branch timings
    are then taken under the winners, and both land in the plan — the
    branch maps drive ghost-vs-instantiate, the v5 ``kernels`` map drives
    Pallas-vs-XLA at trace time.
    """
    kernels = measure_kernels(metas, measure)
    timings = measure_branches(metas, measure, kernels=kernels)
    return ClipPlan(
        fingerprint=shape_fingerprint(metas),
        device=device_string(),
        arch=arch,
        kernels=_kernel_rows(kernels),
        **_plan_fields(timings),
    )


def remeasure_at_batch(
    plan: ClipPlan,
    metas: Mapping[str, TapMeta],
    physical_batch: int,
    cfg: MeasureConfig = MeasureConfig(),
    *,
    cap_bytes: int = 1 << 30,
) -> ClipPlan:
    """Re-time the branches at the tuned physical batch and refresh the plan.

    Branch timings are first measured at the (row-clamped) probe batch;
    after the max-batch search settles, the step actually runs at
    ``physical_batch``.  Timings scale ~linearly in rows so flips are rare,
    but re-measuring closes the loop and removes the assumption (ROADMAP
    "profile at the tuned physical batch").  The fingerprint is batch-free,
    so the refreshed plan stays valid for the same model/device.

    ``cap_bytes`` bounds the largest profiling array per tap (tuning must
    never OOM the device it is sizing — the max-batch search certified the
    *training* graph, not per-tap psg instantiation at full rows): taps whose
    full-batch measurement would exceed it are clamped to the largest batch
    that fits, which preserves the comparison since timings scale ~linearly.

    Kernel winners are RE-RACED at the rebatched shapes, not carried over
    from the probe batch: Pallas-vs-XLA crossover moves with rows (grid
    occupancy and the bank-contraction tile both depend on B), so a plan
    recorded at the certified batch must carry winners raced there — the
    re-timed branches then run under those winners and both land in the
    refreshed plan together.
    """
    rebatched = {}
    clamped = 0
    for name, m in metas.items():
        b = physical_batch
        if m.kind == "matmul":
            reps = max(m.n_stack * max(m.n_groups, 1), 1)
            # a, g, and (bk_inst) psg are all live at once per profiled row
            per_row = 4 * (m.T * m.D + m.T * m.p + m.D * m.p)
            b_cap = max(1, cap_bytes // max(per_row * reps, 1))
            if b_cap < b:
                b, clamped = b_cap, clamped + 1
        rebatched[name] = dataclasses.replace(m, batch_size=b)
    if clamped:
        log.info("remeasure: %d tap(s) clamped below physical batch %d to "
                 "respect the %.1fGB profiling cap", clamped, physical_batch,
                 cap_bytes / 1024**3)
    cfg_full = dataclasses.replace(cfg, max_rows=None)
    kernels = measure_kernels(rebatched, cfg_full)
    old_kernels = plan.kernel_map()
    kernel_flips = sum(
        1 for name, ops in kernels.items()
        for op, impl in ops.items()
        if old_kernels.get(name, {}).get(op, impl) != impl
    )
    if kernel_flips:
        log.info("re-racing kernels at physical batch %d flipped %d "
                 "winner(s)", physical_batch, kernel_flips)
    timings = measure_branches(rebatched, cfg_full, kernels=kernels)
    flips = sum(
        1 for name, b in plan.branches if timings.get(name) and
        timings[name].winner != b
    ) + sum(
        1 for name, b in plan.bk_branches if timings.get(name) and
        timings[name].bk_winner != b
    )
    if flips:
        log.info("re-measuring at physical batch %d flipped %d branch(es)",
                 physical_batch, flips)
    return dataclasses.replace(
        plan, measured_at_physical=True, kernels=_kernel_rows(kernels),
        **_plan_fields(timings)
    )


def close_physical_batch_loop(
    plan: ClipPlan,
    metas: Mapping[str, TapMeta],
    search,  # (plan) -> max physical batch under the caller's budget, <=0 = none
    logical_batch: int,
    budget_bytes: int,
    cfg: MeasureConfig = MeasureConfig(),
    *,
    max_iters: int = 3,
) -> ClipPlan:
    """Converge {branch maps, physical batch} to a mutually consistent pair.

    The coupled loop behind the ROADMAP "profile at the tuned physical
    batch" item: branch timings must be taken at the batch that will run,
    but flipping a branch changes per-tap clipping memory, which can change
    the max batch that fits — so re-measure and re-search alternate until a
    fixpoint (almost always one round; ``max_iters`` bounds pathological
    oscillation).  On a failed re-search the last *certified* plan (branches
    and batch from the same measurement) is returned rather than a plan
    whose branches contradict its own timings.
    """
    from repro.tuner.max_batch import derive_accumulation

    mp = plan.physical_batch
    if not mp or mp <= 0:
        return plan
    for _ in range(max_iters):
        certified = plan
        plan = remeasure_at_batch(plan, metas, mp, cfg)
        if (plan.branches, plan.bk_branches) == (
            certified.branches, certified.bk_branches
        ):
            return plan  # branches stable at the certified batch: converged
        mp2 = search(plan)
        if mp2 <= 0:
            log.warning(
                "re-measured branches no longer fit the budget at batch %d; "
                "keeping the certified plan", mp,
            )
            return certified
        if mp2 == mp:
            return plan  # flips did not move the certificate: converged
        log.info("branch flips moved the max physical batch %d -> %d; "
                 "re-measuring there", mp, mp2)
        _, steps = derive_accumulation(logical_batch, mp2)
        plan = dataclasses.replace(
            plan.replace_batch(
                physical_batch=mp2, logical_batch=logical_batch,
                accumulation_steps=steps, budget_bytes=budget_bytes,
            ),
            # timings are still from mp; only the next remeasure may claim it
            measured_at_physical=False,
        )
        mp = mp2
    log.warning("branch/batch loop did not converge in %d rounds; timings "
                "were last taken one batch behind", max_iters)
    return plan
