"""Max physical microbatch search (paper Table 7, reused as a runtime feature).

The paper bisects the largest batch that trains without OOM on a 16GB V100.
Two search drivers implement that here:

- **trial executions** (``max_batch_by_trial``, the default where real
  arrays are available): each candidate batch actually RUNS the clipped
  gradient step and blocks on the result, so the certificate covers
  everything the compiled-memory model cannot see — allocator
  fragmentation, runtime workspaces, the framework's own buffers.  A trial
  that dies of OOM is caught, the allocator is given a chance to recover
  (gc + XLA cache drop — the retry ladder; pair with
  ``XLA_PYTHON_CLIENT_PREALLOCATE=false`` from ``scripts/launch_env.sh``
  so the backend allocator can actually return memory), and the search
  continues downward instead of killing the process;
- **the compiled peak-memory model** (``max_batch_by_memory``: args +
  outputs + temps from ``memory_analysis()``), which is fast and
  hardware-independent — the fallback when only abstract shapes are
  available or trials are disabled (``REPRO_MAX_BATCH_METHOD=memory``).

``certify_max_batch`` picks between them.  On hosts whose budget is larger
than the device (CPU runs with a paper-sized budget), the trial driver
still applies the memory model as a pre-filter, so both drivers converge to
the same batch — the trial adds the execution certificate on top.  The
result feeds gradient accumulation: a fixed *logical* batch (the privacy
unit) is executed as ``accumulation_steps`` microbatches of the tuned
physical size — the paper's virtual-step pattern.
"""
from __future__ import annotations

import gc
import os
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.utils.logging import get_logger

log = get_logger("tuner.max_batch")

DEFAULT_BUDGET_BYTES = 16 * 1024**3  # the paper's 16GB V100

# substrings that identify an allocator/compiler OOM across backends (XLA
# runtime, PJRT GPU/TPU, host malloc) — anything else propagates: a shape
# bug must not masquerade as "does not fit"
_OOM_TOKENS = (
    "RESOURCE_EXHAUSTED",
    "Out of memory",
    "out of memory",
    "OOM",
    "bad_alloc",
    "Resource exhausted",
)


def is_oom_error(e: BaseException) -> bool:
    """True when the exception is a memory-exhaustion failure."""
    if isinstance(e, MemoryError):
        return True
    msg = str(e)
    return any(tok in msg for tok in _OOM_TOKENS)


def compiled_memory_bytes(fn: Callable, *specs) -> int:
    """Peak-memory model from an AOT compile (no execution, no allocation)."""
    compiled = jax.jit(fn).lower(*specs).compile()
    ma = compiled.memory_analysis()
    return int(
        ma.argument_size_in_bytes + ma.output_size_in_bytes
        + ma.temp_size_in_bytes - ma.alias_size_in_bytes
    )


def batch_specs_at(batch: Any, b: int) -> Any:
    """Shape specs for ``batch`` with its leading (batch) dim replaced by b."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct((b,) + tuple(x.shape[1:]), x.dtype), batch
    )


def find_max_physical_batch(
    fits: Callable[[int], bool], *, lo: int = 1, hi_cap: int = 65536
) -> int:
    """Largest b in [lo, hi_cap] with fits(b), by doubling + exact bisection.

    Assumes ``fits`` is monotone (true below some threshold).  Returns 0 when
    even ``lo`` does not fit.
    """
    if not fits(lo):
        return 0
    hi = lo
    while hi < hi_cap and fits(min(hi * 2, hi_cap)):
        hi = min(hi * 2, hi_cap)
    if hi >= hi_cap:
        return hi_cap
    # invariant: fits(hi) held, fits(min(2*hi, hi_cap)) just failed — reuse
    # that observation as the bisection upper bound (each fits() is a full
    # XLA compile; never re-test a known-failing point)
    bad = min(hi * 2, hi_cap)
    while bad - hi > 1:
        mid = (hi + bad) // 2
        if fits(mid):
            hi = mid
        else:
            bad = mid
    return hi


def resident_state_bytes(params: Any) -> int:
    """Estimate of training-loop memory the microstep compile cannot see.

    The compiled-memory model covers one clipped-grad call (args + outputs +
    temps).  The real loop also keeps the optimizer state (Adam: 2x fp32
    params) and, under accumulation, the running grad_sum plus its transient
    twin during the tree add (~2x fp32 params) resident — reserve them off
    the budget so the tuned batch fits the loop, not just the microstep.
    """
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
    return 4 * 4 * n


def max_batch_by_memory(
    grad_fn: Callable,
    params: Any,
    batch: Any,
    *,
    budget_bytes: int = DEFAULT_BUDGET_BYTES,
    hi_cap: int = 65536,
    reserved_bytes: int = 0,
) -> int:
    """Largest physical batch whose compiled clipping step fits the budget.

    ``grad_fn(params, batch)`` is the clipped-gradient function (typically
    ``dp_value_and_clipped_grad`` output); ``batch`` is a template whose
    leading dim is resized during the search.  ``reserved_bytes`` (see
    ``resident_state_bytes``) is subtracted from the budget up front.
    """
    budget_bytes = budget_bytes - reserved_bytes
    if budget_bytes <= 0:
        log.warning("memory budget entirely consumed by resident state "
                    "(%.2f GB reserved)", reserved_bytes / 1024**3)
        return 0
    p_specs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
    )

    def fits(b: int) -> bool:
        try:
            mem = compiled_memory_bytes(grad_fn, p_specs, batch_specs_at(batch, b))
        except Exception as e:  # noqa: BLE001 — compile failure == does not fit
            msg = str(e)
            if "RESOURCE_EXHAUSTED" in msg or "Out of memory" in msg:
                log.debug("batch %d exhausts memory at compile time", b)
            else:
                # a non-memory failure would silently report "nothing fits";
                # surface it so a grad_fn bug isn't mistaken for a tiny budget
                log.warning("batch %d failed to compile with a non-memory "
                            "error: %s", b, msg.splitlines()[0] if msg else e)
            return False
        log.debug("batch %d -> %.2f GB", b, mem / 1024**3)
        return mem <= budget_bytes

    return find_max_physical_batch(fits, hi_cap=hi_cap)


def batch_at(batch: Any, b: int) -> Any:
    """Real arrays for ``batch`` resized to leading dim ``b`` (tile + slice).

    The trial driver needs concrete data, not specs: content is irrelevant
    to memory behaviour, so the template rows are recycled.
    """

    def resize(x):
        n = x.shape[0]
        if b <= n:
            return x[:b]
        reps = -(-b // n)
        return jnp.concatenate([x] * reps, axis=0)[:b]

    return jax.tree_util.tree_map(resize, batch)


def recover_allocator() -> None:
    """Post-OOM recovery half of the retry ladder.

    Drops every dead Python reference (the failed trial's arrays), then
    XLA's live-executable cache — compiled programs pin their workspace
    reservations, and the just-failed candidate's executable is garbage by
    definition.  With ``XLA_PYTHON_CLIENT_PREALLOCATE=false`` (set by
    ``scripts/launch_env.sh``) the backend allocator can then actually
    return the freed blocks, so the next (smaller) trial starts clean
    instead of inheriting a poisoned arena.
    """
    gc.collect()
    try:
        jax.clear_caches()
    except Exception as e:  # noqa: BLE001 — recovery must never raise
        log.debug("jax.clear_caches failed during OOM recovery: %s", e)
    gc.collect()


def trial_survives(run: Callable[[int], Any], b: int, *, attempts: int = 2) -> bool:
    """Execute ``run(b)`` under the OOM retry ladder; True when it completes.

    A first OOM gets one allocator recovery + retry (fragmentation and a
    genuinely-too-big batch look identical from the exception); a repeat
    failure reports "does not fit".  Either way the process survives and
    the allocator is recovered for the next, smaller candidate.  Non-OOM
    exceptions propagate.
    """
    for attempt in range(1, max(attempts, 1) + 1):
        try:
            run(b)
            return True
        except Exception as e:  # noqa: BLE001 — filtered to OOM below
            if not is_oom_error(e):
                raise
            recover_allocator()
            if attempt > max(attempts, 1) - 1:
                log.debug("batch %d exhausts memory in execution "
                          "(attempt %d/%d)", b, attempt, attempts)
                return False
            log.info("batch %d OOMed; allocator recovered, retrying "
                     "(attempt %d/%d)", b, attempt, attempts)
    return False


def max_batch_by_trial(
    grad_fn: Callable,
    params: Any,
    batch: Any,
    *,
    budget_bytes: Optional[int] = DEFAULT_BUDGET_BYTES,
    hi_cap: int = 65536,
    reserved_bytes: int = 0,
    runner: Optional[Callable[[int], Any]] = None,
    attempts: int = 2,
) -> int:
    """Largest physical batch whose clipping step EXECUTES within budget.

    Each candidate runs ``grad_fn`` for real (``runner`` injects the
    execution for tests — it receives the batch size and must raise on a
    failed allocation).  When ``budget_bytes`` is set, the compiled-memory
    model pre-filters candidates first: on a host with more free memory
    than the budget (CPU certifying for a 16GB device) execution alone
    cannot observe the budget, and on a real device the cheap compile-time
    rejection skips doomed allocations.  ``budget_bytes=None`` trusts
    execution alone.
    """
    mem_budget = None
    if budget_bytes is not None:
        mem_budget = budget_bytes - reserved_bytes
        if mem_budget <= 0:
            log.warning("memory budget entirely consumed by resident state "
                        "(%.2f GB reserved)", reserved_bytes / 1024**3)
            return 0
    p_specs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
    )
    if runner is None:
        jfn = jax.jit(grad_fn)

        def runner(b: int) -> None:
            jax.block_until_ready(jfn(params, batch_at(batch, b)))

    def fits(b: int) -> bool:
        if mem_budget is not None:
            try:
                mem = compiled_memory_bytes(
                    grad_fn, p_specs, batch_specs_at(batch, b)
                )
            except Exception as e:  # noqa: BLE001 — compile OOM == unfit
                if is_oom_error(e):
                    return False
                raise
            if mem > mem_budget:
                log.debug("batch %d rejected by the memory model "
                          "(%.2f GB)", b, mem / 1024**3)
                return False
        return trial_survives(runner, b, attempts=attempts)

    return find_max_physical_batch(fits, hi_cap=hi_cap)


def trials_available(params: Any, batch: Any) -> bool:
    """Trial executions need concrete arrays, not ShapeDtypeStructs."""
    leaves = jax.tree_util.tree_leaves(params) + jax.tree_util.tree_leaves(batch)
    return all(
        not isinstance(x, jax.ShapeDtypeStruct) and hasattr(x, "dtype")
        for x in leaves
    )


def certify_max_batch(
    grad_fn: Callable,
    params: Any,
    batch: Any,
    *,
    budget_bytes: int = DEFAULT_BUDGET_BYTES,
    hi_cap: int = 65536,
    reserved_bytes: int = 0,
    method: Optional[str] = None,
) -> tuple[int, str]:
    """(max physical batch, certification method): the search front door.

    ``method`` (or ``REPRO_MAX_BATCH_METHOD``): ``"trial"`` | ``"memory"``
    | ``"auto"`` (default).  Auto runs real trial executions whenever
    concrete arrays are available and falls back to the compiled-memory
    model otherwise — so ``engine.tune`` certifies by execution on the
    default backend, while spec-only callers (dry runs) keep working.
    """
    method = method or os.environ.get("REPRO_MAX_BATCH_METHOD", "auto")
    if method not in ("auto", "trial", "memory"):
        raise ValueError(f"unknown max-batch method {method!r}")
    if method == "trial" and not trials_available(params, batch):
        raise ValueError("method='trial' needs concrete params/batch arrays")
    if method != "memory" and trials_available(params, batch):
        mb = max_batch_by_trial(
            grad_fn, params, batch, budget_bytes=budget_bytes,
            hi_cap=hi_cap, reserved_bytes=reserved_bytes,
        )
        return mb, "trial"
    return max_batch_by_memory(
        grad_fn, params, batch, budget_bytes=budget_bytes, hi_cap=hi_cap,
        reserved_bytes=reserved_bytes,
    ), "memory"


def derive_accumulation(logical_batch: int, max_physical: int) -> tuple[int, int]:
    """(physical_batch, accumulation_steps) realizing a fixed logical batch.

    Picks the fewest microsteps that respect the memory bound, then evens the
    microbatch out (e.g. logical 256 with max 96 -> 86 x 3, not 96+96+64).
    Guarantees physical <= max_physical and physical * steps >= logical.
    """
    if logical_batch <= 0:
        raise ValueError(f"logical_batch must be positive, got {logical_batch}")
    if max_physical <= 0:
        raise ValueError(f"max_physical must be positive, got {max_physical}")
    steps = -(-logical_batch // max_physical)  # ceil
    physical = -(-logical_batch // steps)
    return physical, steps
