"""Max physical microbatch search (paper Table 7, reused as a runtime feature).

The paper bisects the largest batch that trains without OOM on a 16GB V100;
here the same doubling + binary search runs against XLA's compiled peak-memory
model (args + outputs + temps from ``memory_analysis()``), which is exact,
fast, and hardware-independent — no trial allocations, no poisoned allocator
state after a real OOM.  The result feeds gradient accumulation: a fixed
*logical* batch (the privacy unit) is executed as ``accumulation_steps``
microbatches of the tuned physical size — the paper's virtual-step pattern.
"""
from __future__ import annotations

from typing import Any, Callable, Mapping, Optional

import jax

from repro.utils.logging import get_logger

log = get_logger("tuner.max_batch")

DEFAULT_BUDGET_BYTES = 16 * 1024**3  # the paper's 16GB V100


def compiled_memory_bytes(fn: Callable, *specs) -> int:
    """Peak-memory model from an AOT compile (no execution, no allocation)."""
    compiled = jax.jit(fn).lower(*specs).compile()
    ma = compiled.memory_analysis()
    return int(
        ma.argument_size_in_bytes + ma.output_size_in_bytes
        + ma.temp_size_in_bytes - ma.alias_size_in_bytes
    )


def batch_specs_at(batch: Any, b: int) -> Any:
    """Shape specs for ``batch`` with its leading (batch) dim replaced by b."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct((b,) + tuple(x.shape[1:]), x.dtype), batch
    )


def find_max_physical_batch(
    fits: Callable[[int], bool], *, lo: int = 1, hi_cap: int = 65536
) -> int:
    """Largest b in [lo, hi_cap] with fits(b), by doubling + exact bisection.

    Assumes ``fits`` is monotone (true below some threshold).  Returns 0 when
    even ``lo`` does not fit.
    """
    if not fits(lo):
        return 0
    hi = lo
    while hi < hi_cap and fits(min(hi * 2, hi_cap)):
        hi = min(hi * 2, hi_cap)
    if hi >= hi_cap:
        return hi_cap
    # invariant: fits(hi) held, fits(min(2*hi, hi_cap)) just failed — reuse
    # that observation as the bisection upper bound (each fits() is a full
    # XLA compile; never re-test a known-failing point)
    bad = min(hi * 2, hi_cap)
    while bad - hi > 1:
        mid = (hi + bad) // 2
        if fits(mid):
            hi = mid
        else:
            bad = mid
    return hi


def resident_state_bytes(params: Any) -> int:
    """Estimate of training-loop memory the microstep compile cannot see.

    The compiled-memory model covers one clipped-grad call (args + outputs +
    temps).  The real loop also keeps the optimizer state (Adam: 2x fp32
    params) and, under accumulation, the running grad_sum plus its transient
    twin during the tree add (~2x fp32 params) resident — reserve them off
    the budget so the tuned batch fits the loop, not just the microstep.
    """
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
    return 4 * 4 * n


def max_batch_by_memory(
    grad_fn: Callable,
    params: Any,
    batch: Any,
    *,
    budget_bytes: int = DEFAULT_BUDGET_BYTES,
    hi_cap: int = 65536,
    reserved_bytes: int = 0,
) -> int:
    """Largest physical batch whose compiled clipping step fits the budget.

    ``grad_fn(params, batch)`` is the clipped-gradient function (typically
    ``dp_value_and_clipped_grad`` output); ``batch`` is a template whose
    leading dim is resized during the search.  ``reserved_bytes`` (see
    ``resident_state_bytes``) is subtracted from the budget up front.
    """
    budget_bytes = budget_bytes - reserved_bytes
    if budget_bytes <= 0:
        log.warning("memory budget entirely consumed by resident state "
                    "(%.2f GB reserved)", reserved_bytes / 1024**3)
        return 0
    p_specs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
    )

    def fits(b: int) -> bool:
        try:
            mem = compiled_memory_bytes(grad_fn, p_specs, batch_specs_at(batch, b))
        except Exception as e:  # noqa: BLE001 — compile failure == does not fit
            msg = str(e)
            if "RESOURCE_EXHAUSTED" in msg or "Out of memory" in msg:
                log.debug("batch %d exhausts memory at compile time", b)
            else:
                # a non-memory failure would silently report "nothing fits";
                # surface it so a grad_fn bug isn't mistaken for a tiny budget
                log.warning("batch %d failed to compile with a non-memory "
                            "error: %s", b, msg.splitlines()[0] if msg else e)
            return False
        log.debug("batch %d -> %.2f GB", b, mem / 1024**3)
        return mem <= budget_bytes

    return find_max_physical_batch(fits, hi_cap=hi_cap)


def derive_accumulation(logical_batch: int, max_physical: int) -> tuple[int, int]:
    """(physical_batch, accumulation_steps) realizing a fixed logical batch.

    Picks the fewest microsteps that respect the memory bound, then evens the
    microbatch out (e.g. logical 256 with max 96 -> 86 x 3, not 96+96+64).
    Guarantees physical <= max_physical and physical * steps >= logical.
    """
    if logical_batch <= 0:
        raise ValueError(f"logical_batch must be positive, got {logical_batch}")
    if max_physical <= 0:
        raise ValueError(f"max_physical must be positive, got {max_physical}")
    steps = -(-logical_batch // max_physical)  # ceil
    physical = -(-logical_batch // steps)
    return physical, steps
