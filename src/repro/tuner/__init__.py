"""repro.tuner: measured-cost autotuning for the clipping branch decision.

Replaces the analytic Eq-(4.1) rule with per-tap microbenchmarks on the
actual device, caches the result as a ``ClipPlan`` (plan.py), and
binary-searches the true max physical microbatch (max_batch.py).  On
multi-host fleets, consensus.py turns the per-rank measurement into one
byte-identical fleet-adopted plan (GSPMD requires every rank to trace the
same branch per tap).  Consumed by ``ClipConfig(plan=...)`` /
``PrivacyEngine.tune`` / ``launch.train --tune [--consensus]``.
"""
from repro.tuner.consensus import (
    PlanConsensusError,
    RankReport,
    agree,
    elect_leaders,
    fleet_agree,
    fleet_roles,
    verify_adopted,
)
from repro.tuner.max_batch import (
    certify_max_batch,
    derive_accumulation,
    find_max_physical_batch,
    is_oom_error,
    max_batch_by_memory,
    max_batch_by_trial,
    trials_available,
)
from repro.tuner.measure import (
    MeasureConfig,
    build_plan,
    close_physical_batch_loop,
    measure_branches,
    measure_kernels,
    measure_tap,
    measure_tap_kernels,
    remeasure_at_batch,
)
from repro.tuner.plan import (
    ClipPlan,
    TapTiming,
    default_plan_path,
    device_string,
    load_cached_plan,
    shape_fingerprint,
)

__all__ = [
    "ClipPlan",
    "TapTiming",
    "PlanConsensusError",
    "RankReport",
    "agree",
    "elect_leaders",
    "fleet_agree",
    "fleet_roles",
    "verify_adopted",
    "MeasureConfig",
    "build_plan",
    "close_physical_batch_loop",
    "measure_branches",
    "measure_kernels",
    "measure_tap",
    "measure_tap_kernels",
    "remeasure_at_batch",
    "certify_max_batch",
    "derive_accumulation",
    "find_max_physical_batch",
    "is_oom_error",
    "max_batch_by_memory",
    "max_batch_by_trial",
    "trials_available",
    "default_plan_path",
    "device_string",
    "load_cached_plan",
    "shape_fingerprint",
]
