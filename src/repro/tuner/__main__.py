import sys

from repro.tuner.cli import main

sys.exit(main())
