"""The ClipPlan artifact: a cached, device-specific clipping decision.

The analytic rule Eq (4.1) predicts which branch (ghost norm vs gradient
instantiation) is cheaper from operation counts alone.  On real hardware the
winner also depends on kernel launch overhead, tiling, dtype, and fusion, so
the tuner *measures* the branches per tap (measure.py) and records the
winners here, together with enough provenance to know when the plan is stale:

- a **shape fingerprint** over every tap's (kind, T, D, p, groups, stack,
  dtype) signature — batch size is deliberately excluded so one plan serves
  any physical microbatch (the max-batch search varies B);
- the **device string** (platform + device kind) the plan was measured on.

Plans are **mode-aware** (three-way tuning): each matmul tap is timed on
{ghost norm, instantiated norm, book-keeping ghost-bank, book-keeping
psg-bank, its share of the second backward}, and two branch maps are kept —
``branches`` for the second-backward modes (ghost vs instantiate norms) and
``bk_branches`` for ``bk_mixed`` (which residual bank to keep).  The
book-keeping mode skips the second backward entirely, so its branch
economics differ and the two maps routinely disagree on the same tap.
``recommended_mode()`` compares the measured per-step totals of
{mixed_ghost, bk_mixed}.

``matches(metas)`` is the staleness gate; every consumption goes through it.
``overrides_for(metas, mode=...)`` returns the per-tap branch map when the
plan matches the current model/device and an empty map (analytic fallback)
otherwise — a stale plan can never silently redirect a branch, and callers
using ``physical_batch`` must check ``matches`` first (launch/train.py
does).  Plans round-trip through JSON and live under
``~/.cache/repro-tuner/`` (override with $REPRO_TUNER_CACHE or an explicit
path).

Plan v3 adds **fleet consensus provenance** (repro.tuner.consensus): a
multi-host run must trace byte-identical branch maps on every rank or GSPMD
deadlocks/diverges, so an agreed plan records the devices that ratified it
(``devices`` — ``matches`` accepts any of them, not just the measuring
device), the consensus hash all ranks certified (``agreed_hash``, computed
by ``consensus_hash()`` over everything *except* the provenance fields so
stamping it is idempotent), the fleet size (``agreed_ranks``) and the
measuring leader (``leader_process``).  v2 artifacts load with empty
provenance (single-host plans, never agreed); v1 artifacts are rejected.

Plan v4 adds the **policy fingerprint** (repro.policies): the stable
identity of the clipping policy the run uses, stamped by
``PrivacyEngine.tune`` and — deliberately — covered by the consensus hash,
so a fleet whose ranks run different policies (different quantile targets,
different layer groups) cannot certify one plan.  Branch decisions are
policy-*independent* (both branches compute the same norms; tested), so
``matches``/``overrides_for`` ignore the fingerprint: a cached plan tuned
under one policy still serves another on a single host.  v2/v3 artifacts
migrate with an empty fingerprint; a v3 artifact that carries an
``agreed_hash`` will no longer re-verify (the hash covered the v3 schema)
— re-run the fleet agreement, which is exactly the loud failure wanted.

Plan v5 adds the **kernel map** (``kernels``): per tap and dispatch op
(repro.kernels.dispatch: ghost_norm / embedding_ghost_norm / psg_contract),
the measured Pallas-vs-XLA winner.  Like the branch maps it moves cost and
never math, and like the policy fingerprint it is covered by the consensus
hash — a fleet must trace one kernel per tap everywhere, so mixed kernel
choices cannot certify.  v2–v4 artifacts migrate with an empty map (the
dispatch backend default applies); a v4 ``agreed_hash`` no longer
re-verifies for the same schema-coverage reason as v3 → v4, and the fleet
must re-agree.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.core.taps import TapMeta
from repro.utils.logging import get_logger

log = get_logger("tuner.plan")

PLAN_VERSION = 5
# older versions from_json still understands (migrated with empty defaults
# for the fields they predate); v1 predates the three-way branch maps and is
# stale by construction
COMPAT_VERSIONS = (2, 3, 4, PLAN_VERSION)
BRANCHES = ("ghost", "instantiate")
# kernel ops / impl values a v5 plan may record per tap; mirror
# repro.kernels.dispatch.OPS / .IMPLS (duplicated so plan validation stays
# free of kernel imports — tests/test_kernels.py asserts they agree)
KERNEL_OPS = (
    "ghost_norm", "embedding_ghost_norm", "psg_contract", "flash_attention"
)
KERNEL_IMPLS = ("pallas", "xla")
TUNED_MODES = ("mixed_ghost", "bk_mixed")
# ClipPlan fields that record consensus *provenance* rather than measurement:
# excluded from consensus_hash() so that stamping the agreement outcome onto
# the plan does not change the hash being agreed on
PROVENANCE_FIELDS = ("devices", "agreed_hash", "agreed_ranks", "leader_process")


def device_string(device: Optional[Any] = None) -> str:
    """Stable identity of the accelerator a plan was measured on.

    ``platform:device_kind`` (e.g. ``gpu:NVIDIA A100-SXM4-40GB``,
    ``tpu:TPU v4``) — the granularity at which branch timings transfer: two
    hosts with the same device kind see the same kernel costs, so a fleet
    needs one measurement per *kind*, not per rank (repro.tuner.consensus).
    """
    d = device if device is not None else jax.devices()[0]
    return f"{d.platform}:{d.device_kind}"


def tap_signature(name: str, meta: TapMeta) -> dict:
    """Per-tap shape identity (batch-size free; see module docstring)."""
    return {
        "name": name,
        "kind": meta.kind,
        "T": int(meta.T),
        "D": int(meta.D),
        "p": int(meta.p),
        "n_groups": int(meta.n_groups),
        "stack_dims": [int(s) for s in meta.stack_dims],
        "dtype": str(jnp.dtype(meta.s_dtype)),
        "conv": meta.conv is not None,
    }


def shape_fingerprint(metas: Mapping[str, TapMeta]) -> str:
    """Order-independent hash of every tap's shape signature (16 hex chars).

    This is the plan's model identity: two models whose taps agree on every
    (kind, T, D, p, groups, stack, dtype) tuple — batch size excluded — share
    a fingerprint and can share a plan.  Any change to a layer's shape, a new
    tap, or a dtype switch changes it, which is what makes stale-plan
    rejection (``ClipPlan.matches``) sound.
    """
    sigs = sorted(
        (tap_signature(name, m) for name, m in metas.items()),
        key=lambda s: s["name"],
    )
    blob = json.dumps(sigs, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class TapTiming:
    """Measured branch costs for one tap (microseconds, median-of-k).

    ``ghost_us`` / ``instantiate_us`` time the norm kernels of the
    second-backward modes; ``bk_ghost_us`` / ``bk_instantiate_us`` time the
    full book-keeping pipelines (norm + bank + weighted-grad contraction);
    ``second_bwd_us`` times the tap's share of a second backward pass (its
    dW + dX matmuls) — what book-keeping avoids paying.
    """

    ghost_us: float
    instantiate_us: float
    bk_ghost_us: float = 0.0
    bk_instantiate_us: float = 0.0
    second_bwd_us: float = 0.0

    @property
    def winner(self) -> str:
        """Measured norm branch for the second-backward modes (ties: ghost)."""
        return "ghost" if self.ghost_us <= self.instantiate_us else "instantiate"

    @property
    def bk_winner(self) -> str:
        """Measured bank branch for ``bk_mixed`` (ties: ghost)."""
        return "ghost" if self.bk_ghost_us <= self.bk_instantiate_us else "instantiate"

    def mode_cost_us(self, mode: str) -> float:
        """Measured per-tap cost of running this tap under ``mode``."""
        if mode == "bk_mixed":
            return min(self.bk_ghost_us, self.bk_instantiate_us)
        return min(self.ghost_us, self.instantiate_us) + self.second_bwd_us

    def as_tuple(self, name: str) -> tuple:
        """Flatten to the (name, *timings) row stored in ``ClipPlan.timings``."""
        return (name, self.ghost_us, self.instantiate_us,
                self.bk_ghost_us, self.bk_instantiate_us, self.second_bwd_us)


@dataclasses.dataclass(frozen=True)
class ClipPlan:
    """Serializable result of one tuning run (hashable: tuple fields only)."""

    fingerprint: str
    device: str
    # (tap_name, branch) pairs, sorted by name; matmul taps only — other
    # kinds have a forced branch the tuner never overrides.  ``branches``
    # serves the second-backward modes, ``bk_branches`` serves bk_mixed.
    branches: tuple[tuple[str, str], ...] = ()
    bk_branches: tuple[tuple[str, str], ...] = ()
    # (tap_name, dispatch_op, impl) triples, sorted — the measured
    # Pallas-vs-XLA winner per clipping hot op (repro.kernels.dispatch).
    # Like the branch maps: pure cost, never math; covered by the consensus
    # hash so a fleet cannot mix kernel choices.  Empty on pre-v5 artifacts
    # (the dispatch backend default applies).
    kernels: tuple[tuple[str, str, str], ...] = ()
    # Table-7 measurement reused as a runtime feature: the largest physical
    # microbatch that fits the memory budget, and the accumulation the tuning
    # run derived for its logical batch (informational — consumers re-derive
    # for their own logical batch via max_batch.derive_accumulation).
    physical_batch: Optional[int] = None
    logical_batch: Optional[int] = None
    accumulation_steps: Optional[int] = None
    # the budget the max-batch search ran under; a cached plan is only valid
    # for a re-run with the same budget
    budget_bytes: Optional[int] = None
    # True once branch timings were re-measured at the tuned physical batch
    # (the ROADMAP "profile at the tuned physical batch" loop)
    measured_at_physical: bool = False
    # provenance
    arch: Optional[str] = None
    # (name, ghost, inst, bk_ghost, bk_inst, second_bwd) microseconds
    timings: tuple[tuple[str, float, float, float, float, float], ...] = ()
    # clipping-policy identity (repro.policies.ClipPolicy.fingerprint()),
    # stamped by PrivacyEngine.tune; "" on pre-v4 artifacts and plans built
    # outside an engine.  Covered by consensus_hash() — a fleet cannot mix
    # policies — but ignored by matches(): branch decisions are
    # policy-independent, so the *measurements* stay valid across policies.
    policy_fingerprint: str = ""
    # -- fleet consensus provenance (v3, repro.tuner.consensus) -----------
    # device strings that ratified this plan in a fleet agreement; matches()
    # accepts any of them (a mixed-kind fleet must trace ONE branch map, so
    # the agreed plan is deliberately consumable on every ratifying kind)
    devices: tuple[str, ...] = ()
    # consensus_hash() at agreement time, certified identical on all ranks
    agreed_hash: Optional[str] = None
    # fleet size at agreement time (None = never agreed / single-host plan)
    agreed_ranks: Optional[int] = None
    # jax.process_index of the rank whose measurement won the agreement
    leader_process: Optional[int] = None
    version: int = PLAN_VERSION

    # -- consumption -----------------------------------------------------
    def branch_map(self, mode: str = "mixed_ghost") -> dict[str, str]:
        """The per-tap branch decisions as a dict; ``mode`` picks which map."""
        return dict(self.bk_branches if mode == "bk_mixed" else self.branches)

    def kernel_map(self) -> dict[str, dict[str, str]]:
        """The recorded kernel choices as ``{tap: {op: impl}}``."""
        out: dict[str, dict[str, str]] = {}
        for name, op, impl in self.kernels:
            out.setdefault(name, {})[op] = impl
        return out

    @property
    def device_kind(self) -> str:
        """The accelerator kind (``device_string`` minus the platform prefix)."""
        return self.device.split(":", 1)[-1]

    def ratified_on(self, device: str) -> bool:
        """True when ``device`` measured this plan or agreed to adopt it."""
        return device == self.device or device in self.devices

    def consensus_bytes(self) -> bytes:
        """Canonical serialization for fleet agreement (provenance excluded).

        Two plans with identical measurements produce identical bytes
        regardless of who stamps which agreement fields onto them — the
        property the consensus hash certification rests on.
        """
        d = dataclasses.asdict(self)
        for f in PROVENANCE_FIELDS:
            d.pop(f, None)
        d["branches"] = [list(b) for b in self.branches]
        d["bk_branches"] = [list(b) for b in self.bk_branches]
        d["kernels"] = [list(k) for k in self.kernels]
        d["timings"] = [list(t) for t in self.timings]
        return json.dumps(d, sort_keys=True, separators=(",", ":")).encode()

    def consensus_hash(self) -> str:
        """16-hex-char hash of ``consensus_bytes()`` — the fleet handshake."""
        return hashlib.sha256(self.consensus_bytes()).hexdigest()[:16]

    def matches(
        self, metas: Mapping[str, TapMeta], device: Optional[Any] = None
    ) -> bool:
        """True when this plan is valid on this device for these taps.

        Gate *every* plan consumption on this — branch overrides AND the
        tuned physical batch: a plan tuned on different hardware describes a
        different memory budget just as much as different branch costs.
        Valid means measured on this device OR ratified by it in a fleet
        agreement (``devices``): a mixed-kind fleet must trace one branch
        map everywhere, so adoption extends validity by construction.
        """
        return (
            self.ratified_on(device_string(device))
            and self.fingerprint == shape_fingerprint(metas)
        )

    def overrides_for(
        self,
        metas: Mapping[str, TapMeta],
        device: Optional[Any] = None,
        mode: str = "mixed_ghost",
    ) -> dict[str, str]:
        """Per-tap branch overrides, or {} (analytic fallback) when stale.

        A plan is stale when it was measured on a different device or for
        different tap shapes; using it would apply timings that no longer
        describe the hardware about to run.  ``mode`` selects the branch
        map: ``bk_mixed`` banks residuals instead of paying the second
        backward, so its measured winners are stored separately.
        """
        dev = device_string(device)
        if not self.ratified_on(dev):
            log.warning(
                "ClipPlan measured on %s (ratified by %s) but running on %s; "
                "falling back to the analytic decision",
                self.device, list(self.devices) or "no fleet", dev,
            )
            return {}
        fp = shape_fingerprint(metas)
        if self.fingerprint != fp:
            log.warning(
                "ClipPlan fingerprint %s does not match model taps (%s); "
                "falling back to the analytic decision",
                self.fingerprint, fp,
            )
            return {}
        branches = self.bk_branches if mode == "bk_mixed" else self.branches
        return {name: b for name, b in branches if name in metas}

    def kernels_for(
        self, metas: Mapping[str, TapMeta], device: Optional[Any] = None
    ) -> dict[str, dict[str, str]]:
        """Per-tap kernel-impl choices, or {} (dispatch default) when stale.

        STRICTER than ``overrides_for``: branch overrides are
        backend-portable cost hints (``matches`` accepts any *ratifying*
        device of a fleet agreement), but a kernel impl is backend-specific
        — a ``pallas`` winner measured on the fleet's TPU kind must never
        be applied by a ratifying GPU/CPU rank, where it would silently
        trace the interpreter into the production step.  So the map only
        applies on the device kind that measured it; every other kind
        (ratifying or not) falls back to its own dispatch backend default,
        which is deterministic per kind.
        """
        if not self.kernels:
            return {}
        if (
            self.device != device_string(device)
            or self.fingerprint != shape_fingerprint(metas)
        ):
            log.warning(
                "ClipPlan kernel map dropped (measured on %s for fingerprint "
                "%s); falling back to the dispatch backend default",
                self.device, self.fingerprint,
            )
            return {}
        return {
            name: ks for name, ks in self.kernel_map().items() if name in metas
        }

    def tap_timings(self) -> dict[str, TapTiming]:
        """The stored timing rows re-hydrated as ``TapTiming`` per tap."""
        return {
            name: TapTiming(g, i, bg, bi, sb)
            for name, g, i, bg, bi, sb in self.timings
        }

    def mode_cost_us(self, mode: str) -> float:
        """Measured per-step clipping cost (us) of running under ``mode``."""
        return sum(t.mode_cost_us(mode) for t in self.tap_timings().values())

    def recommended_mode(self) -> str:
        """The measured three-way verdict: cheapest tuned mode per step.

        Compares {ghost-or-instantiate norms + second backward} against
        {book-keeping banks + weighted einsums} using the per-tap timings.
        Memory is not in this comparison — book-keeping banks residuals, so
        callers on the edge of the budget should trust the max-batch search
        (which compiles the actual mode) over this time-only verdict.
        """
        if not self.timings:
            return "mixed_ghost"
        return min(TUNED_MODES, key=self.mode_cost_us)

    def replace_batch(
        self,
        *,
        physical_batch: int,
        logical_batch: Optional[int] = None,
        accumulation_steps: Optional[int] = None,
        budget_bytes: Optional[int] = None,
    ) -> "ClipPlan":
        """Copy with a new batch certificate (branch maps/timings untouched)."""
        return dataclasses.replace(
            self,
            physical_batch=physical_batch,
            logical_batch=logical_batch,
            accumulation_steps=accumulation_steps,
            budget_bytes=budget_bytes,
        )

    # -- serialization ---------------------------------------------------
    def to_json(self) -> str:
        """The on-disk artifact: deterministic, human-inspectable JSON.

        Keys are sorted and tuples listified, so two ``ClipPlan`` objects
        that compare equal serialize byte-identically — the property fleet
        consensus certifies across ranks.
        """
        d = dataclasses.asdict(self)
        d["branches"] = [list(b) for b in self.branches]
        d["bk_branches"] = [list(b) for b in self.bk_branches]
        d["kernels"] = [list(k) for k in self.kernels]
        d["timings"] = [list(t) for t in self.timings]
        d["devices"] = list(self.devices)
        return json.dumps(d, indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ClipPlan":
        """Parse and validate a plan artifact; raises ``ValueError`` when stale.

        v5 is current; v4 (pre-kernel-map), v3 (pre-policy) and v2
        (pre-consensus) migrate with empty defaults for the fields they
        predate — their measurements are still sound on the device that
        took them, though a v3/v4 ``agreed_hash`` no longer re-verifies
        (the hash covered the older schema; re-run the agreement).
        v1 (pre-three-way) and unknown versions are rejected: their branch
        maps know nothing about the bk bank decision.
        """
        d = json.loads(text)
        version = int(d.get("version", 0))
        if version not in COMPAT_VERSIONS:
            raise ValueError(f"unsupported ClipPlan version {version}")
        branches = tuple((str(n), str(b)) for n, b in d.get("branches", ()))
        bk_branches = tuple((str(n), str(b)) for n, b in d.get("bk_branches", ()))
        for _, b in branches + bk_branches:
            if b not in BRANCHES:
                raise ValueError(f"invalid branch {b!r} in ClipPlan")
        kernels = tuple(
            (str(n), str(op), str(impl)) for n, op, impl in d.get("kernels", ())
        )
        for _, op, impl in kernels:
            if op not in KERNEL_OPS:
                raise ValueError(f"unknown kernel op {op!r} in ClipPlan")
            if impl not in KERNEL_IMPLS:
                raise ValueError(
                    f"invalid kernel impl {impl!r} for op {op!r} in ClipPlan"
                )
        return cls(
            fingerprint=str(d["fingerprint"]),
            device=str(d["device"]),
            branches=branches,
            bk_branches=bk_branches,
            kernels=kernels,
            physical_batch=d.get("physical_batch"),
            logical_batch=d.get("logical_batch"),
            accumulation_steps=d.get("accumulation_steps"),
            budget_bytes=d.get("budget_bytes"),
            measured_at_physical=bool(d.get("measured_at_physical", False)),
            arch=d.get("arch"),
            timings=tuple(
                (str(n), float(g), float(i), float(bg), float(bi), float(sb))
                for n, g, i, bg, bi, sb in d.get("timings", ())
            ),
            policy_fingerprint=str(d.get("policy_fingerprint", "")),
            devices=tuple(str(x) for x in d.get("devices", ())),
            agreed_hash=d.get("agreed_hash"),
            agreed_ranks=d.get("agreed_ranks"),
            leader_process=d.get("leader_process"),
            version=PLAN_VERSION,
        )

    def save(self, path: str) -> str:
        """Write the JSON artifact (parent dirs created); returns ``path``."""
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path: str) -> "ClipPlan":
        """Read + validate a plan artifact (see ``from_json`` for staleness)."""
        with open(path) as f:
            return cls.from_json(f.read())


def cache_dir() -> str:
    """Plan cache root: ``$REPRO_TUNER_CACHE`` or ``~/.cache/repro-tuner``."""
    return os.environ.get(
        "REPRO_TUNER_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro-tuner"),
    )


def default_plan_path(arch: Optional[str], fingerprint: str) -> str:
    """Cache path for an (arch, shape-fingerprint) pair's plan artifact."""
    stem = f"{arch or 'model'}-{fingerprint}"
    return os.path.join(cache_dir(), f"{stem}.json")


def load_cached_plan(arch: Optional[str], metas: Mapping[str, TapMeta]) -> Optional[ClipPlan]:
    """Look up a previously tuned plan for these shapes, if any."""
    path = default_plan_path(arch, shape_fingerprint(metas))
    if not os.path.exists(path):
        return None
    try:
        return ClipPlan.load(path)
    except (ValueError, KeyError, json.JSONDecodeError) as e:
        log.warning("ignoring unreadable cached plan %s (%s)", path, e)
        return None
