"""The ClipPlan artifact: a cached, device-specific clipping decision.

The analytic rule Eq (4.1) predicts which branch (ghost norm vs gradient
instantiation) is cheaper from operation counts alone.  On real hardware the
winner also depends on kernel launch overhead, tiling, dtype, and fusion, so
the tuner *measures* both branches per tap (measure.py) and records the
winners here, together with enough provenance to know when the plan is stale:

- a **shape fingerprint** over every tap's (kind, T, D, p, groups, stack,
  dtype) signature — batch size is deliberately excluded so one plan serves
  any physical microbatch (the max-batch search varies B);
- the **device string** (platform + device kind) the plan was measured on.

``matches(metas)`` is the staleness gate; every consumption goes through it.
``overrides_for(metas)`` returns the per-tap branch map when the plan
matches the current model/device and an empty map (analytic fallback)
otherwise — a stale plan can never silently redirect a branch, and callers
using ``physical_batch`` must check ``matches`` first (launch/train.py
does).  Plans round-trip through JSON and live under
``~/.cache/repro-tuner/`` (override with $REPRO_TUNER_CACHE or an explicit
path).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.core.taps import TapMeta
from repro.utils.logging import get_logger

log = get_logger("tuner.plan")

PLAN_VERSION = 1
BRANCHES = ("ghost", "instantiate")


def device_string(device: Optional[Any] = None) -> str:
    """Stable identity of the accelerator a plan was measured on."""
    d = device if device is not None else jax.devices()[0]
    return f"{d.platform}:{d.device_kind}"


def tap_signature(name: str, meta: TapMeta) -> dict:
    """Per-tap shape identity (batch-size free; see module docstring)."""
    return {
        "name": name,
        "kind": meta.kind,
        "T": int(meta.T),
        "D": int(meta.D),
        "p": int(meta.p),
        "n_groups": int(meta.n_groups),
        "stack_dims": [int(s) for s in meta.stack_dims],
        "dtype": str(jnp.dtype(meta.s_dtype)),
        "conv": meta.conv is not None,
    }


def shape_fingerprint(metas: Mapping[str, TapMeta]) -> str:
    sigs = sorted(
        (tap_signature(name, m) for name, m in metas.items()),
        key=lambda s: s["name"],
    )
    blob = json.dumps(sigs, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class TapTiming:
    """Measured branch costs for one tap (microseconds, median-of-k)."""

    ghost_us: float
    instantiate_us: float

    @property
    def winner(self) -> str:
        return "ghost" if self.ghost_us <= self.instantiate_us else "instantiate"


@dataclasses.dataclass(frozen=True)
class ClipPlan:
    """Serializable result of one tuning run (hashable: tuple fields only)."""

    fingerprint: str
    device: str
    # (tap_name, branch) pairs, sorted by name; matmul taps only — other
    # kinds have a forced branch the tuner never overrides.
    branches: tuple[tuple[str, str], ...] = ()
    # Table-7 measurement reused as a runtime feature: the largest physical
    # microbatch that fits the memory budget, and the accumulation the tuning
    # run derived for its logical batch (informational — consumers re-derive
    # for their own logical batch via max_batch.derive_accumulation).
    physical_batch: Optional[int] = None
    logical_batch: Optional[int] = None
    accumulation_steps: Optional[int] = None
    # the budget the max-batch search ran under; a cached plan is only valid
    # for a re-run with the same budget
    budget_bytes: Optional[int] = None
    # provenance
    arch: Optional[str] = None
    timings: tuple[tuple[str, float, float], ...] = ()  # (name, ghost, inst) us
    version: int = PLAN_VERSION

    # -- consumption -----------------------------------------------------
    def branch_map(self) -> dict[str, str]:
        return dict(self.branches)

    def matches(
        self, metas: Mapping[str, TapMeta], device: Optional[Any] = None
    ) -> bool:
        """True when this plan was measured on this device for these taps.

        Gate *every* plan consumption on this — branch overrides AND the
        tuned physical batch: a plan tuned on different hardware describes a
        different memory budget just as much as different branch costs.
        """
        return (
            self.device == device_string(device)
            and self.fingerprint == shape_fingerprint(metas)
        )

    def overrides_for(
        self, metas: Mapping[str, TapMeta], device: Optional[Any] = None
    ) -> dict[str, str]:
        """Per-tap branch overrides, or {} (analytic fallback) when stale.

        A plan is stale when it was measured on a different device or for
        different tap shapes; using it would apply timings that no longer
        describe the hardware about to run.
        """
        dev = device_string(device)
        if self.device != dev:
            log.warning(
                "ClipPlan measured on %s but running on %s; "
                "falling back to the analytic Eq-(4.1) decision", self.device, dev,
            )
            return {}
        fp = shape_fingerprint(metas)
        if self.fingerprint != fp:
            log.warning(
                "ClipPlan fingerprint %s does not match model taps (%s); "
                "falling back to the analytic Eq-(4.1) decision",
                self.fingerprint, fp,
            )
            return {}
        return {name: b for name, b in self.branches if name in metas}

    def replace_batch(
        self,
        *,
        physical_batch: int,
        logical_batch: Optional[int] = None,
        accumulation_steps: Optional[int] = None,
        budget_bytes: Optional[int] = None,
    ) -> "ClipPlan":
        return dataclasses.replace(
            self,
            physical_batch=physical_batch,
            logical_batch=logical_batch,
            accumulation_steps=accumulation_steps,
            budget_bytes=budget_bytes,
        )

    # -- serialization ---------------------------------------------------
    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["branches"] = [list(b) for b in self.branches]
        d["timings"] = [list(t) for t in self.timings]
        return json.dumps(d, indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ClipPlan":
        d = json.loads(text)
        version = int(d.get("version", 0))
        if version != PLAN_VERSION:
            raise ValueError(f"unsupported ClipPlan version {version}")
        branches = tuple((str(n), str(b)) for n, b in d.get("branches", ()))
        for _, b in branches:
            if b not in BRANCHES:
                raise ValueError(f"invalid branch {b!r} in ClipPlan")
        return cls(
            fingerprint=str(d["fingerprint"]),
            device=str(d["device"]),
            branches=branches,
            physical_batch=d.get("physical_batch"),
            logical_batch=d.get("logical_batch"),
            accumulation_steps=d.get("accumulation_steps"),
            budget_bytes=d.get("budget_bytes"),
            arch=d.get("arch"),
            timings=tuple(
                (str(n), float(g), float(i)) for n, g, i in d.get("timings", ())
            ),
            version=version,
        )

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path: str) -> "ClipPlan":
        with open(path) as f:
            return cls.from_json(f.read())


def cache_dir() -> str:
    return os.environ.get(
        "REPRO_TUNER_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro-tuner"),
    )


def default_plan_path(arch: Optional[str], fingerprint: str) -> str:
    stem = f"{arch or 'model'}-{fingerprint}"
    return os.path.join(cache_dir(), f"{stem}.json")


def load_cached_plan(arch: Optional[str], metas: Mapping[str, TapMeta]) -> Optional[ClipPlan]:
    """Look up a previously tuned plan for these shapes, if any."""
    path = default_plan_path(arch, shape_fingerprint(metas))
    if not os.path.exists(path):
        return None
    try:
        return ClipPlan.load(path)
    except (ValueError, KeyError, json.JSONDecodeError) as e:
        log.warning("ignoring unreadable cached plan %s (%s)", path, e)
        return None
