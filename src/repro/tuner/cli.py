"""Tuner CLI: profile a model's taps and write the ClipPlan artifact.

    PYTHONPATH=src python -m repro.tuner --arch xlstm-350m --reduced

Steps: build the arch (registry), discover its taps, time the three-way
branch decision per matmul tap on this device — {ghost norm, instantiated
norm} for the second-backward modes and {ghost-bank, psg-bank} for
book-keeping, plus each tap's share of the second backward — binary-search
the max physical microbatch under the memory budget, re-measure at the tuned
physical batch, and write the plan JSON (cache path or --plan).  The printed
table shows where the measured winner disagrees with the analytic Eq-(4.1)
rule — the entire reason this subsystem exists — and which tuned mode
(mixed_ghost vs bk_mixed) the measurements recommend.

Fleet workflows (repro.tuner.consensus):

- ``--consensus``: run the multi-host agreement after measuring — one
  leader per device kind measures, every rank adopts the byte-identical
  agreed plan (single process: stamps consensus provenance on the plan).
- ``--export-plan out.json``: write the adopted plan for offline fleets
  whose ranks cannot gather at tune time.
- ``--import-plan in.json``: skip measuring; load + strictly verify a plan
  against this host's model/device (exit non-zero on any mismatch — a
  fleet rank must never silently fall back to the analytic rule).
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.configs.registry import build_model, get_arch
from repro.core.clipping import ClipConfig, discover_meta, dp_value_and_clipped_grad
from repro.core.decision import decide
from repro.data.synthetic import synthetic_arch_batch
from repro.tuner import max_batch as mb
from repro.tuner.measure import (
    MeasureConfig,
    build_plan,
    close_physical_batch_loop,
)
from repro.tuner.plan import default_plan_path
from repro.utils.logging import get_logger

log = get_logger("tuner")


def parse_args(argv=None):
    ap = argparse.ArgumentParser(prog="repro.tuner")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="physical microbatch used for profiling")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--logical-batch", type=int, default=None,
                    help="derive accumulation_steps for this logical batch "
                         "(default: --batch)")
    ap.add_argument("--plan", default=None,
                    help="output path (default: ~/.cache/repro-tuner/)")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--max-rows", type=int, default=64,
                    help="clamp profiled rows N (0 = unclamped, use --batch as-is)")
    ap.add_argument("--budget-gb", type=float, default=16.0,
                    help="memory budget for the max-batch search")
    ap.add_argument("--hi-cap", type=int, default=4096)
    ap.add_argument("--skip-max-batch", action="store_true")
    ap.add_argument("--skip-remeasure", action="store_true",
                    help="do not re-time branches at the tuned physical batch")
    ap.add_argument("--mode", default="mixed_ghost",
                    help="clipping mode the max-batch search compiles")
    ap.add_argument("--consensus", action="store_true",
                    help="fleet agreement after measuring: adopt the "
                         "byte-identical plan on every rank")
    ap.add_argument("--export-plan", default=None,
                    help="also write the adopted plan here (offline fleets)")
    ap.add_argument("--import-plan", default=None,
                    help="skip measuring: load + strictly verify this plan "
                         "against the local model/device (non-zero exit on "
                         "mismatch)")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = synthetic_arch_batch(cfg, batch=args.batch, seq=args.seq)

    metas = discover_meta(model.loss_with_ctx, params, batch)
    log.info("discovered %d taps (%d matmul) on %s", len(metas),
             sum(1 for m in metas.values() if m.kind == "matmul"),
             jax.devices()[0].device_kind)

    if args.import_plan:
        # offline-fleet rank: adopt a plan exported elsewhere, or die loudly
        from repro.tuner.consensus import PlanConsensusError, verify_adopted
        from repro.tuner.plan import ClipPlan

        try:
            plan = ClipPlan.load(args.import_plan)
            verify_adopted(plan, metas)
        except (PlanConsensusError, ValueError, OSError) as e:
            log.error("cannot adopt %s: %s", args.import_plan, e)
            return 1
        for out in {args.plan, args.export_plan} - {None}:
            plan.save(out)  # re-export the canonicalized (v3) artifact
        print(f"adopted ClipPlan {args.import_plan} for {cfg.name} on "
              f"{plan.device} (hash {plan.consensus_hash()}"
              f"{f', agreed by {plan.agreed_ranks} rank(s)' if plan.agreed_ranks else ''})")
        print(f"recommended mode: {plan.recommended_mode()}  "
              f"max physical batch: {plan.physical_batch}")
        return 0

    if args.consensus:
        # one measurement per device kind: a non-leader rank measures
        # nothing and adopts the fleet plan (measuring anyway would submit
        # a noise-divergent duplicate the agreement rightly rejects)
        from repro.tuner.consensus import fleet_agree, fleet_roles

        roles = fleet_roles()
        if not roles.is_leader:
            plan = fleet_agree(None, metas)
            plan.save(args.plan or default_plan_path(cfg.name, plan.fingerprint))
            if args.export_plan:
                plan.save(args.export_plan)
            print(f"process {roles.process_index} ({roles.device}): adopted "
                  f"the fleet plan measured by process {plan.leader_process} "
                  f"(hash {plan.agreed_hash}, {plan.agreed_ranks} ranks)")
            return 0

    measure = MeasureConfig(
        repeats=args.repeats, warmup=args.warmup,
        max_rows=args.max_rows or None,
    )
    plan = build_plan(metas, measure=measure, arch=cfg.name)

    if not args.skip_max_batch:
        grad_fn = dp_value_and_clipped_grad(
            model.loss_with_ctx, ClipConfig(mode=args.mode, plan=plan)
        )
        budget = int(args.budget_gb * 1024**3)
        max_physical = mb.max_batch_by_memory(
            grad_fn, params, batch, budget_bytes=budget, hi_cap=args.hi_cap,
            reserved_bytes=mb.resident_state_bytes(params),
        )
        if max_physical <= 0:
            log.warning("no batch fits the %.1fGB budget; plan has no "
                        "physical_batch", args.budget_gb)
        else:
            logical = args.logical_batch or args.batch
            physical, steps = mb.derive_accumulation(logical, max_physical)
            plan = plan.replace_batch(
                physical_batch=max_physical,
                logical_batch=logical,
                accumulation_steps=steps,
                budget_bytes=budget,
            )
            log.info("max physical batch %d under %.1fGB; logical %d -> "
                     "%d x %d microsteps", max_physical, args.budget_gb,
                     logical, physical, steps)
            if not args.skip_remeasure:
                # the step runs at the tuned batch: measure the decision
                # there, re-certifying the batch if any branch flips
                def _search(p):
                    fn = dp_value_and_clipped_grad(
                        model.loss_with_ctx, ClipConfig(mode=args.mode, plan=p)
                    )
                    return mb.max_batch_by_memory(
                        fn, params, batch, budget_bytes=budget,
                        hi_cap=args.hi_cap,
                        reserved_bytes=mb.resident_state_bytes(params),
                    )

                plan = close_physical_batch_loop(
                    plan, metas, _search, logical, budget, measure
                )

    if args.consensus:
        from repro.tuner.consensus import fleet_agree

        plan = fleet_agree(plan, metas)

    path = args.plan or default_plan_path(cfg.name, plan.fingerprint)
    plan.save(path)
    if args.export_plan:
        plan.save(args.export_plan)

    branch_map = plan.branch_map()
    bk_map = plan.branch_map("bk_mixed")
    timing = plan.tap_timings()
    print(f"\nClipPlan for {cfg.name} on {plan.device}  ->  {path}")
    print(f"{'tap':<40s} {'T':>5s} {'D':>6s} {'p':>6s} "
          f"{'ghost_us':>9s} {'inst_us':>9s} {'bk_g_us':>9s} {'bk_i_us':>9s} "
          f"{'2bwd_us':>8s} {'analytic':>11s} {'measured':>11s} {'bk':>11s}")
    flips = 0
    for name in sorted(branch_map):
        m = metas[name]
        analytic = decide(m, mode="mixed_ghost")
        measured = branch_map[name]
        t = timing[name]
        flag = "  <- flip" if analytic != measured else ""
        flips += analytic != measured
        print(f"{name:<40s} {m.T:>5d} {m.D:>6d} {m.p:>6d} "
              f"{t.ghost_us:>9.1f} {t.instantiate_us:>9.1f} "
              f"{t.bk_ghost_us:>9.1f} {t.bk_instantiate_us:>9.1f} "
              f"{t.second_bwd_us:>8.1f} {analytic:>11s} {measured:>11s} "
              f"{bk_map.get(name, '-'):>11s}{flag}")
    print(f"\n{flips}/{len(branch_map)} taps flip vs the analytic rule")
    kmap = plan.kernel_map()
    if kmap:
        # describe the PLAN's map, not the local backend: the plan may have
        # been imported from another device kind (its map then only applies
        # there — ClipPlan.kernels_for)
        if any(i != "xla" for ks in kmap.values() for i in ks.values()):
            for name in sorted(kmap):
                print(f"kernel impls {name}: " + "  ".join(
                    f"{op}={impl}" for op, impl in sorted(kmap[name].items())))
        elif plan.device.startswith("tpu:"):
            # both impls were raced on the measuring TPU and xla swept —
            # the signal that the Pallas kernels are underperforming there
            print("kernel impls: xla everywhere (pallas raced and lost "
                  "every op)")
        else:
            print("kernel impls: xla everywhere (single-impl device, "
                  "nothing raced)")
    print(f"measured per-step clipping cost: mixed_ghost="
          f"{plan.mode_cost_us('mixed_ghost'):.1f}us  "
          f"bk_mixed={plan.mode_cost_us('bk_mixed'):.1f}us  "
          f"-> recommended mode: {plan.recommended_mode()}")
    if plan.physical_batch:
        at = " (branches re-measured there)" if plan.measured_at_physical else ""
        print(f"max physical batch: {plan.physical_batch} "
              f"(logical {plan.logical_batch} = "
              f"{plan.accumulation_steps} microsteps){at}")
    if plan.agreed_ranks:
        print(f"fleet agreement: {plan.agreed_ranks} rank(s) on "
              f"{list(plan.devices)}, hash {plan.agreed_hash}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
