"""Tuner CLI: profile a model's taps and write the ClipPlan artifact.

    PYTHONPATH=src python -m repro.tuner --arch xlstm-350m --reduced

Steps: build the arch (registry), discover its taps, time the three-way
branch decision per matmul tap on this device — {ghost norm, instantiated
norm} for the second-backward modes and {ghost-bank, psg-bank} for
book-keeping, plus each tap's share of the second backward — binary-search
the max physical microbatch under the memory budget, re-measure at the tuned
physical batch, and write the plan JSON (cache path or --plan).  The printed
table shows where the measured winner disagrees with the analytic Eq-(4.1)
rule — the entire reason this subsystem exists — and which tuned mode
(mixed_ghost vs bk_mixed) the measurements recommend.
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.configs.registry import build_model, get_arch
from repro.core.clipping import ClipConfig, discover_meta, dp_value_and_clipped_grad
from repro.core.decision import decide
from repro.data.synthetic import synthetic_arch_batch
from repro.tuner import max_batch as mb
from repro.tuner.measure import (
    MeasureConfig,
    build_plan,
    close_physical_batch_loop,
)
from repro.tuner.plan import default_plan_path
from repro.utils.logging import get_logger

log = get_logger("tuner")


def parse_args(argv=None):
    ap = argparse.ArgumentParser(prog="repro.tuner")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="physical microbatch used for profiling")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--logical-batch", type=int, default=None,
                    help="derive accumulation_steps for this logical batch "
                         "(default: --batch)")
    ap.add_argument("--plan", default=None,
                    help="output path (default: ~/.cache/repro-tuner/)")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--max-rows", type=int, default=64,
                    help="clamp profiled rows N (0 = unclamped, use --batch as-is)")
    ap.add_argument("--budget-gb", type=float, default=16.0,
                    help="memory budget for the max-batch search")
    ap.add_argument("--hi-cap", type=int, default=4096)
    ap.add_argument("--skip-max-batch", action="store_true")
    ap.add_argument("--skip-remeasure", action="store_true",
                    help="do not re-time branches at the tuned physical batch")
    ap.add_argument("--mode", default="mixed_ghost",
                    help="clipping mode the max-batch search compiles")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = synthetic_arch_batch(cfg, batch=args.batch, seq=args.seq)

    metas = discover_meta(model.loss_with_ctx, params, batch)
    log.info("discovered %d taps (%d matmul) on %s", len(metas),
             sum(1 for m in metas.values() if m.kind == "matmul"),
             jax.devices()[0].device_kind)

    measure = MeasureConfig(
        repeats=args.repeats, warmup=args.warmup,
        max_rows=args.max_rows or None,
    )
    plan = build_plan(metas, measure=measure, arch=cfg.name)

    if not args.skip_max_batch:
        grad_fn = dp_value_and_clipped_grad(
            model.loss_with_ctx, ClipConfig(mode=args.mode, plan=plan)
        )
        budget = int(args.budget_gb * 1024**3)
        max_physical = mb.max_batch_by_memory(
            grad_fn, params, batch, budget_bytes=budget, hi_cap=args.hi_cap,
            reserved_bytes=mb.resident_state_bytes(params),
        )
        if max_physical <= 0:
            log.warning("no batch fits the %.1fGB budget; plan has no "
                        "physical_batch", args.budget_gb)
        else:
            logical = args.logical_batch or args.batch
            physical, steps = mb.derive_accumulation(logical, max_physical)
            plan = plan.replace_batch(
                physical_batch=max_physical,
                logical_batch=logical,
                accumulation_steps=steps,
                budget_bytes=budget,
            )
            log.info("max physical batch %d under %.1fGB; logical %d -> "
                     "%d x %d microsteps", max_physical, args.budget_gb,
                     logical, physical, steps)
            if not args.skip_remeasure:
                # the step runs at the tuned batch: measure the decision
                # there, re-certifying the batch if any branch flips
                def _search(p):
                    fn = dp_value_and_clipped_grad(
                        model.loss_with_ctx, ClipConfig(mode=args.mode, plan=p)
                    )
                    return mb.max_batch_by_memory(
                        fn, params, batch, budget_bytes=budget,
                        hi_cap=args.hi_cap,
                        reserved_bytes=mb.resident_state_bytes(params),
                    )

                plan = close_physical_batch_loop(
                    plan, metas, _search, logical, budget, measure
                )

    path = args.plan or default_plan_path(cfg.name, plan.fingerprint)
    plan.save(path)

    branch_map = plan.branch_map()
    bk_map = plan.branch_map("bk_mixed")
    timing = plan.tap_timings()
    print(f"\nClipPlan for {cfg.name} on {plan.device}  ->  {path}")
    print(f"{'tap':<40s} {'T':>5s} {'D':>6s} {'p':>6s} "
          f"{'ghost_us':>9s} {'inst_us':>9s} {'bk_g_us':>9s} {'bk_i_us':>9s} "
          f"{'2bwd_us':>8s} {'analytic':>11s} {'measured':>11s} {'bk':>11s}")
    flips = 0
    for name in sorted(branch_map):
        m = metas[name]
        analytic = decide(m, mode="mixed_ghost")
        measured = branch_map[name]
        t = timing[name]
        flag = "  <- flip" if analytic != measured else ""
        flips += analytic != measured
        print(f"{name:<40s} {m.T:>5d} {m.D:>6d} {m.p:>6d} "
              f"{t.ghost_us:>9.1f} {t.instantiate_us:>9.1f} "
              f"{t.bk_ghost_us:>9.1f} {t.bk_instantiate_us:>9.1f} "
              f"{t.second_bwd_us:>8.1f} {analytic:>11s} {measured:>11s} "
              f"{bk_map.get(name, '-'):>11s}{flag}")
    print(f"\n{flips}/{len(branch_map)} taps flip vs the analytic rule")
    print(f"measured per-step clipping cost: mixed_ghost="
          f"{plan.mode_cost_us('mixed_ghost'):.1f}us  "
          f"bk_mixed={plan.mode_cost_us('bk_mixed'):.1f}us  "
          f"-> recommended mode: {plan.recommended_mode()}")
    if plan.physical_batch:
        at = " (branches re-measured there)" if plan.measured_at_physical else ""
        print(f"max physical batch: {plan.physical_batch} "
              f"(logical {plan.logical_batch} = "
              f"{plan.accumulation_steps} microsteps){at}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
