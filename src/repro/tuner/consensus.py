"""Multi-host plan agreement: one ClipPlan, byte-identical on every rank.

Why this exists
---------------
Under GSPMD every rank traces the *same* program; the per-tap branch choice
(ghost vs instantiate norms, ghost-book vs psg bank) is baked into that
trace.  Since PR 1/2 the branch choice is *measured* — and measurements on
different ranks differ by timer noise, thermal state, or genuinely different
device kinds.  Two ranks tracing different branches for the same tap either
deadlock (collectives issued in different orders) or silently diverge.  Fast
per-example clipping at scale hit exactly this wall before (Lee & Kifer,
arXiv:2009.03106); the three-way measured decision (Bu et al.,
arXiv:2210.00038) makes cross-rank agreement a hard correctness requirement,
not an optimization.

Protocol (three phases, all deterministic given the gathered reports):

1. **roles** — every rank gathers ``(process_index, device_string)``; the
   lowest process index per device *kind* is that kind's leader.  Only
   leaders measure: a fleet tunes once per device kind, not once per rank.
2. **agree** — leaders' plans (as canonical JSON bytes) are all-gathered;
   every rank runs the same pure function ``agree()`` over the same sorted
   report list, so every rank computes the same adopted plan:

   - ranks of one device kind must report one fingerprint (a mismatch means
     ranks are running different models — fail loudly, nothing sane can be
     traced);
   - with a single device kind the leader's plan wins outright;
   - with mixed kinds the winner is the kind whose cost-reporting ranks
     have the lowest *median* measured step cost (in the default flow only
     the leader reports a cost, so the median is just its value; ranks
     that do carry costs — e.g. future cache-holding reporters — are
     aggregated by median so one straggler cannot flip the verdict;
     deterministic tie-break on the device string, then leader index);
   - the adopted ``physical_batch`` is the MIN over every candidate that
     certified one — the weakest device bounds the fleet, since GSPMD
     shards the physical batch uniformly;
   - the adopted plan is stamped with provenance (``devices`` ratifying it,
     ``agreed_hash``, ``agreed_ranks``, ``leader_process``) — stamping is
     excluded from the hash (plan.PROVENANCE_FIELDS) so it is idempotent.

3. **certify** — every rank gathers its adopted plan's ``consensus_hash()``
   and fails loudly unless all hashes are equal.  Only after this gate may a
   step be traced.

The gather primitive is injectable (``gather_fn(payload) -> [payloads]``):
production uses a small all-gather of plan bytes over the processes backing
the existing mesh (``jax.experimental.multihost_utils``); tests simulate
whole fleets with plain lists and no ``jax.distributed`` at all.  Offline
fleets (no interconnect at tune time) use ``repro.tuner.cli --export-plan``
on one host and ``--import-plan`` + ``verify_adopted`` on the rest.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import statistics
from typing import Any, Callable, Mapping, Optional, Sequence

import jax

from repro.core.taps import TapMeta
from repro.obs.events import emit_event
from repro.tuner.plan import (
    TUNED_MODES,
    ClipPlan,
    device_string,
    shape_fingerprint,
)
from repro.utils.logging import get_logger

log = get_logger("tuner.consensus")

# gather_fn contract: given this rank's payload dict, return every rank's
# payload (own included), in any order.  Must be collective-consistent: all
# ranks see the same multiset.
GatherFn = Callable[[dict], list[dict]]


class PlanConsensusError(RuntimeError):
    """A fleet cannot agree on one ClipPlan; tracing must not proceed."""


@dataclasses.dataclass(frozen=True)
class RankReport:
    """One rank's contribution to the agreement phase."""

    process_index: int
    device: str  # plan.device_string() of this rank
    fingerprint: str  # shape_fingerprint of this rank's discovered taps
    plan_json: Optional[str] = None  # leader ranks carry their measured plan
    step_cost_us: Optional[float] = None  # cheapest tuned-mode cost, if known
    # ClipPolicy fingerprint this rank will run ("" = unspecified/legacy).
    # Checked for uniformity like the shape fingerprint: a fleet mixing
    # clipping policies produces mathematically different updates per rank.
    policy: str = ""

    def to_payload(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_payload(cls, d: Mapping[str, Any]) -> "RankReport":
        return cls(
            process_index=int(d["process_index"]),
            device=str(d["device"]),
            fingerprint=str(d["fingerprint"]),
            plan_json=d.get("plan_json"),
            step_cost_us=(
                None if d.get("step_cost_us") is None else float(d["step_cost_us"])
            ),
            policy=str(d.get("policy", "")),
        )


def plan_step_cost_us(plan: ClipPlan) -> Optional[float]:
    """The rank-local scalar the mixed-kind tie-break aggregates: the plan's
    cheapest tuned-mode per-step clipping cost (None without timings)."""
    if not plan.timings:
        return None
    return min(plan.mode_cost_us(m) for m in TUNED_MODES)


# -- gather primitives ----------------------------------------------------
# monotonically increasing gather id: every rank runs the consensus phases
# in the same order (the protocol is SPMD), so the n-th gather on one rank
# pairs with the n-th gather on every other rank
_GATHER_SEQ = itertools.count()

# a hung peer must fail the fleet loudly, not stall it: every blocking
# coordination-service read is bounded by this (override per environment;
# CI uses a tight budget so a wedged collective fails the job fast)
ENV_GATHER_TIMEOUT_MS = "REPRO_CONSENSUS_TIMEOUT_MS"
DEFAULT_GATHER_TIMEOUT_MS = 120_000


def _coordination_client():
    """The jax.distributed coordination-service client, or None.

    Set by ``jax.distributed.initialize`` on every process of a real fleet;
    reaching into ``jax._src`` is deliberate — the coordination service has
    no public KV API yet, and the alternative (device collectives) cannot
    even run on CPU fleets (XLA: "Multiprocess computations aren't
    implemented on the CPU backend").
    """
    try:
        from jax._src import distributed

        return distributed.global_state.client
    except Exception:  # pragma: no cover - jax internals moved
        return None


def _kv_allgather(payload: dict, client) -> list[dict]:
    """All-gather JSON payloads through the coordination-service KV store.

    Control-plane bytes (plan JSON, hashes, certify values) never need a
    device collective: each rank publishes under a sequenced key and
    blocking-reads every peer's.  Works on any backend — including
    2-process CPU fleets in CI, where XLA has no multiprocess computations
    at all — and a missing peer raises ``PlanConsensusError`` after the
    bounded timeout instead of deadlocking the fleet.
    """
    seq = next(_GATHER_SEQ)
    n = jax.process_count()
    idx = jax.process_index()
    timeout_ms = int(
        os.environ.get(ENV_GATHER_TIMEOUT_MS, DEFAULT_GATHER_TIMEOUT_MS)
    )
    prefix = f"repro/consensus/{seq}"
    client.key_value_set(f"{prefix}/{idx}", json.dumps(payload, sort_keys=True))
    out = []
    for r in range(n):
        try:
            blob = client.blocking_key_value_get(f"{prefix}/{r}", timeout_ms)
        except Exception as e:
            raise PlanConsensusError(
                f"rank {r} did not publish its consensus payload within "
                f"{timeout_ms}ms (gather {seq}): {e}"
            ) from e
        out.append(json.loads(blob))
    try:  # bound the KV store's growth over long tuning sessions
        client.wait_at_barrier(f"{prefix}/done", timeout_ms)
        if idx == 0:
            client.key_value_delete(prefix)
    except Exception:  # cleanup is best-effort; the gather already happened
        pass
    return out


def _device_allgather(payload: dict) -> list[dict]:
    """Legacy multi-process path: length-padded uint8 device all-gather via
    ``multihost_utils`` (needs a backend with multiprocess computations —
    TPU/GPU; kept for fleets whose coordination client is unavailable)."""
    import numpy as np
    from jax.experimental import multihost_utils

    blob = json.dumps(payload, sort_keys=True).encode()
    lens = multihost_utils.process_allgather(np.asarray([len(blob)], np.int32))
    buf = np.zeros((int(np.max(lens)) + 1,), np.uint8)
    buf[: len(blob)] = np.frombuffer(blob, np.uint8)
    bufs = multihost_utils.process_allgather(buf)
    return [
        json.loads(bytes(bufs[i, : int(lens[i, 0])]).decode())
        for i in range(bufs.shape[0])
    ]


def default_gather(payload: dict) -> list[dict]:
    """All-gather one JSON-able payload per process over the jax fleet.

    Single-process: the identity (no collectives, no jax.distributed
    requirement — the path every test and single-host run takes).
    Multi-process: the coordination-service KV store carries the payloads
    (``_kv_allgather`` — backend-independent, bounded timeouts), falling
    back to the device all-gather only when no coordination client exists.
    """
    if jax.process_count() == 1:
        return [payload]
    client = _coordination_client()
    if client is not None:
        return _kv_allgather(payload, client)
    return _device_allgather(payload)


# -- phase 1: roles -------------------------------------------------------
def elect_leaders(devices: Mapping[int, str]) -> dict[str, int]:
    """Lowest process index per device string = that kind's tuning leader."""
    leaders: dict[str, int] = {}
    for idx in sorted(devices):
        leaders.setdefault(devices[idx], idx)
    return leaders


@dataclasses.dataclass(frozen=True)
class FleetRoles:
    """Outcome of the role phase for this rank."""

    process_index: int
    device: str
    is_leader: bool
    leaders: tuple[tuple[str, int], ...]  # (device, leader index), sorted
    fleet: tuple[tuple[int, str], ...]  # (process index, device), sorted

    @property
    def n_ranks(self) -> int:
        return len(self.fleet)


def fleet_roles(
    *,
    gather_fn: Optional[GatherFn] = None,
    process_index: Optional[int] = None,
    device: Optional[str] = None,
) -> FleetRoles:
    """Phase 1: gather device kinds, elect one tuning leader per kind."""
    gather = gather_fn or default_gather
    idx = jax.process_index() if process_index is None else process_index
    dev = device_string() if device is None else device
    gathered = gather({"phase": "roles", "process_index": idx, "device": dev})
    fleet = {int(p["process_index"]): str(p["device"]) for p in gathered}
    if idx not in fleet:
        raise PlanConsensusError(
            f"role gather did not include this rank (process {idx}); "
            f"saw processes {sorted(fleet)}"
        )
    leaders = elect_leaders(fleet)
    return FleetRoles(
        process_index=idx,
        device=dev,
        is_leader=leaders[dev] == idx,
        leaders=tuple(sorted(leaders.items())),
        fleet=tuple(sorted(fleet.items())),
    )


# -- phase 2: agreement (pure) --------------------------------------------
def agree(reports: Sequence[RankReport]) -> ClipPlan:
    """Deterministically reduce a fleet's reports to the one adopted plan.

    Pure function of the report multiset: every rank that evaluates it over
    the same gathered reports computes a byte-identical ``ClipPlan`` (the
    certify phase then *checks* that rather than assuming it).  Raises
    ``PlanConsensusError`` on anything that must not be traced over:
    fingerprint mismatches, a device kind whose leader has no plan, or
    candidate plans that disagree with their own kind's duplicates.
    """
    if not reports:
        raise PlanConsensusError("no rank reports to agree over")
    ordered = sorted(reports, key=lambda r: r.process_index)
    if len({r.process_index for r in ordered}) != len(ordered):
        raise PlanConsensusError("duplicate process indices in rank reports")

    # one model everywhere: the fingerprint is batch-free, so it must be
    # identical across ranks regardless of device kind
    fps = {r.fingerprint for r in ordered}
    if len(fps) != 1:
        detail = ", ".join(
            f"process {r.process_index} ({r.device}): {r.fingerprint}"
            for r in ordered
        )
        raise PlanConsensusError(
            f"ranks disagree on the tap-shape fingerprint — they are not "
            f"running the same model: {detail}"
        )

    # one clipping policy everywhere: factors (and for quantile, the very
    # threshold trajectory) differ per policy, so mixing them across ranks
    # is mathematically divergent training, not a tuning detail
    pols = {r.policy for r in ordered}
    if len(pols) != 1:
        detail = ", ".join(
            f"process {r.process_index} ({r.device}): "
            f"{r.policy or '<unspecified>'}"
            for r in ordered
        )
        raise PlanConsensusError(
            f"ranks disagree on the clipping-policy fingerprint: {detail}"
        )

    by_kind: dict[str, list[RankReport]] = {}
    for r in ordered:
        by_kind.setdefault(r.device, []).append(r)

    # per kind: the leader's plan is the candidate; any other plan-carrying
    # rank of the same kind must agree byte-for-byte (same kind + same model
    # => a divergence is timer noise promoted to config state: reject it,
    # re-tune with consensus instead of importing stale per-rank artifacts)
    candidates: dict[str, tuple[RankReport, ClipPlan]] = {}
    for kind, rs in sorted(by_kind.items()):
        carriers = [r for r in rs if r.plan_json is not None]
        if not carriers:
            raise PlanConsensusError(
                f"device kind {kind!r} (processes "
                f"{[r.process_index for r in rs]}) reported no measured plan"
            )
        leader = carriers[0]
        plan = ClipPlan.from_json(leader.plan_json)
        if plan.fingerprint != leader.fingerprint:
            raise PlanConsensusError(
                f"process {leader.process_index} reported a plan whose "
                f"fingerprint {plan.fingerprint} does not match its model "
                f"({leader.fingerprint})"
            )
        for other in carriers[1:]:
            h0 = plan.consensus_hash()
            h1 = ClipPlan.from_json(other.plan_json).consensus_hash()
            if h0 != h1:
                raise PlanConsensusError(
                    f"processes {leader.process_index} and "
                    f"{other.process_index} ({kind}) hold different plans "
                    f"({h0} vs {h1}); a fleet must not adopt per-rank "
                    f"measurements — re-tune with consensus"
                )
        candidates[kind] = (leader, plan)

    # mixed kinds: the winning kind has the lowest median measured step cost
    # across its ranks; ties break on the device string, then leader index —
    # total order, so the choice is deterministic on every rank
    def kind_key(kind: str) -> tuple:
        leader, plan = candidates[kind]
        costs = [
            r.step_cost_us for r in by_kind[kind] if r.step_cost_us is not None
        ]
        if not costs:
            own = plan_step_cost_us(plan)
            costs = [own] if own is not None else [float("inf")]
        return (statistics.median(costs), kind, leader.process_index)

    winner = min(candidates, key=kind_key)
    leader, adopted = candidates[winner]
    if len(candidates) > 1:
        log.info(
            "mixed device kinds %s: adopting %s's plan (leader process %d, "
            "median step cost %.1fus)", sorted(candidates), winner,
            leader.process_index, kind_key(winner)[0],
        )

    # the weakest certified batch bounds the fleet (uniform GSPMD shards).
    # That rule only holds when EVERY kind certified one: a kind without a
    # certificate must not inherit the winner's — its HBM never compiled
    # that batch — so the adopted plan drops the certificate instead and
    # consumers fall back to their own (per-host) re-certification.
    batches = [p.physical_batch for _, p in candidates.values()]
    if all(b is not None and b > 0 for b in batches):
        if min(batches) != adopted.physical_batch:
            adopted = dataclasses.replace(
                adopted.replace_batch(
                    physical_batch=min(batches),
                    logical_batch=adopted.logical_batch,
                    accumulation_steps=None,  # consumers re-derive per logical
                    budget_bytes=adopted.budget_bytes,
                ),
                # the winner's timings were re-measured at ITS batch, not
                # the fleet minimum the step will now run at
                measured_at_physical=False,
            )
    elif adopted.physical_batch is not None:
        log.warning(
            "device kind(s) without a batch certificate ratified the plan; "
            "dropping physical_batch=%s from the adopted plan",
            adopted.physical_batch,
        )
        adopted = dataclasses.replace(
            adopted, physical_batch=None, accumulation_steps=None,
            measured_at_physical=False,
        )

    return dataclasses.replace(
        adopted,
        devices=tuple(sorted({r.device for r in ordered})),
        agreed_hash=adopted.consensus_hash(),
        agreed_ranks=len(ordered),
        leader_process=leader.process_index,
    )


def reconcile_recertification(
    mode_ok: bool,
    physical_batch: Optional[int],
    *,
    gather_fn: Optional[GatherFn] = None,
    process_index: Optional[int] = None,
) -> tuple[bool, Optional[int]]:
    """Reduce each rank's post-adoption re-certification to one fleet verdict.

    ``--mode auto`` re-certifies the max batch under the recommended mode on
    *each rank's own device* — a kind-dependent result on mixed fleets.  The
    adopted mode must fit EVERY rank (one kind falling back alone would
    trace a different program), and the fleet's physical batch is the
    minimum any rank re-certified, mirroring ``agree()``'s batch-min rule.
    Returns ``(all_ranks_fit, fleet_min_batch)``; deterministic on every
    rank.  Single process: the identity.
    """
    gather = gather_fn or default_gather
    idx = jax.process_index() if process_index is None else process_index
    got = gather({
        "phase": "recertify", "process_index": idx,
        "mode_ok": bool(mode_ok), "physical_batch": physical_batch,
    })
    ok = all(bool(p["mode_ok"]) for p in got)
    batches = [int(p["physical_batch"]) for p in got if p.get("physical_batch")]
    return ok, (min(batches) if batches else None)


# -- phase 3: certification -----------------------------------------------
def certify_fleet_value(
    tag: str,
    value: str,
    *,
    gather_fn: Optional[GatherFn] = None,
    process_index: Optional[int] = None,
) -> None:
    """Assert every rank derived the same ``value`` for ``tag``, or abort.

    The general form of the phase-3 gate, for decisions ranks derive
    *locally after* plan adoption (e.g. ``--mode auto``'s re-certified
    {mode, physical batch, accumulation}): a per-rank fallback that
    diverges from its peers must fail loudly before tracing, exactly like
    a diverging plan hash.
    """
    gather = gather_fn or default_gather
    idx = jax.process_index() if process_index is None else process_index
    gathered = gather({"phase": f"certify:{tag}", "process_index": idx,
                       "value": value})
    values = {int(p["process_index"]): str(p["value"]) for p in gathered}
    if len(set(values.values())) != 1:
        raise PlanConsensusError(
            f"ranks diverge on {tag}: {sorted(values.items())} — refusing "
            "to trace"
        )


def certify_fleet_hash(
    plan: ClipPlan,
    *,
    gather_fn: Optional[GatherFn] = None,
    process_index: Optional[int] = None,
) -> None:
    """Every rank cross-checks the adopted plan's hash before any tracing."""
    gather = gather_fn or default_gather
    idx = jax.process_index() if process_index is None else process_index
    h = plan.consensus_hash()
    gathered = gather({"phase": "certify", "process_index": idx, "hash": h})
    hashes = {int(p["process_index"]): str(p["hash"]) for p in gathered}
    if len(set(hashes.values())) != 1:
        raise PlanConsensusError(
            f"adopted-plan hashes diverge across ranks: {sorted(hashes.items())}"
            " — refusing to trace"
        )


def verify_adopted(
    plan: ClipPlan,
    metas: Mapping[str, TapMeta],
    device: Optional[Any] = None,
    policy_fingerprint: Optional[str] = None,
) -> None:
    """Loud, pre-trace validity gate for an imported/adopted plan.

    Unlike ``plan.overrides_for`` (which *falls back* to the analytic rule —
    correct for a best-effort single-host cache hit), a fleet rank holding a
    stale plan must ABORT: its peers will trace the plan's branches, and an
    analytic fallback on one rank is exactly the divergence consensus
    exists to prevent.  Raises ``PlanConsensusError`` on a fingerprint or
    device mismatch, or when a claimed agreement hash fails to re-verify.
    ``device`` accepts a jax device or an already-formatted device string.

    Scope of the hash check: ``consensus_hash`` covers the *measurement
    content* only, so it catches accidental edits to branches/timings/
    batch — NOT edits to the provenance fields themselves (``devices``,
    ``agreed_ranks``, ...), which are excluded by construction so stamping
    stays idempotent.  There is no signing anywhere: artifacts moved
    between offline hosts are integrity-checked, not authenticated —
    transport them over channels you trust.
    """
    dev = device if isinstance(device, str) else device_string(device)
    fp = shape_fingerprint(metas)
    if plan.fingerprint != fp:
        raise PlanConsensusError(
            f"plan fingerprint {plan.fingerprint} does not match the model's "
            f"taps ({fp}); importing it would trace branches measured for a "
            "different model"
        )
    if not plan.ratified_on(dev):
        raise PlanConsensusError(
            f"plan was measured on {plan.device} and ratified by "
            f"{list(plan.devices) or 'no fleet'}; this rank is {dev} — "
            "re-run the fleet agreement to ratify this device kind"
        )
    if plan.agreed_hash is not None and plan.agreed_hash != plan.consensus_hash():
        raise PlanConsensusError(
            f"plan claims agreement hash {plan.agreed_hash} but hashes to "
            f"{plan.consensus_hash()}; the artifact was edited after the "
            "fleet certified it"
        )
    if (
        policy_fingerprint is not None
        and plan.policy_fingerprint
        and plan.policy_fingerprint != policy_fingerprint
    ):
        # an unstamped plan ("" — pre-v4 artifact or engine-less tuner run)
        # is accepted: branch measurements are policy-independent.  A plan
        # STAMPED for a different policy means the fleet certified a
        # different mechanism than this rank is about to run.
        raise PlanConsensusError(
            f"plan was agreed for clipping policy "
            f"{plan.policy_fingerprint!r} but this rank runs "
            f"{policy_fingerprint!r}; re-run the fleet agreement under one "
            "policy"
        )


# -- the one-call driver --------------------------------------------------
def fleet_agree(
    plan: Optional[ClipPlan],
    metas: Mapping[str, TapMeta],
    *,
    gather_fn: Optional[GatherFn] = None,
    process_index: Optional[int] = None,
    device: Optional[str] = None,
    policy_fingerprint: str = "",
) -> ClipPlan:
    """Phases 2+3: gather reports, agree, certify, validate — one call.

    ``plan`` is this rank's measured plan (None on non-leader ranks that
    skipped measuring).  ``policy_fingerprint`` is the clipping policy this
    rank will run (``repro.policies``); every rank — leader or not — must
    report the same one or the agreement aborts.  Returns the fleet-adopted
    plan, guaranteed byte-identical on every rank that returns, and already
    verified against this rank's ``metas``/device.  Raises
    ``PlanConsensusError`` otherwise.
    """
    gather = gather_fn or default_gather
    idx = jax.process_index() if process_index is None else process_index
    dev = device_string() if device is None else device
    report = RankReport(
        process_index=idx,
        device=dev,
        fingerprint=shape_fingerprint(metas),
        plan_json=None if plan is None else plan.to_json(),
        step_cost_us=None if plan is None else plan_step_cost_us(plan),
        policy=policy_fingerprint,
    )
    try:
        payloads = gather(dict(report.to_payload(), phase="agree"))
        reports = [RankReport.from_payload(p) for p in payloads]
        adopted = agree(reports)
        certify_fleet_hash(
            adopted, gather_fn=gather_fn, process_index=process_index
        )
        verify_adopted(
            adopted, metas, device=dev, policy_fingerprint=policy_fingerprint
        )
    except PlanConsensusError as e:
        emit_event("consensus_rejected", rank_index=idx, device=dev,
                   reason=str(e))
        raise
    log.info(
        "fleet agreement: %d rank(s), %d device kind(s), leader process %s, "
        "hash %s", adopted.agreed_ranks, len(adopted.devices),
        adopted.leader_process, adopted.agreed_hash,
    )
    emit_event("consensus_agreed", rank_index=idx,
               agreed_hash=adopted.agreed_hash,
               agreed_ranks=adopted.agreed_ranks,
               leader_process=adopted.leader_process,
               devices=sorted(adopted.devices))
    return adopted
