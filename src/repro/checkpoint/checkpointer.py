"""Atomic pytree checkpointing with elastic restore.

Format: one ``.npz`` of flattened ("a/b/c" -> array) leaves + a json sidecar
(step, leaf treedef metadata, framework version).  Writes go to a temp file
then ``os.replace`` — a crash mid-save never corrupts the latest checkpoint.

Elastic restore: checkpoints store *logical* (unsharded) arrays; ``restore``
re-shards onto whatever mesh the new job brings (different data-parallel
degree, different chip count) via ``jax.device_put`` with the new shardings.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
from typing import Any, Optional

import jax
import numpy as np

from repro.utils.logging import get_logger
from repro.utils.tree import flatten_dict, unflatten_dict

log = get_logger("checkpoint")

# full-name match: ".tmp_step_5.npz" (an in-flight or torn temp file) must
# never be reported as a restorable step
_STEP_RE = re.compile(r"step_(\d+)\.npz")
FORMAT_VERSION = 1


def save_checkpoint(directory: str | os.PathLike, step: int, state: Any) -> pathlib.Path:
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    flat = flatten_dict(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    tmp = d / f".tmp_step_{step}.npz"
    final = d / f"step_{step}.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    meta = {
        "step": int(step),
        "format": FORMAT_VERSION,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in arrays.items()},
    }
    mtmp = d / f".tmp_step_{step}.json"
    mfinal = d / f"step_{step}.json"
    mtmp.write_text(json.dumps(meta))
    os.replace(mtmp, mfinal)
    log.info("saved checkpoint step=%d (%d leaves) -> %s", step, len(arrays), final)
    return final


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = [int(m.group(1)) for p in d.iterdir() if (m := _STEP_RE.fullmatch(p.name))]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str | os.PathLike,
    step: Optional[int] = None,
    *,
    shardings: Any = None,
    cast_to: Any = None,
) -> tuple[int, Any]:
    """Returns (step, state).  ``shardings`` (same tree) re-shards on load —
    this is the elastic path: any mesh shape works."""
    d = pathlib.Path(directory)
    if step is None:
        step = latest_step(d)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {d}")
    path = d / f"step_{step}.npz"
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    state = unflatten_dict(flat)
    if cast_to is not None:
        state = jax.tree_util.tree_map(
            lambda x, spec: np.asarray(x, spec.dtype), state, cast_to
        )
    if shardings is not None:
        state = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), state, shardings
        )
    log.info("restored checkpoint step=%d from %s", step, path)
    return step, state
