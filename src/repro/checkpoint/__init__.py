from repro.checkpoint.checkpointer import save_checkpoint, restore_checkpoint, latest_step
from repro.checkpoint.manager import CheckpointManager

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointManager"]
