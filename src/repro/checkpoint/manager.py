"""Checkpoint lifecycle: rotation, async save, preemption flush."""
from __future__ import annotations

import pathlib
import re
import threading
from typing import Any, Optional

import jax

from repro.checkpoint.checkpointer import latest_step, restore_checkpoint, save_checkpoint
from repro.utils.logging import get_logger

log = get_logger("ckpt-manager")
_STEP_RE = re.compile(r"step_(\d+)\.(npz|json)$")


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        *,
        save_every: int = 100,
        keep: int = 3,
        async_save: bool = True,
    ):
        self.dir = pathlib.Path(directory)
        self.save_every = save_every
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, state: Any, *, force: bool = False) -> None:
        if not force and not self.should_save(step):
            return
        # Snapshot to host BEFORE handing to the writer thread: the train loop
        # may donate/overwrite device buffers on the next step.
        host_state = jax.tree_util.tree_map(jax.device_get, state)
        self.wait()
        if self.async_save and not force:
            self._pending = threading.Thread(
                target=self._write, args=(step, host_state), daemon=True
            )
            self._pending.start()
        else:
            self._write(step, host_state)

    def _write(self, step: int, state: Any) -> None:
        save_checkpoint(self.dir, step, state)
        self._rotate()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _rotate(self) -> None:
        steps = sorted(
            {int(m.group(1)) for p in self.dir.iterdir() if (m := _STEP_RE.search(p.name))}
        )
        for old in steps[: -self.keep] if self.keep else []:
            for suffix in ("npz", "json"):
                p = self.dir / f"step_{old}.{suffix}"
                if p.exists():
                    p.unlink()
            log.info("rotated out checkpoint step=%d", old)

    def latest(self) -> Optional[int]:
        return latest_step(self.dir)

    def restore(self, *, shardings: Any = None, step: Optional[int] = None):
        return restore_checkpoint(self.dir, step, shardings=shardings)
