"""Checkpoint lifecycle: rotation, async save, preemption flush.

Concurrency contract: ``save`` may hand the write (and the rotation that
follows it) to a background thread while the train loop keeps stepping and
— on a crash path — while ``latest_step``/``restore`` scan the same
directory.  All directory mutation and scanning therefore runs under one
instance lock, and every filename check is a *full* match anchored to the
``step_N.{npz,json}`` pattern, so in-flight temp files (``.tmp_step_N.npz``)
and stray droppings never masquerade as restorable checkpoints.

Restore is fall-back-capable: a torn or corrupted newest checkpoint (power
loss mid-fsync, an injected ``torn@step`` fault) is skipped with a warning
and the previous rotated step is loaded instead — a damaged artifact costs
recomputed steps, never the run.
"""
from __future__ import annotations

import pathlib
import re
import threading
import zlib
import zipfile
from typing import Any, Callable, Optional

import jax

from repro.checkpoint.checkpointer import latest_step, restore_checkpoint, save_checkpoint
from repro.obs.events import emit_event
from repro.utils.logging import get_logger

log = get_logger("ckpt-manager")
_STEP_RE = re.compile(r"step_(\d+)\.(npz|json)")

# what a torn/corrupt artifact raises out of np.load / unflatten: zip-layer
# damage, truncated members, bad headers, missing leaves.  FileNotFoundError
# (a step rotated away between scan and open) is an OSError and also lands
# here — fall back rather than die.
CORRUPT_CHECKPOINT_ERRORS = (
    zipfile.BadZipFile,
    zlib.error,
    EOFError,
    OSError,
    ValueError,
    KeyError,
)


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        *,
        save_every: int = 100,
        keep: int = 3,
        async_save: bool = True,
        on_saved: Optional[Callable[[int, pathlib.Path], None]] = None,
    ):
        self.dir = pathlib.Path(directory)
        self.save_every = save_every
        self.keep = keep
        self.async_save = async_save
        # test/CI seam (runtime.inject): called with (step, npz_path) after
        # the write + rotation complete — on the writer thread when async
        self.on_saved = on_saved
        self._pending: Optional[threading.Thread] = None
        # serializes directory mutation (write+rotate, possibly on the
        # writer thread) against scans (latest/restore/available_steps)
        self._io_lock = threading.Lock()

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, state: Any, *, force: bool = False) -> None:
        if not force and not self.should_save(step):
            return
        # Snapshot to host BEFORE handing to the writer thread: the train loop
        # may donate/overwrite device buffers on the next step.
        host_state = jax.tree_util.tree_map(jax.device_get, state)
        self.wait()
        if self.async_save and not force:
            self._pending = threading.Thread(
                target=self._write, args=(step, host_state), daemon=True
            )
            self._pending.start()
        else:
            self._write(step, host_state)

    def _write(self, step: int, state: Any) -> None:
        with self._io_lock:
            path = save_checkpoint(self.dir, step, state)
            self._rotate()
        if self.on_saved is not None:
            self.on_saved(step, path)
        # after on_saved: a torn/corrupt injector has already mangled the
        # artifact, so the event describes what is actually on disk.  The
        # JSONL sink is lock-serialized — this may run on the writer thread.
        emit_event("checkpoint_saved", step=step, path=str(path),
                   async_save=self.async_save)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _steps_on_disk(self) -> list[int]:
        """Sorted completed-checkpoint steps; temp/stray files skipped."""
        if not self.dir.exists():
            return []
        steps = set()
        for p in self.dir.iterdir():
            m = _STEP_RE.fullmatch(p.name)
            if m:
                steps.add(int(m.group(1)))
        return sorted(steps)

    def available_steps(self) -> list[int]:
        with self._io_lock:
            return self._steps_on_disk()

    def _rotate(self) -> None:
        # caller holds _io_lock
        steps = self._steps_on_disk()
        for old in steps[: -self.keep] if self.keep else []:
            for suffix in ("npz", "json"):
                p = self.dir / f"step_{old}.{suffix}"
                try:
                    p.unlink(missing_ok=True)
                except OSError as e:  # a racing scan/unlink is not fatal
                    log.warning("rotation could not remove %s: %s", p, e)
            log.info("rotated out checkpoint step=%d", old)

    def latest(self) -> Optional[int]:
        with self._io_lock:
            return latest_step(self.dir)

    def restore(self, *, shardings: Any = None, step: Optional[int] = None):
        """Restore ``step`` (or the newest *readable* checkpoint).

        With ``step=None`` a torn/corrupt newest artifact falls back to the
        previous rotated step; an explicit ``step`` is the caller asserting
        that exact artifact, so damage propagates as the raw error.
        """
        with self._io_lock:
            if step is not None:
                out = restore_checkpoint(self.dir, step, shardings=shardings)
                emit_event("checkpoint_restored", step=step,
                           directory=str(self.dir))
                return out
            candidates = self._steps_on_disk()
            for s in reversed(candidates):
                try:
                    out = restore_checkpoint(self.dir, s, shardings=shardings)
                    emit_event("checkpoint_restored", step=s,
                               directory=str(self.dir),
                               fell_back=s != candidates[-1])
                    return out
                except CORRUPT_CHECKPOINT_ERRORS as e:
                    log.warning(
                        "checkpoint step=%d unreadable (%s: %s); falling back "
                        "to the previous step", s, type(e).__name__, e,
                    )
            raise FileNotFoundError(
                f"no readable checkpoints under {self.dir} "
                f"(scanned steps {candidates})"
            )
