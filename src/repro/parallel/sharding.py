"""Logical-axis -> mesh-axis resolution (DP / FSDP / TP / EP / SP / pod).

Parallelism layout:
- batch dims shard over (pod, data)          — data parallelism
- "embed" (d_model dims of weights) shards over (pod, data) — FSDP: optimizer
  state and parameters are fully sharded; GSPMD inserts the all-gathers
- "mlp"/"heads"/"kv_heads"/"vocab" shard over model — tensor parallelism
- "expert" shards over model when E % model_size == 0 (expert parallelism),
  otherwise experts replicate and "moe_mlp" takes the model axis (TP inside
  each expert)
- long KV caches shard their sequence dim over model — context parallelism
  for decode (see Attention._blocked_decode)

Every rule is guarded by divisibility: a dim that does not divide evenly on
its target axes falls back to replication (never padded shardings), so odd
head counts (whisper's 20) and vocab sizes (51866) stay correct.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def mesh_axes(mesh: Mesh, cfg: Optional[ArchConfig] = None) -> dict[str, Any]:
    names = mesh.axis_names
    dp_only = cfg is not None and getattr(cfg, "parallelism", "tp") == "dp_only"
    if dp_only:
        # batch spans every axis; params stay FSDP over (pod, data) only —
        # a 256-way fsdp sharding made GSPMD fall back to "involuntary full
        # rematerialization" when gathering (measured: 12x worse, see
        # EXPERIMENTS.md §Perf iteration 3)
        batch_axes = tuple(a for a in ("pod", "data", "model") if a in names)
        fsdp_axes = tuple(a for a in ("pod", "data") if a in names)
        return {"batch": batch_axes, "fsdp": fsdp_axes, "model": ()}
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    return {
        "batch": batch_axes,
        "fsdp": batch_axes,
        "model": ("model",) if "model" in names else (),
    }


def axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def per_host_batch(global_batch: int, mesh: Mesh, cfg: Optional[ArchConfig] = None) -> int:
    """The largest slice of ``global_batch`` any single host materializes.

    Batch dims shard over the mesh's data axes, which span every host; a
    host therefore holds ``global_batch / hosts`` samples (rounded up when
    uneven — certify the worst host, and when the batch does not shard at
    all, the whole thing).  Memory certificates MUST be compiled at this
    size: the tuner's max-batch search and ``PrivacyEngine
    .recertify_max_batch`` size HBM, and compiling them at the global batch
    on a multi-host fleet would reject physical batches that fit every
    host comfortably (or, with a budget per host, certify ones that don't).
    """
    from repro.launch.mesh import mesh_host_count

    hosts = mesh_host_count(mesh)
    if hosts <= 1:
        return global_batch
    # replicated batch (no divisible data axis): every host holds it whole
    nb = axis_size(mesh, mesh_axes(mesh, cfg)["batch"])
    if nb <= 1 or global_batch % nb != 0:
        return global_batch
    # a host can hold at most min(hosts, nb) distinct batch shards: when a
    # model axis also spans hosts, the batch shards fewer ways than there
    # are hosts and each host materializes the LARGER slice — dividing by
    # raw host count here would under-certify memory
    return -(-global_batch // min(hosts, nb))


def logical_rules(mesh: Mesh, cfg: Optional[ArchConfig] = None) -> dict[str, tuple]:
    ax = mesh_axes(mesh, cfg)
    model = ax["model"]
    rules = {
        "embed": ax["fsdp"],
        "mlp": model,
        "heads": model,
        "kv_heads": model,
        "vocab": model,
        "stack": (),
        None: (),
    }
    if cfg is not None and cfg.moe_experts:
        if cfg.moe_experts % max(axis_size(mesh, model), 1) == 0:
            rules["expert"] = model
            rules["moe_mlp"] = ax["fsdp"]  # shard expert d_ff over fsdp axes
        else:
            rules["expert"] = ()
            rules["moe_mlp"] = model
    else:
        rules["expert"] = ()
        rules["moe_mlp"] = model
    return rules


def _spec_for(shape: tuple[int, ...], axes: tuple, rules: dict, mesh: Mesh) -> P:
    entries = []
    used: set[str] = set()
    for dim, logical in zip(shape, axes):
        target = tuple(a for a in rules.get(logical, ()) if a not in used)
        # longest divisible prefix (e.g. batch 256 on a 512-chip multi-pod
        # dp_only mesh falls back to (pod, data))
        while target and dim % axis_size(mesh, target) != 0:
            target = target[:-1]
        if target:
            entries.append(target if len(target) > 1 else target[0])
            used.update(target)
        else:
            entries.append(None)
    return P(*entries)


def param_shardings(model, mesh: Mesh, cfg: Optional[ArchConfig] = None) -> Any:
    """NamedSharding tree for model params from the module's logical axes."""
    rules = logical_rules(mesh, cfg)
    axes_tree = model.axes()
    abstract = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))

    is_axes_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )

    def resolve(ax, leaf):
        assert len(ax) == len(leaf.shape), f"axes {ax} vs shape {leaf.shape}"
        return NamedSharding(mesh, _spec_for(leaf.shape, ax, rules, mesh))

    return jax.tree_util.tree_map(resolve, axes_tree, abstract, is_leaf=is_axes_leaf)


def batch_shardings(specs: Any, mesh: Mesh, cfg: Optional[ArchConfig] = None) -> Any:
    """Inputs: shard dim 0 (batch) over the data axes (longest divisible prefix)."""
    ax_full = mesh_axes(mesh, cfg)["batch"]

    def one(sp):
        ax = ax_full
        while ax and (not sp.shape or sp.shape[0] % axis_size(mesh, ax) != 0):
            ax = ax[:-1]
        if ax:
            return NamedSharding(mesh, P(ax if len(ax) > 1 else ax[0]))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(one, specs)


def state_shardings(model, mesh: Mesh, cfg: ArchConfig, abstract_state: Any) -> Any:
    """Train-state sharding: params + mirrored optimizer moments; rest replicated."""
    p_shard = param_shardings(model, mesh, cfg)
    out = {}
    for k, v in abstract_state.items():
        if k == "params":
            out[k] = p_shard
        elif k == "opt" and isinstance(v, dict):
            # optimizer moments ("m"/"v") mirror the param tree sharding
            out[k] = {kk: mirror_tree(p_shard, vv) for kk, vv in v.items()}
        else:
            out[k] = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), v)
    return out


def mirror_tree(p_shard: Any, moment_tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda s, _: s, p_shard, moment_tree)


def serve_state_shardings(
    mesh: Mesh, cfg: ArchConfig, abstract_state: Any, batch_size: int
) -> Any:
    """Serve-state sharding by leaf-path heuristics (divisibility-guarded):

    KV caches (..., B, S, K, hd): batch over (pod,data); S over model when the
    cache is long (context parallelism for decode), else K over model.
    SSM states (..., B, H, dk, dv): batch over (pod,data), H over model.
    """
    ax = mesh_axes(mesh, cfg)
    batch_ax_full, model_ax = ax["batch"], ax["model"]
    if not model_ax and "model" in mesh.axis_names:
        model_ax = ("model",)  # dp_only: long caches may still CP over model
    nm = axis_size(mesh, model_ax)

    def one(path: str, leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if not shape:
            return NamedSharding(mesh, P())
        batch_ax = batch_ax_full
        while batch_ax and batch_size % axis_size(mesh, batch_ax) != 0:
            batch_ax = batch_ax[:-1]
        nb = axis_size(mesh, batch_ax)
        # batch dim identified by value (stack dims precede it)
        bdim = None
        for i, s in enumerate(shape[: min(3, len(shape))]):
            if s == batch_size and nb > 1:
                bdim = i
                break
        if bdim is not None and nb > 1:
            spec[bdim] = batch_ax if len(batch_ax) > 1 else batch_ax[0]
        model_free = not (bdim is not None and "model" in (
            spec[bdim] if isinstance(spec[bdim], tuple) else (spec[bdim],)))
        is_kv = path.endswith("/k") or path.endswith("/v")
        if model_free and is_kv and len(shape) >= 4:
            sdim = len(shape) - 3  # (..., S, K, hd)
            if sdim != bdim and shape[sdim] >= 32768 and shape[sdim] % nm == 0 and nm > 1:
                spec[sdim] = "model"
            elif (
                len(shape) - 2 != bdim
                and shape[len(shape) - 2] % nm == 0
                and nm > 1
            ):
                spec[len(shape) - 2] = "model"
        elif model_free and path.endswith("ssm") and len(shape) >= 4:
            hdim = len(shape) - 3
            if hdim != bdim and shape[hdim] % nm == 0 and nm > 1:
                spec[hdim] = "model"
        return NamedSharding(mesh, P(*spec))

    from repro.utils.tree import tree_map_with_path_str

    return tree_map_with_path_str(one, abstract_state)
