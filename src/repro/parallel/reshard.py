"""Explicit FSDP weight gathering (the ZeRO-3 compute pattern).

Parameters are stored sharded over the fsdp axes (data[, pod]) — that's the
optimizer-state win — but COMPUTE must see them gathered, with activations
staying batch-sharded.  Left to itself, GSPMD sometimes prefers the dual
plan: keep the weight sharded, replicate the *batch*, and all-reduce the
activations — catastrophically worse (measured: 59 GB of f32[256,4096,*]
all-reduces per layer on yi-6b before this fix; see EXPERIMENTS.md §Perf).

``reshard_param(w, axes)`` pins the intended plan: a sharding constraint that
drops the fsdp axes (=> one all-gather of the bf16 weight per use, freed
after the layer) and keeps the tensor-parallel axes.  In the backward pass
the transpose turns into a reduce-scatter of the weight gradient — exactly
FSDP semantics.  Callers cast to the compute dtype FIRST so the gather moves
bf16, not fp32.

Activated via ``use_reshard_rules(mesh, cfg)`` around tracing/lowering; a
no-op otherwise (single-host smoke tests never notice).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import axis_size, logical_rules, mesh_axes

_STATE: contextvars.ContextVar[Optional[tuple]] = contextvars.ContextVar(
    "reshard_rules", default=None
)


@contextlib.contextmanager
def use_reshard_rules(mesh: Mesh, cfg=None):
    rules = logical_rules(mesh, cfg)
    fsdp = set(mesh_axes(mesh)["fsdp"])
    token = _STATE.set((mesh, rules, fsdp))
    try:
        yield
    finally:
        _STATE.reset(token)


def reshard_param(w: jax.Array, axes: tuple) -> jax.Array:
    """Constrain a parameter to its compute sharding (fsdp axes gathered)."""
    state = _STATE.get()
    if state is None:
        return w
    mesh, rules, fsdp = state
    entries = []
    for dim, logical in zip(w.shape, axes):
        target = tuple(a for a in rules.get(logical, ()) if a not in fsdp)
        if target and dim % axis_size(mesh, target) == 0:
            entries.append(target if len(target) > 1 else target[0])
        else:
            entries.append(None)
    return jax.lax.with_sharding_constraint(
        w, NamedSharding(mesh, P(*entries))
    )


def shard_seq(x: jax.Array) -> jax.Array:
    """Sequence-parallel sharding constraint for a (B, T, d) activation.

    Applied to the layer-scan carry: the activation-checkpoint residuals
    (the dominant train-memory term on TP models — 86 GB on qwen2-72b) are
    then stored sharded T/model_size per device; GSPMD re-gathers the
    sequence just-in-time inside each layer (Korthikanti et al. 2022).
    No-op for dp_only models (model axis already carries batch) and when T
    does not divide.
    """
    state = _STATE.get()
    if state is None or x.ndim != 3:
        return x
    mesh, rules, fsdp = state
    model = tuple(a for a in rules.get("mlp", ()) if a == "model")
    if not model or x.shape[1] % axis_size(mesh, model) != 0:
        return x
    batch_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    spec = [None, "model", None]
    if batch_ax and x.shape[0] % axis_size(mesh, batch_ax) == 0:
        spec[0] = batch_ax if len(batch_ax) > 1 else batch_ax[0]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def shard_heads(x: jax.Array, axis: int = 2) -> jax.Array:
    """Constrain a (B, T, H, d) tensor's head dim onto the model axis.

    The SSM path builds q/k by broadcasting shared (B, T, d_state) streams
    over heads — replicated — while v comes from a TP-sharded projection;
    GSPMD then reshards back and forth every chunk (jamba: 727 all-gathers +
    210 permutes per layer-pass). Pinning heads onto the model axis keeps
    the whole scan local.
    """
    state = _STATE.get()
    if state is None or x.ndim <= axis:
        return x
    mesh, rules, fsdp = state
    model = tuple(a for a in rules.get("heads", ()) if a == "model")
    if not model or x.shape[axis] % axis_size(mesh, model) != 0:
        return x
    batch_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    spec = [None] * x.ndim
    spec[axis] = "model"
    if batch_ax and x.shape[0] % axis_size(mesh, batch_ax) == 0:
        spec[0] = batch_ax if len(batch_ax) > 1 else batch_ax[0]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
