"""Composable transformer blocks driven by ArchConfig."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.taps import Ctx
from repro.nn.attention import Attention, make_kv_cache
from repro.nn.mamba import MambaBlock
from repro.nn.mlp import MLP, GatedMLP
from repro.nn.module import LayerNorm, Module, Params, AxesTree, RMSNorm
from repro.nn.moe import MoE
from repro.nn.stack import SequentialBlocks
from repro.nn.xlstm import MLSTMBlock, SLSTMBlock


def _norm(cfg: ArchConfig, name: str, d: int, dtype, param_dtype):
    cls = RMSNorm if cfg.norm == "rmsnorm" else LayerNorm
    return cls(name, d, dtype=dtype, param_dtype=param_dtype)


def _ffn(cfg: ArchConfig, name: str, d_ff: int, dtype, param_dtype):
    if cfg.act == "swiglu":
        return GatedMLP(name, cfg.d_model, d_ff, dtype=dtype, param_dtype=param_dtype)
    return MLP(name, cfg.d_model, d_ff, dtype=dtype, param_dtype=param_dtype)


class TransformerBlock(Module):
    """Pre-norm attention + {MLP | MoE [+ parallel dense-residual MLP]}."""

    def __init__(
        self,
        name: str,
        cfg: ArchConfig,
        *,
        use_moe: bool = False,
        cross: bool = False,
        causal: bool = True,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
    ):
        self.name = name
        self.cfg = cfg
        self.use_moe = use_moe and cfg.moe_experts > 0
        self.cross = cross
        d = cfg.d_model
        self.n1 = _norm(cfg, "n1", d, dtype, param_dtype)
        self.attn = Attention(
            "attn", d, cfg.n_heads, cfg.n_kv,
            head_dim=cfg.head_dim,
            qkv_bias=cfg.qkv_bias,
            use_rope=cfg.norm == "rmsnorm",  # LN families (whisper) use learned pos
            rope_theta=cfg.rope_theta,
            causal=causal,
            window=cfg.window,
            dtype=dtype, param_dtype=param_dtype,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
        )
        if cross:
            self.nx = _norm(cfg, "nx", d, dtype, param_dtype)
            self.xattn = Attention(
                "xattn", d, cfg.n_heads, cfg.n_kv,
                head_dim=cfg.head_dim, use_rope=False, causal=False, cross=True,
                dtype=dtype, param_dtype=param_dtype,
                block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
            )
        self.n2 = _norm(cfg, "n2", d, dtype, param_dtype)
        if self.use_moe:
            self.moe = MoE(
                "moe", d, cfg.d_ff, cfg.moe_experts, cfg.moe_top_k,
                capacity_factor=cfg.capacity_factor,
                dtype=dtype, param_dtype=param_dtype,
            )
            if cfg.moe_dense_ff:
                self.dense_mlp = _ffn(cfg, "dense_mlp", cfg.moe_dense_ff, dtype, param_dtype)
        else:
            self.mlp = _ffn(cfg, "mlp", cfg.d_ff, dtype, param_dtype)

    def init(self, key: jax.Array) -> Params:
        ks = iter(jax.random.split(key, 8))
        p = {"n1": self.n1.init(next(ks)), "attn": self.attn.init(next(ks)),
             "n2": self.n2.init(next(ks))}
        if self.cross:
            p["nx"] = self.nx.init(next(ks))
            p["xattn"] = self.xattn.init(next(ks))
        if self.use_moe:
            p["moe"] = self.moe.init(next(ks))
            if self.cfg.moe_dense_ff:
                p["dense_mlp"] = self.dense_mlp.init(next(ks))
        else:
            p["mlp"] = self.mlp.init(next(ks))
        return p

    def axes(self) -> AxesTree:
        a = {"n1": self.n1.axes(), "attn": self.attn.axes(), "n2": self.n2.axes()}
        if self.cross:
            a["nx"] = self.nx.axes()
            a["xattn"] = self.xattn.axes()
        if self.use_moe:
            a["moe"] = self.moe.axes()
            if self.cfg.moe_dense_ff:
                a["dense_mlp"] = self.dense_mlp.axes()
        else:
            a["mlp"] = self.mlp.axes()
        return a

    def init_cache(self, batch: int, dtype, *, max_len: int = 0, enc_seq: int = 0):
        c = {
            "kv": make_kv_cache(
                batch, max_len, self.attn.n_kv, self.attn.head_dim, dtype,
                window=self.cfg.window,
            )
        }
        if self.cross:
            c["xkv"] = {
                "k": jnp.zeros((batch, enc_seq, self.xattn.n_kv, self.xattn.head_dim), dtype),
                "v": jnp.zeros((batch, enc_seq, self.xattn.n_kv, self.xattn.head_dim), dtype),
            }
        return c

    def __call__(
        self,
        params: Params,
        x: jax.Array,
        ctx: Ctx,
        *,
        cache: Optional[dict] = None,
        positions: Optional[jax.Array] = None,
        enc_out: Optional[jax.Array] = None,
        dispatch: str = "per_sample",
    ):
        kv_cache = cache["kv"] if cache is not None else None
        h, new_kv = self.attn(
            params["attn"], self.n1(params["n1"], x, ctx.scope("n1")),
            ctx.scope("attn"), positions=positions, cache=kv_cache,
        )
        x = x + h
        new_cache = {"kv": new_kv} if cache is not None else None
        if self.cross:
            xc = cache["xkv"] if cache is not None else None
            h, new_x = self.xattn(
                params["xattn"], self.nx(params["nx"], x, ctx.scope("nx")),
                ctx.scope("xattn"), cache=xc, kv_src=enc_out,
            )
            x = x + h
            if cache is not None:
                new_cache["xkv"] = new_x
        h_in = self.n2(params["n2"], x, ctx.scope("n2"))
        if self.use_moe:
            h = self.moe(params["moe"], h_in, ctx.scope("moe"), dispatch=dispatch)
            if self.cfg.moe_dense_ff:
                h = h + self.dense_mlp(params["dense_mlp"], h_in, ctx.scope("dense_mlp"))
        else:
            h = self.mlp(params["mlp"], h_in, ctx.scope("mlp"))
        return x + h, new_cache


class MambaWrap(Module):
    """Mamba block + optional MoE/MLP sublayer (Jamba layer layout)."""

    def __init__(self, name: str, cfg: ArchConfig, *, use_moe: bool,
                 dtype=jnp.float32, param_dtype=jnp.float32):
        self.name = name
        self.cfg = cfg
        self.use_moe = use_moe and cfg.moe_experts > 0
        d = cfg.d_model
        self.n1 = _norm(cfg, "n1", d, dtype, param_dtype)
        self.mamba = MambaBlock(
            "mamba", d, head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_d_state,
            chunk=cfg.ssm_chunk, dtype=dtype, param_dtype=param_dtype,
        )
        self.n2 = _norm(cfg, "n2", d, dtype, param_dtype)
        if self.use_moe:
            self.moe = MoE(
                "moe", d, cfg.d_ff, cfg.moe_experts, cfg.moe_top_k,
                capacity_factor=cfg.capacity_factor, dtype=dtype, param_dtype=param_dtype,
            )
        else:
            self.mlp = _ffn(cfg, "mlp", cfg.d_ff, dtype, param_dtype)

    def init(self, key: jax.Array) -> Params:
        ks = jax.random.split(key, 4)
        p = {"n1": self.n1.init(ks[0]), "mamba": self.mamba.init(ks[1]),
             "n2": self.n2.init(ks[2])}
        if self.use_moe:
            p["moe"] = self.moe.init(ks[3])
        else:
            p["mlp"] = self.mlp.init(ks[3])
        return p

    def axes(self) -> AxesTree:
        a = {"n1": self.n1.axes(), "mamba": self.mamba.axes(), "n2": self.n2.axes()}
        if self.use_moe:
            a["moe"] = self.moe.axes()
        else:
            a["mlp"] = self.mlp.axes()
        return a

    def init_cache(self, batch: int, dtype, **kw):
        return {"mamba": self.mamba.init_cache(batch, dtype)}

    def __call__(self, params, x, ctx, *, cache=None, positions=None,
                 enc_out=None, dispatch="per_sample"):
        mc = cache["mamba"] if cache is not None else None
        h, new_mc = self.mamba(
            params["mamba"], self.n1(params["n1"], x, ctx.scope("n1")),
            ctx.scope("mamba"), cache=mc,
        )
        x = x + h
        h_in = self.n2(params["n2"], x, ctx.scope("n2"))
        if self.use_moe:
            h = self.moe(params["moe"], h_in, ctx.scope("moe"), dispatch=dispatch)
        else:
            h = self.mlp(params["mlp"], h_in, ctx.scope("mlp"))
        new_cache = {"mamba": new_mc} if cache is not None else None
        return x + h, new_cache


class XLSTMWrap(Module):
    """mLSTM or sLSTM block adapter with the uniform block interface."""

    def __init__(self, name: str, cfg: ArchConfig, kind: str,
                 dtype=jnp.float32, param_dtype=jnp.float32):
        self.name = name
        self.kind = kind
        if kind == "mlstm":
            self.block = MLSTMBlock(
                "m", cfg.d_model, cfg.n_heads, chunk=cfg.ssm_chunk,
                dtype=dtype, param_dtype=param_dtype,
            )
        else:
            self.block = SLSTMBlock(
                "s", cfg.d_model, cfg.n_heads, dtype=dtype, param_dtype=param_dtype,
            )

    def init(self, key):
        return {"b": self.block.init(key)}

    def axes(self):
        return {"b": self.block.axes()}

    def init_cache(self, batch: int, dtype, **kw):
        return {"b": self.block.init_cache(batch, dtype)}

    def __call__(self, params, x, ctx, *, cache=None, positions=None,
                 enc_out=None, dispatch="per_sample"):
        c = cache["b"] if cache is not None else None
        x, new_c = self.block(params["b"], x, ctx.scope("b"), cache=c)
        return x, ({"b": new_c} if cache is not None else None)


def build_period(cfg: ArchConfig, *, cross: bool = False, causal: bool = True,
                 dtype=jnp.float32, param_dtype=jnp.float32) -> tuple[Module, int]:
    """Build the repeating period block; returns (period_module, n_periods)."""
    pattern = cfg.block_pattern
    if not pattern:
        period_len = cfg.moe_every if cfg.moe_experts else 1
        blocks = []
        for i in range(period_len):
            use_moe = cfg.moe_experts > 0 and (i % cfg.moe_every == cfg.moe_every - 1)
            blocks.append(
                TransformerBlock(
                    f"b{i}", cfg, use_moe=use_moe, cross=cross, causal=causal,
                    dtype=dtype, param_dtype=param_dtype,
                )
            )
        assert cfg.n_layers % period_len == 0
        if period_len == 1:
            return blocks[0], cfg.n_layers
        return SequentialBlocks("period", blocks), cfg.n_layers // period_len
    # explicit pattern (jamba / xlstm)
    blocks = []
    for i, kind in enumerate(pattern):
        use_moe = cfg.moe_experts > 0 and (i % cfg.moe_every == cfg.moe_every - 1)
        if kind == "attn":
            blocks.append(TransformerBlock(f"b{i}", cfg, use_moe=use_moe,
                                           dtype=dtype, param_dtype=param_dtype))
        elif kind == "mamba":
            blocks.append(MambaWrap(f"b{i}", cfg, use_moe=use_moe,
                                    dtype=dtype, param_dtype=param_dtype))
        elif kind in ("mlstm", "slstm"):
            blocks.append(XLSTMWrap(f"b{i}", cfg, kind, dtype=dtype, param_dtype=param_dtype))
        else:
            raise ValueError(kind)
    assert cfg.n_layers % len(pattern) == 0
    return SequentialBlocks("period", blocks), cfg.n_layers // len(pattern)
