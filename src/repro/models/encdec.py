"""Encoder-decoder transformer (Whisper-large-v3 backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, enc_seq, d_model) in place of the
mel->conv1d->GELU stem.  Everything downstream (encoder stack, cross
attention, decoder, DP clipping of all of it) is real.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.taps import Ctx
from repro.models.blocks import TransformerBlock
from repro.models.losses import per_sample_xent
from repro.nn.module import Dense, Embedding, LayerNorm
from repro.nn.stack import ScannedStack


class EncDecLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        dtype = jnp.dtype(cfg.dtype)
        param_dtype = jnp.dtype(cfg.param_dtype)
        self.dtype = dtype
        d = cfg.d_model
        self.enc_pos = Embedding(
            "enc_pos", cfg.encoder_seq, d, dtype=dtype, param_dtype=param_dtype,
            axes_=(None, "embed"),
        )
        enc_block = TransformerBlock(
            "eb", cfg, use_moe=False, cross=False, causal=False,
            dtype=dtype, param_dtype=param_dtype,
        )
        self.encoder = ScannedStack("encoder", enc_block, cfg.encoder_layers, remat=cfg.remat)
        self.enc_norm = LayerNorm("enc_norm", d, dtype=dtype, param_dtype=param_dtype)

        self.embed = Embedding("embed", cfg.vocab, d, dtype=dtype, param_dtype=param_dtype)
        self.pos_embed = Embedding(
            "pos_embed", 32768, d, dtype=dtype, param_dtype=param_dtype, axes_=(None, "embed"),
        )
        dec_block = TransformerBlock(
            "db", cfg, use_moe=False, cross=True, causal=True,
            dtype=dtype, param_dtype=param_dtype,
        )
        self.decoder = ScannedStack("decoder", dec_block, cfg.n_layers, remat=cfg.remat)
        self.dec_norm = LayerNorm("dec_norm", d, dtype=dtype, param_dtype=param_dtype)
        self.lm_head = Dense(
            "lm_head", d, cfg.vocab, use_bias=False,
            dtype=dtype, param_dtype=param_dtype, w_axes=("embed", "vocab"),
        )

    def init(self, key: jax.Array) -> Any:
        ks = iter(jax.random.split(key, 8))
        return {
            "enc_pos": self.enc_pos.init(next(ks)),
            "encoder": self.encoder.init(next(ks)),
            "enc_norm": self.enc_norm.init(next(ks)),
            "embed": self.embed.init(next(ks)),
            "pos_embed": self.pos_embed.init(next(ks)),
            "decoder": self.decoder.init(next(ks)),
            "dec_norm": self.dec_norm.init(next(ks)),
            "lm_head": self.lm_head.init(next(ks)),
        }

    def axes(self) -> Any:
        return {
            "enc_pos": self.enc_pos.axes(),
            "encoder": self.encoder.axes(),
            "enc_norm": self.enc_norm.axes(),
            "embed": self.embed.axes(),
            "pos_embed": self.pos_embed.axes(),
            "decoder": self.decoder.axes(),
            "dec_norm": self.dec_norm.axes(),
            "lm_head": self.lm_head.axes(),
        }

    def _encode(self, params, frames, ctx: Ctx) -> jax.Array:
        b, s, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        x = frames.astype(self.dtype) + self.enc_pos(
            params["enc_pos"], pos, ctx.scope("enc_pos")
        )
        x, _ = self.encoder(params["encoder"], x, ctx.scope("encoder"))
        return self.enc_norm(params["enc_norm"], x, ctx.scope("enc_norm"))

    def _decode_trunk(self, params, tokens, enc_out, ctx, *, cache=None, positions=None):
        b, s = tokens.shape
        if positions is None:
            positions = jnp.arange(s)
        pos_ids = jnp.broadcast_to(positions, (b, s))
        x = self.embed(params["embed"], tokens, ctx.scope("embed"))
        x = x + self.pos_embed(params["pos_embed"], pos_ids, ctx.scope("pos_embed"))
        x, new_cache = self.decoder(
            params["decoder"], x, ctx.scope("decoder"), cache=cache,
            positions=positions, enc_out=enc_out,
        )
        x = self.dec_norm(params["dec_norm"], x, ctx.scope("dec_norm"))
        return x, new_cache

    def loss_with_ctx(self, params, batch, ctx: Ctx) -> jax.Array:
        enc_out = self._encode(params, batch["frames"], ctx)
        x, _ = self._decode_trunk(params, batch["tokens"], enc_out, ctx)
        logits = self.lm_head(params["lm_head"], x, ctx.scope("lm_head"))
        return per_sample_xent(logits, batch["labels"], batch.get("mask"))

    # -- serving ---------------------------------------------------------------
    def init_state(self, batch: int, max_len: int) -> dict:
        cache = self.decoder.init_cache(
            batch, self.dtype, max_len=max_len, enc_seq=self.cfg.encoder_seq
        )
        return {"cache": cache, "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params, batch, state) -> tuple[jax.Array, dict]:
        ctx = Ctx.disabled()
        enc_out = self._encode(params, batch["frames"], ctx)
        x, cache = self._decode_trunk(
            params, batch["tokens"], enc_out, ctx, cache=state["cache"]
        )
        logits = self.lm_head(params["lm_head"], x[:, -1:], ctx)
        return logits, {"cache": cache, "pos": state["pos"] + batch["tokens"].shape[1]}

    def decode_step(self, params, tokens, state) -> tuple[jax.Array, dict]:
        ctx = Ctx.disabled()
        pos = state["pos"]
        positions = pos + jnp.arange(tokens.shape[1])
        # cross-attention reads the cached encoder projections (kv_src=None)
        x, cache = self._decode_trunk(
            params, tokens, None, ctx, cache=state["cache"], positions=positions
        )
        logits = self.lm_head(params["lm_head"], x, ctx)
        return logits, {"cache": cache, "pos": pos + tokens.shape[1]}
