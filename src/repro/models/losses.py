"""Per-sample losses (the DP unit of account is the sample, not the token)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def per_sample_xent(
    logits: jax.Array,  # (B, S, V)
    labels: jax.Array,  # (B, S) int; -100 = ignore
    sample_mask: Optional[jax.Array] = None,  # (B,)
) -> jax.Array:
    """Mean token cross-entropy per sample: (B,) fp32.

    The logsumexp upcast is fused by XLA (no fp32 logits materialization).
    """
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)  # (B, S)
    picked = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    tok_loss = (lse - picked) * valid.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(valid, axis=-1), 1).astype(jnp.float32)
    loss = jnp.sum(tok_loss, axis=-1) / denom
    if sample_mask is not None:
        loss = loss * sample_mask.astype(loss.dtype)
    return loss
