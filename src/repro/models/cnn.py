"""Paper-native CNNs: VGG and (pre-activation) ResNet for image classification.

These are the models from the paper's Tables 3/4/6 (CIFAR / ImageNet): the 2D
convolution ghost-clipping path, the layerwise decision table, and the
accuracy-parity benchmarks all run on them.  BatchNorm is replaced by
GroupNorm exactly as the paper does (BN mixes samples and is not DP-safe).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.taps import Ctx
from repro.models.losses import per_sample_xent
from repro.nn.conv import Conv2d, global_avg_pool, max_pool2d
from repro.nn.module import Dense, GroupNorm

VGG_PLANS = {
    "vgg11": (64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"),
    "vgg13": (64, 64, "M", 128, 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"),
    "vgg16": (64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
              512, 512, 512, "M", 512, 512, 512, "M"),
    "vgg19": (64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
              512, 512, 512, 512, "M", 512, 512, 512, 512, "M"),
}


class VGG:
    def __init__(self, plan: str = "vgg11", *, n_classes: int = 10, in_ch: int = 3,
                 groups: int = 16, dtype=jnp.float32):
        self.plan = VGG_PLANS[plan]
        self.n_classes = n_classes
        self.dtype = dtype
        self.convs = []
        self.norms = []
        ch = in_ch
        for i, item in enumerate(self.plan):
            if item == "M":
                self.convs.append("M")
                continue
            self.convs.append(Conv2d(f"conv{i}", ch, item, (3, 3), padding="SAME", dtype=dtype))
            self.norms.append(GroupNorm(f"gn{i}", item, groups=min(groups, item), dtype=dtype))
            ch = item
        self.head = Dense("head", ch, n_classes, dtype=dtype)

    def init(self, key: jax.Array) -> Any:
        params: dict[str, Any] = {}
        ks = iter(jax.random.split(key, len(self.plan) * 2 + 1))
        ni = 0
        for i, c in enumerate(self.convs):
            if c == "M":
                continue
            params[f"conv{i}"] = c.init(next(ks))
            params[f"gn{i}"] = self.norms[ni].init(next(ks))
            ni += 1
        params["head"] = self.head.init(next(ks))
        return params

    def features(self, params, x, ctx: Ctx) -> jax.Array:
        ni = 0
        for i, c in enumerate(self.convs):
            if c == "M":
                x = max_pool2d(x)
                continue
            x = c(params[f"conv{i}"], x, ctx.scope(f"conv{i}"))
            x = jax.nn.relu(self.norms[ni](params[f"gn{i}"], x, ctx.scope(f"gn{i}")))
            ni += 1
        return global_avg_pool(x)

    def logits(self, params, x, ctx: Ctx) -> jax.Array:
        h = self.features(params, x, ctx)
        return self.head(params["head"], h[:, None, :], ctx.scope("head"))[:, 0]

    def loss_with_ctx(self, params, batch, ctx: Ctx) -> jax.Array:
        logits = self.logits(params, batch["image"], ctx)
        return per_sample_xent(logits[:, None, :], batch["label"][:, None],
                               batch.get("mask"))


class ResNet:
    """Pre-activation basic-block ResNet (18/34-style) with GroupNorm."""

    def __init__(self, blocks_per_stage: Sequence[int] = (2, 2, 2, 2), *,
                 width: int = 64, n_classes: int = 10, in_ch: int = 3,
                 dtype=jnp.float32):
        self.bps = tuple(blocks_per_stage)
        self.width = width
        self.n_classes = n_classes
        self.dtype = dtype
        self.stem = Conv2d("stem", in_ch, width, (3, 3), padding="SAME", dtype=dtype)
        self.units = []  # (name, conv1, gn1, conv2, gn2, proj|None, stride)
        ch = width
        for s, n in enumerate(self.bps):
            out = width * (2**s)
            for b in range(n):
                stride = 2 if (s > 0 and b == 0) else 1
                name = f"s{s}b{b}"
                conv1 = Conv2d(f"{name}.c1", ch, out, (3, 3), strides=(stride, stride),
                               padding="SAME", dtype=dtype)
                gn1 = GroupNorm(f"{name}.g1", ch, groups=min(16, ch), dtype=dtype)
                conv2 = Conv2d(f"{name}.c2", out, out, (3, 3), padding="SAME", dtype=dtype)
                gn2 = GroupNorm(f"{name}.g2", out, groups=min(16, out), dtype=dtype)
                proj = None
                if stride != 1 or ch != out:
                    proj = Conv2d(f"{name}.proj", ch, out, (1, 1),
                                  strides=(stride, stride), padding="SAME",
                                  use_bias=False, dtype=dtype)
                self.units.append((name, conv1, gn1, conv2, gn2, proj))
                ch = out
        self.final_gn = GroupNorm("final_gn", ch, groups=16, dtype=dtype)
        self.head = Dense("head", ch, n_classes, dtype=dtype)

    def init(self, key: jax.Array) -> Any:
        params: dict[str, Any] = {}
        ks = iter(jax.random.split(key, 6 * len(self.units) + 4))
        params["stem"] = self.stem.init(next(ks))
        for name, c1, g1, c2, g2, proj in self.units:
            params[name] = {
                "g1": g1.init(next(ks)), "c1": c1.init(next(ks)),
                "g2": g2.init(next(ks)), "c2": c2.init(next(ks)),
            }
            if proj is not None:
                params[name]["proj"] = proj.init(next(ks))
        params["final_gn"] = self.final_gn.init(next(ks))
        params["head"] = self.head.init(next(ks))
        return params

    def logits(self, params, x, ctx: Ctx) -> jax.Array:
        x = self.stem(params["stem"], x, ctx.scope("stem"))
        for name, c1, g1, c2, g2, proj in self.units:
            p = params[name]
            sub = ctx.scope(name)
            h = jax.nn.relu(g1(p["g1"], x, sub.scope("g1")))
            shortcut = proj(p["proj"], h, sub.scope("proj")) if proj is not None else x
            h = c1(p["c1"], h, sub.scope("c1"))
            h = c2(p["c2"], jax.nn.relu(g2(p["g2"], h, sub.scope("g2"))), sub.scope("c2"))
            x = shortcut + h
        x = jax.nn.relu(self.final_gn(params["final_gn"], x, ctx.scope("final_gn")))
        h = global_avg_pool(x)
        return self.head(params["head"], h[:, None, :], ctx.scope("head"))[:, 0]

    def loss_with_ctx(self, params, batch, ctx: Ctx) -> jax.Array:
        logits = self.logits(params, batch["image"], ctx)
        return per_sample_xent(logits[:, None, :], batch["label"][:, None],
                               batch.get("mask"))
