"""Paper-native Vision Transformer (ViT/BEiT backbone) classifier.

The patch embedding is a real strided Conv2d — so DP-ViT exercises the conv
ghost-clipping path exactly as the paper's "convolutional ViTs" do (BEiT,
CrossViT etc. in Table 5).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.taps import Ctx
from repro.models.blocks import TransformerBlock
from repro.models.losses import per_sample_xent
from repro.nn.conv import Conv2d
from repro.nn.module import Dense, Embedding, LayerNorm
from repro.nn.stack import ScannedStack


class ViT:
    def __init__(self, cfg: ArchConfig, *, image_size: int = 224, patch: int = 16,
                 n_classes: int = 1000, in_ch: int = 3):
        self.cfg = cfg
        dtype = jnp.dtype(cfg.dtype)
        param_dtype = jnp.dtype(cfg.param_dtype)
        self.dtype = dtype
        self.n_patches = (image_size // patch) ** 2
        self.patch_embed = Conv2d(
            "patch_embed", in_ch, cfg.d_model, (patch, patch),
            strides=(patch, patch), padding="VALID", dtype=dtype, param_dtype=param_dtype,
        )
        self.pos_embed = Embedding(
            "pos_embed", self.n_patches, cfg.d_model,
            dtype=dtype, param_dtype=param_dtype, axes_=(None, "embed"),
        )
        block = TransformerBlock(
            "vb", dataclasses.replace(cfg, norm="layernorm", act="gelu"),
            causal=False, dtype=dtype, param_dtype=param_dtype,
        )
        self.layers = ScannedStack("layers", block, cfg.n_layers, remat=cfg.remat)
        self.norm_f = LayerNorm("norm_f", cfg.d_model, dtype=dtype, param_dtype=param_dtype)
        self.head = Dense("head", cfg.d_model, n_classes, dtype=dtype, param_dtype=param_dtype)

    def init(self, key: jax.Array) -> Any:
        ks = jax.random.split(key, 5)
        return {
            "patch_embed": self.patch_embed.init(ks[0]),
            "pos_embed": self.pos_embed.init(ks[1]),
            "layers": self.layers.init(ks[2]),
            "norm_f": self.norm_f.init(ks[3]),
            "head": self.head.init(ks[4]),
        }

    def axes(self) -> Any:
        return {
            "patch_embed": self.patch_embed.axes(),
            "pos_embed": self.pos_embed.axes(),
            "layers": self.layers.axes(),
            "norm_f": self.norm_f.axes(),
            "head": self.head.axes(),
        }

    def logits(self, params, image, ctx: Ctx) -> jax.Array:
        x = self.patch_embed(params["patch_embed"], image.astype(self.dtype),
                             ctx.scope("patch_embed"))
        b = x.shape[0]
        x = x.reshape(b, -1, self.cfg.d_model)
        pos = jnp.broadcast_to(jnp.arange(x.shape[1]), (b, x.shape[1]))
        x = x + self.pos_embed(params["pos_embed"], pos, ctx.scope("pos_embed"))
        x, _ = self.layers(params["layers"], x, ctx.scope("layers"))
        x = self.norm_f(params["norm_f"], x, ctx.scope("norm_f"))
        h = jnp.mean(x, axis=1)
        return self.head(params["head"], h[:, None, :], ctx.scope("head"))[:, 0]

    def loss_with_ctx(self, params, batch, ctx: Ctx) -> jax.Array:
        logits = self.logits(params, batch["image"], ctx)
        return per_sample_xent(logits[:, None, :], batch["label"][:, None],
                               batch.get("mask"))
