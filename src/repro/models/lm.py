"""Decoder-only language model (dense / MoE / hybrid / SSM / VLM families).

Exposes the three entry points the launcher lowers:
- ``loss_with_ctx(params, batch, ctx)`` — per-sample losses, DP taps threaded
- ``prefill(params, batch, state)``     — full forward + cache fill
- ``decode_step(params, tokens, state)``— one token with cache/SSM state
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.taps import Ctx
from repro.models.blocks import build_period
from repro.models.losses import per_sample_xent
from repro.nn.module import Dense, Embedding, LayerNorm, RMSNorm
from repro.nn.stack import ScannedStack


class DecoderLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        dtype = jnp.dtype(cfg.dtype)
        param_dtype = jnp.dtype(cfg.param_dtype)
        self.dtype = dtype
        d = cfg.d_model
        self.embed = Embedding("embed", cfg.vocab, d, dtype=dtype, param_dtype=param_dtype)
        self.use_learned_pos = cfg.norm == "layernorm"
        if self.use_learned_pos:
            self.pos_embed = Embedding(
                "pos_embed", max(cfg.encoder_seq, 32768), d,
                dtype=dtype, param_dtype=param_dtype, axes_=(None, "embed"),
            )
        if cfg.prefix_tokens:
            self.prefix_proj = Dense(
                "prefix_proj", cfg.prefix_dim, d, use_bias=True,
                dtype=dtype, param_dtype=param_dtype, w_axes=(None, "embed"),
            )
        period, n_periods = build_period(cfg, dtype=dtype, param_dtype=param_dtype)
        self.layers = ScannedStack("layers", period, n_periods, remat=cfg.remat)
        norm_cls = RMSNorm if cfg.norm == "rmsnorm" else LayerNorm
        self.norm_f = norm_cls("norm_f", d, dtype=dtype, param_dtype=param_dtype)
        self.lm_head = Dense(
            "lm_head", d, cfg.vocab, use_bias=False,
            dtype=dtype, param_dtype=param_dtype, w_axes=("embed", "vocab"),
        )

    # -- params ------------------------------------------------------------
    def init(self, key: jax.Array) -> Any:
        ks = iter(jax.random.split(key, 6))
        p = {
            "embed": self.embed.init(next(ks)),
            "layers": self.layers.init(next(ks)),
            "norm_f": self.norm_f.init(next(ks)),
            "lm_head": self.lm_head.init(next(ks)),
        }
        if self.use_learned_pos:
            p["pos_embed"] = self.pos_embed.init(next(ks))
        if self.cfg.prefix_tokens:
            p["prefix_proj"] = self.prefix_proj.init(next(ks))
        return p

    def axes(self) -> Any:
        a = {
            "embed": self.embed.axes(),
            "layers": self.layers.axes(),
            "norm_f": self.norm_f.axes(),
            "lm_head": self.lm_head.axes(),
        }
        if self.use_learned_pos:
            a["pos_embed"] = self.pos_embed.axes()
        if self.cfg.prefix_tokens:
            a["prefix_proj"] = self.prefix_proj.axes()
        return a

    # -- shared trunk --------------------------------------------------------
    def _trunk(self, params, tokens, ctx, *, prefix=None, cache=None,
               positions=None, dispatch="per_sample"):
        x = self.embed(params["embed"], tokens, ctx.scope("embed"))
        if prefix is not None:
            pe = self.prefix_proj(
                params["prefix_proj"], prefix.astype(self.dtype), ctx.scope("prefix_proj")
            )
            x = jnp.concatenate([pe, x], axis=1)
        s = x.shape[1]
        if positions is None:
            positions = jnp.arange(s)
        if self.use_learned_pos:
            pos_ids = jnp.broadcast_to(positions, (x.shape[0], s))
            x = x + self.pos_embed(params["pos_embed"], pos_ids, ctx.scope("pos_embed"))
        x, new_cache = self.layers(
            params["layers"], x, ctx.scope("layers"), cache=cache,
            positions=positions, dispatch=dispatch,
        )
        x = self.norm_f(params["norm_f"], x, ctx.scope("norm_f"))
        return x, new_cache

    # -- training ------------------------------------------------------------
    def loss_with_ctx(self, params, batch, ctx: Ctx) -> jax.Array:
        x, _ = self._trunk(
            params, batch["tokens"], ctx, prefix=batch.get("prefix"),
        )
        if self.cfg.prefix_tokens:
            x = x[:, batch["prefix"].shape[1]:]

        # head + CE rematted: the (B, S, V) logits region dominates fixed
        # memory at 150k vocab; recomputing it per backward pass keeps only
        # x as the residual
        def head_loss(head_params, x_in):
            logits = self.lm_head(head_params, x_in, ctx.scope("lm_head"))
            return per_sample_xent(logits, batch["labels"], batch.get("mask"))

        if self.cfg.remat:
            head_loss = jax.checkpoint(head_loss)
        return head_loss(params["lm_head"], x)

    # -- serving ---------------------------------------------------------------
    def init_state(self, batch: int, max_len: int) -> dict:
        cache = self.layers.init_cache(batch, self.dtype, max_len=max_len)
        return {"cache": cache, "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params, batch, state) -> tuple[jax.Array, dict]:
        ctx = Ctx.disabled()
        tokens = batch["tokens"]
        x, cache = self._trunk(
            params, tokens, ctx, prefix=batch.get("prefix"),
            cache=state["cache"], dispatch="global",
        )
        logits = self.lm_head(params["lm_head"], x[:, -1:], ctx)
        s = x.shape[1]
        return logits, {"cache": cache, "pos": state["pos"] + s}

    def decode_step(self, params, tokens, state) -> tuple[jax.Array, dict]:
        ctx = Ctx.disabled()
        pos = state["pos"]
        positions = pos + jnp.arange(tokens.shape[1])
        x, cache = self._trunk(
            params, tokens, ctx, cache=state["cache"], positions=positions,
            dispatch="global",
        )
        logits = self.lm_head(params["lm_head"], x, ctx)
        return logits, {"cache": cache, "pos": pos + tokens.shape[1]}
