"""CLI: render a run directory's obs streams into a human summary.

    python -m repro.obs RUN_DIR [--json] [--require-epsilon]
                        [--timeline] [--step-pattern REGEX]

``RUN_DIR`` is the directory ``launch.train``/``launch.serve`` wrote
``events.jsonl``/``metrics.jsonl`` into (the ``--ckpt-dir``/``--obs-dir``).
``--json`` emits the machine summary instead of text; ``--require-epsilon``
exits non-zero when no epsilon trajectory was recorded (the tier-1 smoke
gate's assertion); ``--timeline`` additionally extracts per-step wall
times from a captured profiler trace under ``RUN_DIR/profile``.

Deliberately jax-free: reading a run's telemetry must work on a laptop
that cannot even initialize the run's backend.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.obs.report import render_text, summarize_run
from repro.obs.timeline import (
    DEFAULT_STEP_PATTERN,
    percentile,
    step_wall_times_ms,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    ap.add_argument("run_dir", help="directory holding events.jsonl/metrics.jsonl")
    ap.add_argument("--json", action="store_true",
                    help="print the machine-readable summary")
    ap.add_argument("--require-epsilon", action="store_true",
                    help="exit 1 unless a non-empty epsilon trajectory was "
                         "recorded (CI smoke assertion)")
    ap.add_argument("--timeline", action="store_true",
                    help="extract per-step wall times from the profiler "
                         "trace under RUN_DIR/profile")
    ap.add_argument("--step-pattern", default=DEFAULT_STEP_PATTERN,
                    help="regex over trace event names that count as "
                         "step/execution spans")
    args = ap.parse_args(argv)

    summary = summarize_run(args.run_dir)
    if args.timeline:
        times = step_wall_times_ms(
            pathlib.Path(args.run_dir) / "profile", pattern=args.step_pattern
        )
        summary["profile_step_times_ms"] = times
        summary["profile_step_p50_ms"] = (
            percentile(times, 0.50) if times else None
        )

    if args.json:
        print(json.dumps(summary, sort_keys=True))
    else:
        print(render_text(summary))
        if args.timeline:
            times = summary["profile_step_times_ms"]
            if times:
                print(
                    f"  profiled steps: {len(times)} span group(s), "
                    f"p50 {percentile(times, 0.5):.1f}ms "
                    f"p95 {percentile(times, 0.95):.1f}ms"
                )
            else:
                print("  profiled steps: no trace found")

    if args.require_epsilon and not summary["epsilon_trajectory"]:
        print("ERROR: no epsilon trajectory in the metrics stream",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
