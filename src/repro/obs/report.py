"""Fold a run directory's record streams into one human/machine summary.

``summarize_run`` is the pure half (dict in, dict out — tests and the
bench dashboard consume it); ``render_text`` is the presentation half the
``python -m repro.obs`` CLI prints.  Everything reads through
``sinks.read_jsonl``, so a crash-torn final line costs one record, not the
report.
"""
from __future__ import annotations

import pathlib
from collections import Counter
from typing import Optional

from repro.obs.events import EVENTS_FILENAME, METRICS_FILENAME
from repro.obs.sinks import read_jsonl
from repro.obs.timeline import percentile


def summarize_run(run_dir) -> dict:
    """Digest ``events.jsonl``/``metrics.jsonl`` under ``run_dir``."""
    run_dir = pathlib.Path(run_dir)
    events = read_jsonl(run_dir / EVENTS_FILENAME)
    metrics = read_jsonl(run_dir / METRICS_FILENAME)
    train = [m for m in metrics if m.get("kind") == "train_step"]
    serving = [m for m in metrics if m.get("kind") == "serving_step"]

    eps_traj = [
        (int(m["step"]), float(m["epsilon"]))
        for m in train
        if m.get("epsilon") is not None and m.get("step") is not None
    ]
    step_times = [float(m["step_s"]) for m in train if m.get("step_s")]
    clip_fracs = [
        float(m["clip_frac"]) for m in train if m.get("clip_frac") is not None
    ]
    ex_rates = [
        float(m["examples_per_s"]) for m in train if m.get("examples_per_s")
    ]
    event_counts = Counter(str(e.get("kind", "?")) for e in events)

    # the newest plan_adopted event carries the per-tap branch + kernel maps
    plan_ev: Optional[dict] = None
    for e in events:
        if e.get("kind") == "plan_adopted":
            plan_ev = e

    run_ids = {m.get("run_id") for m in (train + events) if m.get("run_id")}
    return {
        "run_dir": str(run_dir),
        "run_ids": sorted(run_ids),
        "train_steps": len(train),
        "epsilon_trajectory": eps_traj,
        "final_epsilon": eps_traj[-1][1] if eps_traj else None,
        "final_delta": (
            float(train[-1]["delta"])
            if train and train[-1].get("delta") is not None else None
        ),
        "clip_frac_mean": (
            sum(clip_fracs) / len(clip_fracs) if clip_fracs else None
        ),
        "step_time_p50_s": percentile(step_times, 0.50) if step_times else None,
        "step_time_p95_s": percentile(step_times, 0.95) if step_times else None,
        "examples_per_s_mean": (
            sum(ex_rates) / len(ex_rates) if ex_rates else None
        ),
        "events": dict(sorted(event_counts.items())),
        "restarts": event_counts.get("restart_attempt", 0),
        "sheds": event_counts.get("request_shed", 0),
        "watchdog_trips": event_counts.get("watchdog_trip", 0),
        "plan": plan_ev,
        "serving_steps": len(serving),
        "last_serving": serving[-1] if serving else None,
    }


def _sparkline(values: list[float], width: int = 32) -> str:
    """Compact ASCII trend (monotone epsilon curves read fine at 8 levels)."""
    if not values:
        return ""
    if len(values) > width:  # subsample evenly to the display width
        idx = [round(i * (len(values) - 1) / (width - 1)) for i in range(width)]
        values = [values[i] for i in idx]
    lo, hi = min(values), max(values)
    chars = ".:-=+*#%"
    if hi <= lo:
        return chars[0] * len(values)
    return "".join(
        chars[min(len(chars) - 1, int((v - lo) / (hi - lo) * len(chars)))]
        for v in values
    )


def render_text(summary: dict) -> str:
    lines = [f"run {summary['run_dir']}"]
    if summary["run_ids"]:
        lines.append(f"  run_id(s): {', '.join(summary['run_ids'])}")
    lines.append(f"  train steps recorded: {summary['train_steps']}")

    traj = summary["epsilon_trajectory"]
    if traj:
        eps = [e for _, e in traj]
        lines.append(
            f"  epsilon: {eps[0]:.4f} -> {eps[-1]:.4f} over steps "
            f"{traj[0][0]}..{traj[-1][0]}  [{_sparkline(eps)}]"
        )
        if summary["final_delta"] is not None:
            lines.append(f"  delta: {summary['final_delta']:.2e}")
    else:
        lines.append("  epsilon: no trajectory recorded")
    if summary["clip_frac_mean"] is not None:
        lines.append(f"  clip fraction (mean): {summary['clip_frac_mean']:.3f}")
    if summary["step_time_p50_s"] is not None:
        lines.append(
            f"  step time: p50 {summary['step_time_p50_s'] * 1e3:.1f}ms "
            f"p95 {summary['step_time_p95_s'] * 1e3:.1f}ms"
        )
    if summary["examples_per_s_mean"] is not None:
        lines.append(
            f"  throughput: {summary['examples_per_s_mean']:.1f} examples/s"
        )

    plan = summary["plan"]
    if plan is not None:
        src = plan.get("source", "plan")
        lines.append(
            f"  clipping: mode={plan.get('mode')} policy={plan.get('policy')} "
            f"({src}; physical={plan.get('physical_batch')} "
            f"accum={plan.get('accumulation_steps')})"
        )
        branches = plan.get("branches") or {}
        kernels = plan.get("kernels") or {}
        for tap in sorted(set(branches) | set(kernels)):
            b = branches.get(tap, "-")
            k = kernels.get(tap)
            ktxt = (
                " ".join(f"{op}={impl}" for op, impl in sorted(k.items()))
                if k else "-"
            )
            lines.append(f"    tap {tap}: branch={b} kernels[{ktxt}]")

    ev = summary["events"]
    if ev:
        lines.append(
            "  events: " + ", ".join(f"{k}={v}" for k, v in ev.items())
        )
    if summary["serving_steps"]:
        last = summary["last_serving"] or {}
        lines.append(
            f"  serving: {summary['serving_steps']} step records, "
            f"queue_depth={last.get('queue_depth')} "
            f"shed_total={last.get('shed_total')}"
        )
    return "\n".join(lines)
