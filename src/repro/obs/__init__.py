"""repro.obs — low-overhead observability: metrics stream, lifecycle
events, profiler trace capture.

Three pieces (see docs/ARCHITECTURE.md "Observability"):

* ``sinks`` — the ``MetricsSink`` protocol (JSONL-file / in-memory / null)
  plus the process-wide stream registry.  The default sink is inert, so
  instrumented library code costs nothing until a driver calls
  ``configure_run(run_dir)``.
* ``events`` — the closed lifecycle-event taxonomy (``EVENT_KINDS``) and
  the ``emit_event``/``emit_metrics`` stamping layer (run_id/rank/seq).
* ``profile``/``timeline`` — ``--profile-steps N:M`` trace capture and the
  stdlib-only extraction of per-step wall times from the written trace.

``python -m repro.obs RUN_DIR`` renders a run's streams into a summary.
"""
from repro.obs.events import (
    EVENT_KINDS,
    configure_run,
    emit_event,
    emit_metrics,
    events_active,
    flush_all,
    metrics_active,
)
from repro.obs.profile import ProfileWindow
from repro.obs.report import render_text, summarize_run
from repro.obs.sinks import (
    JsonlSink,
    MemorySink,
    MetricsSink,
    NullSink,
    get_sink,
    read_jsonl,
    reset_sinks,
    set_sink,
)

__all__ = [
    "EVENT_KINDS",
    "JsonlSink",
    "MemorySink",
    "MetricsSink",
    "NullSink",
    "ProfileWindow",
    "configure_run",
    "emit_event",
    "emit_metrics",
    "events_active",
    "flush_all",
    "get_sink",
    "metrics_active",
    "read_jsonl",
    "render_text",
    "reset_sinks",
    "set_sink",
    "summarize_run",
]
