"""Metric/event sinks: where observability records go, at what cost.

The contract is deliberately tiny — ``emit(record)`` with JSON-able dicts —
so instrumentation points stay one-liners and the cost model is explicit:

* ``NullSink`` (the default for every stream) is inert: ``active`` is False
  and instrumentation sites are expected to check it BEFORE building a
  record, so an un-instrumented run does zero extra work — no host
  transfers, no string formatting, no epsilon computation.
* ``JsonlSink`` appends one ``json.dumps`` line per record to a file opened
  in append mode and flushes after each write.  Append-only by
  construction: the file is never seeked, truncated, or rewritten, so
  concurrent readers (and post-crash forensics) always see a prefix of the
  true record stream.  Emission is serialized by a lock — the checkpoint
  manager emits from its async writer thread.
* ``MemorySink`` collects records in a list (tests, in-process dashboards).

``read_jsonl`` is the matching reader: it tolerates a crash-torn final
line (a process killed mid-``write``) by skipping any line that fails to
parse, mirroring the checkpoint manager's fall-back-past-torn-artifacts
policy — a damaged tail costs one record, never the stream.

The process-wide registry maps stream names (``"metrics"``, ``"events"``)
to sinks so deep emit points (watchdog, injector, consensus, queue) need no
plumbing: they ask ``get_sink(stream)`` and check ``.active``.
"""
from __future__ import annotations

import json
import pathlib
import threading
from typing import Any, Optional, Protocol, runtime_checkable


@runtime_checkable
class MetricsSink(Protocol):
    """Destination for one stream of JSON-able records."""

    active: bool

    def emit(self, record: dict) -> None: ...

    def flush(self) -> None: ...

    def close(self) -> None: ...


class NullSink:
    """Inert sink: ``active=False`` so emit sites skip record-building."""

    active = False

    def emit(self, record: dict) -> None:  # pragma: no cover - never called
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class MemorySink:
    """In-memory sink (tests, notebooks): records accumulate in ``records``."""

    active = True

    def __init__(self):
        self.records: list[dict] = []
        self._lock = threading.Lock()

    def emit(self, record: dict) -> None:
        with self._lock:
            self.records.append(dict(record))

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class JsonlSink:
    """Append-only JSONL file sink; one flushed line per record.

    The file handle opens lazily (on the first emit) in ``"a"`` mode, so
    constructing a sink for a directory that does not exist yet is safe and
    in-process restarts APPEND to the same stream instead of clobbering the
    pre-crash records — the post-mortem timeline stays whole.  Open also
    self-heals a crash-torn tail: if the existing file does not end in a
    newline (the previous process died mid-write), a newline is appended
    first so the next record starts on its own line instead of gluing onto
    the torn fragment and being lost with it.
    """

    active = True

    def __init__(self, path):
        self.path = pathlib.Path(path)
        self._fh = None
        self._lock = threading.Lock()

    def emit(self, record: dict) -> None:
        line = json.dumps(record, sort_keys=True, default=str)
        with self._lock:
            if self._fh is None:
                self.path.parent.mkdir(parents=True, exist_ok=True)
                terminate = False
                try:
                    with self.path.open("rb") as fh:
                        fh.seek(-1, 2)
                        terminate = fh.read(1) != b"\n"
                except OSError:
                    pass  # missing or empty file: nothing to heal
                self._fh = self.path.open("a", encoding="utf-8")
                if terminate:
                    self._fh.write("\n")
            self._fh.write(line + "\n")
            self._fh.flush()

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


def read_jsonl(path) -> list[dict]:
    """Parse a JSONL stream, skipping torn lines.

    A process crashing mid-write leaves a final line that is a prefix of a
    JSON document (``runtime.inject``'s ``torn@step`` injector manufactures
    exactly this); any line that fails to parse — torn tail or interleaved
    garbage — is dropped rather than failing the whole read.
    """
    p = pathlib.Path(path)
    if not p.exists():
        return []
    out: list[dict] = []
    for line in p.read_text(encoding="utf-8", errors="replace").splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue  # torn write: a prefix of a record, never a record
        if isinstance(rec, dict):
            out.append(rec)
    return out


# -- process-wide registry -------------------------------------------------
_NULL = NullSink()
_SINKS: dict[str, Any] = {}
_REG_LOCK = threading.Lock()


def get_sink(stream: str):
    """The sink for ``stream`` (``NullSink`` when none is installed)."""
    return _SINKS.get(stream, _NULL)


def set_sink(stream: str, sink: Optional[Any]):
    """Install (or with ``None``, remove) the sink for ``stream``.

    Returns the previous sink (callers may restore it); the previous sink
    is NOT closed — tests swap ``MemorySink``s in and out freely.
    """
    with _REG_LOCK:
        prev = _SINKS.get(stream)
        if sink is None:
            _SINKS.pop(stream, None)
        else:
            _SINKS[stream] = sink
        return prev


def reset_sinks() -> None:
    """Close and remove every installed sink (test isolation, run teardown)."""
    with _REG_LOCK:
        for sink in _SINKS.values():
            try:
                sink.close()
            except OSError:  # pragma: no cover - best-effort teardown
                pass
        _SINKS.clear()
