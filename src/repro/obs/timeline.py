"""Extract per-step wall times from a captured profiler trace.

``jax.profiler.start_trace`` writes a TensorBoard-layout directory::

    <trace_dir>/plugins/profile/<timestamp>/<host>.trace.json.gz

whose payload is Chrome-trace JSON (``traceEvents``: complete events with
``ph="X"``, ``ts``/``dur`` in microseconds).  This module reads those files
with the stdlib only (no jax, no tensorboard) and pulls out the *device
execution* events — the spans the step-time gate should compare, as opposed
to bench medians which time the host loop around them (ROADMAP item 5
follow-on: "gate on step markers from real profiles rather than bench
medians").

What counts as a step span is backend-dependent, so the matcher is a
regex over event names with a default covering the backends we run:

* TPU: XLA step markers (``--xla_step_marker_location=1`` via
  ``launch/env.py``) surface as ``StepMarker``/``XlaModule`` events;
* CPU: each compiled program execution is one ``TfrtCpuExecutable::Execute``
  event (an accumulation run has ``accum+1`` executions per logical step);
* GPU: module execution lands as ``XlaModule:``-prefixed events.
"""
from __future__ import annotations

import gzip
import json
import pathlib
import re
from typing import Iterable, Optional

DEFAULT_STEP_PATTERN = (
    r"StepMarker|XlaModule|TfrtCpuExecutable::Execute|TpuExecute"
)


def trace_files(trace_dir) -> list[pathlib.Path]:
    """Every ``*.trace.json[.gz]`` under ``trace_dir``, sorted for determinism."""
    root = pathlib.Path(trace_dir)
    if not root.exists():
        return []
    return sorted(
        p for p in root.rglob("*")
        if p.is_file() and (
            p.name.endswith(".trace.json.gz") or p.name.endswith(".trace.json")
        )
    )


def load_trace_events(trace_dir) -> list[dict]:
    """All Chrome-trace ``traceEvents`` from every trace file, ``ts``-ordered."""
    events: list[dict] = []
    for path in trace_files(trace_dir):
        raw = path.read_bytes()
        if path.name.endswith(".gz"):
            raw = gzip.decompress(raw)
        payload = json.loads(raw)
        evs = payload.get("traceEvents", payload if isinstance(payload, list) else [])
        events.extend(e for e in evs if isinstance(e, dict))
    events.sort(key=lambda e: float(e.get("ts", 0.0)))
    return events


def execution_spans(
    trace_dir, pattern: str = DEFAULT_STEP_PATTERN
) -> list[dict]:
    """Complete (``ph="X"``) events whose name matches ``pattern``.

    Returns ``[{"name", "ts_us", "dur_us"}, ...]`` in timestamp order —
    the raw material for per-step wall times.
    """
    rx = re.compile(pattern)
    out = []
    for e in load_trace_events(trace_dir):
        name = str(e.get("name", ""))
        if e.get("ph") == "X" and rx.search(name):
            out.append({
                "name": name,
                "ts_us": float(e.get("ts", 0.0)),
                "dur_us": float(e.get("dur", 0.0)),
            })
    return out


def step_wall_times_ms(
    trace_dir,
    pattern: str = DEFAULT_STEP_PATTERN,
    group_us: Optional[float] = None,
) -> list[float]:
    """Per-step wall times (ms) from the trace's execution spans.

    Consecutive spans separated by less than ``group_us`` of idle gap are
    folded into one step (an accumulation loop is several executions per
    logical batch); ``group_us=None`` derives the threshold as half the
    median inter-span gap, which cleanly splits back-to-back microsteps
    from the between-step host work in practice.  Each step's wall time is
    last-span-end minus first-span-start.
    """
    spans = execution_spans(trace_dir, pattern)
    if not spans:
        return []
    if len(spans) == 1:
        return [spans[0]["dur_us"] / 1e3]
    gaps = [
        max(0.0, b["ts_us"] - (a["ts_us"] + a["dur_us"]))
        for a, b in zip(spans, spans[1:])
    ]
    if group_us is None:
        ordered = sorted(gaps)
        group_us = ordered[len(ordered) // 2] / 2.0
    steps: list[list[dict]] = [[spans[0]]]
    for gap, span in zip(gaps, spans[1:]):
        if gap <= group_us:
            steps[-1].append(span)
        else:
            steps.append([span])
    out = []
    for group in steps:
        start = group[0]["ts_us"]
        end = max(s["ts_us"] + s["dur_us"] for s in group)
        out.append((end - start) / 1e3)
    return out


def percentile(xs: Iterable[float], q: float) -> float:
    """Nearest-rank percentile (mirrors serving.engine's aggregation)."""
    s = sorted(xs)
    if not s:
        return 0.0
    i = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
    return s[i]
