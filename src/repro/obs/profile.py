"""Profiler trace capture around a step window (``--profile-steps N:M``).

``jax.profiler.start_trace`` / ``stop_trace`` bracket the inclusive step
range ``[N, M]``: the trace opens before step N dispatches and closes after
step M's work is synced, so the captured window contains exactly M-N+1
logical batches of device execution.  On TPU the
``--xla_step_marker_location=1`` groundwork (``launch/env.py``) makes XLA
mark each outer-loop step inside that window; on CPU/GPU the
``TfrtCpuExecutable::Execute`` / module events carry the same information
(``repro.obs.timeline`` extracts either).

The window degrades gracefully: a backend whose profiler cannot start
(sandboxed CI, missing permissions) logs a warning and the run proceeds
untraced — profiling is observability, never a correctness dependency.
"""
from __future__ import annotations

import pathlib
from typing import Optional

from repro.obs.events import emit_event
from repro.utils.logging import get_logger

log = get_logger("obs.profile")


def parse_window(spec: str) -> tuple[int, int]:
    """``"N:M"`` -> inclusive (first, last) step; ``"N"`` means one step."""
    lo_s, _, hi_s = spec.partition(":")
    try:
        lo = int(lo_s)
        hi = int(hi_s) if hi_s else lo
    except ValueError as e:
        raise ValueError(
            f"bad --profile-steps spec {spec!r}: expected N or N:M"
        ) from e
    if lo < 0 or hi < lo:
        raise ValueError(
            f"bad --profile-steps window {spec!r}: need 0 <= N <= M"
        )
    return lo, hi


class ProfileWindow:
    """Drives one start_trace/stop_trace pair from the train loop.

    The loop calls ``before_step(step)`` ahead of dispatch and
    ``after_step(step)`` once the step's sync point has passed; ``stop()``
    (idempotent) runs in the loop's ``finally`` so a crash inside the
    window still flushes a usable partial trace.
    """

    def __init__(self, first: int, last: int, trace_dir):
        self.first = first
        self.last = last
        self.trace_dir = pathlib.Path(trace_dir)
        self.active = False
        self.done = False

    @classmethod
    def from_spec(cls, spec: str, run_dir) -> "ProfileWindow":
        first, last = parse_window(spec)
        return cls(first, last, pathlib.Path(run_dir) / "profile")

    def before_step(self, step: int) -> None:
        if self.done or self.active or not (self.first <= step <= self.last):
            return
        import jax

        try:
            self.trace_dir.mkdir(parents=True, exist_ok=True)
            jax.profiler.start_trace(str(self.trace_dir))
        except Exception as e:  # pragma: no cover - backend-dependent
            log.warning("profiler could not start (%s: %s); continuing "
                        "untraced", type(e).__name__, e)
            self.done = True
            return
        self.active = True
        log.info("profiler trace open: steps [%d, %d] -> %s",
                 self.first, self.last, self.trace_dir)
        emit_event("profile_started", step=step, first=self.first,
                   last=self.last, trace_dir=str(self.trace_dir))

    def after_step(self, step: int) -> None:
        if self.active and step >= self.last:
            self.stop(step=step)

    def stop(self, step: Optional[int] = None) -> None:
        if not self.active:
            return
        import jax

        self.active = False
        self.done = True
        try:
            jax.profiler.stop_trace()
        except Exception as e:  # pragma: no cover - backend-dependent
            log.warning("profiler stop failed (%s: %s)", type(e).__name__, e)
            return
        log.info("profiler trace written: %s", self.trace_dir)
        emit_event("profile_stopped", step=step,
                   trace_dir=str(self.trace_dir))
