"""Structured lifecycle events + the metrics stream: one emit API.

Every record — event or metric — is stamped with the run context
(``run_id``, ``rank``), a process-monotone sequence number, and a wall
clock, then handed to the registered sink for its stream
(``repro.obs.sinks``).  The default sink is inert, so library code may
emit unconditionally-guarded one-liners::

    from repro.obs import events as obs

    obs.emit_event("watchdog_trip", step=step, dt_s=dt, median_s=med)

and pay nothing until a driver calls ``configure_run(run_dir)`` — which
installs append-only JSONL sinks for both streams next to ``summary.json``
(``events.jsonl`` / ``metrics.jsonl``).

The event taxonomy is CLOSED (``EVENT_KINDS``): an unknown kind raises at
the emit site, so the set of things that can appear in ``events.jsonl`` is
reviewable here rather than discovered by grepping consumers.

This module must stay importable without jax (the ``python -m repro.obs``
reader and the docs tooling parse record files offline); the rank stamp is
therefore resolved lazily from ``sys.modules`` like ``utils/logging``.
"""
from __future__ import annotations

import itertools
import os
import sys
import threading
import time
from typing import Optional

from repro.obs.sinks import JsonlSink, get_sink, reset_sinks, set_sink
from repro.utils.logging import get_logger

log = get_logger("obs")

# the closed event taxonomy; see docs/ARCHITECTURE.md "Observability"
EVENT_KINDS = (
    "run_started",        # launcher entry: arch/mode/policy/batch layout
    "plan_adopted",       # ClipPlan (or analytic fallback) chosen: per-tap
    #                       branch maps + kernel winners + batch certificate
    "checkpoint_saved",   # manager: artifact durably written + rotated
    "checkpoint_restored",  # manager: restore() succeeded at a step
    "watchdog_trip",      # StepWatchdog: step slower than trip_factor*median
    "preemption",         # SIGTERM observed -> checkpoint-and-exit path
    "restart_attempt",    # --auto-restart supervisor retrying after a crash
    "fault_injected",     # runtime.inject fired a deterministic fault
    "consensus_agreed",   # fleet adopted one plan (hash, ranks, leader)
    "consensus_rejected",  # PlanConsensusError: fleet must not trace
    "request_shed",       # serving admission: projected TTFT blew the SLO
    "profile_started",    # jax.profiler trace window opened
    "profile_stopped",    # trace window closed (trace_dir recorded)
    "epsilon_budget_crossed",  # accountant passed the configured fraction of
    #                       the target epsilon (one-shot per run)
    "run_finished",       # launcher exit: final step + privacy spend
)

_SEQ = itertools.count()
_CONTEXT = {"run_id": None}
_CONF_LOCK = threading.Lock()

EVENTS_FILENAME = "events.jsonl"
METRICS_FILENAME = "metrics.jsonl"


def _rank() -> int:
    """This process's fleet rank, without forcing a jax import.

    ``jax.process_index()`` is only meaningful once jax is already in the
    process (any instrumented run); the offline readers never import it and
    stamp rank 0.
    """
    jax_mod = sys.modules.get("jax")
    if jax_mod is None:
        return 0
    try:
        return int(jax_mod.process_index())
    except Exception:  # pragma: no cover - backend not initialized yet
        return 0


def set_run_context(run_id: Optional[str]) -> None:
    _CONTEXT["run_id"] = run_id


def run_context() -> dict:
    return {"run_id": _CONTEXT["run_id"], "rank": _rank()}


def configure_run(run_dir, run_id: Optional[str] = None) -> Optional[str]:
    """Point both streams at ``run_dir`` (append-only JSONL files).

    ``run_dir=None`` resets to the inert default — drivers call this
    unconditionally so a run without an obs/checkpoint directory cannot
    inherit a previous in-process run's sinks (test isolation).

    Reconfiguring for the SAME directory keeps the existing sinks and
    ``run_id``: in-process ``--auto-restart`` attempts append to one
    stream, so the post-mortem timeline spans every attempt.  Returns the
    effective run id.
    """
    with _CONF_LOCK:
        if run_dir is None:
            reset_sinks()
            _CONTEXT["run_id"] = None
            return None
        import pathlib

        run_dir = pathlib.Path(run_dir)
        existing = get_sink("events")
        if (
            isinstance(existing, JsonlSink)
            and existing.path == run_dir / EVENTS_FILENAME
        ):
            return _CONTEXT["run_id"]  # same run: keep appending
        reset_sinks()
        set_sink("events", JsonlSink(run_dir / EVENTS_FILENAME))
        set_sink("metrics", JsonlSink(run_dir / METRICS_FILENAME))
        if run_id is None:
            run_id = f"run-{int(time.time())}-{os.getpid()}"
        _CONTEXT["run_id"] = run_id
        return run_id


def _stamp(record: dict, step: Optional[int]) -> dict:
    out = {
        "run_id": _CONTEXT["run_id"],
        "rank": _rank(),
        "seq": next(_SEQ),
        "t": time.time(),
    }
    if step is not None:
        out["step"] = int(step)
    out.update(record)
    return out


def events_active() -> bool:
    return get_sink("events").active


def metrics_active() -> bool:
    return get_sink("metrics").active


_RESERVED_FIELDS = frozenset({"run_id", "rank", "seq", "t", "step", "kind"})


def emit_event(kind: str, *, step: Optional[int] = None, **fields) -> None:
    """Append one lifecycle event to the events stream (no-op when inert)."""
    if kind not in EVENT_KINDS:
        raise ValueError(
            f"unknown event kind {kind!r}; add it to repro.obs.events."
            f"EVENT_KINDS (known: {', '.join(EVENT_KINDS)})"
        )
    clash = _RESERVED_FIELDS.intersection(fields)
    if clash:
        raise ValueError(
            f"event field(s) {sorted(clash)} collide with the record stamp; "
            "rename them (e.g. seq -> seq_len)"
        )
    sink = get_sink("events")
    if not sink.active:
        return
    sink.emit(_stamp({"kind": kind, **fields}, step))


def emit_metrics(record: dict, *, step: Optional[int] = None) -> None:
    """Append one metrics record (e.g. kind="train_step") to the stream.

    Callers must gate any host-side value materialization on
    ``metrics_active()`` — this function only stamps and forwards.
    """
    sink = get_sink("metrics")
    if not sink.active:
        return
    sink.emit(_stamp(dict(record), step))


def flush_all() -> None:
    for stream in ("events", "metrics"):
        get_sink(stream).flush()
