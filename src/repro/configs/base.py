"""Architecture + shape configuration schema.

Every assigned architecture is one ``ArchConfig``; the four assigned input
shapes are ``ShapeConfig``s.  ``reduced()`` produces the CPU-smoke variant of
any architecture (same family/topology, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm | cnn | vit
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 1e6
    window: Optional[int] = None  # sliding-window attention (Mixtral)
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | gelu
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 2
    moe_every: int = 1  # MoE on layer i iff i % moe_every == moe_every - 1
    moe_dense_ff: int = 0  # Arctic: parallel dense-residual MLP width
    capacity_factor: float = 1.25
    # hybrid (Jamba): per-period block pattern; empty = all-attention
    block_pattern: tuple[str, ...] = ()  # entries: "attn" | "mamba" | "slstm" | "mlstm"
    # SSM dims
    ssm_d_state: int = 64
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # encoder-decoder (Whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0  # stub frontend: precomputed frame embeddings
    # VLM (phi-3-vision): stub frontend provides patch embeddings
    prefix_tokens: int = 0
    prefix_dim: int = 0
    # precision
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    opt_state_dtype: str = "float32"
    # runtime
    # "tp": weights tensor-parallel over the model axis (big models)
    # "dp_only": the model axis joins data parallelism; weights fully
    #   FSDP-sharded and gathered per layer (small models — kills the
    #   per-layer activation all-reduces entirely)
    parallelism: str = "tp"
    scan_layers: bool = True
    remat: bool = True
    attn_block_q: int = 512
    attn_block_kv: int = 512
    # whether long_500k is runnable (sub-quadratic / bounded-context)
    sub_quadratic: bool = False
    # DP defaults
    clipping_mode: str = "mixed_ghost"
    # notes for DESIGN.md / dry-run reports
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def supports(self, shape: ShapeConfig) -> bool:
        if shape.name == "long_500k":
            return self.sub_quadratic
        return True

    def reduced(self) -> "ArchConfig":
        """CPU-smoke variant: same topology, tiny dims."""
        pattern = self.block_pattern
        n_layers = max(2, min(4, self.n_layers)) if not pattern else len(pattern)
        heads = max(2, min(4, self.n_heads))
        kv = max(1, min(self.n_kv, heads))
        # keep the GQA grouping style (kv<heads vs kv==heads)
        if self.n_kv == self.n_heads:
            kv = heads
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=64,
            n_heads=heads,
            n_kv=kv,
            head_dim=None,
            d_ff=96 if self.d_ff else 0,
            vocab=128,
            moe_experts=min(self.moe_experts, 4),
            moe_dense_ff=48 if self.moe_dense_ff else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=12 if self.encoder_seq else 0,
            prefix_tokens=4 if self.prefix_tokens else 0,
            prefix_dim=16 if self.prefix_dim else 0,
            ssm_d_state=8,
            ssm_head_dim=8,
            ssm_chunk=8,
            attn_block_q=16,
            attn_block_kv=16,
            dtype="float32",
            param_dtype="float32",
        )
