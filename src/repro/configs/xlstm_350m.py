"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H d_ff=0 (xLSTM blocks carry their own projections)
vocab=50304.  Period of 8 = 1 sLSTM + 7 mLSTM (the paper's [7:1] ratio).
Recurrent-state decode => long_500k runs.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv=4,
    d_ff=0,
    vocab=50304,
    block_pattern=("slstm",) + ("mlstm",) * 7,
    sub_quadratic=True,
    ssm_chunk=256,
    parallelism="dp_only",
    source="arXiv:2405.04517 (xLSTM); pool tier: unverified",
)
