"""Paper-native configs: the models from the paper's own tables.

vgg11/vgg19 + resnet18 (CIFAR) exercise the 2D-conv layerwise decision
(Tables 3/4/6); vit_base / beit_large are the convolutional-ViT DP SOTA
models of Table 5.
"""
from repro.configs.base import ArchConfig

VIT_BASE = ArchConfig(
    name="vit-base-patch16",
    family="vit",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv=12,
    d_ff=3072,
    vocab=0,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    source="arXiv:2010.11929",
)

BEIT_LARGE = ArchConfig(
    name="beit-large-patch16",
    family="vit",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=4096,
    vocab=0,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    source="arXiv:2106.08254 (BEiT); paper Table 5",
)
