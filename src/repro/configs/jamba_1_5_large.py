"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7, MoE 16e top-2
[arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.  Period of 8 layers:
attention at index 3, Mamba elsewhere (1:7), MoE on every other layer.
Hardware adaptation: Mamba layers use the SSD scalar-decay form (Mamba-2)
whose chunked scan is MXU matmuls — see DESIGN.md.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_ff=24576,
    vocab=65536,
    moe_experts=16,
    moe_top_k=2,
    moe_every=2,
    block_pattern=("mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba", "mamba"),
    ssm_d_state=64,
    ssm_head_dim=64,
    sub_quadratic=True,
    param_dtype="bfloat16",
    opt_state_dtype="bfloat16",
    source="arXiv:2403.19887",
)
