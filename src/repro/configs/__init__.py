from repro.configs.base import ArchConfig, ShapeConfig, SHAPES

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES"]
