"""mixtral-8x7b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2,
sliding-window 4096.  SWA bounds the reachable context, so long_500k decode
runs on a 4096-slot ring KV cache.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=32000,
    moe_experts=8,
    moe_top_k=2,
    window=4096,
    rope_theta=1e6,
    sub_quadratic=True,  # via SWA ring cache
    source="arXiv:2401.04088",
)
