"""whisper-large-v3 [audio] — enc-dec, conv frontend stubbed
[arXiv:2212.04356; unverified].

32L encoder + 32L decoder, d_model=1280 20H (MHA kv=20) d_ff=5120
vocab=51866.  The mel->conv1d stem is a STUB: input_specs provides
precomputed frame embeddings (B, 1500, 1280).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv=20,
    d_ff=5120,
    vocab=51866,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    encoder_layers=32,
    encoder_seq=1500,
    parallelism="dp_only",
    source="arXiv:2212.04356",
)
