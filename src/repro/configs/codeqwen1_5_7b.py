"""codeqwen1.5-7b [dense] — qwen1.5 arch [hf:Qwen/CodeQwen1.5-7B; hf].

32L d_model=4096 32H (MHA kv=32) d_ff=13440 vocab=92416, QKV bias.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=32,
    d_ff=13440,
    vocab=92416,
    qkv_bias=True,
    rope_theta=1e6,
    parallelism="dp_only",
    source="hf:Qwen/CodeQwen1.5-7B",
)
