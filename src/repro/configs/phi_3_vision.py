"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP stub
[hf:microsoft/Phi-3-vision-128k-instruct; hf].

32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064.  The CLIP ViT-L/14
image tower is a STUB: input_specs provides precomputed patch embeddings
(B, 576, 1024) which a trainable projector maps into the LM.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=32064,
    rope_theta=1e4,
    prefix_tokens=576,
    prefix_dim=1024,
    parallelism="dp_only",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
