"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2 with a
parallel dense-residual MLP (width d_model, matching Arctic's ~10B dense
trunk / 35 layers).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv=8,
    d_ff=4864,
    vocab=32000,
    moe_experts=128,
    moe_top_k=2,
    moe_dense_ff=7168,
    rope_theta=1e6,
    param_dtype="bfloat16",
    opt_state_dtype="bfloat16",
    source="hf:Snowflake/snowflake-arctic-base",
)
