"""Architecture registry: ``--arch <id>`` resolution and model construction."""
from __future__ import annotations

from typing import Any

from repro.configs import (
    arctic_480b,
    codeqwen1_5_7b,
    jamba_1_5_large,
    mixtral_8x7b,
    phi_3_vision,
    qwen1_5_32b,
    qwen2_72b,
    whisper_large_v3,
    xlstm_350m,
    yi_6b,
)
from repro.configs.base import ArchConfig, SHAPES, ShapeConfig

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        xlstm_350m.CONFIG,
        codeqwen1_5_7b.CONFIG,
        qwen2_72b.CONFIG,
        yi_6b.CONFIG,
        qwen1_5_32b.CONFIG,
        mixtral_8x7b.CONFIG,
        arctic_480b.CONFIG,
        jamba_1_5_large.CONFIG,
        whisper_large_v3.CONFIG,
        phi_3_vision.CONFIG,
    )
}


def get_arch(name: str) -> ArchConfig:
    key = name.strip()
    if key in ARCHS:
        return ARCHS[key]
    alt = key.replace("_", "-").replace(".", "-")
    for k in ARCHS:
        if k.replace(".", "-") == alt:
            return ARCHS[k]
    raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")
    return SHAPES[name]


def build_model(cfg: ArchConfig) -> Any:
    if cfg.family == "audio":
        from repro.models.encdec import EncDecLM

        return EncDecLM(cfg)
    from repro.models.lm import DecoderLM

    return DecoderLM(cfg)


def all_cells():
    """All 40 (arch x shape) cells with runnability flags."""
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            yield arch, shape, arch.supports(shape)
