"""Tracing-hygiene lints: f64 promotions, host callbacks, donation misses.

Two kinds of check live here:

* jaxpr walks over the traced train step (``jaxpr_hygiene``): any float64 /
  complex128 aval means a silent 2x-memory promotion snuck into the jitted
  program (jax keeps x64 off by default, but ``enable_x64`` scopes and
  explicit ``astype(float64)`` both get through); any host-callback
  primitive means a device->host round trip serializing every step.
* an AST lint over ``src/repro/launch/train.py`` (``donation_lint``): the
  accumulation-loop jit sites must donate their accumulator/state argument
  (the PR-7 step-time floor depends on it) — a refactor that drops
  ``donate_argnums`` doubles peak memory without failing any test.

Everything here is severity ``warn``: hygiene, not privacy.
"""
from __future__ import annotations

import ast
import pathlib

from repro.analysis.report import Finding
from repro.analysis.taint import ClosedJaxpr, Jaxpr, eqn_summary

_WIDE_DTYPES = ("float64", "complex128")
_CALLBACK_PRIMS = frozenset(
    {
        "pure_callback",
        "io_callback",
        "debug_callback",
        "host_callback_call",
        "outside_call",
    }
)


def _walk_jaxprs(jaxpr: Jaxpr):
    yield jaxpr
    for eqn in jaxpr.eqns:
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                yield from _walk_jaxprs(sub)


def _sub_jaxprs(val):
    if isinstance(val, ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, Jaxpr):
        yield val
    elif isinstance(val, (tuple, list)):
        for item in val:
            yield from _sub_jaxprs(item)


def jaxpr_hygiene(closed: ClosedJaxpr, arch: str = "-") -> list:
    """Walk every eqn (all sub-jaxprs) for wide dtypes and host callbacks."""
    findings = []
    seen_wide = set()
    for jaxpr in _walk_jaxprs(closed.jaxpr):
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            if prim in _CALLBACK_PRIMS:
                findings.append(
                    Finding(
                        code="host_callback",
                        severity="warn",
                        arch=arch,
                        subject=eqn_summary(eqn),
                        detail=(
                            f"host callback primitive {prim!r} inside the "
                            "jitted step: device->host sync every step"
                        ),
                    )
                )
            for v in eqn.outvars:
                dtype = str(getattr(getattr(v, "aval", None), "dtype", ""))
                if dtype in _WIDE_DTYPES and (prim, dtype) not in seen_wide:
                    seen_wide.add((prim, dtype))
                    findings.append(
                        Finding(
                            code="f64_promotion",
                            severity="warn",
                            arch=arch,
                            subject=eqn_summary(eqn),
                            detail=(
                                f"{dtype} value produced by {prim!r} inside "
                                "the jitted step (weak-type or explicit "
                                "promotion; 2x memory + slow on accelerators)"
                            ),
                        )
                    )
    return findings


# launch/train.py jit sites that must donate, and the argument each donates:
# the train state for the fused step and finalize, the device-resident
# accumulator for the microstep.  init_fn is deliberately absent — it
# CONSUMES nothing (builds the zero accumulator from specs).
EXPECTED_DONATIONS = {
    "jit_step": 0,
    "micro_fn": 2,
    "fin_fn": 0,
}


def donation_lint(repo_root=None, arch: str = "-") -> list:
    """AST-check the accumulation loop's jit sites for donate_argnums."""
    root = pathlib.Path(repo_root) if repo_root else _find_root()
    path = root / "src" / "repro" / "launch" / "train.py"
    findings = []
    if not path.exists():
        findings.append(
            Finding(
                code="donation_miss",
                severity="warn",
                arch=arch,
                subject=str(path),
                detail="launch/train.py not found; donation lint skipped",
            )
        )
        return findings
    tree = ast.parse(path.read_text(encoding="utf-8"))
    seen: dict[str, object] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        target = node.targets[0]
        if not isinstance(target, ast.Name) or target.id not in EXPECTED_DONATIONS:
            continue
        call = _peel_jit_call(node.value)
        if call is None:
            continue
        donated: tuple = ()
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                try:
                    donated = tuple(ast.literal_eval(kw.value))
                except (ValueError, TypeError):
                    donated = ("<dynamic>",)
        seen[target.id] = (node.lineno, donated)
    for name, argnum in EXPECTED_DONATIONS.items():
        if name not in seen:
            findings.append(
                Finding(
                    code="donation_miss",
                    severity="warn",
                    arch=arch,
                    subject=f"launch/train.py:{name}",
                    detail=(
                        f"expected jit site {name!r} not found; if it was "
                        "renamed, update analysis.hygiene.EXPECTED_DONATIONS"
                    ),
                )
            )
            continue
        lineno, donated = seen[name]
        if donated == ("<dynamic>",):
            continue  # computed donate_argnums: assume intentional
        if argnum not in donated:
            findings.append(
                Finding(
                    code="donation_miss",
                    severity="warn",
                    arch=arch,
                    subject=f"launch/train.py:{lineno}:{name}",
                    detail=(
                        f"jit site {name!r} does not donate argument "
                        f"{argnum}: the accum loop keeps a second copy of "
                        "the buffer alive (step-time floor regression)"
                    ),
                )
            )
    return findings


def _peel_jit_call(node):
    """The ``jax.jit(...)`` call inside a ``jit(...).lower(...).compile()``
    chain (the AOT pattern in launch/train.py), or None."""
    call = node
    while isinstance(call, ast.Call):
        func = call.func
        if isinstance(func, ast.Attribute) and func.attr in ("lower", "compile"):
            call = func.value
            continue
        break
    if isinstance(call, ast.Call):
        func = call.func
        if (isinstance(func, ast.Attribute) and func.attr == "jit") or (
            isinstance(func, ast.Name) and func.id == "jit"
        ):
            return call
    return None


def _find_root() -> pathlib.Path:
    # src/repro/analysis/hygiene.py -> repo root is four parents up
    return pathlib.Path(__file__).resolve().parents[3]
