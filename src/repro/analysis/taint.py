"""Batch-axis taint propagation over jaxprs: the per-sample isolation pass.

The ghost/book-keeping norms of Algorithm 1 are only the true per-sample
gradient norms when the traced computation is *batch-diagonal*: sample i's
data influences tap pre-activation rows ``s[i]`` and loss ``L_i`` only.  By
linearity of the vjp, forward diagonality is equivalent to cotangent
diagonality (``dL_i/ds_j = 0`` for ``i != j`` iff no forward path carries
sample j into ``L_i``), so ONE abstract forward pass over the explicit-tap
jaxpr certifies both halves of every tap's (activation, cotangent) pair —
see docs/ARCHITECTURE.md "Static analysis" for the full argument.

The abstract value per jaxpr var is a :class:`Taint`:

- ``None``            CLEAN — no sample data flows here (params, constants).
- ``Taint(axis=k)``   samples ride axis ``k``; element ``i`` of that axis is
                      a function of sample ``i`` (and clean inputs) only.
- ``Taint(axis=None)``MIXED — some eqn combined samples; ``trail`` records
                      the originating eqn plus the propagation path (capped).

Per-primitive transfer rules keep the axis through shape ops, drop it through
batch-axis reductions/contractions/scans, and understand the
``operand_batching_dims`` that jax >= 0.4.31 emits for vmapped
gather/scatter (what proves the MoE per-sample dispatch block-isolated).
Unknown primitives are *conservative*: any tainted input makes the output
MIXED with an "unknown primitive" trail, so gaps fail loudly instead of
certifying silently.

Scatters whose write positions are themselves sample-derived (the MoE slot
table) are block-isolated but order-sensitive under collisions — proving the
recorded activations faithful needs the value-level occupancy invariant the
lattice cannot express, so they are surfaced separately as *routed* sites
for the per-config allowlist (``repro.analysis.allowlist``).

This module walks jax internals (``jax._src.core``); the repo pins
jax 0.4.37 (see .github/workflows/tier1.yml) and the import guard below
keeps the public-API fallback alive for nearby versions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

try:  # jax 0.4.x: the public aliases re-export these; _src is the stable home
    from jax._src.core import ClosedJaxpr, Jaxpr, JaxprEqn, Literal, Var
except ImportError:  # pragma: no cover - newer/older layouts
    from jax.core import ClosedJaxpr, Jaxpr, JaxprEqn, Literal, Var  # type: ignore

try:
    from jax._src import source_info_util as _siu
except ImportError:  # pragma: no cover
    _siu = None

TRAIL_CAP = 8
_SCAN_FIXPOINT_CAP = 16


@dataclasses.dataclass(frozen=True)
class Taint:
    """Batch-axis location (``axis``) or sample-mixedness (``axis=None``)."""

    axis: Optional[int]
    trail: tuple[str, ...] = ()

    @property
    def mixed(self) -> bool:
        return self.axis is None


def eqn_summary(eqn: JaxprEqn) -> str:
    """One-line human-locatable eqn identity: prim, shapes, source site."""
    ins = ",".join(
        "x".join(map(str, getattr(a.aval, "shape", ()))) for a in eqn.invars
    )
    outs = ",".join(
        "x".join(map(str, getattr(v.aval, "shape", ()))) for v in eqn.outvars
    )
    src = ""
    if _siu is not None:
        try:
            src = f" @ {_siu.summarize(eqn.source_info)}"
        except Exception:  # pragma: no cover - source info shape changed
            src = ""
    return f"{eqn.primitive.name}[{ins}->{outs}]{src}"


@dataclasses.dataclass
class TapSite:
    """One tap-add eqn: where a zero tap joins its pre-activation."""

    tap: str
    taint: Optional[Taint]  # taint of the pre-activation operand
    summary: str
    eqn: JaxprEqn
    jaxpr: Jaxpr  # the (sub)jaxpr the add lives in — coverage cuts start here


@dataclasses.dataclass
class RoutedSite:
    """A scatter with sample-derived write positions (MoE slot tables)."""

    summary: str
    taint: Optional[Taint]
    isolated: bool  # True when batching dims confine writes per sample


@dataclasses.dataclass
class TaintResult:
    out_taints: list  # one Optional[Taint] per top-level outvar
    sites: list  # TapSite, deduped per add eqn
    routed: list  # RoutedSite, deduped per scatter eqn
    unknown_prims: list  # sorted prim names hit by the conservative fallback


# primitives that reduce over params["axes"]
_REDUCE_PRIMS = frozenset(
    {
        "reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
        "reduce_and", "reduce_or", "reduce_xor", "argmax", "argmin",
    }
)
_CUM_PRIMS = frozenset(
    {"cumsum", "cumprod", "cummax", "cummin", "cumlogsumexp"}
)
_SCATTER_PRIMS = frozenset(
    {"scatter", "scatter-add", "scatter-mul", "scatter-min", "scatter-max"}
)
# tapness (the identity of a zero tap) survives these before its add
_TAP_TRANSPARENT = frozenset(
    {"convert_element_type", "broadcast_in_dim", "reshape", "transpose", "copy"}
)


def _worse(a: Optional[Taint], b: Optional[Taint]) -> Optional[Taint]:
    """Severity order for site dedup across scan fixpoint iterations."""
    if a is None:
        return b
    if b is None:
        return a
    return a if (a.mixed or not b.mixed) else b


class TaintInterpreter:
    """Abstract forward interpreter; one instance per traced model."""

    def __init__(self, batch_size: int):
        self.batch_size = batch_size
        self._sites: dict[int, TapSite] = {}
        self._routed: dict[int, RoutedSite] = {}
        self._unknown: set[str] = set()

    # -- public ----------------------------------------------------------
    def run(
        self,
        closed: ClosedJaxpr,
        in_taints: list,
        in_taps: list,
    ) -> TaintResult:
        outs, _ = self._run_jaxpr(closed.jaxpr, in_taints, in_taps)
        return TaintResult(
            out_taints=outs,
            sites=list(self._sites.values()),
            routed=list(self._routed.values()),
            unknown_prims=sorted(self._unknown),
        )

    # -- environment helpers ---------------------------------------------
    @staticmethod
    def _read(env: dict, atom: Any) -> Optional[Taint]:
        return None if isinstance(atom, Literal) else env.get(atom)

    def _mix(
        self, eqn: JaxprEqn, parents: list, why: str
    ) -> Taint:
        """A mixed taint whose trail extends the first mixed parent's."""
        base: tuple[str, ...] = ()
        extra = 0
        for t in parents:
            if t is not None and t.trail:
                if not base:
                    base = t.trail
                else:
                    extra += 1
        here = eqn_summary(eqn) + (f" ({why})" if why else "")
        if extra:
            here += f" [+{extra} more tainted sources]"
        trail = base + (here,) if len(base) < TRAIL_CAP else base
        return Taint(None, trail)

    def _join_elementwise(self, eqn: JaxprEqn, in_t: list) -> Optional[Taint]:
        live = [t for t in in_t if t is not None]
        if not live:
            return None
        axes = {t.axis for t in live if not t.mixed}
        return self._join(eqn, live, axes)

    def _join(
        self, eqn: JaxprEqn, taints: list, axes: set
    ) -> Optional[Taint]:
        """Join already-mapped output axes; conflicting axes mean the eqn
        pairs two different sample axes in one value (an outer product over
        the batch) — mixed."""
        live = [t for t in taints if t is not None]
        if not live and not axes:
            return None
        if any(t.mixed for t in live):
            return self._mix(eqn, live, "propagates mixed input")
        if len(axes) > 1:
            return self._mix(eqn, live, "pairs two sample axes")
        if not axes:
            return None
        trail = next((t.trail for t in live if t.trail), ())
        return Taint(axes.pop(), trail)

    # -- jaxpr traversal -------------------------------------------------
    def _run_jaxpr(
        self, jaxpr: Jaxpr, in_taints: list, in_taps: list
    ) -> tuple[list, list]:
        env: dict[Var, Taint] = {}
        taps: dict[Var, str] = {}
        for v, t in zip(jaxpr.invars, in_taints):
            if t is not None:
                env[v] = t
        for v, name in zip(jaxpr.invars, in_taps):
            if name is not None:
                taps[v] = name
        # constvars carry trace-time constants: clean by construction (the
        # audit passes params/taps/batch as arguments, never via closure)
        for eqn in jaxpr.eqns:
            self._eqn(eqn, env, taps, jaxpr)
        out_t = [self._read(env, v) for v in jaxpr.outvars]
        out_taps = [
            taps.get(v) if isinstance(v, Var) else None for v in jaxpr.outvars
        ]
        return out_t, out_taps

    # -- per-eqn dispatch ------------------------------------------------
    def _eqn(
        self, eqn: JaxprEqn, env: dict, taps: dict, jaxpr: Jaxpr
    ) -> None:
        prim = eqn.primitive.name
        in_t = [self._read(env, a) for a in eqn.invars]
        in_tap = [
            taps.get(a) if isinstance(a, Var) else None for a in eqn.invars
        ]

        # tap-add site: exactly one operand is a (possibly cast/sliced) zero
        # tap; the other is the pre-activation whose diagonality we certify
        if prim == "add" and sum(n is not None for n in in_tap) == 1:
            k = 0 if in_tap[0] is not None else 1
            name = in_tap[k]
            site = TapSite(
                tap=name,
                taint=in_t[1 - k],
                summary=eqn_summary(eqn),
                eqn=eqn,
                jaxpr=jaxpr,
            )
            old = self._sites.get(id(eqn))
            if old is None or _worse(old.taint, site.taint) is site.taint:
                self._sites[id(eqn)] = site
            # the sum is the real pre-activation stream; tapness is consumed
            self._set_out(eqn, env, taps, self._join_elementwise(eqn, in_t))
            return

        if prim in _TAP_TRANSPARENT and in_tap[0] is not None:
            taps[eqn.outvars[0]] = in_tap[0]

        out = self._rule(prim, eqn, env, taps, in_t, in_tap)
        if out is not _HANDLED:
            self._set_out(eqn, env, taps, out)

    def _set_out(self, eqn: JaxprEqn, env: dict, taps: dict, out: Any) -> None:
        """Assign taints to outvars; ``out`` is one taint (broadcast to all
        outvars) or a list aligned with them."""
        if not isinstance(out, list):
            out = [out] * len(eqn.outvars)
        for v, t in zip(eqn.outvars, out):
            if t is not None:
                env[v] = t

    # -- transfer rules --------------------------------------------------
    def _rule(
        self,
        prim: str,
        eqn: JaxprEqn,
        env: dict,
        taps: dict,
        in_t: list,
        in_tap: list,
    ) -> Any:
        if all(t is None for t in in_t):
            # clean in, clean out — except subjaxpr prims, which may need
            # tapness threaded (a tap slice rides scan xs while clean)
            if prim not in ("scan", "pjit", "remat", "checkpoint", "cond",
                            "while", "custom_jvp_call", "custom_vjp_call",
                            "custom_vjp_call_jaxpr") or all(
                n is None for n in in_tap
            ):
                return None

        if prim == "broadcast_in_dim":
            t = in_t[0]
            if t is None or t.mixed:
                return t
            bdims = tuple(eqn.params["broadcast_dimensions"])
            return Taint(bdims[t.axis], t.trail)

        if prim == "reshape":
            return self._reshape(eqn, in_t[0])

        if prim == "transpose":
            t = in_t[0]
            if t is None or t.mixed:
                return t
            perm = tuple(eqn.params["permutation"])
            return Taint(perm.index(t.axis), t.trail)

        if prim == "squeeze":
            t = in_t[0]
            if t is None or t.mixed:
                return t
            dims = tuple(eqn.params["dimensions"])
            if t.axis in dims:
                return self._mix(eqn, [t], "squeezes the batch axis")
            return Taint(
                t.axis - sum(1 for d in dims if d < t.axis), t.trail
            )

        if prim in _REDUCE_PRIMS:
            t = in_t[0]
            if t is None or t.mixed:
                return t
            axes = tuple(eqn.params["axes"])
            if t.axis in axes:
                return self._mix(eqn, [t], "reduces over the batch axis")
            return Taint(t.axis - sum(1 for ax in axes if ax < t.axis), t.trail)

        if prim in _CUM_PRIMS:
            t = in_t[0]
            if t is None or t.mixed:
                return t
            if eqn.params["axis"] == t.axis:
                return self._mix(eqn, [t], "cumulates over the batch axis")
            return t

        if prim == "dot_general":
            return self._dot_general(eqn, in_t)

        if prim == "conv_general_dilated":
            return self._conv(eqn, in_t)

        if prim == "gather":
            return self._gather(eqn, in_t)

        if prim in _SCATTER_PRIMS:
            return self._scatter(eqn, in_t)

        if prim == "concatenate":
            dim = eqn.params["dimension"]
            axes = set()
            for t in in_t:
                if t is not None and not t.mixed:
                    if t.axis == dim:
                        return self._mix(
                            eqn, in_t, "concatenates along the batch axis"
                        )
                    axes.add(t.axis)
            return self._join(eqn, in_t, axes)

        if prim == "slice":
            t = in_t[0]
            if t is None or t.mixed:
                return t
            start = eqn.params["start_indices"][t.axis]
            limit = eqn.params["limit_indices"][t.axis]
            strides = eqn.params["strides"]
            stride = 1 if strides is None else strides[t.axis]
            full = eqn.invars[0].aval.shape[t.axis]
            if start == 0 and limit == full and stride == 1:
                return t
            return self._mix(eqn, [t], "slices a subrange of the batch axis")

        if prim == "dynamic_slice":
            t = in_t[0]
            if any(x is not None for x in in_t[1:]):
                return self._mix(eqn, in_t, "sample-dependent slice start")
            if t is None or t.mixed:
                return t
            if eqn.params["slice_sizes"][t.axis] == eqn.invars[0].aval.shape[t.axis]:
                return t
            return self._mix(eqn, [t], "dynamic-slices the batch axis")

        if prim == "dynamic_update_slice":
            op_t, upd_t = in_t[0], in_t[1]
            if any(x is not None for x in in_t[2:]):
                return self._mix(eqn, in_t, "sample-dependent update position")
            if upd_t is not None and (
                upd_t.mixed
                or tuple(eqn.invars[1].aval.shape) != tuple(eqn.invars[0].aval.shape)
            ):
                return self._mix(
                    eqn, in_t, "partial update into a sample-carrying buffer"
                ) if (op_t is not None or upd_t is not None) else None
            axes = {
                t.axis for t in (op_t, upd_t) if t is not None and not t.mixed
            }
            return self._join(eqn, in_t, axes)

        if prim == "pad":
            t = in_t[0]
            if in_t[1] is not None:  # padding value tainted: scalar -> mixed
                return self._mix(eqn, in_t, "sample-dependent pad value")
            if t is None or t.mixed:
                return t
            lo, hi, interior = eqn.params["padding_config"][t.axis]
            if lo == 0 and hi == 0 and interior == 0:
                return t
            return self._mix(eqn, [t], "pads the batch axis")

        if prim == "rev":
            t = in_t[0]
            if t is None or t.mixed:
                return t
            if t.axis in tuple(eqn.params["dimensions"]):
                return self._mix(eqn, [t], "reverses the batch axis")
            return t

        if prim == "sort":
            dim = eqn.params["dimension"]
            axes = set()
            for t in in_t:
                if t is None:
                    continue
                if t.mixed:
                    return [self._mix(eqn, in_t, "")] * len(eqn.outvars)
                if t.axis == dim:
                    return [
                        self._mix(eqn, in_t, "sorts along the batch axis")
                    ] * len(eqn.outvars)
                axes.add(t.axis)
            return [self._join(eqn, in_t, set(axes))] * len(eqn.outvars)

        if prim == "top_k":
            t = in_t[0]
            if t is None or t.mixed:
                return [t, t]
            last = len(eqn.invars[0].aval.shape) - 1
            if t.axis == last:
                m = self._mix(eqn, [t], "selects top-k over the batch axis")
                return [m, m]
            return [t, t]

        if prim == "split":
            t = in_t[0]
            if t is None or t.mixed:
                return [t] * len(eqn.outvars)
            if eqn.params.get("axis") == t.axis:
                m = self._mix(eqn, [t], "splits the batch axis")
                return [m] * len(eqn.outvars)
            return [t] * len(eqn.outvars)

        if prim == "scan":
            return self._scan(eqn, in_t, in_tap)

        if prim == "while":
            return self._while(eqn, in_t)

        if prim == "cond":
            return self._cond(eqn, in_t, in_tap)

        if prim in ("pjit", "closed_call", "core_call", "xla_call"):
            closed = eqn.params["jaxpr"]
            outs, out_taps = self._run_jaxpr(
                closed.jaxpr, in_t, in_tap
            )
            for v, name in zip(eqn.outvars, out_taps):
                if name is not None:
                    taps[v] = name
            return outs

        if prim in ("remat", "checkpoint", "remat2"):
            body = eqn.params["jaxpr"]  # open Jaxpr
            outs, out_taps = self._run_jaxpr(body, in_t, in_tap)
            for v, name in zip(eqn.outvars, out_taps):
                if name is not None:
                    taps[v] = name
            return outs

        if prim in ("custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
                    "custom_jvp_call_jaxpr"):
            sub = eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr")
            body = sub.jaxpr if isinstance(sub, ClosedJaxpr) else sub
            outs, out_taps = self._run_jaxpr(body, in_t, in_tap)
            for v, name in zip(eqn.outvars, out_taps):
                if name is not None:
                    taps[v] = name
            return outs

        # elementwise fallback: covers every elementwise/unary primitive
        # (add, mul, exp, select_n, compares, convert_element_type, ...)
        # without enumerating them, including lax's rank-matching size-1
        # broadcasting (keepdims stats in the norms).  Safe because
        # shape-preserving prims that PERMUTE the distinguished axis
        # (rev, sort) were handled above; anything else maps element i ->
        # element i along every full-size axis.
        tainted = [
            (a, t) for a, t in zip(eqn.invars, in_t) if t is not None
        ]
        if not tainted:
            return None
        out_shape = tuple(eqn.outvars[0].aval.shape)
        if all(tuple(v.aval.shape) == out_shape for v in eqn.outvars):
            axes: set = set()
            applicable = True
            for a, t in tainted:
                if t.mixed:
                    continue
                s = tuple(a.aval.shape)
                if len(s) != len(out_shape) or any(
                    d != o and d != 1 for d, o in zip(s, out_shape)
                ):
                    applicable = False
                    break
                if s[t.axis] == out_shape[t.axis]:
                    axes.add(t.axis)
                else:
                    # a size-1 "batch" axis broadcast up: cannot be the
                    # real batch; conservative
                    return [
                        self._mix(eqn, in_t, "broadcasts the batch axis")
                    ] * len(eqn.outvars)
            if applicable:
                return [self._join(eqn, in_t, axes)] * len(eqn.outvars)

        # conservative: unknown primitive with tainted inputs
        self._unknown.add(prim)
        m = self._mix(
            eqn, in_t, f"no transfer rule for primitive {prim!r} (conservative)"
        )
        return [m] * len(eqn.outvars)

    # -- structured primitives -------------------------------------------
    def _reshape(self, eqn: JaxprEqn, t: Optional[Taint]) -> Optional[Taint]:
        if t is None or t.mixed:
            return t
        if eqn.params.get("dimensions") is not None:
            return self._mix(eqn, [t], "reshape with permutation")
        src = tuple(eqn.invars[0].aval.shape)
        dst = tuple(eqn.params["new_sizes"])
        # the batch dim survives as a unit iff some out axis has the same
        # size AND the same prefix product (position) — splitting or merging
        # it folds samples into another axis
        pre = 1
        for d in src[: t.axis]:
            pre *= d
        acc = 1
        for b, d in enumerate(dst):
            if acc == pre and d == src[t.axis]:
                return Taint(b, t.trail)
            acc *= d
        return self._mix(
            eqn, [t], "reshape merges/splits the batch axis"
        )

    def _dot_general(self, eqn: JaxprEqn, in_t: list) -> Optional[Taint]:
        lhs_t, rhs_t = in_t[0], in_t[1]
        if (lhs_t is not None and lhs_t.mixed) or (
            rhs_t is not None and rhs_t.mixed
        ):
            return self._mix(eqn, in_t, "propagates mixed input")
        (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
        lhs_shape = eqn.invars[0].aval.shape
        rhs_shape = eqn.invars[1].aval.shape
        axes = set()

        def free_out_axis(shape, contract, batch, axis, offset):
            free = [
                d
                for d in range(len(shape))
                if d not in contract and d not in batch
            ]
            return len(lb) + offset + free.index(axis)

        for t, contract, batch, shape, is_lhs in (
            (lhs_t, tuple(lc), tuple(lb), lhs_shape, True),
            (rhs_t, tuple(rc), tuple(rb), rhs_shape, False),
        ):
            if t is None:
                continue
            if t.axis in contract:
                return self._mix(
                    eqn, in_t, "contracts over the batch axis"
                )
            if t.axis in batch:
                axes.add(batch.index(t.axis))
                continue
            # a free sample axis on BOTH operands would pair samples
            offset = 0
            if not is_lhs:
                offset = len(
                    [
                        d
                        for d in range(len(lhs_shape))
                        if d not in tuple(lc) and d not in tuple(lb)
                    ]
                )
            axes.add(free_out_axis(shape, contract, batch, t.axis, offset))
        return self._join(eqn, in_t, axes)

    def _conv(self, eqn: JaxprEqn, in_t: list) -> Optional[Taint]:
        lhs_t, rhs_t = in_t[0], in_t[1]
        if rhs_t is not None:
            return self._mix(eqn, in_t, "sample data in convolution weights")
        if lhs_t is None or lhs_t.mixed:
            return lhs_t
        dn = eqn.params["dimension_numbers"]
        if lhs_t.axis == dn.lhs_spec[0]:
            return Taint(dn.out_spec[0], lhs_t.trail)
        return self._mix(
            eqn, [lhs_t], "convolves over a sample-carrying axis"
        )

    def _gather(self, eqn: JaxprEqn, in_t: list) -> Optional[Taint]:
        op_t, idx_t = in_t[0], in_t[1]
        if (op_t is not None and op_t.mixed) or (
            idx_t is not None and idx_t.mixed
        ):
            return self._mix(eqn, in_t, "propagates mixed input")
        dn = eqn.params["dimension_numbers"]
        operand = eqn.invars[0].aval
        indices = eqn.invars[1].aval
        out_rank = len(eqn.outvars[0].aval.shape)
        offset_dims = tuple(int(d) for d in dn.offset_dims)
        obd = tuple(int(d) for d in getattr(dn, "operand_batching_dims", ()))
        sbd = tuple(
            int(d) for d in getattr(dn, "start_indices_batching_dims", ())
        )
        non_offset_out = [d for d in range(out_rank) if d not in offset_dims]
        axes = set()
        if op_t is not None:
            a = op_t.axis
            if a in obd:
                # vmapped gather: reads are confined to the matching block
                axes.add(non_offset_out[sbd[obd.index(a)]])
            else:
                sim = tuple(int(d) for d in dn.start_index_map)
                csd = tuple(int(d) for d in dn.collapsed_slice_dims)
                slice_sizes = tuple(eqn.params["slice_sizes"])
                if (
                    a not in sim
                    and a not in csd
                    and slice_sizes[a] == operand.shape[a]
                ):
                    kept = [
                        d
                        for d in range(len(operand.shape))
                        if d not in csd and d not in obd
                    ]
                    axes.add(offset_dims[kept.index(a)])
                else:
                    return self._mix(
                        eqn, in_t, "gathers across the batch axis"
                    )
        if idx_t is not None:
            j = idx_t.axis
            if j == len(indices.shape) - 1:
                return self._mix(
                    eqn, in_t, "sample data in the gather index vector"
                )
            axes.add(non_offset_out[j])
        return self._join(eqn, in_t, axes)

    def _scatter(self, eqn: JaxprEqn, in_t: list) -> Optional[Taint]:
        op_t, idx_t, upd_t = in_t[0], in_t[1], in_t[2]
        if any(t is not None and t.mixed for t in in_t):
            return self._mix(eqn, in_t, "propagates mixed input")
        dn = eqn.params["dimension_numbers"]
        indices = eqn.invars[1].aval
        obd = tuple(int(d) for d in getattr(dn, "operand_batching_dims", ()))
        sibd = tuple(
            int(d) for d in getattr(dn, "scatter_indices_batching_dims", ())
        )
        uwd = tuple(int(d) for d in dn.update_window_dims)
        axes = set()
        if op_t is not None:
            axes.add(op_t.axis)  # operand axes are preserved in the output
        if idx_t is not None:
            j = idx_t.axis
            if j == len(indices.shape) - 1 or j not in sibd:
                return self._mix(
                    eqn, in_t, "sample-dependent scatter positions without "
                    "batching isolation"
                )
            axes.add(obd[sibd.index(j)])
        if upd_t is not None:
            u = upd_t.axis
            if u in uwd:
                return self._mix(
                    eqn, in_t, "sample axis inside a scattered window"
                )
            scatter_batch = [
                d
                for d in range(len(eqn.invars[2].aval.shape))
                if d not in uwd
            ]
            k = scatter_batch.index(u)  # k-th non-last indices dim
            if k in sibd:
                axes.add(obd[sibd.index(k)])
            else:
                return self._mix(
                    eqn, in_t, "sample updates at data-dependent positions"
                )
        out = self._join(eqn, in_t, axes)
        if idx_t is not None:
            # block-isolated, but which of a sample's updates survives a slot
            # collision is a value-level invariant: surface for the allowlist
            site = RoutedSite(
                summary=eqn_summary(eqn),
                taint=out,
                isolated=out is not None and not out.mixed,
            )
            self._routed.setdefault(id(eqn), site)
        return out

    def _scan(self, eqn: JaxprEqn, in_t: list, in_tap: list) -> list:
        p = eqn.params
        closed: ClosedJaxpr = p["jaxpr"]
        body = closed.jaxpr
        nc, ncar = p["num_consts"], p["num_carry"]
        n_xs = len(eqn.invars) - nc - ncar
        consts_t = in_t[:nc]
        carry_t = list(in_t[nc : nc + ncar])
        xs_t = in_t[nc + ncar :]
        xs_body_t: list = []
        for t in xs_t:
            if t is None or t.mixed:
                xs_body_t.append(t)
            elif t.axis == 0:
                xs_body_t.append(
                    self._mix(eqn, [t], "scans over the batch axis")
                )
            else:
                xs_body_t.append(Taint(t.axis - 1, t.trail))
        body_taps = list(in_tap[:nc]) + [None] * ncar + list(
            in_tap[nc + ncar :]
        )
        out_t: list = [None] * len(body.outvars)
        for _ in range(_SCAN_FIXPOINT_CAP):
            out_t, _ = self._run_jaxpr(
                body, consts_t + carry_t + xs_body_t, body_taps
            )
            new_carry = []
            changed = False
            for cur, nxt in zip(carry_t, out_t[:ncar]):
                joined = self._join_carry(cur, nxt)
                changed = changed or joined != cur
                new_carry.append(joined)
            carry_t = new_carry
            if not changed:
                break
        ys_out = []
        for t in out_t[ncar:]:
            if t is None or t.mixed:
                ys_out.append(t)
            else:
                ys_out.append(Taint(t.axis + 1, t.trail))
        del n_xs
        return carry_t + ys_out

    @staticmethod
    def _join_carry(a: Optional[Taint], b: Optional[Taint]) -> Optional[Taint]:
        if a is None:
            return b
        if b is None:
            return a
        if a.mixed:
            return a
        if b.mixed:
            return b
        if a.axis == b.axis:
            return a
        return Taint(None, a.trail + (f"carry axis conflict {a.axis}/{b.axis}",))

    def _while(self, eqn: JaxprEqn, in_t: list) -> list:
        p = eqn.params
        cn, bn = p["cond_nconsts"], p["body_nconsts"]
        body: ClosedJaxpr = p["body_jaxpr"]
        cond: ClosedJaxpr = p["cond_jaxpr"]
        body_consts = in_t[cn : cn + bn]
        carry_t = list(in_t[cn + bn :])
        for _ in range(_SCAN_FIXPOINT_CAP):
            out_t, _ = self._run_jaxpr(
                body.jaxpr, body_consts + carry_t, [None] * (bn + len(carry_t))
            )
            new_carry = [
                self._join_carry(a, b) for a, b in zip(carry_t, out_t)
            ]
            if new_carry == carry_t:
                break
            carry_t = new_carry
        pred_t, _ = self._run_jaxpr(
            cond.jaxpr,
            in_t[:cn] + carry_t,
            [None] * (cn + len(carry_t)),
        )
        if any(t is not None for t in pred_t):
            m = self._mix(eqn, in_t, "sample-dependent while trip count")
            return [m] * len(eqn.outvars)
        return carry_t

    def _cond(self, eqn: JaxprEqn, in_t: list, in_tap: list) -> list:
        branches = eqn.params["branches"]
        pred_t = in_t[0]
        op_t = in_t[1:]
        op_tap = in_tap[1:]
        if pred_t is not None:
            m = self._mix(eqn, in_t, "sample-dependent branch predicate")
            return [m] * len(eqn.outvars)
        outs: list = [None] * len(eqn.outvars)
        for br in branches:
            b_out, _ = self._run_jaxpr(br.jaxpr, list(op_t), list(op_tap))
            outs = [self._join_carry(a, b) for a, b in zip(outs, b_out)]
        return outs


_HANDLED = object()
