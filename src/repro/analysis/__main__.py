"""CLI: ``python -m repro.analysis [--arch NAME ... | --all-configs]``.

Exit status 1 iff any non-allowlisted ``error`` finding survives — warns
are reported but never fatal, allowlisted errors are downgraded to info
with their documented reason attached.  ``--out DIR`` additionally writes
``findings.jsonl`` (obs-style records, ``repro.obs.sinks`` shapes).
"""
from __future__ import annotations

import argparse
import pathlib
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr-level DP-correctness auditor + hygiene lints",
    )
    ap.add_argument(
        "--arch",
        action="append",
        default=None,
        metavar="NAME",
        help="config to audit (repeatable; fuzzy-matched like launch.train)",
    )
    ap.add_argument(
        "--all-configs",
        action="store_true",
        help="sweep every config in configs/registry.py",
    )
    ap.add_argument("--batch", type=int, default=3, help="audit batch size")
    ap.add_argument("--seq", type=int, default=16, help="audit seq length")
    ap.add_argument(
        "--full",
        action="store_true",
        help="audit full-size configs (default: .reduced())",
    )
    ap.add_argument(
        "--no-hygiene",
        action="store_true",
        help="skip the train-step hygiene pass (taint + coverage only)",
    )
    ap.add_argument(
        "--no-allowlist",
        action="store_true",
        help="report allowlisted findings at full severity",
    )
    ap.add_argument(
        "--out",
        default=None,
        metavar="DIR",
        help="also write findings.jsonl under DIR",
    )
    args = ap.parse_args(argv)

    from repro.analysis.audit import audit_arch
    from repro.analysis.hygiene import donation_lint
    from repro.analysis.report import (
        FINDINGS_FILENAME,
        counts,
        render,
        write_findings,
    )
    from repro.configs.registry import ARCHS

    if args.all_configs:
        names = sorted(ARCHS)
    elif args.arch:
        names = args.arch
    else:
        ap.error("pass --arch NAME (repeatable) or --all-configs")

    findings = donation_lint()  # arch-independent: once per invocation
    for name in names:
        print(f"auditing {name} ...", file=sys.stderr)
        findings += audit_arch(
            name,
            batch=args.batch,
            seq=args.seq,
            reduced=not args.full,
            hygiene_pass=not args.no_hygiene,
            apply_allowlist=not args.no_allowlist,
        )

    text = render(findings)
    if text:
        print(text)
    c = counts(findings)
    print(
        f"audited {len(names)} config(s): {c['error']} error(s), "
        f"{c['warn']} warn(s), {c['info']} info"
    )
    if args.out:
        path = pathlib.Path(args.out) / FINDINGS_FILENAME
        write_findings(findings, path)
        print(f"findings written to {path}")
    return 1 if c["error"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
