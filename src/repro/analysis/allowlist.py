"""Per-config waivers for known-mixed structures the auditor must not fail.

Each entry downgrades matching ``error`` findings to ``info`` (they stay in
the stream, stamped ``allowlisted_by``, so the waiver is always visible).
Matching is (config glob, finding code, subject glob) via ``fnmatch`` —
narrow on purpose: an entry is a *documented argument*, not a mute button,
and ``reason`` is required.

An entry that matches nothing in an audit yields a ``stale_allowlist``
warning: refactors that remove the waived structure must retire the waiver.

Shipped waivers
---------------
MoE expert dispatch (``src/repro/nn/moe.py``) writes tokens into a slot
table with ``.at[...].set(..., mode="drop")`` at *sample-derived* positions.
The taint pass proves the writes block-isolated per sample (jax's vmap
batching dims confine each sample to its own table), but which of a
sample's tokens survives a capacity collision depends on write order — a
value-level invariant (the per-sample cumsum occupancy counter makes slots
unique) that a type-level analysis cannot discharge.  The auditor therefore
reports ``routed_scatter`` as an error, and the three MoE configs waive it
here with exactly that argument.
"""
from __future__ import annotations

import dataclasses
import fnmatch


@dataclasses.dataclass(frozen=True)
class AllowlistEntry:
    configs: str  # fnmatch glob over config names
    code: str  # finding code this entry may waive
    subject: str  # fnmatch glob over finding subjects
    reason: str

    def matches(self, arch: str, finding) -> bool:
        return (
            finding.code == self.code
            and fnmatch.fnmatch(arch, self.configs)
            and fnmatch.fnmatch(finding.subject, self.subject)
        )


_MOE_REASON = (
    "MoE slot-table dispatch: writes are proven block-isolated per sample "
    "(vmap batching dims), but collision survival under mode='drop' rests on "
    "the per-sample cumsum occupancy invariant (slots unique within a "
    "sample), which is value-level and outside the taint lattice; reviewed "
    "in nn/moe.py"
)

ALLOWLIST: tuple[AllowlistEntry, ...] = (
    AllowlistEntry("mixtral-8x7b", "routed_scatter", "*moe.py*", _MOE_REASON),
    AllowlistEntry("arctic-480b", "routed_scatter", "*moe.py*", _MOE_REASON),
    AllowlistEntry(
        "jamba-1.5-large-398b", "routed_scatter", "*moe.py*", _MOE_REASON
    ),
)


def apply(arch: str, findings, entries=ALLOWLIST):
    """Downgrade matching errors to info; append stale-entry warnings.

    Returns (findings, used_entries).
    """
    from repro.analysis.report import Finding

    used = set()
    out = []
    for f in findings:
        entry = next(
            (e for e in entries if f.severity == "error" and e.matches(arch, f)),
            None,
        )
        if entry is None:
            out.append(f)
        else:
            used.add(entry)
            out.append(
                dataclasses.replace(
                    f, severity="info", allowlisted_by=entry.reason
                )
            )
    for e in entries:
        if e not in used and fnmatch.fnmatch(arch, e.configs):
            out.append(
                Finding(
                    code="stale_allowlist",
                    severity="warn",
                    arch=arch,
                    subject=f"{e.code}:{e.subject}",
                    detail=(
                        "allowlist entry matched no finding this audit; "
                        "retire it if the waived structure is gone"
                    ),
                )
            )
    return out, used
