"""Findings: the auditor's structured output records.

Record shape mirrors the ``repro.obs`` streams (one JSON object per line,
``kind``/severity/payload keys, written through ``repro.obs.sinks.JsonlSink``
so the append-only/torn-tail semantics and reader tooling carry over), but
findings are NOT lifecycle events — they go to ``findings.jsonl`` via their
own sink, never through the closed ``EVENT_KINDS`` taxonomy.

Severity tiers:
- ``error``  privacy violation — sample mixing at a tap, an uncovered or
             bypassed gradient path, unprovable routed writes.  Fails the
             CLI (exit 1) and therefore CI.
- ``warn``   tracing hygiene — f64 promotions, host callbacks, donation
             misses, dead params.  Reported, never fatal.
- ``info``   allowlisted errors (documented known-mixed structures) and
             notes; kept in the stream so a waiver is still visible.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.sinks import JsonlSink

SEVERITIES = ("error", "warn", "info")

# closed finding taxonomy, mirroring obs.events.EVENT_KINDS discipline
FINDING_CODES = (
    "sample_mixing",      # tap/act/loss value is sample-mixed (taint pass)
    "batch_axis_moved",   # taint survived but on the wrong axis for the tap
    "routed_scatter",     # data-dependent scatter writes (MoE slot tables)
    "unknown_primitive",  # conservative taint fallback fired (rule gap)
    "uncovered_param",    # trainable leaf reaches the loss with no tap
    "tap_bypass",         # claimed leaf has a gradient route around its tap
    "dead_param",         # leaf never reaches the loss (unclipped but inert)
    "tap_unthreaded",     # declared tap has no add eqn in the graph
    "f64_promotion",      # float64/complex128 value inside the jitted step
    "host_callback",      # host callback primitive inside the jitted step
    "donation_miss",      # jit site in the accum loop without donate_argnums
    "stale_allowlist",    # allowlist entry matched nothing this audit
)


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str
    severity: str  # "error" | "warn" | "info"
    arch: str  # config name, or "-" for arch-independent lints
    subject: str  # tap name, param path, or eqn locator
    detail: str
    provenance: tuple[str, ...] = ()  # eqn-level trail (taint pass)
    allowlisted_by: Optional[str] = None

    def __post_init__(self):
        if self.code not in FINDING_CODES:
            raise ValueError(
                f"unknown finding code {self.code!r}; add it to "
                "repro.analysis.report.FINDING_CODES"
            )
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def to_record(self) -> dict:
        rec = {
            "kind": "finding",
            "code": self.code,
            "severity": self.severity,
            "arch": self.arch,
            "subject": self.subject,
            "detail": self.detail,
        }
        if self.provenance:
            rec["provenance"] = list(self.provenance)
        if self.allowlisted_by is not None:
            rec["allowlisted_by"] = self.allowlisted_by
        return rec


FINDINGS_FILENAME = "findings.jsonl"


def write_findings(findings, path) -> None:
    """Append findings as JSONL through the obs sink (torn-tail-safe)."""
    sink = JsonlSink(path)
    try:
        for f in findings:
            sink.emit(f.to_record())
    finally:
        sink.close()


def render(findings) -> str:
    """Human summary: one line per finding, provenance indented under it."""
    lines = []
    for f in findings:
        waiver = f" [allowlisted: {f.allowlisted_by}]" if f.allowlisted_by else ""
        lines.append(
            f"{f.severity.upper():5s} {f.code:18s} {f.arch}: {f.subject} — "
            f"{f.detail}{waiver}"
        )
        for hop in f.provenance:
            lines.append(f"        ↳ {hop}")
    return "\n".join(lines)


def counts(findings) -> dict:
    out = {s: 0 for s in SEVERITIES}
    for f in findings:
        out[f.severity] += 1
    return out
