"""Gradient-path coverage: every trainable leaf's cotangent crosses a tap.

``validate_coverage`` (src/repro/core/clipping.py:166) checks the *declared*
map — each param leaf appears in some TapMeta's ``param_path``/``bias_path``.
This module checks the complement against the actual computation graph: in
the traced jaxpr, does each claimed leaf's gradient really flow through the
eqn where its tap's zero array is added, and does any unclaimed leaf reach
the loss at all?

Method: reverse liveness over the forward jaxpr.  The cotangent of a var is
nonzero only if the var (transitively) feeds the loss, so gradient paths are
exactly the data-dependence paths restricted to inexact (float/complex)
dtypes — integer/bool vars have no tangent space, which is what lets router
argmax/top_k index paths (real data dependence, zero cotangent) not count
as gradient bypasses.

Cut sets: a tap intercepts the cotangent at its add eqn's output.  When that
output has a *single* use and the use preserves cotangent determination
(add/sub — the captured dL/dw equals dL/dv; cast, transpose, reshape —
linear bijections; a scan xs operand — the per-step body cotangent), the
downstream var's cotangent is determined by the captured one too, so it
joins the cut set.  This chain is what covers recurrent late taps: xlstm
adds the tap to the scan *input stream* (``src/repro/nn/xlstm.py``) and the
true pre-activation ``s = pre_t + h @ wr`` only exists inside the scan body.

Per-claim passes are deliberate: one global all-cuts pass would let an
untapped middle layer hide behind a downstream tap's cut, so each tap's
claimed leaves are tested against that tap's cuts alone.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.analysis.taint import ClosedJaxpr, Jaxpr, JaxprEqn, Var  # noqa: F401

_CALL_PRIMS = ("pjit", "closed_call", "core_call", "xla_call")
_REMAT_PRIMS = ("remat", "remat2", "checkpoint")
_CUSTOM_PRIMS = (
    "custom_jvp_call",
    "custom_vjp_call",
    "custom_jvp_call_jaxpr",
    "custom_vjp_call_jaxpr",
)
# single-use eqns through which a captured cotangent stays determined
_CHAIN_PRIMS = frozenset(
    {"add", "sub", "convert_element_type", "transpose", "reshape"}
)


def _custom_body(eqn: JaxprEqn) -> Jaxpr:
    sub = eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr")
    return sub.jaxpr if isinstance(sub, ClosedJaxpr) else sub


def _grad_carrying(v) -> bool:
    dtype = getattr(getattr(v, "aval", None), "dtype", None)
    return dtype is not None and jnp.issubdtype(dtype, jnp.inexact)


class ForwardUses:
    """Forward-use index over a jaxpr and all sub-jaxprs.

    ``ident`` edges are var->var hops whose cotangent relation is the
    identity (call-boundary plumbing, scan xs slicing, scan ys stacking);
    ``eqn_uses`` are ordinary consuming eqns; ``stop_uses`` counts uses the
    cut chain must not cross (scan consts/carries, cond/while operands,
    loss/act outputs).
    """

    def __init__(self, jaxpr: Jaxpr):
        self.eqn_uses: dict[Var, list[JaxprEqn]] = {}
        self.ident: dict[Var, list[Var]] = {}
        self.stop_uses: dict[Var, int] = {}
        self._walk(jaxpr)
        for v in jaxpr.outvars:
            if isinstance(v, Var):
                self._stop(v)

    def _stop(self, v: Var) -> None:
        self.stop_uses[v] = self.stop_uses.get(v, 0) + 1

    def _ident(self, a, b) -> None:
        if isinstance(a, Var) and isinstance(b, Var):
            self.ident.setdefault(a, []).append(b)

    def _walk(self, jaxpr: Jaxpr) -> None:
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            if prim == "scan":
                nc = eqn.params["num_consts"]
                ncar = eqn.params["num_carry"]
                body = eqn.params["jaxpr"].jaxpr
                for pos, a in enumerate(eqn.invars):
                    if not isinstance(a, Var):
                        continue
                    if pos >= nc + ncar:
                        self._ident(a, body.invars[pos])
                    else:
                        self._stop(a)
                for i, bv in enumerate(body.outvars):
                    if not isinstance(bv, Var):
                        continue
                    if i >= ncar:
                        self._ident(bv, eqn.outvars[i])
                    else:
                        self._stop(bv)
                self._walk(body)
            elif prim in _CALL_PRIMS or prim in _REMAT_PRIMS or prim in _CUSTOM_PRIMS:
                if prim in _CALL_PRIMS:
                    body = eqn.params["jaxpr"].jaxpr
                elif prim in _REMAT_PRIMS:
                    body = eqn.params["jaxpr"]
                else:
                    body = _custom_body(eqn)
                for a, bv in zip(eqn.invars, body.invars):
                    self._ident(a, bv)
                for bv, ov in zip(body.outvars, eqn.outvars):
                    self._ident(bv, ov)
                self._walk(body)
            elif prim == "cond":
                for a in eqn.invars:
                    if isinstance(a, Var):
                        self._stop(a)
                for br in eqn.params["branches"]:
                    for bv in br.jaxpr.outvars:
                        if isinstance(bv, Var):
                            self._stop(bv)
                    self._walk(br.jaxpr)
            elif prim == "while":
                for a in eqn.invars:
                    if isinstance(a, Var):
                        self._stop(a)
                for key in ("cond_jaxpr", "body_jaxpr"):
                    body = eqn.params[key].jaxpr
                    for bv in body.outvars:
                        if isinstance(bv, Var):
                            self._stop(bv)
                    self._walk(body)
            else:
                for a in eqn.invars:
                    if isinstance(a, Var):
                        self.eqn_uses.setdefault(a, []).append(eqn)

    def extend_cuts(self, seed: Var) -> frozenset:
        """The seed plus every downstream var whose cotangent the tap
        determines (single-use chains through _CHAIN_PRIMS and ident hops)."""
        cuts = {seed}
        v = seed
        while True:
            eqns = self.eqn_uses.get(v, [])
            idents = self.ident.get(v, [])
            total = len(eqns) + len(idents) + self.stop_uses.get(v, 0)
            if total != 1:
                break
            if idents:
                v = idents[0]
                cuts.add(v)
                continue
            if not eqns:
                break
            eqn = eqns[0]
            if eqn.primitive.name not in _CHAIN_PRIMS or len(eqn.outvars) != 1:
                break
            v = eqn.outvars[0]
            cuts.add(v)
        return frozenset(cuts)


def live_invars(
    jaxpr: Jaxpr, out_live: list, cuts: frozenset
) -> list:
    """Which invars can carry a nonzero cotangent from the live outputs,
    with every var in ``cuts`` treated as an interception point."""
    live: set[Var] = set()

    def mark(v) -> None:
        if isinstance(v, Var) and v not in cuts and _grad_carrying(v):
            live.add(v)

    def mark_eqn_invars(eqn: JaxprEqn, in_live=None) -> None:
        if in_live is None:
            for a in eqn.invars:
                mark(a)
        else:
            for a, flag in zip(eqn.invars, in_live):
                if flag:
                    mark(a)

    for v, flag in zip(jaxpr.outvars, out_live):
        if flag:
            mark(v)
    for eqn in reversed(jaxpr.eqns):
        outs_live = [isinstance(v, Var) and v in live for v in eqn.outvars]
        if not any(outs_live):
            continue
        prim = eqn.primitive.name
        if prim == "scan":
            nc = eqn.params["num_consts"]
            ncar = eqn.params["num_carry"]
            body = eqn.params["jaxpr"].jaxpr
            cur_out = list(outs_live)
            while True:
                in_live = live_invars(body, cur_out, cuts)
                changed = False
                for i in range(ncar):
                    if in_live[nc + i] and not cur_out[i]:
                        cur_out[i] = True
                        changed = True
                if not changed:
                    break
            mark_eqn_invars(eqn, in_live)
        elif prim in _CALL_PRIMS:
            in_live = live_invars(eqn.params["jaxpr"].jaxpr, outs_live, cuts)
            mark_eqn_invars(eqn, in_live)
        elif prim in _REMAT_PRIMS:
            in_live = live_invars(eqn.params["jaxpr"], outs_live, cuts)
            mark_eqn_invars(eqn, in_live)
        elif prim in _CUSTOM_PRIMS:
            body = _custom_body(eqn)
            in_live = live_invars(body, outs_live, cuts)
            mark_eqn_invars(eqn, in_live)
        elif prim == "cond":
            agg = [False] * (len(eqn.invars) - 1)
            for br in eqn.params["branches"]:
                bl = live_invars(br.jaxpr, outs_live, cuts)
                agg = [a or b for a, b in zip(agg, bl)]
            mark_eqn_invars(eqn, [False] + agg)
        else:
            # includes `while` (conservative: everything feeds the carry)
            mark_eqn_invars(eqn)
    return [isinstance(v, Var) and v in live for v in jaxpr.invars]


@dataclasses.dataclass
class CoverageReport:
    """Graph-level coverage facts; the audit layer turns these into findings."""

    # tap -> claimed param paths whose gradient has a route around the tap
    bypassed: dict
    # unclaimed, non-frozen param paths that reach the loss (privacy bug)
    uncovered_live: list
    # unclaimed param paths that never reach the loss (dead weight — warn)
    uncovered_dead: list
    # taps declared in meta with no add eqn found in the graph
    unthreaded: list


def coverage_report(
    closed: ClosedJaxpr,
    param_invars: dict,
    losses_out_index: int,
    sites: list,
    meta: dict,
    frozen_prefixes: tuple = (),
) -> CoverageReport:
    """``param_invars``: param-leaf path -> top-level invar index.
    ``sites``: TapSites from the taint pass (their add-eqn outputs seed the
    cut sets).  ``meta``: tap name -> TapMeta (the declared claims).
    """
    jaxpr = closed.jaxpr
    uses = ForwardUses(jaxpr)
    out_live = [i == losses_out_index for i in range(len(jaxpr.outvars))]

    cuts_by_tap: dict = {}
    for site in sites:
        seed = site.eqn.outvars[0]
        cuts_by_tap.setdefault(site.tap, set()).update(uses.extend_cuts(seed))

    claims: dict = {}
    for name, m in meta.items():
        paths = [m.param_path] + ([m.bias_path] if m.bias_path else [])
        claims[name] = [
            p
            for p in paths
            if p in param_invars
            and not any(p.startswith(fp) for fp in frozen_prefixes)
        ]
    claimed_paths = {p for paths in claims.values() for p in paths}

    base_live = live_invars(jaxpr, out_live, frozenset())
    uncovered_live, uncovered_dead = [], []
    for path, idx in sorted(param_invars.items()):
        if path in claimed_paths:
            continue
        if any(path.startswith(fp) for fp in frozen_prefixes):
            continue
        (uncovered_live if base_live[idx] else uncovered_dead).append(path)

    bypassed: dict = {}
    unthreaded = []
    for name, paths in sorted(claims.items()):
        if name not in cuts_by_tap:
            unthreaded.append(name)
            continue
        if not paths:
            continue
        live = live_invars(jaxpr, out_live, frozenset(cuts_by_tap[name]))
        leaks = [p for p in paths if live[param_invars[p]]]
        if leaks:
            bypassed[name] = leaks
    return CoverageReport(
        bypassed=bypassed,
        uncovered_live=uncovered_live,
        uncovered_dead=uncovered_dead,
        unthreaded=unthreaded,
    )
