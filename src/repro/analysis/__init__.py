"""Static DP-correctness auditor + tracing-hygiene lints.

``python -m repro.analysis --all-configs`` sweeps ``configs/registry.py``;
see docs/ARCHITECTURE.md "Static analysis" for what each pass proves.
"""
from repro.analysis.allowlist import ALLOWLIST, AllowlistEntry
from repro.analysis.audit import audit_arch, audit_loss_fn, audit_step_hygiene
from repro.analysis.hygiene import donation_lint, jaxpr_hygiene
from repro.analysis.report import (
    FINDING_CODES,
    Finding,
    counts,
    render,
    write_findings,
)

__all__ = [
    "ALLOWLIST",
    "AllowlistEntry",
    "FINDING_CODES",
    "Finding",
    "audit_arch",
    "audit_loss_fn",
    "audit_step_hygiene",
    "counts",
    "donation_lint",
    "jaxpr_hygiene",
    "render",
    "write_findings",
]
