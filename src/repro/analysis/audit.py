"""The auditor: trace a model's clipped step and run all three passes.

``audit_loss_fn`` is the core entry — it takes the same
``loss_with_ctx(params, batch, ctx)`` contract the clipping engines consume
(src/repro/core/clipping.py), traces the *explicit-tap* formulation
(zero taps added, activations recorded — the reference engine the fused
probes are tested against), and runs:

1. the batch-axis taint pass (``repro.analysis.taint``) over the jaxpr,
   checking every tap-add site, every recorded activation, and the
   per-sample losses output for batch-diagonality;
2. the gradient-path coverage pass (``repro.analysis.coverage``) proving
   each claimed param leaf's cotangent is intercepted by its tap and each
   unclaimed leaf never reaches the loss;
3. optionally (``audit_arch``) the tracing-hygiene pass over the full
   jitted train step (``repro.analysis.hygiene``).

Audit batches deliberately use ``batch=3`` — distinct from every model
dimension in the reduced configs — so the reshape rule's prefix-product
matching can never confuse the batch axis with a feature axis of the same
size.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax

from repro.analysis import allowlist as allowlist_mod
from repro.analysis import hygiene
from repro.analysis.coverage import coverage_report
from repro.analysis.report import Finding
from repro.analysis.taint import Taint, TaintInterpreter
from repro.core.clipping import discover_meta
from repro.core.taps import Ctx, make_zero_taps


def _path_str(path) -> str:
    """jax key-path -> the "a/b/w" form used by TapMeta.param_path."""
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:  # pragma: no cover - unknown key type
            parts.append(str(k))
    return "/".join(parts)


def _flat_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [_path_str(p) for p, _ in flat]


def audit_loss_fn(
    loss_with_ctx: Callable,
    params: Any,
    batch: Any,
    *,
    arch: str = "-",
    meta: Optional[dict] = None,
    frozen_prefixes: tuple = (),
    apply_allowlist: bool = True,
    entries=None,
) -> list:
    """Taint + coverage findings for one model/batch pair."""
    if meta is None:
        meta = discover_meta(loss_with_ctx, params, batch, clip=None)
    taps0 = make_zero_taps(meta)

    def traced(p, taps, b):
        ctx = Ctx(taps=taps, meta={})
        losses = loss_with_ctx(p, b, ctx)
        return losses, ctx.acts

    closed, out_shape = jax.make_jaxpr(traced, return_shape=True)(
        params, taps0, batch
    )

    param_paths = _flat_paths(params)
    tap_names = _flat_paths(taps0)
    batch_paths = _flat_paths(batch)
    n_p, n_t = len(param_paths), len(tap_names)
    in_taints = (
        [None] * (n_p + n_t)
        + [Taint(0, (f"batch[{p}] (network input)",)) for p in batch_paths]
    )
    in_taps = [None] * n_p + list(tap_names) + [None] * len(batch_paths)

    out_flat, _ = jax.tree_util.tree_flatten_with_path(out_shape)
    losses_out_index = None
    act_out_names: dict[int, str] = {}
    for i, (path, _sds) in enumerate(out_flat):
        if getattr(path[0], "idx", None) == 0:
            losses_out_index = i
        elif len(path) > 1:
            act_out_names[i] = _path_str(path[1:])
    assert losses_out_index is not None, "loss_with_ctx returned no losses"
    batch_size = out_flat[losses_out_index][1].shape[0]

    interp = TaintInterpreter(batch_size)
    result = interp.run(closed, in_taints, in_taps)
    findings: list = []

    # -- pass 1: per-sample isolation ------------------------------------
    for site in result.sites:
        if site.taint is None:
            continue  # sample-independent pre-activation: nothing to leak
        if site.taint.mixed:
            findings.append(
                Finding(
                    code="sample_mixing",
                    severity="error",
                    arch=arch,
                    subject=site.tap,
                    detail=(
                        "pre-activation at the tap-add site is sample-mixed: "
                        "its cotangent dL/ds is not batch-diagonal, so ghost "
                        "norms are NOT the per-sample gradient norms"
                    ),
                    provenance=site.taint.trail + (f"tap add: {site.summary}",),
                )
            )
        elif site.taint.axis != 0:
            findings.append(
                Finding(
                    code="batch_axis_moved",
                    severity="error",
                    arch=arch,
                    subject=site.tap,
                    detail=(
                        f"batch axis arrived at the tap-add site on axis "
                        f"{site.taint.axis}, expected 0: per-sample reductions "
                        "would reduce the wrong dimension"
                    ),
                    provenance=site.taint.trail + (f"tap add: {site.summary}",),
                )
            )

    for i, name in act_out_names.items():
        t = result.out_taints[i]
        if t is None:
            continue
        expected = meta[name].batch_axis if name in meta else 0
        if t.mixed:
            findings.append(
                Finding(
                    code="sample_mixing",
                    severity="error",
                    arch=arch,
                    subject=f"{name}:act",
                    detail=(
                        "recorded activation is sample-mixed: the ghost-norm "
                        "Gram a_i a_j^T would pair data across samples"
                    ),
                    provenance=t.trail,
                )
            )
        elif t.axis != expected:
            findings.append(
                Finding(
                    code="batch_axis_moved",
                    severity="error",
                    arch=arch,
                    subject=f"{name}:act",
                    detail=(
                        f"recorded activation carries the batch on axis "
                        f"{t.axis}, but TapMeta (stack_dims) expects axis "
                        f"{expected}"
                    ),
                    provenance=t.trail,
                )
            )

    t_loss = result.out_taints[losses_out_index]
    if t_loss is None or t_loss.mixed or t_loss.axis != 0:
        findings.append(
            Finding(
                code="sample_mixing",
                severity="error",
                arch=arch,
                subject="losses",
                detail=(
                    "per-sample losses output is not batch-diagonal on axis 0 "
                    + (
                        "(sample-independent)"
                        if t_loss is None
                        else "(mixed)"
                        if t_loss.mixed
                        else f"(batch on axis {t_loss.axis})"
                    )
                    + ": L_i must depend on sample i only"
                ),
                provenance=() if t_loss is None else t_loss.trail,
            )
        )

    for site in result.routed:
        findings.append(
            Finding(
                code="routed_scatter",
                severity="error",
                arch=arch,
                subject=site.summary,
                detail=(
                    (
                        "sample-derived scatter positions: writes are proven "
                        "block-isolated per sample (batching dims), but "
                        "collision order-sensitivity needs a value-level "
                        "invariant the analysis cannot discharge"
                    )
                    if site.isolated
                    else (
                        "sample-derived scatter positions without batching "
                        "isolation: writes may land in other samples' blocks"
                    )
                ),
                provenance=() if site.taint is None else site.taint.trail,
            )
        )

    for prim in result.unknown_prims:
        findings.append(
            Finding(
                code="unknown_primitive",
                severity="warn",
                arch=arch,
                subject=prim,
                detail=(
                    "no taint transfer rule; outputs were conservatively "
                    "marked sample-mixed — add a rule to analysis/taint.py "
                    "if this primitive is isolation-preserving"
                ),
            )
        )

    # -- pass 2: gradient-path coverage ----------------------------------
    param_invars = {p: i for i, p in enumerate(param_paths)}
    cov = coverage_report(
        closed,
        param_invars,
        losses_out_index,
        result.sites,
        meta,
        frozen_prefixes=frozen_prefixes,
    )
    for tap, leaks in sorted(cov.bypassed.items()):
        findings.append(
            Finding(
                code="tap_bypass",
                severity="error",
                arch=arch,
                subject=tap,
                detail=(
                    "claimed param leaf(s) have a gradient route around the "
                    f"tap's cut set: {', '.join(leaks)} — their full cotangent "
                    "is not intercepted, so clipping under-counts them"
                ),
            )
        )
    for path in cov.uncovered_live:
        findings.append(
            Finding(
                code="uncovered_param",
                severity="error",
                arch=arch,
                subject=path,
                detail=(
                    "trainable param leaf reaches the loss but no tap claims "
                    "it: its gradient escapes clipping entirely (privacy bug); "
                    "declare it frozen or add a tap"
                ),
            )
        )
    for path in cov.uncovered_dead:
        findings.append(
            Finding(
                code="dead_param",
                severity="warn",
                arch=arch,
                subject=path,
                detail=(
                    "param leaf never reaches the loss: unclipped but inert "
                    "(gradient is identically zero)"
                ),
            )
        )
    for tap in cov.unthreaded:
        findings.append(
            Finding(
                code="tap_unthreaded",
                severity="error",
                arch=arch,
                subject=tap,
                detail=(
                    "tap is declared in meta but its zero array is never "
                    "added in the traced graph: its cotangent would be "
                    "identically zero and the layer's norm silently missing"
                ),
            )
        )

    if apply_allowlist:
        findings, _ = allowlist_mod.apply(
            arch,
            findings,
            entries=allowlist_mod.ALLOWLIST if entries is None else entries,
        )
    return findings


def audit_step_hygiene(model, batch, *, arch: str, batch_size: int) -> list:
    """Trace the full jitted DP train step and lint the jaxpr."""
    from repro.launch.steps import DPTrainConfig, make_train_state, make_train_step
    from repro.optim import adam, warmup_cosine

    optimizer = adam()
    state = make_train_state(model, jax.random.PRNGKey(0), optimizer)
    dp = DPTrainConfig(
        clipping_mode="mixed_ghost",
        clip_norm=1.0,
        noise_multiplier=0.5,
        logical_batch=batch_size,
    )
    step = make_train_step(model, optimizer, warmup_cosine(1e-3, 2, 10), dp)
    closed = jax.make_jaxpr(step)(state, batch)
    return hygiene.jaxpr_hygiene(closed, arch=arch)


def audit_arch(
    name: str,
    *,
    batch: int = 3,
    seq: int = 16,
    reduced: bool = True,
    hygiene_pass: bool = True,
    apply_allowlist: bool = True,
) -> list:
    """Audit one registry config end to end (taint + coverage + hygiene)."""
    from repro.configs.base import ShapeConfig
    from repro.configs.registry import build_model, get_arch
    from repro.launch.specs import materialize, train_batch_specs

    cfg = get_arch(name)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = ShapeConfig("audit", seq, batch, "train")
    b = materialize(
        train_batch_specs(cfg, shape, batch),
        jax.random.PRNGKey(1),
        vocab=cfg.vocab,
    )
    findings = audit_loss_fn(
        model.loss_with_ctx,
        params,
        b,
        arch=name,
        apply_allowlist=apply_allowlist,
    )
    if hygiene_pass:
        findings += audit_step_hygiene(model, b, arch=name, batch_size=batch)
    return findings
