"""Poisson subsampling for DP-SGD.

The RDP accountant assumes each example joins a batch independently with
probability q = B/N.  With fixed-shape batches (a jit requirement) we draw a
Bernoulli(q') inclusion mask over the B slots calibrated so the expected
contribution matches; masked samples get zero clip weight (C_i *= mask) so
they contribute nothing to the gradient — the mechanism sees exactly a
Poisson-sampled batch of random size <= B.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def poisson_sample_mask(
    key: jax.Array, batch: int, sampling_rate: float, slots_per_sample: float = 1.25
) -> jax.Array:
    """(B,) float mask; E[#included] = batch * (sampling_rate*...)/...

    Slots are over-provisioned by ``slots_per_sample`` relative to the mean so
    truncation (more sampled than slots) is vanishingly rare; the truncation
    probability is what a production deployment monitors.
    """
    q = min(1.0, sampling_rate * slots_per_sample)
    include = jax.random.bernoulli(key, q, (batch,))
    return include.astype(jnp.float32)
