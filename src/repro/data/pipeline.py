"""Host-sharded data pipeline with background prefetch.

Each host generates only its shard (process_index-keyed); a daemon thread
keeps ``prefetch`` batches ahead of the training loop.  Because batches are a
pure function of the step index, restart/elastic resume is a seek:
``pipeline.seek(step)``.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax


class DataPipeline:
    def __init__(
        self,
        batch_fn: Callable[[int, int], dict],  # (step, shard) -> batch
        *,
        start_step: int = 0,
        prefetch: int = 2,
        shard: Optional[int] = None,
    ):
        self.batch_fn = batch_fn
        self.shard = jax.process_index() if shard is None else shard
        self._step = start_step
        self._queue: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    def _worker(self):
        while not self._stop.is_set():
            with self._lock:
                step = self._step
                self._step += 1
            batch = self.batch_fn(step, self.shard)
            while not self._stop.is_set():
                try:
                    self._queue.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue

    def start(self) -> "DataPipeline":
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def seek(self, step: int) -> None:
        """Restart the stream at ``step`` (restore / elastic resume)."""
        self.stop()
        with self._lock:
            self._step = step
        self._queue = queue.Queue(maxsize=self._queue.maxsize)
        self._stop = threading.Event()
        self._thread = None
        self.start()

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        self.start()
        while True:
            yield self._queue.get()

    def next(self) -> tuple[int, dict]:
        self.start()
        return self._queue.get()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
