from repro.data.synthetic import SyntheticLMConfig, synthetic_lm_batch, synthetic_vision_batch
from repro.data.poisson import poisson_sample_mask
from repro.data.pipeline import DataPipeline

__all__ = [
    "SyntheticLMConfig",
    "synthetic_lm_batch",
    "synthetic_vision_batch",
    "poisson_sample_mask",
    "DataPipeline",
]
