"""Deterministic synthetic data with learnable structure.

The stream is a first-order Markov chain over a Zipf-ish marginal: token
t+1 = (a * t + drift) mod V with state-dependent noise.  Losses genuinely
decrease under training, which the accuracy-parity benchmark (paper Table 5's
"same accuracy" claim) and the end-to-end example rely on.

Batches are a pure function of (seed, step, host_shard) — restart/elastic
resume just recomputes the same batch for any step index.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticLMConfig:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    markov_mult: int = 31
    noise: float = 0.1


def _fold(cfg: SyntheticLMConfig, step: int, shard: int) -> jax.Array:
    key = jax.random.PRNGKey(cfg.seed)
    key = jax.random.fold_in(key, step)
    return jax.random.fold_in(key, shard)


def synthetic_lm_batch(cfg: SyntheticLMConfig, step: int, shard: int = 0) -> dict:
    """{"tokens": (B, S), "labels": (B, S), "mask": (B,)}; labels = next token."""
    key = _fold(cfg, step, shard)
    k1, k2, k3 = jax.random.split(key, 3)
    b, s, v = cfg.batch, cfg.seq_len, cfg.vocab
    start = jax.random.randint(k1, (b, 1), 0, v)
    noise = jax.random.bernoulli(k2, cfg.noise, (b, s + 1))
    rand = jax.random.randint(k3, (b, s + 1), 0, v)

    def step_fn(tok, xs):
        nz, rnd = xs
        nxt = jnp.where(nz, rnd, (tok * cfg.markov_mult + 7) % v)
        return nxt, nxt

    _, seq = jax.lax.scan(
        step_fn, start[:, 0], (noise.T, rand.T)
    )
    seq = seq.T  # (B, S+1)
    return {
        "tokens": seq[:, :-1].astype(jnp.int32),
        "labels": seq[:, 1:].astype(jnp.int32),
        "mask": jnp.ones((b,), jnp.float32),
    }


def synthetic_arch_batch(cfg, *, batch: int, seq: int, step: int = 0, shard: int = 0) -> dict:
    """Family-aware batch for an ``ArchConfig``: LM tokens plus the stub
    frontend inputs (VLM patch prefixes, audio frames) the family expects.

    Shared by launch/train.py and the tuner CLI so both profile and train on
    identically-shaped inputs.
    """
    text_len = seq - (getattr(cfg, "prefix_tokens", 0) or 0)
    b = synthetic_lm_batch(
        SyntheticLMConfig(vocab=cfg.vocab, seq_len=text_len, batch=batch), step, shard
    )
    dtype = jnp.dtype(getattr(cfg, "dtype", "float32"))
    if cfg.family == "vlm":
        key = jax.random.fold_in(jax.random.PRNGKey(77), step)
        b["prefix"] = jax.random.normal(
            key, (batch, cfg.prefix_tokens, cfg.prefix_dim), jnp.float32
        ).astype(dtype)
    if cfg.family == "audio":
        key = jax.random.fold_in(jax.random.PRNGKey(78), step)
        b["frames"] = jax.random.normal(
            key, (batch, cfg.encoder_seq, cfg.d_model), jnp.float32
        ).astype(dtype)
    return b


def synthetic_vision_batch(
    *, batch: int, image: int, channels: int, n_classes: int, step: int,
    shard: int = 0, seed: int = 0,
) -> dict:
    """Class-conditional Gaussian blobs: linearly separable enough to learn."""
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), step), shard)
    k1, k3 = jax.random.split(key, 2)
    labels = jax.random.randint(k1, (batch,), 0, n_classes)
    # class prototypes are a function of the SEED only (step-invariant),
    # otherwise the task is unlearnable
    protos = jax.random.normal(jax.random.PRNGKey(seed + 9999),
                               (n_classes, image, image, channels))
    x = protos[labels] + 0.5 * jax.random.normal(k3, (batch, image, image, channels))
    return {
        "image": x.astype(jnp.float32),
        "label": labels.astype(jnp.int32),
        "mask": jnp.ones((batch,), jnp.float32),
    }
