from repro.utils.tree import (
    tree_size,
    tree_bytes,
    tree_zeros_like,
    tree_map_with_path_str,
    flatten_dict,
    unflatten_dict,
)
from repro.utils.logging import get_logger

__all__ = [
    "tree_size",
    "tree_bytes",
    "tree_zeros_like",
    "tree_map_with_path_str",
    "flatten_dict",
    "unflatten_dict",
    "get_logger",
]
