"""Pytree utilities shared across the framework."""
from __future__ import annotations

from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp


def tree_size(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total bytes across all leaves (per leaf dtype)."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_zeros_like(tree: Any) -> Any:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path_str(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """tree_map where fn receives ("a/b/c", leaf)."""
    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_path_str(p), x), tree)


def flatten_dict(d: Mapping[str, Any], sep: str = "/", prefix: str = "") -> dict[str, Any]:
    out: dict[str, Any] = {}
    for k, v in d.items():
        key = f"{prefix}{sep}{k}" if prefix else str(k)
        if isinstance(v, Mapping):
            out.update(flatten_dict(v, sep=sep, prefix=key))
        else:
            out[key] = v
    return out


def unflatten_dict(d: Mapping[str, Any], sep: str = "/") -> dict[str, Any]:
    out: dict[str, Any] = {}
    for k, v in d.items():
        parts = k.split(sep)
        cur = out
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return out
