"""Minimal structured logging for the framework.

Two fleet-scale ergonomics live here:

* the level is re-read from ``REPRO_LOG_LEVEL`` on every ``get_logger``
  call and on ``reconfigure()`` — it is NOT frozen at the first call, so a
  supervisor (or a test) can turn debug logging on between ``--auto-restart``
  attempts without restarting the process;
* once a distributed client is initialized (``jax.process_count() > 1``),
  every record is prefixed with this process's rank (``p0]``, ``p1]``, ...)
  so interleaved multi-process output — ``tests/distributed/`` runs two
  real ranks through one terminal — stays attributable.  The rank is
  resolved lazily through ``sys.modules``: this module must stay importable
  (and silent) before jax is, because ``launch/env.py`` pins the
  environment pre-import.
"""
from __future__ import annotations

import logging
import os
import sys

_FORMAT = "%(asctime)s %(levelname).1s %(rank)s%(name)s] %(message)s"
_LEVEL_ENV = "REPRO_LOG_LEVEL"
# every name handed out, so reconfigure() can re-level the whole family
_LOGGERS: set[str] = set()


def _rank_prefix() -> str:
    """``"p<rank> "`` on a multi-process fleet, else ``""`` — no jax import."""
    jax_mod = sys.modules.get("jax")
    if jax_mod is None:
        return ""
    try:
        if jax_mod.process_count() > 1:
            return f"p{jax_mod.process_index()} "
    except Exception:  # backend not initialized yet
        return ""
    return ""


class _RankFilter(logging.Filter):
    def filter(self, record: logging.LogRecord) -> bool:
        record.rank = _rank_prefix()
        return True


def _env_level() -> str:
    return os.environ.get(_LEVEL_ENV, "INFO")


def get_logger(name: str) -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))
        handler.addFilter(_RankFilter())
        logger.addHandler(handler)
        logger.propagate = False
    logger.setLevel(_env_level())
    _LOGGERS.add(name)
    return logger


def reconfigure() -> None:
    """Re-apply ``REPRO_LOG_LEVEL`` to every logger this module handed out.

    Module-level ``log = get_logger(...)`` bindings read the env once, at
    import; callers that change the level afterwards (restart supervisors,
    tests) call this to push the new level to the whole family.
    """
    level = _env_level()
    for name in _LOGGERS:
        logging.getLogger(name).setLevel(level)
