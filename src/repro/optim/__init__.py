from repro.optim.optimizers import sgd, adam, adamw, apply_updates, Optimizer
from repro.optim.schedules import constant, warmup_cosine, warmup_linear
from repro.optim.compression import bf16_compress_with_error_feedback

__all__ = [
    "sgd",
    "adam",
    "adamw",
    "apply_updates",
    "Optimizer",
    "constant",
    "warmup_cosine",
    "warmup_linear",
    "bf16_compress_with_error_feedback",
]
