"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_linear(lr: float, warmup: int, total: int):
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        decay = jnp.maximum(0.0, (total - s) / jnp.maximum(total - warmup, 1))
        return lr * jnp.where(s < warmup, warm, decay)

    return fn


def warmup_cosine(lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * jnp.where(s < warmup, warm, cos)

    return fn
