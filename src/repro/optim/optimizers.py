"""Functional optimizers (no optax dependency).

An Optimizer is a pair of pure functions:
    init(params) -> state
    update(grads, state, params, step, lr) -> (updates, state)
Updates are ADDED to params via ``apply_updates`` (they carry the -lr sign).

DP-SGD / DP-Adam are these optimizers fed the privatized gradient (Eq. 2.1):
the mechanism lives entirely in the gradient, as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any
State = Any
Schedule = Callable[[jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], State]
    update: Callable[..., tuple[Params, State]]


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params,
        updates,
    )


def sgd(momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, step, lr):
        del params, step
        if momentum == 0.0:
            return jax.tree_util.tree_map(lambda g: -lr * g.astype(jnp.float32), grads), state
        m = jax.tree_util.tree_map(
            lambda mm, g: momentum * mm + g.astype(jnp.float32), state["m"], grads
        )
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda mm, g: -lr * (momentum * mm + g.astype(jnp.float32)), m, grads
            )
        else:
            upd = jax.tree_util.tree_map(lambda mm: -lr * mm, m)
        return upd, {"m": m}

    return Optimizer(init, update)


def adam(
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    *,
    weight_decay: float = 0.0,
    state_dtype=jnp.float32,
) -> Optimizer:
    """Adam / AdamW (decoupled weight decay when weight_decay > 0)."""

    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
        }

    def update(grads, state, params, step, lr):
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t

        def upd_mv(mm, vv, g):
            g = g.astype(jnp.float32)
            m_new = b1 * mm.astype(jnp.float32) + (1 - b1) * g
            v_new = b2 * vv.astype(jnp.float32) + (1 - b2) * g * g
            return m_new.astype(state_dtype), v_new.astype(state_dtype)

        mv = jax.tree_util.tree_map(
            upd_mv, state["m"], state["v"], grads,
            is_leaf=lambda x: isinstance(x, jax.Array),
        )
        m = jax.tree_util.tree_map(lambda x: x[0], mv, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree_util.tree_map(lambda x: x[1], mv, is_leaf=lambda x: isinstance(x, tuple))

        def upd(mm, vv, p):
            mhat = mm.astype(jnp.float32) / c1
            vhat = vv.astype(jnp.float32) / c2
            u = -lr * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"m": m, "v": v}

    return Optimizer(init, update)


def adamw(
    b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
    weight_decay: float = 0.01, state_dtype=jnp.float32,
) -> Optimizer:
    return adam(b1, b2, eps, weight_decay=weight_decay, state_dtype=state_dtype)
