"""Gradient compression with error feedback (distributed-optimization trick).

On a real fleet the cross-pod gradient all-reduce is the DCN bottleneck;
reducing in bf16 halves that traffic.  Error feedback (Karimireddy et al.
2019) keeps an fp32 residual of what compression dropped and re-injects it the
next step, preserving convergence.  DP interacts favorably: the injected
Gaussian noise floor (sigma*R per coordinate) dominates bf16 rounding error,
so compression is effectively free under DP (§Perf discusses).

Usage: wrap the gradient before the optimizer update:
    comp, ef_state = bf16_compress_with_error_feedback(grads, ef_state)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def init_error_feedback(params: Any) -> Any:
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def bf16_compress_with_error_feedback(
    grads: Any, ef_state: Optional[Any] = None
) -> tuple[Any, Any]:
    """Returns (bf16-rounded grads in fp32, new error-feedback state)."""
    if ef_state is None:
        ef_state = init_error_feedback(grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        compressed = corrected.astype(jnp.bfloat16)
        new_e = corrected - compressed.astype(jnp.float32)
        return compressed.astype(jnp.float32), new_e

    pairs = jax.tree_util.tree_map(one, grads, ef_state)
    comp = jax.tree_util.tree_map(lambda x: x[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    ef = jax.tree_util.tree_map(lambda x: x[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return comp, ef
