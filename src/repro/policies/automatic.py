"""Automatic (normalization) clipping — Bu et al., arXiv:2206.07136.

AUTO-S: ``C_i = 1 / (||g_i|| + gamma)`` — every per-sample gradient is
*normalized* rather than thresholded, which removes the R hyperparameter
entirely (R merges multiplicatively into the learning rate, so it is fixed
at 1 here).  The stability constant ``gamma > 0`` keeps small gradients
informative and yields the convergence guarantee of the paper; ``gamma = 0``
recovers AUTO-V (pure normalization).

Sensitivity: ``||C_i g_i|| = ||g_i|| / (||g_i|| + gamma) <= 1`` — the noise
is calibrated to 1 regardless of the norm distribution, which is exactly why
no R sweep is needed.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.policies.base import ClipPolicy


class AutomaticPolicy(ClipPolicy):
    name = "automatic"

    def __init__(self, gamma: float = 0.01):
        if gamma < 0:
            raise ValueError(f"gamma must be >= 0, got {gamma}")
        self.gamma = float(gamma)

    def clip_factors(
        self,
        norms: jax.Array,
        state: dict[str, jax.Array],
        *,
        path_norms2: Optional[dict[str, jax.Array]] = None,
    ) -> jax.Array:
        del state, path_norms2
        # AUTO-V (gamma == 0) guards the division; AUTO-S is smooth already
        denom = norms + self.gamma if self.gamma > 0 else jax.numpy.maximum(
            norms, 1e-12
        )
        return 1.0 / denom

    def sensitivity(self, state: dict[str, jax.Array]) -> float:
        del state
        return 1.0

    def fingerprint(self) -> str:
        return f"automatic:gamma={self.gamma:g}"
