"""Per-layer-group clipping — Stevens et al., arXiv:2202.05089 territory.

One threshold per *group* of parameters instead of one global R: group ``g``
clips its own slice of the per-sample gradient to ``R_g``, so a layer with
structurally large gradients (an lm_head, the first conv) cannot eat the
whole clipping budget of the rest of the network.

Groups are param-path prefixes (longest match wins; a ``""`` catch-all is
appended automatically so every leaf belongs to exactly one group).  The
thresholds satisfy ``sum_g R_g^2 = R^2`` (equal split by default), which
bounds one sample's total clipped contribution by

    || concat_g C_{i,g} g_{i,g} ||  <=  sqrt(sum_g R_g^2)  =  R,

so the noise calibration is exactly the global-R one and the privacy
accounting is unchanged — the policy only re-shapes *where* the budget goes.

Cost per executor family (the factors are per (group, sample)):

- book-keeping (``bk_mixed``/``bk_mixed_taps``): free — each tap's bank is
  contracted against its own group's factors, same einsums;
- vmap oracle: free — per-leaf scaling;
- second-backward modes: one extra backward *per group* (the pullback
  cotangent is per-sample, not per-param) — correct everywhere, but prefer
  the book-keeping engine when G is large.

Constraint: a tap's weight and bias share one per-sample norm, so a group
boundary must not split them (the executors validate this at trace time).

State: ``{"step": int32, "thresholds": (G,) float32}`` — checkpointed with
the train state, so custom threshold splits survive save/restore.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.functions import get_clip_fn
from repro.policies.base import ClipPolicy, GroupedFactors, group_index


class PerLayerPolicy(ClipPolicy):
    name = "per_layer"
    grouped = True

    def __init__(
        self,
        groups: Sequence[str] = (),
        clip_norm: float = 1.0,
        clip_fn: str = "abadi",
        weights: Optional[Sequence[float]] = None,
    ):
        gs = tuple(str(g) for g in groups)
        if "" not in gs:
            gs = gs + ("",)  # catch-all: every leaf belongs somewhere
        if len(set(gs)) != len(gs):
            raise ValueError(f"duplicate layer-group prefixes in {gs!r}")
        self.groups = gs
        self.clip_norm = float(clip_norm)
        self.clip_fn_name = clip_fn
        self._clip_fn = get_clip_fn(clip_fn)
        if weights is None:
            w = [1.0] * len(gs)
        else:
            w = [float(x) for x in weights]
            if len(w) != len(gs) or any(x <= 0 for x in w):
                raise ValueError(
                    f"need one positive weight per group ({len(gs)} incl. the "
                    f"catch-all), got {weights!r}"
                )
        # R_g = R * sqrt(w_g / sum(w)): sum_g R_g^2 == R^2 by construction
        z = math.sqrt(sum(w))
        self._thresholds0 = tuple(
            self.clip_norm * math.sqrt(x) / z for x in w
        )

    def init_state(self) -> dict[str, jax.Array]:
        return {
            "step": jnp.zeros((), jnp.int32),
            "thresholds": jnp.asarray(self._thresholds0, jnp.float32),
        }

    def group_of(self, path: str) -> int:
        return group_index(self.groups, path)

    def clip_factors(
        self,
        norms: jax.Array,
        state: dict[str, jax.Array],
        *,
        path_norms2: Optional[dict[str, jax.Array]] = None,
    ) -> GroupedFactors:
        if path_norms2 is None:
            raise ValueError(
                "per_layer policy needs per-path norm contributions; the "
                "executor must surface path_norms2 (grouped policies only "
                "run on modes that compute per-tap norms)"
            )
        b = norms.shape[0]
        g_norms2 = [jnp.zeros((b,), jnp.float32) for _ in self.groups]
        for path, n2 in sorted(path_norms2.items()):
            gi = self.group_of(path)
            g_norms2[gi] = g_norms2[gi] + n2.astype(jnp.float32)
        th = state["thresholds"]
        factors = jnp.stack(
            [
                self._clip_fn(jnp.sqrt(n2), th[gi])
                for gi, n2 in enumerate(g_norms2)
            ]
        )
        return GroupedFactors(groups=self.groups, factors=factors)

    def sensitivity(self, state: dict[str, jax.Array]) -> jax.Array:
        # sqrt(sum R_g^2) — equals clip_norm for the built-in splits, but
        # reading the state keeps restored custom thresholds honest
        return jnp.sqrt(jnp.sum(jnp.square(state["thresholds"])))

    def fingerprint(self) -> str:
        th = ",".join(f"{t:g}" for t in self._thresholds0)
        return (
            f"per_layer:groups={'|'.join(self.groups)},R={self.clip_norm:g},"
            f"th={th},fn={self.clip_fn_name}"
        )
