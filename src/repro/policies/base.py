"""The ClipPolicy protocol: how per-sample norms become clip factors.

The paper fixes one global threshold R and spends its machinery on computing
``||g_i||`` cheaply; the *policy* that turns those norms into clip factors is
a separate axis entirely — and the one where accuracy and usability now live
(Automatic Clipping, arXiv:2206.07136; per-layer thresholds,
arXiv:2202.05089; DP quantile-adaptive R, Andrew et al. 2021).  Every
``ClipExecutor`` mode delegates its factor stage to a ``ClipPolicy``:

    init_state()                      -> pytree of jnp scalars/vectors, the
                                         policy's trainable-adjacent state
                                         (carried through the jitted step,
                                         checkpointed with the train state)
    clip_factors(norms, state, ...)   -> (B,) factors, or GroupedFactors for
                                         per-layer-group policies
    update(state, norms, ...)         -> (new_state, PrivacyEvent) — runs
                                         once per *logical* batch; a policy
                                         that adapts from the data must pay
                                         for the release it makes, and the
                                         PrivacyEvent is that bill
    sensitivity(state)                -> the L2 bound on one sample's clipped
                                         contribution; the noise std is
                                         ``noise_multiplier * sensitivity``
    fingerprint()                     -> stable string identity, folded into
                                         the tuner ClipPlan consensus hash so
                                         a fleet cannot mix policies

State is a flat dict of jnp arrays (never empty — every policy carries at
least a ``step`` counter) so it round-trips through ``checkpoint/`` and
crosses jit boundaries as a plain pytree.  ``update`` must be jit-pure:
host-side accounting reads the *static* ``release_event()`` instead of the
traced return value.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PrivacyEvent:
    """Static description of one policy update's side release.

    ``release_sigma`` is the noise multiplier of the extra query the policy
    makes against the batch (sensitivity 1 — e.g. the quantile policy's
    noised indicator count); ``None`` means the update is data-free and
    spends nothing.  The accountant composes one such release per step
    alongside the gradient mechanism (``core.accountant.compute_epsilon``'s
    ``release_sigmas``).  This is trace-time-static by design: epsilon is
    computed on the host, never inside jit.
    """

    release_sigma: Optional[float] = None

    @property
    def spends(self) -> bool:
        return self.release_sigma is not None and self.release_sigma > 0


NO_RELEASE = PrivacyEvent()


def group_index(groups: tuple[str, ...], path: str) -> int:
    """Longest-prefix match of a param path against the group prefixes.

    ``""`` is the catch-all (matches every path); grouped policies append it
    automatically so every leaf belongs to exactly one group.
    """
    best, best_len = -1, -1
    for i, prefix in enumerate(groups):
        if path.startswith(prefix) and len(prefix) > best_len:
            best, best_len = i, len(prefix)
    if best < 0:
        raise ValueError(
            f"param path {path!r} matches no layer group in {groups!r} "
            "(add a '' catch-all prefix)"
        )
    return best


@dataclasses.dataclass
class GroupedFactors:
    """Per-layer-group clip factors: one (B,) row per group.

    The gradient stages consume these per param path (``for_path``): the
    book-keeping engines contract each tap's bank against its own group's
    factors, the second-backward engines run one pullback per group, and the
    vmap oracle scales each leaf's per-sample gradients directly.
    ``representative`` is the per-sample scalar reported in aux (the most
    aggressive factor across groups, so ``clip_frac`` metrics stay
    meaningful).
    """

    groups: tuple[str, ...]  # static prefixes, aligned with factors rows
    factors: jax.Array  # (G, B)

    def group_index(self, path: str) -> int:
        return group_index(self.groups, path)

    def for_path(self, path: str) -> jax.Array:
        return self.factors[self.group_index(path)]

    @property
    def representative(self) -> jax.Array:
        return jnp.min(self.factors, axis=0)


class ClipPolicy:
    """Base class: the fixed-R defaults every policy inherits or overrides.

    ``grouped`` policies receive ``path_norms2`` — per-param-path squared
    norm contributions (every executor mode computes them per tap anyway) —
    instead of collapsing everything into one scalar norm per sample.
    """

    name: str = "abstract"
    grouped: bool = False

    # -- state -------------------------------------------------------------
    def init_state(self) -> dict[str, jax.Array]:
        return {"step": jnp.zeros((), jnp.int32)}

    # -- factor stage -------------------------------------------------------
    def clip_factors(
        self,
        norms: jax.Array,
        state: dict[str, jax.Array],
        *,
        path_norms2: Optional[dict[str, jax.Array]] = None,
    ) -> Any:
        raise NotImplementedError

    # -- adaptation ---------------------------------------------------------
    def update(
        self,
        state: dict[str, jax.Array],
        norms: jax.Array,
        *,
        key: Optional[jax.Array] = None,
        mask: Optional[jax.Array] = None,
    ) -> tuple[dict[str, jax.Array], PrivacyEvent]:
        """Default: data-free no-op (step counter only).  jit-pure."""
        del norms, key, mask
        return {**state, "step": state["step"] + 1}, NO_RELEASE

    def release_event(self) -> PrivacyEvent:
        """The static per-step privacy bill of ``update`` (host-side)."""
        return NO_RELEASE

    # -- noise calibration ---------------------------------------------------
    def sensitivity(self, state: dict[str, jax.Array]) -> Any:
        """L2 bound on one sample's clipped contribution (scalar, traceable)."""
        raise NotImplementedError

    # -- identity ------------------------------------------------------------
    def fingerprint(self) -> str:
        """Stable identity folded into ClipPlan consensus (fleet gate)."""
        raise NotImplementedError

    def __repr__(self) -> str:  # logs/debugging
        return f"<ClipPolicy {self.fingerprint()}>"
