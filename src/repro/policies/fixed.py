"""Fixed-threshold policy: the paper's flat R, extracted from the executor.

This is byte-for-byte today's behavior — ``clip_fn(||g_i||, R)`` with a
static threshold — expressed as a ``ClipPolicy`` so the factor stage has one
seam for every policy.  The default when ``ClipConfig.policy`` is unset.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.functions import get_clip_fn
from repro.policies.base import ClipPolicy


class FixedPolicy(ClipPolicy):
    name = "fixed"

    def __init__(self, clip_norm: float = 1.0, clip_fn: str = "abadi"):
        self.clip_norm = float(clip_norm)
        self.clip_fn_name = clip_fn
        self._clip_fn = get_clip_fn(clip_fn)

    def clip_factors(
        self,
        norms: jax.Array,
        state: dict[str, jax.Array],
        *,
        path_norms2: Optional[dict[str, jax.Array]] = None,
    ) -> jax.Array:
        del state, path_norms2
        return self._clip_fn(norms, self.clip_norm)

    def sensitivity(self, state: dict[str, jax.Array]) -> float:
        del state
        return self.clip_norm

    def fingerprint(self) -> str:
        return f"fixed:R={self.clip_norm:g},fn={self.clip_fn_name}"
