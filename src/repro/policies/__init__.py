"""repro.policies — pluggable clipping policies on the ClipExecutor pipeline.

The norms machinery (ghost / instantiation / book-keeping) answers "what is
``||g_i||``, cheaply"; a policy answers "what do we do with it".  Four ship:

- ``fixed``      the paper's flat R (the default; extracted, not changed)
- ``automatic``  AUTO-S/AUTO-V normalization (arXiv:2206.07136) — no R
- ``quantile``   DP-adaptive R tracking a target norm quantile, paying for
                 its noised indicator release in the accountant
- ``per_layer``  per-param-prefix-group thresholds with sum R_g^2 = R^2

Select with ``make_policy(name, **kwargs)`` (kwargs filtered per policy) or
construct directly.  ``ClipConfig.policy`` / ``PrivacyEngine(clip_policy=)``
/ ``launch.train --clip-policy`` thread a policy end to end.
"""
from __future__ import annotations

import inspect
from typing import Any

from repro.policies.automatic import AutomaticPolicy
from repro.policies.base import (
    NO_RELEASE,
    ClipPolicy,
    GroupedFactors,
    PrivacyEvent,
    group_index,
)
from repro.policies.fixed import FixedPolicy
from repro.policies.per_layer import PerLayerPolicy
from repro.policies.quantile import QuantilePolicy

POLICIES: dict[str, type] = {
    "fixed": FixedPolicy,
    "automatic": AutomaticPolicy,
    "quantile": QuantilePolicy,
    "per_layer": PerLayerPolicy,
}


def make_policy(name: str, **kwargs: Any) -> ClipPolicy:
    """Build a policy by name, keeping only the kwargs its __init__ takes.

    One call site (the CLI) holds the union of every policy's knobs; the
    filter means adding a knob to one policy never breaks constructing the
    others.
    """
    try:
        cls = POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown clip policy {name!r}; have {sorted(POLICIES)}"
        ) from None
    accepted = set(inspect.signature(cls.__init__).parameters) - {"self"}
    return cls(**{k: v for k, v in kwargs.items() if k in accepted})


__all__ = [
    "ClipPolicy",
    "PrivacyEvent",
    "NO_RELEASE",
    "GroupedFactors",
    "group_index",
    "FixedPolicy",
    "AutomaticPolicy",
    "QuantilePolicy",
    "PerLayerPolicy",
    "POLICIES",
    "make_policy",
]
