"""DP quantile-adaptive clipping — Andrew et al. 2021 (arXiv:1905.03871).

Tracks a target quantile of the per-sample norm distribution instead of
fixing R: each logical step releases the *noised* fraction of samples whose
norm fell below the current threshold,

    b_t = ( sum_i mask_i * I[||g_i|| <= R_t]  +  sigma_b * N(0,1) ) / B,

and updates the threshold geometrically toward the target quantile ``q``::

    R_{t+1} = R_t * exp(-lr * (b_t - q))

The indicator count has L2 sensitivity 1 (one sample flips one indicator),
so the release is a Poisson-subsampled Gaussian mechanism with noise
multiplier ``sigma_b`` — composed into the accountant *per step* alongside
the gradient mechanism (``PrivacyEvent(release_sigma=sigma_b)``; see
``core.accountant.compute_epsilon``'s ``release_sigmas``).  R itself stays
public because it is a function of noised releases only.

``release_sigma = 0`` disables the noise (and the spend): useful for tests
and non-private threshold tuning, but NOT differentially private — the
engine will account zero extra cost for it.

State: ``{"step": int32, "clip_norm": float32 scalar}`` — carried through
the jitted train step and checkpointed, so adaptation survives preemption
bit-identically.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.functions import get_clip_fn
from repro.policies.base import NO_RELEASE, ClipPolicy, PrivacyEvent


class QuantilePolicy(ClipPolicy):
    name = "quantile"

    def __init__(
        self,
        target_quantile: float = 0.5,
        lr: float = 0.2,
        release_sigma: float = 1.0,
        init_clip_norm: float = 1.0,
        clip_fn: str = "abadi",
    ):
        if not 0.0 < target_quantile < 1.0:
            raise ValueError(f"target_quantile must be in (0, 1), got {target_quantile}")
        if release_sigma < 0:
            raise ValueError(f"release_sigma must be >= 0, got {release_sigma}")
        self.target_quantile = float(target_quantile)
        self.lr = float(lr)
        self.release_sigma = float(release_sigma)
        self.init_clip_norm = float(init_clip_norm)
        self.clip_fn_name = clip_fn
        self._clip_fn = get_clip_fn(clip_fn)

    def init_state(self) -> dict[str, jax.Array]:
        return {
            "step": jnp.zeros((), jnp.int32),
            "clip_norm": jnp.asarray(self.init_clip_norm, jnp.float32),
        }

    def clip_factors(
        self,
        norms: jax.Array,
        state: dict[str, jax.Array],
        *,
        path_norms2: Optional[dict[str, jax.Array]] = None,
    ) -> jax.Array:
        del path_norms2
        return self._clip_fn(norms, state["clip_norm"])

    def update(
        self,
        state: dict[str, jax.Array],
        norms: jax.Array,
        *,
        key: Optional[jax.Array] = None,
        mask: Optional[jax.Array] = None,
    ) -> tuple[dict[str, jax.Array], PrivacyEvent]:
        r = state["clip_norm"]
        below = (norms.astype(jnp.float32) <= r).astype(jnp.float32)
        if mask is not None:
            below = below * mask.astype(jnp.float32)
        count = jnp.sum(below)
        if self.release_sigma > 0:
            if key is None:
                raise ValueError(
                    "quantile policy with release_sigma > 0 needs an rng key "
                    "for the noised indicator release"
                )
            count = count + self.release_sigma * jax.random.normal(key, ())
        # the denominator must be data-independent: the static physical batch
        # size, not the (private) count of unmasked samples
        b_t = count / norms.shape[0]
        new_r = r * jnp.exp(-self.lr * (b_t - self.target_quantile))
        new_state = {"step": state["step"] + 1, "clip_norm": new_r}
        return new_state, self.release_event()

    def release_event(self) -> PrivacyEvent:
        if self.release_sigma > 0:
            return PrivacyEvent(release_sigma=self.release_sigma)
        return NO_RELEASE

    def sensitivity(self, state: dict[str, jax.Array]) -> jax.Array:
        return state["clip_norm"]

    def fingerprint(self) -> str:
        return (
            f"quantile:q={self.target_quantile:g},lr={self.lr:g},"
            f"sigma={self.release_sigma:g},R0={self.init_clip_norm:g},"
            f"fn={self.clip_fn_name}"
        )
