#!/usr/bin/env python3
"""Quantile-policy training smoke (CI tier-1): adaptation + accounting.

Two short CLI runs with opposite quantile targets must pull the threshold R
in opposite directions (target 0.9 ends above target 0.1 — no assumption
about the norm distribution beyond it being non-degenerate), and the engine
must bill the noised indicator release: epsilon under the quantile policy
strictly exceeds the fixed-policy epsilon at the same sigma, and matches
the manual RDP composition of {gradient mechanism + release}.

Run from the repo root (scripts/tier1.sh does): PYTHONPATH=src expected.
"""
from __future__ import annotations

import os
import sys
import tempfile

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402


def final_clip_norm(ckpt_dir: str) -> float:
    from repro.checkpoint import latest_step

    step = latest_step(ckpt_dir)
    with np.load(os.path.join(ckpt_dir, f"step_{step}.npz")) as z:
        return float(z["policy/clip_norm"])


def main() -> int:
    from repro.launch.train import main as train_main

    steps, r0 = 6, 1.0
    finals = {}
    for q in (0.1, 0.9):
        with tempfile.TemporaryDirectory() as d:
            argv = [
                "--arch", "yi-6b", "--reduced", "--steps", str(steps),
                "--batch", "4", "--seq", "16", "--log-every", str(steps),
                "--clip-policy", "quantile", "--clip-quantile", str(q),
                "--clip-norm", str(r0), "--quantile-sigma", "0.5",
                "--ckpt-dir", d, "--ckpt-every", str(steps),
            ]
            assert train_main(argv) == 0, f"train run failed (q={q})"
            finals[q] = final_clip_norm(d)
    print(f"R0={r0} -> R(q=0.1)={finals[0.1]:.4f}, R(q=0.9)={finals[0.9]:.4f}")
    assert finals[0.9] > finals[0.1], (
        "quantile targets did not order the adapted thresholds: "
        f"{finals} — R is not tracking the norm quantile"
    )
    assert finals[0.1] != r0 and finals[0.9] != r0, (
        f"thresholds never moved from init {r0}: {finals}"
    )

    # accounting: the quantile release must be billed, and exactly once per
    # step at the release sigma — cross-check against manual composition
    from repro.core.accountant import (
        DEFAULT_ALPHAS,
        eps_from_rdp,
        rdp_subsampled_gaussian,
    )
    from repro.core.engine import PrivacyEngine
    from repro.policies import QuantilePolicy

    def dummy_loss(params, batch, ctx):  # accounting-only engine
        raise NotImplementedError

    kw = dict(loss_with_ctx=dummy_loss, batch_size=4, sample_size=10_000,
              steps=steps, max_grad_norm=r0, noise_multiplier=1.1)
    sigma_b = 0.5
    eng_q = PrivacyEngine(
        **kw, clip_policy=QuantilePolicy(release_sigma=sigma_b)
    )
    eng_f = PrivacyEngine(**kw)
    eps_q, delta = eng_q.privacy_spent(steps=steps)
    eps_f, _ = eng_f.privacy_spent(steps=steps)
    q_rate = eng_q.sampling_rate
    rdp = steps * (
        rdp_subsampled_gaussian(q_rate, 1.1, DEFAULT_ALPHAS)
        + rdp_subsampled_gaussian(q_rate, sigma_b, DEFAULT_ALPHAS)
    )
    eps_manual = eps_from_rdp(rdp, DEFAULT_ALPHAS, delta)[0]
    print(f"eps fixed={eps_f:.4f} quantile={eps_q:.4f} manual={eps_manual:.4f}")
    assert eps_q > eps_f, "quantile release cost missing from epsilon"
    assert abs(eps_q - eps_manual) < 1e-9, (
        f"epsilon {eps_q} != manual composition {eps_manual}"
    )
    print("policy smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
