#!/usr/bin/env python
"""Kernel-dispatch parity lint (CI docs/lint job, no jax required).

Asserts, by AST inspection only, that the kernel surface stays coherent:

1. ``KERNEL_OPS`` in ``src/repro/tuner/plan.py`` (the tuner/plan view,
   deliberately duplicated so plan validation stays free of kernel
   imports) matches ``OPS`` in ``src/repro/kernels/dispatch.py`` — and
   ``KERNEL_IMPLS`` matches ``IMPLS``.
2. Every op in ``OPS`` is dispatched somewhere in ``dispatch.py`` with BOTH
   impls structurally present: an ``if resolve("<op>", ...) == "pallas"``
   branch that imports/calls a ``*_pallas`` kernel, and a fallback return
   outside that branch (the XLA path).
3. Every op has at least one interpret-mode parity test: some
   ``tests/test_*.py`` mentions the op name and ``interpret`` (the Pallas
   kernels only run off-TPU through the interpreter, so a parity test that
   never says ``interpret`` cannot be exercising the Pallas side in CI).

Run:  python scripts/check_kernel_parity.py
"""
from __future__ import annotations

import ast
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
DISPATCH = ROOT / "src" / "repro" / "kernels" / "dispatch.py"
PLAN = ROOT / "src" / "repro" / "tuner" / "plan.py"
TESTS = ROOT / "tests"


def module_tuple(path: pathlib.Path, name: str) -> tuple:
    """A module-level ``NAME = ("a", "b", ...)`` literal, by AST."""
    tree = ast.parse(path.read_text(encoding="utf-8"))
    for node in tree.body:
        if (
            isinstance(node, ast.Assign)
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and node.targets[0].id == name
        ):
            return tuple(ast.literal_eval(node.value))
    raise AssertionError(f"{path}: no module-level tuple {name!r}")


def _resolve_op(test: ast.expr):
    """The op literal in a ``resolve("<op>", ...) == "pallas"`` test, which
    may be wrapped in a BoolOp (flash_attention adds ``and pallas_ok``)."""
    for node in ast.walk(test):
        if not isinstance(node, ast.Compare):
            continue
        call = node.left
        if (
            isinstance(call, ast.Call)
            and isinstance(call.func, ast.Name)
            and call.func.id == "resolve"
            and call.args
            and isinstance(call.args[0], ast.Constant)
            and any(
                isinstance(c, ast.Constant) and c.value == "pallas"
                for c in node.comparators
            )
        ):
            return call.args[0].value
    return None


def _mentions_pallas(body: list) -> bool:
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.ImportFrom) and any(
                alias.name.endswith("_pallas") for alias in node.names
            ):
                return True
            if isinstance(node, ast.Name) and node.id.endswith("_pallas"):
                return True
    return False


def dispatch_coverage(path: pathlib.Path) -> dict:
    """op -> {"pallas": bool, "xla": bool} from dispatch.py's structure."""
    tree = ast.parse(path.read_text(encoding="utf-8"))
    cov: dict = {}
    for func in tree.body:
        if not isinstance(func, ast.FunctionDef):
            continue
        for node in ast.walk(func):
            if not isinstance(node, ast.If):
                continue
            op = _resolve_op(node.test)
            if op is None:
                continue
            entry = cov.setdefault(op, {"pallas": False, "xla": False})
            if _mentions_pallas(node.body):
                entry["pallas"] = True
            # the XLA path: a return in the function outside this If's body
            in_branch = {id(n) for stmt in node.body for n in ast.walk(stmt)}
            for n in ast.walk(func):
                if isinstance(n, ast.Return) and id(n) not in in_branch:
                    entry["xla"] = True
                    break
    return cov


def parity_test_files(ops) -> dict:
    """op -> test files mentioning the op AND interpret-mode execution."""
    hits: dict = {op: [] for op in ops}
    for path in sorted(TESTS.rglob("test_*.py")):
        text = path.read_text(encoding="utf-8")
        if "interpret" not in text:
            continue
        for op in ops:
            if op in text:
                hits[op].append(path.relative_to(ROOT))
    return hits


def main() -> int:
    failures = []

    kernel_ops = module_tuple(PLAN, "KERNEL_OPS")
    dispatch_ops = module_tuple(DISPATCH, "OPS")
    if kernel_ops != dispatch_ops:
        failures.append(
            f"tuner/plan.py KERNEL_OPS {kernel_ops} != kernels/dispatch.py "
            f"OPS {dispatch_ops}"
        )
    kernel_impls = module_tuple(PLAN, "KERNEL_IMPLS")
    dispatch_impls = module_tuple(DISPATCH, "IMPLS")
    if kernel_impls != dispatch_impls:
        failures.append(
            f"tuner/plan.py KERNEL_IMPLS {kernel_impls} != kernels/"
            f"dispatch.py IMPLS {dispatch_impls}"
        )

    cov = dispatch_coverage(DISPATCH)
    for op in dispatch_ops:
        entry = cov.get(op)
        if entry is None:
            failures.append(
                f"dispatch.py never dispatches {op!r} "
                "(no resolve(...) == 'pallas' branch found)"
            )
            continue
        if not entry["pallas"]:
            failures.append(
                f"dispatch.py {op!r}: pallas branch imports/calls no "
                "*_pallas kernel"
            )
        if not entry["xla"]:
            failures.append(
                f"dispatch.py {op!r}: no XLA fallback return outside the "
                "pallas branch"
            )
    for op in cov:
        if op not in dispatch_ops:
            failures.append(
                f"dispatch.py dispatches unknown op {op!r} (not in OPS)"
            )

    hits = parity_test_files(dispatch_ops)
    for op, files in hits.items():
        if not files:
            failures.append(
                f"no interpret-mode parity test references op {op!r} "
                "(expected some tests/test_*.py mentioning both the op and "
                "'interpret')"
            )

    if failures:
        for f in failures:
            print(f"check_kernel_parity: FAIL: {f}")
        return 1
    print(
        f"check_kernel_parity: OK — {len(dispatch_ops)} ops, both impls "
        "dispatched, parity tests present: "
        + ", ".join(f"{op} ({len(hits[op])} file(s))" for op in dispatch_ops)
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
