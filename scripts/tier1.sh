#!/usr/bin/env bash
# Tier-1 smoke gate: the full pytest suite plus a fast benchmark pass that
# exercises the complexity model (table1), the Eq-4.1 decision (table3), the
# kernel-dispatch hot ops per impl (kernels -> BENCH_kernels.json), the
# mode trajectory non_private / mixed_ghost / fused bk_mixed (modes ->
# BENCH_modes.json), the clipping-policy trajectory (policies ->
# BENCH_policies.json), and the continuous-batching serving engine under
# load (decode -> BENCH_decode.json), then a quantile-policy training
# smoke (R adapts toward the target, epsilon includes the release cost).
#
# Bench artifacts are copied into benchmarks/history/ stamped with the git
# SHA, so the perf trajectory accumulates in-repo — commit them with the PR.
#
#   bash scripts/tier1.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# pin allocator + XLA flags so archived step times are comparable run-to-run
source scripts/launch_env.sh

python -m pytest -x -q --ignore=tests/distributed
# the live 2-process jax.distributed fleet (real coordination-service
# gathers) runs isolated with its own hard timeout: a wedged collective
# must fail the gate, never hang it
timeout "${DIST_SUITE_TIMEOUT:-600}" python -m pytest -q tests/distributed
python -m benchmarks.run --fast --only table1,table3,kernels,modes,policies,decode --out-dir "${BENCH_OUT:-.}"
python scripts/check_docs_links.py
python scripts/check_kernel_parity.py
python scripts/policy_smoke.py

# static DP-correctness audit: every shipped config's traced step must be
# free of sample mixing / uncovered gradient paths (errors fail the gate;
# the documented MoE routed-scatter waivers surface as info)
python -m repro.analysis --all-configs

# style gate runs when ruff is available (CI installs it; local dev boxes
# without it skip rather than fail)
if command -v ruff >/dev/null 2>&1; then
  ruff check src scripts
else
  echo "# ruff not installed; skipping style gate (CI runs it)" >&2
fi

# observability smoke: a short instrumented run must leave a readable
# events/metrics stream with a non-empty epsilon trajectory, and the
# dashboard must surface the observed step-time percentiles
OBS_DIR="${OBS_DIR:-$(mktemp -d)}"
python -m repro.launch.train --arch yi-6b --reduced --seq 16 --steps 3 \
  --batch 2 --log-every 1 --obs-dir "$OBS_DIR"
python -m repro.obs "$OBS_DIR" --require-epsilon
python scripts/bench_dashboard.py --obs-run "$OBS_DIR"

# accumulate the perf trajectory in-repo (SHA-stamped; commit with the PR)
sha="$(git rev-parse --short HEAD 2>/dev/null || echo nogit)"
mkdir -p benchmarks/history
for f in BENCH_modes.json BENCH_policies.json BENCH_kernels.json BENCH_decode.json; do
  if [ -f "${BENCH_OUT:-.}/$f" ]; then
    cp "${BENCH_OUT:-.}/$f" "benchmarks/history/${sha}-$f"
    echo "# archived benchmarks/history/${sha}-$f" >&2
  fi
done

# fold the history dir into the markdown trend dashboard (commit with the PR)
python scripts/bench_dashboard.py

# step-time floor gate: fail when this run's archived rows regressed any
# same-host step time beyond the budget (waive intentional trade-offs with
# BENCH_STEP_TIME_WAIVER=<reason>)
python scripts/bench_dashboard.py --check-step-time "${BENCH_STEP_TIME_PCT:-20}"
