#!/usr/bin/env bash
# Tier-1 smoke gate: the full pytest suite plus a fast benchmark pass that
# exercises the complexity model (table1), the Eq-4.1 decision (table3), and
# the mode trajectory non_private / mixed_ghost / fused bk_mixed (modes ->
# BENCH_modes.json).
#
#   bash scripts/tier1.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python -m pytest -x -q
python -m benchmarks.run --fast --only table1,table3,modes --out-dir "${BENCH_OUT:-.}"
python scripts/check_docs_links.py
