#!/usr/bin/env bash
# Tier-1 smoke gate: the full pytest suite plus a fast benchmark pass that
# exercises the complexity model (table1) and the Eq-4.1 decision (table3).
#
#   bash scripts/tier1.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python -m pytest -x -q
python -m benchmarks.run --fast --only table1,table3 --out-dir "${BENCH_OUT:-.}"
