#!/usr/bin/env python3
"""Fail on broken intra-repo references in docs/*.md and README.md.

Two kinds of reference are checked, both relative to the repo root (or to
the doc's own directory, whichever resolves):

1. markdown links ``[text](target)`` whose target is not an URL or a pure
   in-page anchor — the target file (or directory) must exist;
2. backtick code anchors `` `path/to/file.py:123` `` (the docs' file:line
   claim style) — the file must exist AND have at least that many lines, so
   a refactor that moves an anchored claim fails CI instead of silently
   pointing documentation at unrelated code.

Exit status: 0 when every reference resolves, 1 otherwise (one line per
broken reference).  No dependencies beyond the stdlib; runs as the tier-1
``docs`` CI job (.github/workflows/tier1.yml) and from scripts/tier1.sh.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `src/repro/core/ghost.py:123` or `tests/test_tuner.py:43-58` inside backticks
FILE_LINE = re.compile(r"`([A-Za-z0-9_./-]+\.[A-Za-z0-9]+):(\d+)(?:-(\d+))?`")


def _line_count(path: Path, cache: dict) -> int:
    if path not in cache:
        cache[path] = sum(1 for _ in path.open(encoding="utf-8"))
    return cache[path]


def check_file(doc: Path, cache: dict) -> list[str]:
    errors = []
    text = doc.read_text(encoding="utf-8")
    rel = doc.relative_to(REPO)

    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path_part = target.split("#", 1)[0]
        if not path_part:
            continue
        candidates = [REPO / path_part, doc.parent / path_part]
        if not any(c.exists() for c in candidates):
            errors.append(f"{rel}: broken link target {target!r}")

    for m in FILE_LINE.finditer(text):
        path_part, lo, hi = m.group(1), int(m.group(2)), m.group(3)
        candidates = [REPO / path_part, doc.parent / path_part]
        hit = next((c for c in candidates if c.is_file()), None)
        if hit is None:
            errors.append(f"{rel}: file:line anchor to missing file {path_part!r}")
            continue
        last = int(hi) if hi else lo
        n = _line_count(hit, cache)
        if last > n:
            errors.append(
                f"{rel}: anchor {path_part}:{m.group(2)}"
                f"{'-' + hi if hi else ''} beyond end of file ({n} lines)"
            )
    return errors


def main() -> int:
    docs = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]
    docs = [d for d in docs if d.exists()]
    cache: dict = {}
    errors = []
    for doc in docs:
        errors.extend(check_file(doc, cache))
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print(f"checked {len(docs)} doc(s): "
          f"{'OK' if not errors else f'{len(errors)} broken reference(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
