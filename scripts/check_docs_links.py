#!/usr/bin/env python3
"""Fail on broken intra-repo references in docs/*.md and README.md.

Two kinds of reference are checked, both relative to the repo root (or to
the doc's own directory, whichever resolves):

1. markdown links ``[text](target)`` whose target is not an URL or a pure
   in-page anchor — the target file (or directory) must exist;
2. backtick code anchors `` `path/to/file.py:123` `` (the docs' file:line
   claim style) — the file must exist AND have at least that many lines, so
   a refactor that moves an anchored claim fails CI instead of silently
   pointing documentation at unrelated code;
3. symbol proximity: when an anchor is annotated with a backticked symbol
   nearby (the docs' ``` `discover_meta` (`src/.../clipping.py:125`) ```
   convention, in any of its orderings), at least one nearby symbol must
   appear within +/-5 lines of the cited line — a refactor that *shifts*
   an anchored function without moving the anchor now fails CI too,
   instead of silently pointing at whatever code slid into that line.

Exit status: 0 when every reference resolves, 1 otherwise (one line per
broken reference).  No dependencies beyond the stdlib; runs as the tier-1
``docs`` CI job (.github/workflows/tier1.yml) and from scripts/tier1.sh.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `src/repro/core/ghost.py:123` or `tests/test_tuner.py:43-58` inside backticks
FILE_LINE = re.compile(r"`([A-Za-z0-9_./-]+\.[A-Za-z0-9]+):(\d+)(?:-(\d+))?`")
# a backticked identifier-ish token (dotted names and trailing () allowed):
# the symbol half of an annotated anchor
SYMBOL = re.compile(r"`([A-Za-z_][A-Za-z0-9_.]*)(?:\(\))?`")
# how far around an anchor to look for its symbol annotation (characters),
# and how far around the cited line the symbol must appear (lines)
SYMBOL_BEFORE_CHARS = 150
SYMBOL_AFTER_CHARS = 60
SYMBOL_LINE_WINDOW = 5


def _line_count(path: Path, cache: dict) -> int:
    return len(_lines(path, cache))


def _lines(path: Path, cache: dict) -> list[str]:
    if path not in cache:
        cache[path] = path.read_text(encoding="utf-8").splitlines()
    return cache[path]


def _nearby_symbols(text: str, start: int, end: int) -> list[str]:
    """Backticked identifiers around an anchor (its candidate annotations).

    Path-like and line-anchor tokens are excluded; the remaining tokens are
    the symbols the surrounding prose claims live at the cited line.
    """
    before = text[max(0, start - SYMBOL_BEFORE_CHARS):start]
    after = text[end:end + SYMBOL_AFTER_CHARS]
    out = []
    for m in SYMBOL.finditer(before + " " + after):
        tok = m.group(1)
        parts = tok.split(".")
        if parts[-1] in ("py", "md", "sh", "json", "yml", "txt", "jsonc"):
            continue  # a bare filename, not a symbol
        # dotted tokens contribute every component (`RankReport.policy`:
        # the class line OR the attribute may sit at the cited line);
        # slashed paths never match the SYMBOL regex
        out.extend(p for p in parts if p)
    return out


def _symbol_near_line(
    symbols: list[str], lines: list[str], lo: int, hi: int
) -> bool:
    w0 = max(0, lo - 1 - SYMBOL_LINE_WINDOW)
    w1 = min(len(lines), hi + SYMBOL_LINE_WINDOW)
    window = "\n".join(lines[w0:w1])
    return any(s in window for s in symbols)


def check_file(doc: Path, cache: dict) -> list[str]:
    errors = []
    text = doc.read_text(encoding="utf-8")
    rel = doc.relative_to(REPO)

    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path_part = target.split("#", 1)[0]
        if not path_part:
            continue
        candidates = [REPO / path_part, doc.parent / path_part]
        if not any(c.exists() for c in candidates):
            errors.append(f"{rel}: broken link target {target!r}")

    for m in FILE_LINE.finditer(text):
        path_part, lo, hi = m.group(1), int(m.group(2)), m.group(3)
        candidates = [REPO / path_part, doc.parent / path_part]
        hit = next((c for c in candidates if c.is_file()), None)
        if hit is None:
            errors.append(f"{rel}: file:line anchor to missing file {path_part!r}")
            continue
        last = int(hi) if hi else lo
        n = _line_count(hit, cache)
        if last > n:
            errors.append(
                f"{rel}: anchor {path_part}:{m.group(2)}"
                f"{'-' + hi if hi else ''} beyond end of file ({n} lines)"
            )
            continue
        symbols = _nearby_symbols(text, m.start(), m.end())
        if symbols and not _symbol_near_line(
            symbols, _lines(hit, cache), lo, last
        ):
            errors.append(
                f"{rel}: anchor {path_part}:{m.group(2)} — none of the "
                f"annotated symbol(s) {sorted(set(symbols))} appear within "
                f"+/-{SYMBOL_LINE_WINDOW} lines of the cited line; the "
                "anchor drifted after a refactor"
            )
    return errors


def main() -> int:
    docs = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]
    docs = [d for d in docs if d.exists()]
    cache: dict = {}
    errors = []
    for doc in docs:
        errors.extend(check_file(doc, cache))
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print(f"checked {len(docs)} doc(s): "
          f"{'OK' if not errors else f'{len(errors)} broken reference(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
