"""Fill EXPERIMENTS.md placeholders from the dry-run artifacts."""
from __future__ import annotations

import io
import pathlib
import sys
from contextlib import redirect_stdout

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks import roofline  # noqa: E402

EXP = pathlib.Path("EXPERIMENTS.md")


def capture_tables() -> str:
    buf = io.StringIO()
    with redirect_stdout(buf):
        roofline.run("single")
        roofline.run("multi")
    return buf.getvalue()


def dryrun_summary() -> str:
    lines = []
    for mesh in ("single", "multi"):
        cells = roofline.load_cells(roofline.RESULTS, mesh)
        ok = sum(1 for m in cells.values() if m["status"] == "ok")
        sk = sum(1 for m in cells.values() if m["status"] == "skipped")
        er = len(cells) - ok - sk
        fits = sum(
            1 for m in cells.values()
            if m["status"] == "ok"
            and m["roofline"]["memory_stats"]["peak_bytes_estimate"] <= 16e9
        )
        lines.append(
            f"- **{mesh}-pod mesh**: {ok} compiled / {sk} documented skips / "
            f"{er} errors (of {len(cells)} cells); {fits}/{ok} compiled cells "
            f"fit the 16 GB v5e HBM budget as a single physical batch "
            f"(the rest use gradient accumulation — §Perf)."
        )
    return "\n".join(lines)


def perf_summary() -> str:
    rows = [
        "| cell | metric | paper-faithful baseline | optimized | gain |",
        "|---|---|---|---|---|",
    ]
    picks = [
        ("qwen2-72b", "train_4k"),
        ("jamba-1.5-large-398b", "train_4k"),
        ("arctic-480b", "prefill_32k"),
        ("yi-6b", "train_4k"),
        ("mixtral-8x7b", "train_4k"),
    ]
    base = roofline.load_cells(roofline.BASELINE, "single")
    opt = roofline.load_cells(roofline.RESULTS, "single")
    for key in picks:
        b, o = base.get(key), opt.get(key)
        if not (b and o and b["status"] == o["status"] == "ok"):
            continue
        br, orr = b["roofline"], o["roofline"]
        bd, od = roofline._dom(br), roofline._dom(orr)
        bp = br["memory_stats"]["peak_bytes_estimate"] / 1e9
        op = orr["memory_stats"]["peak_bytes_estimate"] / 1e9
        rows.append(
            f"| {key[0]} {key[1]} | dominant term (s) | {bd:.2f} ({br['bottleneck']}) "
            f"| {od:.2f} ({orr['bottleneck']}) | {bd/od:.1f}x |"
        )
        rows.append(
            f"| {key[0]} {key[1]} | peak GB/device | {bp:.0f} | {op:.0f} | {bp/op:.1f}x |"
        )
    rows.append("")
    rows.append(
        "Roofline fraction achieved (MODEL_FLOPS / (chips x peak x dominant "
        "term)) equals the `useful/total` column when compute-bound — see the "
        "tables above. The residual gap to 1.0 on compute-bound train cells "
        "(~0.37-0.43) is structural to the paper's algorithm + remat: "
        "1 fwd + 2 bwd + 2 remat-fwd + ghost norms ~= 2.3-2.7x the 6ND "
        "useful work; `bk_mixed` (beyond-paper) removes the second backward "
        "for small models."
    )
    return "\n".join(rows)


def main() -> None:
    t = EXP.read_text()
    t = t.replace("<!-- DRYRUN_SUMMARY -->", dryrun_summary())
    t = t.replace("<!-- ROOFLINE_TABLES -->", "```\n" + "```\n\n".join([]) +
                  capture_tables())
    t = t.replace("<!-- PERF_SUMMARY -->", perf_summary())
    EXP.write_text(t)
    print("EXPERIMENTS.md finalized")


if __name__ == "__main__":
    main()
