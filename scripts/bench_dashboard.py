#!/usr/bin/env python3
"""Render benchmarks/history/*.json into a markdown trend dashboard.

The tier-1 gate archives every bench artifact as
``benchmarks/history/<sha>-BENCH_<name>.json`` (a list of
``{name, us_per_call, derived}`` rows).  This script folds that directory
into ``benchmarks/history/DASHBOARD.md``: one table per benchmark, one row
per git SHA (oldest first, ordered by this checkout's history where
possible), one column per metric — step times in ms, plus whatever the
``derived`` field carries (peak memory, ratios).  Commit the regenerated
dashboard with each PR so the perf trajectory is reviewable in-repo, not
buried in CI artifact retention.

    python scripts/bench_dashboard.py [--history-dir benchmarks/history]
                                      [--out DASHBOARD.md] [--check]
                                      [--check-step-time PCT]

``--check`` exits non-zero when the written dashboard differs from what the
current artifacts render to — the CI guard against archiving new artifacts
without regenerating.

``--obs-run DIR`` prints the latest training run's *observed* step-time
percentiles (from ``DIR/metrics.jsonl``, the repro.obs stream) alongside
the newest archived bench medians — observed wall times vs the isolated
bench numbers, on stdout only; the written dashboard never changes, so
``--check`` stays stable across obs runs.

``--check-step-time PCT`` is the step-time floor gate: for every metric it
compares the newest archived row against the most recent OLDER row from the
same host class (rows carry a ``host`` fingerprint stamped by
``benchmarks/run.py``; rows from different hosts, or legacy rows without
the stamp, never pair) and exits non-zero when any step time regressed by
more than PCT percent.  Intentional trade-offs ship by setting
``BENCH_STEP_TIME_WAIVER`` to a short justification — the gate then prints
the regressions and the waiver and passes.  Stdlib only; runs from
scripts/tier1.sh.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
from pathlib import Path

WAIVER_ENV = "BENCH_STEP_TIME_WAIVER"

REPO = Path(__file__).resolve().parent.parent
# "nogit" is tier1.sh's stamp when git rev-parse fails — still rendered
ARTIFACT = re.compile(r"^([0-9a-f]{6,40}|nogit)-BENCH_([A-Za-z0-9_]+)\.json$")


def git_sha_order(repo: Path) -> dict[str, int]:
    """{short-sha-prefix-able sha: age index} — 0 is the OLDEST commit."""
    try:
        out = subprocess.run(
            ["git", "rev-list", "--reverse", "HEAD"],
            cwd=repo, capture_output=True, text=True, check=True,
        ).stdout.split()
    except (OSError, subprocess.CalledProcessError):
        return {}
    return {sha: i for i, sha in enumerate(out)}


def load_history(history_dir: Path) -> dict[str, dict[str, list[dict]]]:
    """{bench_name: {sha: rows}} from every artifact in the directory."""
    out: dict[str, dict[str, list[dict]]] = {}
    for path in sorted(history_dir.glob("*.json")):
        m = ARTIFACT.match(path.name)
        if not m:
            continue
        sha, bench = m.group(1), m.group(2)
        try:
            rows = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"WARNING: skipping unreadable {path.name}: {e}",
                  file=sys.stderr)
            continue
        if isinstance(rows, list):
            out.setdefault(bench, {})[sha] = rows
    return out


def _order_shas(shas: list[str], full_order: dict[str, int]) -> list[str]:
    """Oldest first by git history; unknown SHAs (other checkouts) last,
    alphabetically — deterministic regardless of file mtimes."""

    def key(sha: str):
        for full, idx in full_order.items():
            if full.startswith(sha):
                return (0, idx, sha)
        return (1, 0, sha)

    return sorted(shas, key=key)


def _cell(row: dict) -> str:
    us = float(row.get("us_per_call", 0.0))
    derived = str(row.get("derived", "") or "")
    parts = []
    if us > 0.0:
        parts.append(f"{us / 1000.0:.1f}ms")
    if derived:
        parts.append(derived)
    return " ".join(parts) if parts else "-"


def render(history: dict[str, dict[str, list[dict]]],
           full_order: dict[str, int]) -> str:
    lines = [
        "# Benchmark trend dashboard",
        "",
        "Rendered from the SHA-stamped artifacts in this directory by",
        "`scripts/bench_dashboard.py` (run by `scripts/tier1.sh` after each",
        "gate; regenerate + commit with every PR).  Rows are commits, oldest",
        "first; cells are `step-time derived` (times in ms).  Numbers are",
        "machine-dependent — compare rows produced on the same host class.",
        "",
    ]
    if not history:
        lines += ["_No artifacts found._", ""]
        return "\n".join(lines)
    for bench in sorted(history):
        per_sha = history[bench]
        shas = _order_shas(list(per_sha), full_order)
        metrics: list[str] = []
        for sha in shas:
            for row in per_sha[sha]:
                name = str(row.get("name", ""))
                if name and name not in metrics:
                    metrics.append(name)
        lines.append(f"## BENCH_{bench}")
        lines.append("")
        lines.append("| sha | " + " | ".join(metrics) + " |")
        lines.append("|---" * (len(metrics) + 1) + "|")
        for sha in shas:
            by_name = {str(r.get("name", "")): r for r in per_sha[sha]}
            cells = [
                _cell(by_name[m]) if m in by_name else "-" for m in metrics
            ]
            lines.append(f"| {sha} | " + " | ".join(cells) + " |")
        lines.append("")
    return "\n".join(lines)


def step_time_regressions(
    history: dict[str, dict[str, list[dict]]],
    full_order: dict[str, int],
    pct: float,
) -> list[str]:
    """Same-host step-time regressions beyond ``pct`` percent, newest row
    vs its closest same-host predecessor.  One message per offense.

    Only rows with a positive ``us_per_call`` AND a ``host`` stamp
    participate: ratio rows (us=0) carry no step time, and legacy
    stampless artifacts predate the harness, so comparing against them
    would gate on cross-host noise.
    """
    offenses: list[str] = []
    for bench in sorted(history):
        per_sha = history[bench]
        shas = _order_shas(list(per_sha), full_order)
        if len(shas) < 2:
            continue
        newest = shas[-1]
        for row in per_sha[newest]:
            name, host = str(row.get("name", "")), row.get("host")
            us = float(row.get("us_per_call", 0.0))
            if not name or not host or us <= 0.0:
                continue
            for prev in reversed(shas[:-1]):
                base = next(
                    (r for r in per_sha[prev]
                     if str(r.get("name", "")) == name
                     and r.get("host") == host
                     and float(r.get("us_per_call", 0.0)) > 0.0),
                    None,
                )
                if base is None:
                    continue
                base_us = float(base["us_per_call"])
                if us > base_us * (1.0 + pct / 100.0):
                    offenses.append(
                        f"BENCH_{bench}/{name}: {us / 1000.0:.1f}ms at "
                        f"{newest} vs {base_us / 1000.0:.1f}ms at {prev} "
                        f"(+{(us / base_us - 1.0) * 100.0:.1f}% > "
                        f"{pct:.0f}% budget, host {host})"
                    )
                break  # compare against the closest same-host row only
    return offenses


def check_step_time(
    history: dict[str, dict[str, list[dict]]],
    full_order: dict[str, int],
    pct: float,
    *,
    waiver: str | None = None,
) -> int:
    """Gate exit code: 0 clean (or waived), 1 on unwaived regressions."""
    offenses = step_time_regressions(history, full_order, pct)
    if not offenses:
        print(f"step-time gate: no same-host regressions beyond {pct:.0f}%")
        return 0
    for line in offenses:
        print(f"STEP-TIME REGRESSION: {line}", file=sys.stderr)
    if waiver:
        print(f"step-time gate: {len(offenses)} regression(s) WAIVED "
              f"({WAIVER_ENV}={waiver!r})", file=sys.stderr)
        return 0
    print(f"ERROR: {len(offenses)} step-time regression(s); optimize, or "
          f"ship the trade-off explicitly with {WAIVER_ENV}=<reason>",
          file=sys.stderr)
    return 1


def _read_jsonl(path: Path) -> list[dict]:
    """Torn-tolerant JSONL reader (local copy: this script runs stdlib-only,
    without PYTHONPATH=src, in the docs CI job)."""
    if not path.exists():
        return []
    out = []
    for line in path.read_text(errors="replace").splitlines():
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue
        if isinstance(rec, dict):
            out.append(rec)
    return out


def _pctile(xs: list[float], q: float) -> float:
    s = sorted(xs)
    return s[min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))]


def report_obs_run(run_dir: Path,
                   history: dict[str, dict[str, list[dict]]],
                   full_order: dict[str, int]) -> int:
    """Print the run's observed step-time percentiles next to the newest
    archived bench medians (stdout only — the dashboard file is untouched)."""
    train = [
        m for m in _read_jsonl(run_dir / "metrics.jsonl")
        if m.get("kind") == "train_step" and m.get("step_s")
    ]
    if not train:
        print(f"obs run {run_dir}: no train_step records", file=sys.stderr)
        return 1
    times = [float(m["step_s"]) for m in train]
    print(f"observed ({run_dir}, {len(times)} steps): "
          f"p50 {_pctile(times, 0.5) * 1e3:.1f}ms "
          f"p95 {_pctile(times, 0.95) * 1e3:.1f}ms")
    for bench in ("modes", "policies"):
        per_sha = history.get(bench, {})
        if not per_sha:
            continue
        newest = _order_shas(list(per_sha), full_order)[-1]
        cells = [
            f"{r['name']} {float(r['us_per_call']) / 1e3:.1f}ms"
            for r in per_sha[newest]
            if float(r.get("us_per_call", 0.0)) > 0.0
        ]
        if cells:
            print(f"bench medians (BENCH_{bench} @ {newest}): "
                  + ", ".join(cells))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--history-dir", default=str(REPO / "benchmarks" / "history"))
    ap.add_argument("--out", default=None,
                    help="output path (default: <history-dir>/DASHBOARD.md)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if the existing dashboard is out of date "
                         "instead of writing")
    ap.add_argument("--check-step-time", type=float, default=None,
                    metavar="PCT",
                    help="exit 1 when the newest same-host row regressed "
                         "any step time by more than PCT percent "
                         f"(waive with {WAIVER_ENV}=<reason>)")
    ap.add_argument("--obs-run", default=None, metavar="DIR",
                    help="print DIR's observed step-time percentiles "
                         "(repro.obs metrics.jsonl) alongside the newest "
                         "bench medians; the dashboard file is not written")
    args = ap.parse_args(argv)

    history_dir = Path(args.history_dir)
    out_path = Path(args.out) if args.out else history_dir / "DASHBOARD.md"
    history = load_history(history_dir)
    order = git_sha_order(REPO)
    text = render(history, order) + "\n"

    if args.obs_run is not None:
        return report_obs_run(Path(args.obs_run), history, order)

    if args.check_step_time is not None:
        return check_step_time(
            history, order, args.check_step_time,
            waiver=os.environ.get(WAIVER_ENV),
        )

    if args.check:
        current = out_path.read_text() if out_path.exists() else ""
        if current != text:
            print(f"ERROR: {out_path} is out of date; re-run "
                  "scripts/bench_dashboard.py and commit the result",
                  file=sys.stderr)
            return 1
        print(f"{out_path} is up to date")
        return 0

    out_path.write_text(text)
    benches = len(load_history(history_dir))
    print(f"wrote {out_path} ({benches} benchmark table(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
