# Launch environment harness (shell half of src/repro/launch/env.py).
#
# Source this before any python that imports jax:
#
#   source scripts/launch_env.sh
#
# It pins the parts of the environment that move step timings between
# otherwise-identical runs: the allocator (tcmalloc preloaded when the
# host has it), XLA's step markers (so profilers bracket whole steps),
# allocator preallocation (OFF, so the tuner's OOM-trial ladder can
# actually reclaim a failed trial), and log noise.  Every assignment is a
# default — values already exported by the caller are left alone.  Keep
# the variable list in sync with repro.launch.env, which applies the same
# defaults from inside Python for entry points not launched through here.

# tcmalloc: steadier large-allocation behavior than glibc malloc for
# host-staged batches; preload only when present (absent in slim images)
if [ -z "${LD_PRELOAD:-}" ]; then
  for _tcm in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
              /usr/lib/libtcmalloc.so.4 \
              /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4; do
    if [ -f "$_tcm" ]; then
      export LD_PRELOAD="$_tcm"
      break
    fi
  done
  unset _tcm
fi

# only report pathological single allocations, not every weight buffer
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"

# silence libtf/XLA info chatter that skews wall-clock on slow ttys
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# let the OOM-trial retry ladder reclaim failed trials' arenas
export XLA_PYTHON_CLIENT_PREALLOCATE="${XLA_PYTHON_CLIENT_PREALLOCATE:-false}"

# step markers at the outer while loop (1); 0 would mark program entry.
# TPU only: the CPU/GPU wheels abort on unknown DebugOptions in XLA_FLAGS
case "${JAX_PLATFORMS:-cpu}" in
  tpu*)
    case " ${XLA_FLAGS:-} " in
      *"--xla_step_marker_location"*) : ;;
      *) export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_step_marker_location=1" ;;
    esac
    ;;
esac
