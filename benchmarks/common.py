"""Shared benchmark machinery: timing, memory-model probes, tiny models."""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.clipping import ClipConfig, dp_value_and_clipped_grad
from repro.core.taps import Ctx
from repro.data.synthetic import synthetic_vision_batch
from repro.models.cnn import VGG
from repro.models.losses import per_sample_xent
from repro.nn.conv import Conv2d, global_avg_pool
from repro.nn.module import Dense, GroupNorm


def time_fn(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall seconds per call (jit-compiled fns; blocks on output)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def compiled_memory_bytes(fn: Callable, *specs) -> int:
    """Peak-memory model from AOT compile: args + outputs + temps."""
    compiled = jax.jit(fn).lower(*specs).compile()
    ma = compiled.memory_analysis()
    return int(
        ma.argument_size_in_bytes + ma.output_size_in_bytes
        + ma.temp_size_in_bytes - ma.alias_size_in_bytes
    )


class SmallCNN:
    """The paper's CIFAR CNN analogue (Table 4 row 1, ~0.5M params)."""

    def __init__(self, n_classes: int = 10, width: int = 32):
        w = width
        self.c1 = Conv2d("c1", 3, w, (3, 3))
        self.g1 = GroupNorm("g1", w, groups=8)
        self.c2 = Conv2d("c2", w, 2 * w, (3, 3), strides=(2, 2))
        self.g2 = GroupNorm("g2", 2 * w, groups=8)
        self.c3 = Conv2d("c3", 2 * w, 2 * w, (3, 3), strides=(2, 2))
        self.head = Dense("head", 2 * w, n_classes)

    def init(self, key):
        ks = jax.random.split(key, 6)
        return {
            "c1": self.c1.init(ks[0]), "g1": self.g1.init(ks[1]),
            "c2": self.c2.init(ks[2]), "g2": self.g2.init(ks[3]),
            "c3": self.c3.init(ks[4]), "head": self.head.init(ks[5]),
        }

    def loss_with_ctx(self, params, batch, ctx: Ctx):
        h = jax.nn.relu(self.g1(params["g1"],
                                self.c1(params["c1"], batch["image"], ctx.scope("c1")),
                                ctx.scope("g1")))
        h = jax.nn.relu(self.g2(params["g2"],
                                self.c2(params["c2"], h, ctx.scope("c2")),
                                ctx.scope("g2")))
        h = self.c3(params["c3"], h, ctx.scope("c3"))
        h = global_avg_pool(h)
        logits = self.head(params["head"], h[:, None, :], ctx.scope("head"))[:, 0]
        return per_sample_xent(logits[:, None, :], batch["label"][:, None],
                               batch.get("mask"))


def cnn_batch(batch: int, image: int = 32, step: int = 0):
    return synthetic_vision_batch(
        batch=batch, image=image, channels=3, n_classes=10, step=step
    )


def clipping_step_fn(model, mode: str, clip_norm: float = 1.0):
    return jax.jit(
        dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig(mode=mode, clip_norm=clip_norm))
    )


MODES_BENCH = ["non_private", "vmap", "ghost", "fastgradclip", "mixed_ghost", "bk_mixed"]
