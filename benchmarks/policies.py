"""Policy trajectory benchmark: the cost of each clipping policy's factors.

The norms machinery is shared; what differs per policy is the factor stage
and (for grouped policies on second-backward modes) the gradient stage.
Rows cover each policy under the fused book-keeping engine — the engine
where every policy is one einsum schedule — plus ``per_layer`` under
``mixed_ghost``, whose per-group pullbacks are the one genuinely more
expensive combination (G extra backwards; see docs/ARCHITECTURE.md).

``benchmarks/run.py`` writes the rows to ``BENCH_policies.json``;
``scripts/tier1.sh`` copies it (git-SHA-stamped) into ``benchmarks/history/``
so the policy-cost trajectory accumulates in-repo alongside the mode
trajectory.
"""
from __future__ import annotations

import jax

from benchmarks.common import SmallCNN, cnn_batch, time_fn

POLICY_SPECS = (
    ("fixed", {}),
    ("automatic", {}),
    ("quantile", {"release_sigma": 1.0}),
    ("per_layer", {"groups": ("c1", "head")}),
)


def run(batch: int = 64, image: int = 32) -> list[tuple[str, float, str]]:
    from repro.core.clipping import ClipConfig, dp_value_and_clipped_grad
    from repro.policies import make_policy

    model = SmallCNN()
    params = model.init(jax.random.PRNGKey(0))
    batch_data = cnn_batch(batch, image)

    rows = []
    baseline_us = None
    for mode in ("bk_mixed", "mixed_ghost"):
        for name, kw in POLICY_SPECS:
            if mode == "mixed_ghost" and name != "per_layer":
                continue  # only the grouped policy pays extra off-bk
            policy = make_policy(name, clip_norm=1.0, init_clip_norm=1.0, **kw)
            fn = jax.jit(
                dp_value_and_clipped_grad(
                    model.loss_with_ctx, ClipConfig(mode=mode, policy=policy)
                )
            )
            pstate = policy.init_state()
            t = time_fn(lambda f=fn, s=pstate: f(params, batch_data, s))
            us = t * 1e6
            if mode == "bk_mixed" and name == "fixed":
                baseline_us = us
            rel = us / baseline_us if baseline_us else float("nan")
            rows.append((
                f"policies_cnn_b{batch}_{mode}_{name}",
                us,
                f"policy={name};mode={mode};vs_fixed_bk={rel:.3f}",
            ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
