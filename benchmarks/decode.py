"""Serving latency benchmark: the continuous-batching engine under load.

Sweeps offered load (queued requests per decode slot) and reports, per
load point: throughput (tok/s), p50/p95 TTFT and p50/p95 per-token
latency — the row schema every other benchmark uses, so the history
archive and the dashboard track serving regressions exactly like training
ones.  A sequential one-request-at-a-time baseline anchors the batching
win on the same prompts.

Row naming: ``decode/<arch>/seq`` and ``decode/<arch>/load<r>``;
``us_per_call`` is the p50 per-token decode latency (µs), ``derived``
carries the full metric set.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.registry import build_model, get_arch
from repro.serving import Engine, aggregate_metrics, sequential_decode


def _prompts(n: int, vocab: int, lo: int, hi: int, seed: int = 7):
    out = []
    for i in range(n):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed + i))
        plen = int(jax.random.randint(k1, (), lo, hi + 1))
        out.append((1 + jax.random.randint(
            k2, (plen,), 0, vocab - 1, dtype=jnp.int32)).tolist())
    return out


def run(fast: bool = True, arch: str = "codeqwen1.5-7b"):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    slots = 4
    max_new = 8 if fast else 16
    lo, hi = (4, 10) if fast else (8, 24)
    max_len = hi + max_new
    loads = (1.0, 2.0) if fast else (0.5, 1.0, 2.0, 4.0)

    rows = []

    # sequential baseline: same prompts as the load=1.0 point
    base_prompts = _prompts(slots, cfg.vocab, lo, hi)
    view_len = Engine(model, params, n_slots=slots, page_size=8,
                      max_len=max_len).view_len
    sequential_decode(model, params, base_prompts[:1], max_new=2,
                      view_len=view_len)  # compile warmup
    t0 = time.perf_counter()
    seq_out = sequential_decode(model, params, base_prompts,
                                max_new=max_new, view_len=view_len)
    dt = time.perf_counter() - t0
    n_tok = sum(len(t) for t in seq_out)
    rows.append((
        f"decode/{arch}/seq",
        dt / max(n_tok, 1) * 1e6,
        f"tok/s={n_tok / dt:.1f} requests={slots}",
    ))

    for load in loads:
        n_req = max(1, round(load * slots))
        engine = Engine(model, params, n_slots=slots, page_size=8,
                        max_len=max_len)
        # warmup: compile prefill (per prompt length) + the decode step
        # outside the timed region
        prompts = _prompts(n_req, cfg.vocab, lo, hi, seed=31)
        for p in {len(q): q for q in prompts}.values():
            engine.submit(p, max_new=2)
        engine.drain()
        engine = Engine(model, params, n_slots=slots, page_size=8,
                        max_len=max_len)
        for p in prompts:
            engine.submit(p, max_new=max_new)
        completions = engine.drain()
        m = aggregate_metrics(completions)
        rows.append((
            f"decode/{arch}/load{load:g}",
            m["per_token_p50_ms"] * 1e3,
            f"tok/s={m['tok_per_s']:.1f} requests={n_req} "
            f"ttft_p50_ms={m['ttft_p50_ms']:.1f} "
            f"ttft_p95_ms={m['ttft_p95_ms']:.1f} "
            f"per_token_p95_ms={m['per_token_p95_ms']:.1f} "
            f"shed={int(m['shed'])}",
        ))
    return rows
