"""Mode trajectory benchmark: non_private vs mixed_ghost vs fused bk_mixed.

The repo's two headline claims ride on this comparison (table4 CNN config):

- ``mixed_ghost`` reproduces the paper — small memory overhead, one extra
  backward pass;
- fused ``bk_mixed`` (book-keeping on the probe engine) must be *strictly
  faster per step* than ``mixed_ghost`` while keeping peak memory within
  ~10% of ``non_private`` — no tap-sized zeros, no activation dict, no
  second backward.

``benchmarks/run.py`` writes the rows to ``BENCH_modes.json`` so the perf
trajectory accumulates across PRs.  Each row's derived field carries the
peak-memory model and the ratios the acceptance gates read.
"""
from __future__ import annotations

import jax

from benchmarks.common import (
    SmallCNN,
    cnn_batch,
    compiled_memory_bytes,
    time_fn,
)

MODES_TRACKED = ("non_private", "mixed_ghost", "bk_mixed")


def run(batch: int = 64, image: int = 32) -> list[tuple[str, float, str]]:
    from repro.core.clipping import ClipConfig, dp_value_and_clipped_grad

    model = SmallCNN()
    params = model.init(jax.random.PRNGKey(0))
    batch_data = cnn_batch(batch, image)
    specs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), (params, batch_data)
    )
    rows = []
    stats: dict[str, tuple[float, int]] = {}
    for mode in MODES_TRACKED:
        raw_fn = dp_value_and_clipped_grad(
            model.loss_with_ctx, ClipConfig(mode=mode, clip_norm=1.0)
        )
        t = time_fn(jax.jit(raw_fn), params, batch_data)
        mem = compiled_memory_bytes(raw_fn, *specs)
        stats[mode] = (t, mem)
        rows.append(
            (f"modes_cnn_b{batch}_{mode}", t * 1e6, f"mem_mb={mem / 1e6:.1f}")
        )

    np_t, np_mem = stats["non_private"]
    mg_t, _ = stats["mixed_ghost"]
    bk_t, bk_mem = stats["bk_mixed"]
    rows.append((
        f"modes_cnn_b{batch}_bk_vs_mixed_speedup",
        0.0,
        f"step_time_ratio={mg_t / bk_t:.3f}",  # > 1 == bk strictly faster
    ))
    rows.append((
        f"modes_cnn_b{batch}_bk_vs_np_memory",
        0.0,
        f"peak_mem_ratio={bk_mem / np_mem:.3f}",  # <= 1.10 == within 10%
    ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
