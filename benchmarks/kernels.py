"""Kernel-dispatch benchmark: the three clipping hot ops, per impl.

Times every *available* implementation of each dispatch op
(repro.kernels.dispatch) on representative clipping shapes — the dense
ghost norm, the index-equality embedding ghost norm, and both psg
bank-contraction entry points.  On TPU this races Pallas against XLA (the
same comparison the tuner runs per tap, ``measure_kernels``); elsewhere
only the XLA path is timed — interpreted Pallas timings would be noise,
not signal.  Rows land in ``BENCH_kernels.json`` so the kernel trajectory
accumulates in ``benchmarks/history/`` next to the mode and policy
trajectories.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.kernels import dispatch

# (label, N, T, D, p): a conv-ish mid tap and an lm_head-ish ghost tap
SHAPES = [
    ("conv_mid", 16, 196, 288, 64),
    ("lm_head", 8, 128, 256, 512),
]


def run(fast: bool = True):
    rows = []
    impls = dispatch.available_impls()
    for si, (label, n, t, d, p) in enumerate(SHAPES):
        ks = jax.random.split(jax.random.PRNGKey(si), 4)
        a = jax.random.normal(ks[0], (n, t, d))
        g = jax.random.normal(ks[1], (n, t, p))
        c = jax.random.uniform(ks[2], (n,))
        ids = jax.random.randint(ks[3], (n, t), 0, 1000).astype(jnp.float32)
        w = jnp.broadcast_to(c[:, None], (n, t)).reshape(1, n * t)
        psg = a.reshape(n, t * d)

        # every operand is a traced argument of the jitted fn — a closed-over
        # constant would be folded by XLA and the timing would measure
        # dispatch overhead, not the kernel
        per_op = {
            "ghost_norm": (
                lambda impl: jax.jit(
                    lambda x, y: dispatch.ghost_norm_sq(x, y, impl=impl)
                ),
                (a, g),
            ),
            "embedding_ghost_norm": (
                lambda impl: jax.jit(
                    lambda i, y: dispatch.embedding_ghost_norm_sq(
                        i, y, impl=impl
                    )
                ),
                (ids, g),
            ),
            "book_contract": (
                lambda impl: jax.jit(
                    lambda x, y, ww: dispatch.book_weighted_grad(
                        x.reshape(1, n * t, d), y.reshape(1, n * t, p), ww,
                        impl=impl,
                    )
                ),
                (a, g, w),
            ),
            "psg_contract": (
                lambda impl: jax.jit(
                    lambda x, cc: dispatch.psg_contract(x, cc, impl=impl)
                ),
                (psg, c),
            ),
        }
        for op, (make, args) in per_op.items():
            per_impl = {}
            for impl in impls:
                sec = time_fn(make(impl), *args, iters=2 if fast else 5)
                per_impl[impl] = sec * 1e6
                rows.append((f"kernels_{label}_{op}_{impl}", sec * 1e6,
                             f"N={n};T={t};D={d};p={p}"))
            if len(per_impl) > 1:
                winner = min(sorted(per_impl), key=per_impl.get)
                rows.append((
                    f"kernels_{label}_{op}_winner", 0.0,
                    f"impl={winner};speedup="
                    f"{max(per_impl.values()) / max(min(per_impl.values()), 1e-9):.3f}",
                ))
    return rows
