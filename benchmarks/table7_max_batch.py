"""Paper Table 7: maximum physical batch size per clipping algorithm.

The paper bisects on a 16GB V100; we bisect on the XLA compiled-memory model
with a 16GB budget — same experiment, hardware-independent methodology.
"""
from __future__ import annotations

import jax

from benchmarks.common import MODES_BENCH, SmallCNN, cnn_batch, compiled_memory_bytes
from repro.core.clipping import ClipConfig, dp_value_and_clipped_grad

BUDGET = 16 * 1024**3


def max_batch(model, params, mode: str, image: int = 32, hi_cap: int = 65536) -> int:
    fn = dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig(mode=mode))

    def fits(b: int) -> bool:
        batch = cnn_batch(b, image)
        specs = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), (params, batch)
        )
        try:
            return compiled_memory_bytes(fn, *specs) <= BUDGET
        except Exception:
            return False

    lo, hi = 1, 2
    while hi < hi_cap and fits(hi):
        lo, hi = hi, hi * 2
    if hi >= hi_cap:
        return lo
    while hi - lo > max(lo // 8, 1):  # ~12% resolution, keeps compiles cheap
        mid = (lo + hi) // 2
        if fits(mid):
            lo = mid
        else:
            hi = mid
    return lo


def run(image: int = 32) -> list[tuple[str, float, str]]:
    model = SmallCNN()
    params = model.init(jax.random.PRNGKey(0))
    rows = []
    for mode in MODES_BENCH:
        mb = max_batch(model, params, mode, image)
        rows.append((f"table7_maxbatch_{mode}", 0.0, f"max_batch={mb}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
