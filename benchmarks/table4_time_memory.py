"""Paper Table 4/6: per-step time and memory of each clipping algorithm at a
fixed physical batch size (CNN on 32x32 images, the paper's CIFAR setting).

Memory is the XLA compiled-program peak model (args+outputs+temps) — the CPU
analogue of the paper's `torch.cuda` active memory.
"""
from __future__ import annotations

import jax

from benchmarks.common import (
    MODES_BENCH,
    SmallCNN,
    clipping_step_fn,
    cnn_batch,
    compiled_memory_bytes,
    time_fn,
)


def run(batch: int = 64, image: int = 32) -> list[tuple[str, float, str]]:
    rows = vgg11_memory()
    model = SmallCNN()
    params = model.init(jax.random.PRNGKey(0))
    batch_data = cnn_batch(batch, image)
    specs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), (params, batch_data)
    )
    for mode in MODES_BENCH:
        from repro.core.clipping import ClipConfig, dp_value_and_clipped_grad

        raw_fn = dp_value_and_clipped_grad(
            model.loss_with_ctx, ClipConfig(mode=mode, clip_norm=1.0)
        )
        step = jax.jit(raw_fn)
        t = time_fn(step, params, batch_data)
        mem = compiled_memory_bytes(raw_fn, *specs)
        rows.append(
            (f"table4_cnn_b{batch}_{mode}", t * 1e6, f"mem_mb={mem / 1e6:.1f}")
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))


def vgg11_memory(batch: int = 128) -> list[tuple[str, float, str]]:
    """Paper Table 6 setting: VGG-11 on 32x32, physical batch 128.

    Paper (GB): Opacus 6.19, Ghost 1.85, Mixed 1.85, NonDP 1.83.
    Memory-model analogue (no timing — VGG11 x 6 modes is compile-only).
    """
    from repro.models.cnn import VGG

    model = VGG("vgg11", n_classes=10)
    params = model.init(jax.random.PRNGKey(0))
    bd = cnn_batch(batch, 32)
    specs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), (params, bd)
    )
    rows = []
    from repro.core.clipping import ClipConfig, dp_value_and_clipped_grad

    for mode in ["non_private", "vmap", "ghost", "mixed_ghost"]:
        fn = dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig(mode=mode))
        mem = compiled_memory_bytes(fn, *specs)
        rows.append((f"table6_vgg11_b{batch}_{mode}", 0.0, f"mem_gb={mem/1e9:.2f}"))
    return rows
