"""Paper Table 3: VGG-11 / ImageNet layerwise ghost-vs-instantiate decision."""
from __future__ import annotations

from repro.core.decision import ghost_is_cheaper

VGG11_LAYERS = [
    ("conv1", 224 * 224, 3, 64, 3),
    ("conv2", 112 * 112, 64, 128, 3),
    ("conv3", 56 * 56, 128, 256, 3),
    ("conv4", 56 * 56, 256, 256, 3),
    ("conv5", 28 * 28, 256, 512, 3),
    ("conv6", 28 * 28, 512, 512, 3),
    ("conv7", 14 * 14, 512, 512, 3),
    ("conv8", 14 * 14, 512, 512, 3),
    ("fc9", 1, 512 * 7 * 7, 4096, 1),
    ("fc10", 1, 4096, 4096, 1),
    ("fc11", 1, 4096, 1000, 1),
]


def run() -> list[tuple[str, float, str]]:
    rows = []
    ghost_total = nonghost_total = mixed_total = 0.0
    for name, t, d, p, k in VGG11_LAYERS:
        ghost_cost = 2.0 * t * t
        nong = float(p * d * k * k)
        pick = "ghost" if ghost_is_cheaper(t, d * k * k, p) else "instantiate"
        ghost_total += ghost_cost
        nonghost_total += nong
        mixed_total += min(ghost_cost, nong)
        rows.append(
            (f"table3_{name}", 0.0,
             f"ghost={ghost_cost:.2e};nonghost={nong:.2e};selected={pick}")
        )
    rows.append(("table3_total", 0.0,
                 f"ghost={ghost_total:.2e};nonghost={nonghost_total:.2e};"
                 f"mixed={mixed_total:.2e}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
