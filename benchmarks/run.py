# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure + the roofline report.

    PYTHONPATH=src python -m benchmarks.run [--only table4,table7] [--fast]

Each benchmark also writes a machine-readable ``BENCH_<name>.json`` (list of
{name, us_per_call, derived} rows) under --out-dir, so the perf trajectory
can accumulate across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list of benchmark names")
    ap.add_argument("--fast", action="store_true", help="smaller sweeps")
    ap.add_argument("--out-dir", default=".",
                    help="directory for the BENCH_<name>.json artifacts")
    args = ap.parse_args()

    # pin the launch env (allocator, XLA step markers, preallocate-off)
    # before the benchmark imports below pull in jax — timings archived to
    # benchmarks/history/ are only comparable under the same harness
    from repro.launch.env import apply_env, host_fingerprint

    apply_env()
    host = host_fingerprint()

    from benchmarks import (
        decode,
        fig3_memory_curve,
        kernels,
        modes,
        policies,
        roofline,
        table1_complexity,
        table3_decision,
        table4_time_memory,
        table5_accuracy,
        table7_max_batch,
    )

    benches = {
        "table1": lambda: table1_complexity.run(),
        "table3": lambda: table3_decision.run(),
        "kernels": lambda: kernels.run(fast=args.fast),
        "decode": lambda: decode.run(fast=args.fast),
        "table4": lambda: table4_time_memory.run(batch=32 if args.fast else 64),
        "table5": lambda: table5_accuracy.run(steps=10 if args.fast else 30),
        "table7": lambda: table7_max_batch.run(),
        "fig3": lambda: fig3_memory_curve.run(fast=args.fast),
        "modes": lambda: modes.run(batch=32 if args.fast else 64),
        "policies": lambda: policies.run(batch=32 if args.fast else 64),
        "roofline": lambda: roofline.run("single") + roofline.run("multi"),
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    os.makedirs(args.out_dir, exist_ok=True)
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        t0 = time.time()
        rows = []
        try:
            for row_name, us, derived in fn():
                print(f"{row_name},{us:.1f},{derived}")
                # "host" tags the row's host class so the step-time gate
                # only ever compares same-host rows (render ignores it)
                rows.append(
                    {"name": row_name, "us_per_call": us,
                     "derived": str(derived), "host": host}
                )
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0.0,FAILED", file=sys.stderr)
            traceback.print_exc()
            # a stale artifact from an earlier healthy run would mask the
            # regression — remove it so the trajectory shows the gap
            stale = os.path.join(args.out_dir, f"BENCH_{name}.json")
            if os.path.exists(stale):
                os.remove(stale)
        else:
            path = os.path.join(args.out_dir, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump(rows, f, indent=2)
            print(f"# wrote {path}", file=sys.stderr)
        print(f"# {name} finished in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
