"""Paper Table 5 / Sec 2.1 claim: the efficient implementation does not change
the mathematics — DP training curves are identical across clipping modes, and
DP training actually learns.

We train the small CNN on class-conditional synthetic data with DP-Adam under
(a) vmap (Opacus analogue) and (b) mixed ghost clipping, same seeds/noise:
the loss trajectories must match to float tolerance, and accuracy must beat
chance by a wide margin.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import SmallCNN, cnn_batch
from repro.core.clipping import ClipConfig, dp_value_and_clipped_grad
from repro.core.noise import add_dp_noise
from repro.core.taps import Ctx
from repro.optim import adam, apply_updates


def train(mode: str, steps: int = 30, batch: int = 64, lr: float = 5e-3,
          sigma: float = 0.4, clip: float = 4.0):
    model = SmallCNN(width=16)
    params = model.init(jax.random.PRNGKey(0))
    opt = adam()
    opt_state = opt.init(params)
    grad_fn = jax.jit(
        dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig(mode=mode, clip_norm=clip))
    )

    @jax.jit
    def update(params, opt_state, batch_data, key, step):
        loss, gsum, _ = grad_fn(params, batch_data)
        noisy = add_dp_noise(gsum, key, sigma * clip)
        grads = jax.tree_util.tree_map(lambda g: g / batch, noisy)
        upd, opt_state = opt.update(grads, opt_state, params, step, lr)
        return apply_updates(params, upd), opt_state, loss

    losses = []
    for step in range(steps):
        bd = cnn_batch(batch, image=16, step=step)
        key = jax.random.fold_in(jax.random.PRNGKey(99), step)
        params, opt_state, loss = update(params, opt_state, bd, key, jnp.asarray(step))
        losses.append(float(loss))

    # eval accuracy on held-out steps
    correct = total = 0
    for step in range(1000, 1005):
        bd = cnn_batch(64, image=16, step=step)
        h = model.loss_with_ctx  # reuse trunk via logits path
        logits_fn = jax.jit(lambda p, b: _logits(model, p, b))
        pred = jnp.argmax(logits_fn(params, bd), axis=-1)
        correct += int(jnp.sum(pred == bd["label"]))
        total += int(bd["label"].shape[0])
    return losses, correct / total


def _logits(model, params, batch):
    import jax.nn as jnn

    from repro.nn.conv import global_avg_pool

    ctx = Ctx.disabled()
    h = jnn.relu(model.g1(params["g1"], model.c1(params["c1"], batch["image"], ctx), ctx))
    h = jnn.relu(model.g2(params["g2"], model.c2(params["c2"], h, ctx), ctx))
    h = model.c3(params["c3"], h, ctx)
    h = global_avg_pool(h)
    return model.head(params["head"], h[:, None, :], ctx)[:, 0]


def run(steps: int = 30) -> list[tuple[str, float, str]]:
    losses_vmap, acc_vmap = train("vmap", steps)
    losses_mixed, acc_mixed = train("mixed_ghost", steps)
    max_diff = max(abs(a - b) for a, b in zip(losses_vmap, losses_mixed))
    learned = losses_mixed[-1] < losses_mixed[0] - 0.1
    return [
        ("table5_parity_maxlossdiff", 0.0, f"{max_diff:.2e}"),
        ("table5_acc_vmap", 0.0, f"{acc_vmap:.3f}"),
        ("table5_acc_mixed", 0.0, f"{acc_mixed:.3f}"),
        ("table5_dp_learns", 0.0, str(bool(learned))),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
