"""Paper Table 1/2: module complexities + measured validation.

Prints the analytic model for a representative conv layer and validates the
*ratio* structure empirically: ghost-norm time scales ~T^2, instantiation
~D*p — measured on CPU with the chunked ops.
"""
from __future__ import annotations

import jax

from benchmarks.common import time_fn
from repro.core.decision import algorithm_cost, back_propagation, ghost_norm, grad_instantiation, weighted_grad
from repro.core.taps import TapMeta
from repro.kernels.ghost_norm import ops as gops

import jax.numpy as jnp


def run() -> list[tuple[str, float, str]]:
    rows = []
    b, t, d, p = 8, 28 * 28, 256 * 9, 512  # VGG conv5-like
    rows.append(("table1_backprop", 0.0, f"time={back_propagation(b,t,d,p).time:.3e}"))
    rows.append(("table1_ghostnorm", 0.0,
                 f"time={ghost_norm(b,t,d,p).time:.3e};space={ghost_norm(b,t,d,p).space:.3e}"))
    rows.append(("table1_instantiation", 0.0,
                 f"time={grad_instantiation(b,t,d,p).time:.3e};space={grad_instantiation(b,t,d,p).space:.3e}"))
    rows.append(("table1_weightedgrad", 0.0, f"time={weighted_grad(b,t,d,p).time:.3e}"))

    # empirical scaling check (T doubles -> ghost ~4x, instantiation ~2x)
    key = jax.random.PRNGKey(0)
    for tt in (256, 512):
        a = jax.random.normal(key, (4, tt, 64))
        g = jax.random.normal(key, (4, tt, 48))
        gh = jax.jit(lambda a, g: gops.ghost_norm_sq(a, g, block=128))
        inst = jax.jit(lambda a, g: gops.instantiated_norm_sq(a, g))
        rows.append((f"table1_measured_ghost_T{tt}", time_fn(gh, a, g) * 1e6, ""))
        rows.append((f"table1_measured_inst_T{tt}", time_fn(inst, a, g) * 1e6, ""))

    # Table 2: whole-algorithm costs for the same layer
    meta = TapMeta(kind="matmul", T=t, D=d, p=p, s_shape=(b, t, p),
                   s_dtype=jnp.float32, param_path="w", batch_size=b)
    for mode in ("non_private", "opacus", "ghost", "fastgradclip", "mixed_ghost", "bk_mixed"):
        c = algorithm_cost({"l": meta}, mode)
        rows.append((f"table2_{mode}", 0.0,
                     f"time={c['time']:.3e};space={c['space']:.3e}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
