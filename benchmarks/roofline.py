"""§Roofline: aggregate the dry-run artifacts into the roofline tables.

Reads results/dryrun/{single,multi}/*.json (optimized) and
results/dryrun_baseline/ (paper-faithful pre-optimization) and emits, per
(arch x shape x mesh): the three roofline terms in seconds, the dominant
bottleneck, MODEL_FLOPS/total, memory fit, and the baseline->optimized delta
on the dominant term.
"""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path("results/dryrun")
BASELINE = pathlib.Path("results/dryrun_baseline")
HBM_BUDGET = 16e9  # v5e chip


def load_cells(root: pathlib.Path, mesh_dir: str) -> dict[tuple, dict]:
    d = root / mesh_dir
    if not d.exists():
        return {}
    out = {}
    for p in sorted(d.glob("*.json")):
        m = json.loads(p.read_text())
        out[(m.get("arch"), m.get("shape"))] = m
    return out


def _dom(r: dict) -> float:
    return max(r["compute_s"], r["memory_s"], r["collective_s"])


def fmt_row(m: dict, base: dict | None) -> str:
    if m["status"] == "skipped":
        return (f"| {m['arch']} | {m['shape']} | skipped | | | | | | "
                f"{m.get('reason','')[:48]} |")
    if m["status"] != "ok":
        return (f"| {m['arch']} | {m['shape']} | ERROR | | | | | | "
                f"{m.get('error','')[:48]} |")
    r = {k: (max(v, 0.0) if isinstance(v, float) else v)
         for k, v in m["roofline"].items()}
    peak = r["memory_stats"]["peak_bytes_estimate"]
    fits = "yes" if peak <= HBM_BUDGET else f"NO ({peak/1e9:.0f}GB)"
    delta = ""
    if base and base.get("status") == "ok":
        b = base["roofline"]
        if _dom(r) > 0:
            delta = f"{_dom(b)/_dom(r):.1f}x"
    return (
        f"| {m['arch']} | {m['shape']} | {r['compute_s']:.3f} | {r['memory_s']:.3f} "
        f"| {r['collective_s']:.3f} | **{r['bottleneck']}** "
        f"| {r['useful_flops_ratio']:.2f} | {fits} | {delta} |"
    )


def run(mesh_dir: str = "single") -> list[tuple[str, float, str]]:
    cells = load_cells(RESULTS, mesh_dir)
    base = load_cells(BASELINE, mesh_dir)
    rows = []
    print(f"\n## Roofline ({mesh_dir}-pod mesh) — optimized; last column = "
          "dominant-term speedup vs paper-faithful baseline")
    print("| arch | shape | compute_s | memory_s | collective_s | bottleneck "
          "| useful/total | fits 16GB | vs baseline |")
    print("|---|---|---|---|---|---|---|---|---|")
    for key, m in sorted(cells.items()):
        print(fmt_row(m, base.get(key)))
        if m["status"] == "ok":
            r = m["roofline"]
            rows.append(
                (f"roofline_{mesh_dir}_{m['arch']}_{m['shape']}", _dom(r) * 1e6,
                 f"bottleneck={r['bottleneck']};useful_ratio={r['useful_flops_ratio']:.3f}")
            )
        else:
            rows.append(
                (f"roofline_{mesh_dir}_{m['arch']}_{m['shape']}", 0.0, m["status"])
            )
    return rows


if __name__ == "__main__":
    run("single")
    run("multi")
