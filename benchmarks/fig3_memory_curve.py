"""Paper Figure 3: memory vs batch size per clipping algorithm (CNN)."""
from __future__ import annotations

import jax

from benchmarks.common import MODES_BENCH, SmallCNN, cnn_batch, compiled_memory_bytes
from repro.core.clipping import ClipConfig, dp_value_and_clipped_grad


def run(fast: bool = False) -> list[tuple[str, float, str]]:
    model = SmallCNN(width=16)
    params = model.init(jax.random.PRNGKey(0))
    batches = [16, 64] if fast else [16, 64, 256]
    rows = []
    for mode in MODES_BENCH:
        fn = dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig(mode=mode))
        pts = []
        for b in batches:
            bd = cnn_batch(b, image=16)
            specs = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), (params, bd)
            )
            pts.append(f"{b}:{compiled_memory_bytes(fn, *specs)/1e6:.1f}MB")
        rows.append((f"fig3_memcurve_{mode}", 0.0, ";".join(pts)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
