"""One rank of the live 2-process consensus fleet (run under pytest via
``test_two_process.py`` — not a test module itself).

Each rank joins a real ``jax.distributed`` fleet (CPU backend) and drives
the actual ``repro.tuner.consensus`` code paths — ``default_gather`` over
the coordination-service KV store, leader election, full ``fleet_agree``
plan adoption with a measured plan built only on the leader, and the
certify gate's divergence detection.  Results are written as JSON so the
parent test can cross-check the two ranks byte for byte.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import traceback

os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--coordinator", required=True)
    ap.add_argument("--rank", type=int, required=True)
    ap.add_argument("--num", type=int, required=True)
    ap.add_argument("--out", required=True)
    args = ap.parse_args()

    import jax

    jax.distributed.initialize(
        coordinator_address=args.coordinator,
        num_processes=args.num,
        process_id=args.rank,
    )
    assert jax.process_count() == args.num, jax.process_count()
    assert jax.process_index() == args.rank, jax.process_index()

    from repro.tuner import consensus

    results: dict = {"rank": args.rank, "n": jax.process_count()}

    # 1. raw payload all-gather over the real fleet (the primitive every
    # consensus phase rides on) — NOT a simulated list-gather
    gathered = consensus.default_gather(
        {"rank": args.rank, "token": f"tok-{args.rank}"}
    )
    results["gather_tokens"] = sorted(p["token"] for p in gathered)
    results["gather_ranks"] = sorted(int(p["rank"]) for p in gathered)

    # 2. leader election over live device reports
    roles = consensus.fleet_roles()
    results["is_leader"] = roles.is_leader
    results["leaders"] = list(map(list, roles.leaders))
    results["fleet"] = list(map(list, roles.fleet))

    # 3. full plan adoption: the leader measures a real (tiny) plan; the
    # non-leader contributes None and must still adopt identical bytes
    from repro.configs.registry import build_model, get_arch
    from repro.core.clipping import discover_meta
    from repro.data.synthetic import synthetic_arch_batch
    from repro.tuner.measure import MeasureConfig, build_plan

    cfg = get_arch("yi-6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    probe = synthetic_arch_batch(cfg, batch=2, seq=16)
    metas = discover_meta(model.loss_with_ctx, params, probe)
    local_plan = None
    if roles.is_leader:
        local_plan = build_plan(
            metas,
            measure=MeasureConfig(repeats=1, warmup=0, max_rows=8),
            arch=cfg.name,
        )
    adopted = consensus.fleet_agree(local_plan, metas)
    results["plan_json"] = adopted.to_json()
    results["plan_hash"] = adopted.consensus_hash()
    results["agreed_ranks"] = adopted.agreed_ranks
    results["leader_process"] = adopted.leader_process

    # 4. certify gate: agreement passes, a rank-dependent value must raise
    # PlanConsensusError on EVERY rank (all gathers stay sequence-aligned)
    consensus.certify_fleet_value("uniform", "same-everywhere")
    results["certify_uniform_ok"] = True
    try:
        consensus.certify_fleet_value("divergent", f"rank-{args.rank}")
        results["divergence_detected"] = False
    except consensus.PlanConsensusError as e:
        results["divergence_detected"] = True
        results["divergence_error"] = str(e)[:200]

    pathlib.Path(args.out).write_text(json.dumps(results, sort_keys=True))
    jax.distributed.shutdown()
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except Exception:
        traceback.print_exc()
        sys.exit(1)
