"""Live 2-process ``jax.distributed`` consensus test.

Launches two real OS processes that join one coordination service and run
``repro.tuner.consensus`` end to end (see ``_worker.py``): the gather here
is the production ``default_gather`` over the coordination-service KV
store — no simulated list-gather anywhere.  CI runs this file as its own
job (CPU backend, bounded timeout); a hung collective kills the fleet and
fails the test instead of wedging the runner.
"""
from __future__ import annotations

import json
import os
import pathlib
import socket
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[2]
WORKER = pathlib.Path(__file__).with_name("_worker.py")
N = 2
TIMEOUT_S = 300


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _launch_fleet(tmp_path):
    port = _free_port()
    env = dict(
        os.environ,
        JAX_PLATFORMS="cpu",
        XLA_FLAGS="--xla_force_host_platform_device_count=1",
        PYTHONPATH=str(REPO / "src"),
        REPRO_CONSENSUS_TIMEOUT_MS="120000",
    )
    outs = [tmp_path / f"rank{r}.json" for r in range(N)]
    procs = [
        subprocess.Popen(
            [sys.executable, str(WORKER),
             "--coordinator", f"127.0.0.1:{port}",
             "--rank", str(r), "--num", str(N), "--out", str(outs[r])],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True,
        )
        for r in range(N)
    ]
    logs = []
    try:
        for p in procs:
            out, _ = p.communicate(timeout=TIMEOUT_S)
            logs.append(out)
    except subprocess.TimeoutExpired:
        for p in procs:
            p.kill()
        for p in procs:
            out, _ = p.communicate()
            logs.append(out)
        pytest.fail(
            "fleet hung past %ds:\n%s" % (TIMEOUT_S, "\n---\n".join(logs))
        )
    for r, p in enumerate(procs):
        assert p.returncode == 0, (
            f"rank {r} exited {p.returncode}:\n" + "\n---\n".join(logs)
        )
    return [json.loads(o.read_text()) for o in outs]


def test_two_process_consensus_fleet(tmp_path):
    r0, r1 = _launch_fleet(tmp_path)

    # both ranks saw a real 2-process fleet
    assert r0["n"] == N and r1["n"] == N

    # the raw default_gather carried every rank's payload to every rank
    for r in (r0, r1):
        assert r["gather_tokens"] == ["tok-0", "tok-1"]
        assert r["gather_ranks"] == [0, 1]

    # leader election: rank 0 (lowest index of the one CPU device kind)
    assert r0["is_leader"] is True
    assert r1["is_leader"] is False
    assert r0["leaders"] == r1["leaders"]
    assert r0["fleet"] == r1["fleet"] and len(r0["fleet"]) == N

    # plan adoption: only the leader measured, yet BOTH ranks hold the
    # byte-identical fleet-agreed plan (the GSPMD correctness requirement)
    assert r0["plan_json"] == r1["plan_json"]
    assert r0["plan_hash"] == r1["plan_hash"]
    assert r0["agreed_ranks"] == r1["agreed_ranks"] == N
    assert r0["leader_process"] == r1["leader_process"] == 0

    # certify gate: uniform values pass, a rank-dependent value raised
    # PlanConsensusError on BOTH ranks (divergence may never pass silently)
    for r in (r0, r1):
        assert r["certify_uniform_ok"] is True
        assert r["divergence_detected"] is True
