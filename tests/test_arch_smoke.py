"""Per-architecture smoke tests: reduced config of the same family, one DP
train step + prefill/decode on CPU; output shapes + no NaNs (assignment
requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS, build_model
from repro.launch.specs import materialize, prefill_batch_specs, train_batch_specs
from repro.launch.steps import (
    DPTrainConfig,
    make_decode_step,
    make_train_state,
    make_train_step,
)
from repro.optim import adam, warmup_cosine

SMOKE = ShapeConfig("smoke", 32, 2, "train")


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_train_and_serve_smoke(name):
    cfg = ARCHS[name].reduced()
    model = build_model(cfg)
    optimizer = adam()
    state = make_train_state(model, jax.random.PRNGKey(0), optimizer)

    batch = materialize(
        train_batch_specs(cfg, SMOKE, 2), jax.random.PRNGKey(1), vocab=cfg.vocab
    )
    dp = DPTrainConfig(clipping_mode="mixed_ghost", clip_norm=1.0,
                       noise_multiplier=0.5, logical_batch=2)
    step = jax.jit(make_train_step(model, optimizer, warmup_cosine(1e-3, 2, 10), dp))
    state2, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    for leaf in jax.tree_util.tree_leaves(state2["params"]):
        assert not bool(jnp.any(jnp.isnan(leaf)))

    # serving: prefill 16 tokens then decode 2
    pre = ShapeConfig("p", 16, 2, "prefill")
    pbatch = materialize(
        prefill_batch_specs(cfg, pre, 2), jax.random.PRNGKey(2), vocab=cfg.vocab
    )
    sstate = model.init_state(2, 32)
    logits, sstate = jax.jit(model.prefill)(state2["params"], pbatch, sstate)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    decode = jax.jit(make_decode_step(model))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for _ in range(2):
        tok, lg, sstate = decode(state2["params"], tok, sstate)
    assert lg.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(lg)))


def test_decode_matches_full_forward_dense():
    """Incremental decode must equal teacher-forced forward (KV-cache proof)."""
    cfg = ARCHS["yi-6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)

    from repro.core.taps import Ctx

    x, _ = model._trunk(params, toks, Ctx.disabled())
    full_logits = model.lm_head(params["lm_head"], x, Ctx.disabled())

    state = model.init_state(2, 16)
    logits, state = model.prefill(params, {"tokens": toks[:, :8]}, state)
    assert jnp.allclose(logits[:, -1], full_logits[:, 7], atol=2e-4)
    for i in range(8, 12):
        logits, state = model.decode_step(params, toks[:, i : i + 1], state)
        assert jnp.allclose(logits[:, 0], full_logits[:, i], atol=2e-4), i


def test_decode_matches_full_forward_ssm():
    cfg = ARCHS["xlstm-350m"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0, cfg.vocab)

    from repro.core.taps import Ctx

    x, _ = model._trunk(params, toks, Ctx.disabled())
    full_logits = model.lm_head(params["lm_head"], x, Ctx.disabled())

    state = model.init_state(1, 12)
    logits, state = model.prefill(params, {"tokens": toks[:, :6]}, state)
    assert jnp.allclose(logits[:, -1], full_logits[:, 5], atol=3e-4)
    for i in range(6, 10):
        logits, state = model.decode_step(params, toks[:, i : i + 1], state)
        assert jnp.allclose(logits[:, 0], full_logits[:, i], atol=3e-4), i


def test_all_cells_enumerated():
    from repro.configs.registry import all_cells

    cells = list(all_cells())
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(skipped) == 7  # 7 full-attention archs skip long_500k
    assert all(s.name == "long_500k" for _, s, ok in skipped if not ok)
