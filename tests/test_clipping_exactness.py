"""The paper's central correctness claim (Sec. 2.1): mixed ghost clipping is
*exactly* the same mechanism as per-sample-gradient clipping — only cheaper.

Every mode must produce the same per-sample norms and the same clipped
gradient sum as the vmap(grad) oracle, across every layer family the
framework supports.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.clipping import (
    ClipConfig,
    discover_meta,
    dp_value_and_clipped_grad,
    validate_coverage,
)
from repro.core.taps import Ctx
from repro.nn.attention import Attention
from repro.nn.conv import Conv2d, global_avg_pool
from repro.nn.mamba import MambaBlock
from repro.nn.mlp import GatedMLP
from repro.nn.module import Dense, Embedding, GroupNorm, LayerNorm, Module, RMSNorm
from repro.nn.moe import MoE
from repro.nn.stack import ScannedStack
from repro.nn.xlstm import MLSTMBlock, SLSTMBlock

from helpers import lm_batch, max_tree_diff

MODES = ["ghost", "fastgradclip", "mixed_ghost", "bk_mixed", "bk_mixed_taps"]


def _run_all_modes(loss_with_ctx, params, batch, clip_norm=0.3):
    out = {}
    for mode in ["vmap"] + MODES:
        fn = jax.jit(
            dp_value_and_clipped_grad(loss_with_ctx, ClipConfig(mode=mode, clip_norm=clip_norm))
        )
        out[mode] = fn(params, batch)
    return out


def _assert_matches(results, tol=5e-5):
    ref_loss, ref_g, ref_aux = results["vmap"]
    scale = max(float(jnp.max(ref_aux["per_sample_norms"])), 1.0)
    for mode in MODES:
        loss, g, aux = results[mode]
        assert jnp.allclose(loss, ref_loss, rtol=1e-5), mode
        nerr = float(jnp.max(jnp.abs(aux["per_sample_norms"] - ref_aux["per_sample_norms"])))
        assert nerr / scale < tol, (mode, nerr, scale)
        gerr = max_tree_diff(ref_g, g)
        assert gerr < tol, (mode, gerr)


class _MLPModel:
    def __init__(self, vocab=17, d=8, f=12, key=jax.random.PRNGKey(0)):
        self.emb = Embedding("emb", vocab, d)
        self.l1 = Dense("l1", d, f, use_bias=True)
        self.norm = RMSNorm("n", f)
        self.l2 = Dense("l2", f, vocab, use_bias=False)
        ks = jax.random.split(key, 4)
        self.params = {
            "emb": self.emb.init(ks[0]), "l1": self.l1.init(ks[1]),
            "n": self.norm.init(ks[2]), "l2": self.l2.init(ks[3]),
        }

    def loss_with_ctx(self, params, batch, ctx):
        x = self.emb(params["emb"], batch["tokens"], ctx.scope("emb"))
        h = jax.nn.gelu(self.l1(params["l1"], x, ctx.scope("l1")))
        h = self.norm(params["n"], h, ctx.scope("n"))
        logits = self.l2(params["l2"], h, ctx.scope("l2"))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
        nll = nll * batch["mask"][:, None]
        return jnp.mean(nll, axis=-1)


def test_dense_embedding_norm_exactness():
    m = _MLPModel()
    batch = lm_batch(jax.random.PRNGKey(1), 4, 6, 17)
    _assert_matches(_run_all_modes(m.loss_with_ctx, m.params, batch))


def test_poisson_mask_zeroes_contributions():
    m = _MLPModel()
    batch = lm_batch(jax.random.PRNGKey(1), 4, 6, 17)
    batch["mask"] = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    res = _run_all_modes(m.loss_with_ctx, m.params, batch)
    _assert_matches(res)
    # masked samples must have zero clip factor
    _, _, aux = res["mixed_ghost"]
    assert float(aux["clip_factors"][1]) == 0.0
    assert float(aux["clip_factors"][3]) == 0.0


def test_coverage_validation_catches_untapped_params():
    m = _MLPModel()
    batch = lm_batch(jax.random.PRNGKey(1), 2, 4, 17)

    def leaky_loss(params, b, ctx):
        # l1 applied WITHOUT dp taps (dp disabled via Ctx.disabled scope hack)
        x = m.emb(params["emb"], b["tokens"], ctx.scope("emb"))
        h = jax.nn.gelu(m.l1(params["l1"], x, Ctx.disabled()))
        h = m.norm(params["n"], h, ctx.scope("n"))
        logits = m.l2(params["l2"], h, ctx.scope("l2"))
        return jnp.mean(logits, axis=(1, 2))

    meta = discover_meta(leaky_loss, m.params, batch)
    missing = validate_coverage(meta, m.params)
    assert "l1/w" in missing and "l1/b" in missing


def test_conv2d_exactness():
    gn = GroupNorm("gn", 8, groups=4)
    c1 = Conv2d("c1", 3, 8, (3, 3), padding="SAME")
    c2 = Conv2d("c2", 8, 8, (3, 3), strides=(2, 2), padding="SAME")
    head = Dense("head", 8, 10)
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    params = {"c1": c1.init(ks[0]), "gn": gn.init(ks[1]), "c2": c2.init(ks[2]),
              "head": head.init(ks[3])}

    def loss(params, batch, ctx):
        h = c1(params["c1"], batch["image"], ctx.scope("c1"))
        h = jax.nn.relu(gn(params["gn"], h, ctx.scope("gn")))
        h = c2(params["c2"], h, ctx.scope("c2"))
        h = global_avg_pool(h)
        logits = head(params["head"], h[:, None, :], ctx.scope("head"))[:, 0]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]

    batch = {
        "image": jax.random.normal(jax.random.PRNGKey(4), (4, 10, 10, 3)),
        "y": jax.random.randint(jax.random.PRNGKey(5), (4,), 0, 10),
    }
    _assert_matches(_run_all_modes(loss, params, batch))


class _StackModel(Module):
    def __init__(self):
        d = 16
        self.d = d

        class Block(Module):
            def __init__(self):
                self.n1 = RMSNorm("n1", d)
                self.attn = Attention("attn", d, 4, 2, block_q=4, block_kv=4)
                self.n2 = RMSNorm("n2", d)
                self.moe = MoE("moe", d, 20, n_experts=4, top_k=2)

            def init(self, key):
                ks = jax.random.split(key, 4)
                return {"n1": self.n1.init(ks[0]), "attn": self.attn.init(ks[1]),
                        "n2": self.n2.init(ks[2]), "moe": self.moe.init(ks[3])}

            def __call__(self, params, x, ctx, cache=None, **kw):
                h, _ = self.attn(params["attn"], self.n1(params["n1"], x, ctx.scope("n1")),
                                 ctx.scope("attn"))
                x = x + h
                x = x + self.moe(params["moe"], self.n2(params["n2"], x, ctx.scope("n2")),
                                 ctx.scope("moe"))
                return x, cache

        self.emb = Embedding("emb", 13, d)
        self.stack = ScannedStack("layers", Block(), 2, remat=True)
        self.head = Dense("head", d, 13, use_bias=False)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        self.params = {"emb": self.emb.init(ks[0]), "layers": self.stack.init(ks[1]),
                       "head": self.head.init(ks[2])}

    def loss_with_ctx(self, params, batch, ctx):
        x = self.emb(params["emb"], batch["tokens"], ctx.scope("emb"))
        x, _ = self.stack(params["layers"], x, ctx.scope("layers"))
        logits = self.head(params["head"], x, ctx.scope("head"))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
        return jnp.mean(nll, axis=-1)


def test_scanned_stack_attention_moe_exactness():
    m = _StackModel()
    batch = lm_batch(jax.random.PRNGKey(1), 3, 6, 13)
    _assert_matches(_run_all_modes(m.loss_with_ctx, m.params, batch))


def test_ssm_blocks_exactness():
    d, v = 8, 11
    mamba = MambaBlock("m", d, expand=2, head_dim=4, d_state=4, chunk=4)
    mls = MLSTMBlock("ml", d, n_heads=2, chunk=4)
    sls = SLSTMBlock("sl", d, n_heads=2)
    emb = Embedding("emb", v, d)
    head = Dense("head", d, v, use_bias=False)
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    params = {"emb": emb.init(ks[0]), "mamba": mamba.init(ks[1]),
              "mlstm": mls.init(ks[2]), "slstm": sls.init(ks[3]),
              "head": head.init(ks[4])}

    def loss(params, batch, ctx):
        x = emb(params["emb"], batch["tokens"], ctx.scope("emb"))
        h, _ = mamba(params["mamba"], x, ctx.scope("mamba"))
        x = x + h
        x, _ = mls(params["mlstm"], x, ctx.scope("mlstm"))
        x, _ = sls(params["slstm"], x, ctx.scope("slstm"))
        logits = head(params["head"], x, ctx.scope("head"))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
        return jnp.mean(nll, axis=-1)

    batch = lm_batch(jax.random.PRNGKey(1), 3, 7, v)
    meta = discover_meta(loss, params, batch)
    assert not validate_coverage(meta, params)
    _assert_matches(_run_all_modes(loss, params, batch))


def test_decision_modes_agree_on_gradients_not_costs():
    """ghost vs instantiate pick different branches but identical results."""
    m = _MLPModel()
    batch = lm_batch(jax.random.PRNGKey(2), 4, 6, 17)
    meta = discover_meta(m.loss_with_ctx, m.params, batch)
    from repro.core.decision import decide

    branches_space = {k: decide(v, mode="mixed_ghost", by="space") for k, v in meta.items()}
    branches_time = {k: decide(v, mode="mixed_ghost", by="time") for k, v in meta.items()}
    branches_bk = {k: decide(v, mode="bk_mixed") for k, v in meta.items()}
    assert set(branches_space.values()) <= {"ghost", "instantiate"}
    assert set(branches_time.values()) <= {"ghost", "instantiate"}
    assert set(branches_bk.values()) <= {"ghost", "instantiate"}


def test_coverage_validation_raises_on_duplicate_taps():
    """Two taps claiming the same param leaf double-count its norm: raise."""
    m = _MLPModel()
    batch = lm_batch(jax.random.PRNGKey(1), 2, 4, 17)

    def doubled_loss(params, b, ctx):
        # the same Dense applied twice under different tap names but the
        # SAME param path: classic accidental weight sharing
        x = m.emb(params["emb"], b["tokens"], ctx.scope("emb"))
        h = jax.nn.gelu(m.l1(params["l1"], x, ctx.scope("l1")))
        h = h + m.l1(params["l1"], x, ctx.scope("l1_again").scope("l1"))
        h = m.norm(params["n"], h, ctx.scope("n"))
        logits = m.l2(params["l2"], h, ctx.scope("l2"))
        return jnp.mean(logits, axis=(1, 2))

    meta = discover_meta(doubled_loss, m.params, batch)
    # rewrite the duplicate tap's param_path back to the shared leaf (the
    # scope prefix would otherwise make it a distinct — missing — path)
    import dataclasses as _dc

    dup = {}
    for name, mm in meta.items():
        if name.startswith("l1_again/"):
            mm = _dc.replace(mm, param_path="l1/w", bias_path="l1/b")
        dup[name] = mm
    with pytest.raises(ValueError) as e:
        validate_coverage(dup, m.params)
    assert "l1/out" in str(e.value) and "l1_again/l1/out" in str(e.value)
    assert "double-counted" in str(e.value)


def test_frozen_prefixes_bk_and_ghost_agree_on_covered_leaves():
    """Untapped-but-frozen params: clean coverage, zero bk grads, and the
    fused bk gradients still match mixed_ghost on every covered leaf."""
    m = _MLPModel()
    frozen_head = Dense("l2", 12, 17, use_bias=False, dp=False)

    def loss(params, b, ctx):
        x = m.emb(params["emb"], b["tokens"], ctx.scope("emb"))
        h = jax.nn.gelu(m.l1(params["l1"], x, ctx.scope("l1")))
        logits = frozen_head(params["l2"], h, ctx.scope("l2"))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, b["labels"][..., None], axis=-1)[..., 0]
        return jnp.mean(nll * b["mask"][:, None], axis=-1)

    params = {"emb": m.params["emb"], "l1": m.params["l1"],
              "l2": frozen_head.init(jax.random.PRNGKey(7))}
    batch = lm_batch(jax.random.PRNGKey(1), 4, 6, 17)
    batch["mask"] = jnp.asarray([1.0, 0.0, 1.0, 1.0])

    meta = discover_meta(loss, params, batch)
    assert validate_coverage(meta, params) == ["l2/w"]
    assert validate_coverage(meta, params, frozen_prefixes=("l2",)) == []

    cfg = dict(clip_norm=0.3, frozen_prefixes=("l2",))
    out = {}
    for mode in ["mixed_ghost", "bk_mixed", "bk_mixed_taps"]:
        fn = jax.jit(dp_value_and_clipped_grad(loss, ClipConfig(mode=mode, **cfg)))
        out[mode] = fn(params, batch)
    _, g_ref, aux_ref = out["mixed_ghost"]
    for mode in ["bk_mixed", "bk_mixed_taps"]:
        _, g, aux = out[mode]
        assert jnp.allclose(
            aux["per_sample_norms"], aux_ref["per_sample_norms"], atol=1e-5
        ), mode
        # frozen leaf: book-keeping owes it nothing (zeros) — the
        # second-backward engine reports its unclipped weighted grad, which
        # is why frozen params must never reach the optimizer
        assert float(jnp.max(jnp.abs(g["l2"]["w"]))) == 0.0
        for key in ("emb", "l1"):
            assert max_tree_diff(g_ref[key], g[key]) < 5e-5, (mode, key)


def test_kernel_choice_flips_cost_not_math():
    """The psg-contraction (and every other dispatch op) in the oracle
    matrix with the kernel choice flipped both ways: Pallas and XLA impls
    must produce the same losses, per-sample norms, and clipped gradients —
    a kernel choice moves timings only."""
    from repro.kernels import dispatch

    m = _MLPModel()
    batch = lm_batch(jax.random.PRNGKey(1), 4, 6, 17)

    def run(mode, impl):
        # build + trace inside the context: dispatch resolves at trace time
        with dispatch.force_impl(impl):
            fn = dp_value_and_clipped_grad(
                m.loss_with_ctx, ClipConfig(mode=mode, clip_norm=0.3)
            )
            return fn(m.params, batch)

    for mode in ["mixed_ghost", "bk_mixed", "bk_mixed_taps"]:
        l_x, g_x, aux_x = run(mode, "xla")
        l_p, g_p, aux_p = run(mode, "pallas")
        assert jnp.allclose(l_x, l_p, rtol=1e-6), mode
        assert jnp.allclose(
            aux_x["per_sample_norms"], aux_p["per_sample_norms"], atol=2e-5
        ), mode
        assert max_tree_diff(g_x, g_p) < 2e-5, mode


def test_embedding_vocab_guard_raises_on_fused_engines():
    """Ids cross the fused bank side channel as fp32: a vocab >= 2^24 would
    silently corrupt high token ids, so tracing must raise — on the norm
    path and the book-keeping weighted-grad path alike.  The explicit taps
    engine keeps integer ids and stays usable."""
    import dataclasses as _dc

    import repro.core.ghost as ghost_mod
    from repro.core.taps import TapMeta

    big_vocab = ghost_mod.MAX_EXACT_FP32_ID  # == 2^24: first size the (
    # deliberately conservative) guard rejects
    b, t, p = 2, 4, 3
    meta = TapMeta(
        kind="embedding", T=t, D=big_vocab, p=p, s_shape=(b, t, p),
        s_dtype=jnp.float32, param_path="emb/e", batch_size=b, fused=True,
        a_shape=(b, t), a_dtype=jnp.float32,
    )
    ids_f32 = jnp.zeros((b, t), jnp.float32)
    ids_int = jnp.zeros((b, t), jnp.int32)
    g = jnp.ones((b, t, p), jnp.float32)

    # norm path, fp32 ids (fused engine): trace-time error
    with pytest.raises(ValueError, match="2\\^24"):
        ghost_mod.tap_norm_sq(meta, ids_f32, g)
    # bank path (bk_mixed): same guard before anything is banked
    with pytest.raises(ValueError, match="2\\^24"):
        ghost_mod.tap_bank(meta, ids_f32, g, mode="bk_mixed")
    # weighted-grad path from a banked book: guarded before the round-trip
    with pytest.raises(ValueError, match="banked-id round-trip"):
        ghost_mod.bank_weighted_grads(
            meta, {"a": ids_f32, "g": g, "n": jnp.ones((b,))},
            jnp.ones((b,)), (big_vocab, p),
        )
    # integer ids (explicit taps engine) are exact at any vocab: no raise
    out = ghost_mod.tap_norm_sq(meta, ids_int, g)
    assert out.shape == (b,)
    # one id below the limit: fp32 is exact and the fused engine works
    ok_meta = _dc.replace(meta, D=big_vocab - 1)
    out = ghost_mod.tap_norm_sq(ok_meta, ids_f32, g)
    assert out.shape == (b,)


def test_fused_bk_never_pays_the_explicit_engine_memory():
    """The fused bk engine must beat the zero-taps + acts-dict formulation
    on XLA's compiled peak-memory model (no tap-sized zeros, no acts dict)."""
    gn = GroupNorm("gn", 8, groups=4)
    c1 = Conv2d("c1", 3, 8, (3, 3), padding="SAME")
    head = Dense("head", 8, 10)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    params = {"c1": c1.init(ks[0]), "gn": gn.init(ks[1]), "head": head.init(ks[2])}

    def loss(params, batch, ctx):
        h = jax.nn.relu(gn(params["gn"],
                           c1(params["c1"], batch["image"], ctx.scope("c1")),
                           ctx.scope("gn")))
        h = global_avg_pool(h)
        logits = head(params["head"], h[:, None, :], ctx.scope("head"))[:, 0]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]

    batch = {
        "image": jax.random.normal(jax.random.PRNGKey(4), (16, 16, 16, 3)),
        "y": jax.random.randint(jax.random.PRNGKey(5), (16,), 0, 10),
    }
    specs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), (params, batch)
    )

    def peak(mode):
        fn = dp_value_and_clipped_grad(loss, ClipConfig(mode=mode))
        ma = jax.jit(fn).lower(*specs).compile().memory_analysis()
        return int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                   + ma.temp_size_in_bytes - ma.alias_size_in_bytes)

    assert peak("bk_mixed") < peak("bk_mixed_taps")
