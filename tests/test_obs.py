"""repro.obs: metrics/event streams, sync-count parity, restart survival.

The load-bearing assertions:

* the instrumented train loop performs EXACTLY the same number of
  ``jax.block_until_ready`` calls per run as the un-instrumented loop —
  the PR-7 one-sync-per-logical-batch invariant survives observability;
* the JSONL streams are append-only and a crash-torn final line (made with
  the same ``runtime.inject`` truncation the checkpoint injector uses)
  costs one record, never the read;
* events written across an in-process ``--auto-restart`` land in ONE
  stream with monotone step stamps and a process-monotone ``seq``.
"""
from __future__ import annotations

import gzip
import json
import logging
import os
import sys

import pytest

from repro.obs import (
    EVENT_KINDS,
    JsonlSink,
    MemorySink,
    NullSink,
    configure_run,
    emit_event,
    emit_metrics,
    events_active,
    read_jsonl,
    reset_sinks,
    set_sink,
    summarize_run,
)
from repro.obs import events as obs_events
from repro.obs.profile import ProfileWindow, parse_window
from repro.obs.report import render_text
from repro.obs.timeline import execution_spans, percentile, step_wall_times_ms
from repro.runtime.inject import InjectionPlan, tear_file

ARCH = ["--arch", "yi-6b", "--reduced", "--seq", "16", "--log-every", "4"]


def _mem_sinks():
    ev, mt = MemorySink(), MemorySink()
    set_sink("events", ev)
    set_sink("metrics", mt)
    return ev, mt


# -- sinks + stamping ------------------------------------------------------
def test_default_sink_is_inert_and_emits_are_free():
    reset_sinks()
    assert not events_active()
    emit_event("run_started", arch="x")  # no sink: must not raise
    emit_metrics({"kind": "train_step"})


def test_unknown_event_kind_raises_even_when_inert():
    reset_sinks()
    with pytest.raises(ValueError, match="unknown event kind"):
        emit_event("made_up_kind")


def test_reserved_stamp_fields_rejected():
    _mem_sinks()
    with pytest.raises(ValueError, match="collide"):
        emit_event("run_started", seq=16)


def test_stamping_run_id_rank_and_monotone_seq():
    ev, _ = _mem_sinks()
    obs_events.set_run_context("run-test")
    emit_event("run_started", arch="a")
    emit_event("run_finished", step=3, epsilon=1.0)
    a, b = ev.records
    assert a["kind"] == "run_started" and a["run_id"] == "run-test"
    assert a["rank"] == 0 and "t" in a
    assert b["step"] == 3 and b["seq"] > a["seq"]
    assert all(k in EVENT_KINDS for k in (a["kind"], b["kind"]))


def test_jsonl_sink_appends_and_survives_torn_tail(tmp_path):
    path = tmp_path / "events.jsonl"
    sink = JsonlSink(path)
    sink.emit({"kind": "a", "n": 1})
    sink.emit({"kind": "b", "n": 2})
    sink.close()
    # crash mid-write: the SAME truncation the torn@step checkpoint
    # injector applies — the final line becomes a prefix of a record
    tear_file(path)
    torn_lines = path.read_text().splitlines()
    assert len(torn_lines) >= 1
    with pytest.raises(json.JSONDecodeError):
        json.loads(torn_lines[-1])
    assert read_jsonl(path) == []  # both records damaged at 1/3 length
    # a restarted process APPENDS past the torn tail; the new record reads
    # back even though the torn prefix is still physically in the file
    sink2 = JsonlSink(path)
    sink2.emit({"kind": "c", "n": 3})
    sink2.close()
    got = read_jsonl(path)
    assert [r["kind"] for r in got] == ["c"]
    assert path.read_text().splitlines()[0] == torn_lines[0]  # append-only


def test_read_jsonl_missing_file_and_garbage_lines(tmp_path):
    assert read_jsonl(tmp_path / "nope.jsonl") == []
    p = tmp_path / "m.jsonl"
    p.write_text('{"ok": 1}\nnot json\n[1,2]\n{"ok": 2}\n')
    assert [r["ok"] for r in read_jsonl(p)] == [1, 2]


def test_configure_run_same_dir_keeps_stream_none_resets(tmp_path):
    rid = configure_run(tmp_path)
    assert rid and events_active()
    emit_event("run_started")
    # same dir (a --auto-restart attempt): sinks and run_id survive
    assert configure_run(tmp_path) == rid
    emit_event("run_finished")
    assert [r["kind"] for r in read_jsonl(tmp_path / "events.jsonl")] == [
        "run_started", "run_finished",
    ]
    assert configure_run(None) is None
    assert not events_active()


# -- emit points in the runtime --------------------------------------------
def test_watchdog_trip_emits_event():
    from repro.runtime.fault import StepWatchdog

    ev, _ = _mem_sinks()
    wd = StepWatchdog(trip_factor=3.0)
    wd.times.extend([0.01] * 10)
    wd.start_step()
    wd._t0 -= 1.0  # pretend the step took ~1s against a 10ms median
    wd.end_step(7)
    trips = [r for r in ev.records if r["kind"] == "watchdog_trip"]
    assert len(trips) == 1
    assert trips[0]["step"] == 7 and trips[0]["dt_s"] > trips[0]["median_s"]


def test_injection_emits_fault_event():
    ev, _ = _mem_sinks()
    InjectionPlan.from_spec("slow@1:0", env="").on_step(1)
    faults = [r for r in ev.records if r["kind"] == "fault_injected"]
    assert faults and faults[0]["spec"] == "slow@1:0"


def test_checkpoint_manager_emits_saved_and_restored(tmp_path):
    import numpy as np

    from repro.checkpoint.manager import CheckpointManager

    ev, _ = _mem_sinks()
    mgr = CheckpointManager(str(tmp_path), save_every=1, async_save=False)
    mgr.save(1, {"w": np.ones((2,), np.float32)}, force=True)
    step, state = mgr.restore()
    assert step == 1 and state["w"].shape == (2,)
    kinds = [r["kind"] for r in ev.records]
    assert kinds.count("checkpoint_saved") == 1
    assert kinds.count("checkpoint_restored") == 1
    saved = next(r for r in ev.records if r["kind"] == "checkpoint_saved")
    assert saved["step"] == 1 and saved["path"].endswith("step_1.npz")


def test_queue_stats_and_shed_event():
    from repro.serving.queue import LatencyModel, Request, RequestQueue

    ev, _ = _mem_sinks()
    q = RequestQueue(LatencyModel())
    q.model.observe_prefill(10, 1.0)   # 100ms per prompt token
    q.model.observe_step(0.05)
    s = q.stats(free_slots=0, active_remaining=[4])
    assert s["queue_depth"] == 0 and s["shed_total"] == 0
    assert s["prefill_s_per_token"] == pytest.approx(0.1)
    assert s["step_s"] == pytest.approx(0.05)
    assert s["projected_wait_s"] == pytest.approx(4 * 0.05)
    # a 20-token prompt projects ~2s TTFT: a 100ms SLO must shed, and the
    # shed decision must land in the events stream with its projection
    admitted = q.offer(Request(rid=7, tokens=[1] * 20, slo_ttft_ms=100.0),
                       free_slots=1, active_remaining=[])
    assert not admitted
    shed = [r for r in ev.records if r["kind"] == "request_shed"]
    assert shed[0]["rid"] == 7
    assert shed[0]["projected_ttft_ms"] > shed[0]["slo_ttft_ms"]
    assert q.stats()["shed_total"] == 1


# -- train-loop integration ------------------------------------------------
def _count_syncs(monkeypatch, argv):
    """Run launch.train.main(argv) counting jax.block_until_ready calls."""
    import jax

    from repro.launch import train

    real = jax.block_until_ready
    calls = {"n": 0}

    def counting(x):
        calls["n"] += 1
        return real(x)

    monkeypatch.setattr(jax, "block_until_ready", counting)
    try:
        assert train.main(argv) == 0
    finally:
        monkeypatch.setattr(jax, "block_until_ready", real)
    return calls["n"]


def test_instrumentation_adds_zero_block_until_ready(tmp_path, monkeypatch):
    """The tentpole invariant: with the metrics stream ON, the accumulation
    loop performs exactly the same number of host syncs per run as with it
    OFF — one ``block_until_ready`` per logical batch, metrics riding it."""
    # --batch 4 --data-shards 2 on one process -> physical 2, accum 2:
    # the donated-accumulation path, no tuner needed
    base = ARCH + ["--steps", "3", "--batch", "4", "--data-shards", "2"]
    plain = _count_syncs(monkeypatch, list(base))
    obs_dir = tmp_path / "obs"
    instrumented = _count_syncs(
        monkeypatch, base + ["--obs-dir", str(obs_dir)]
    )
    assert plain == instrumented == 3  # one per logical batch, no extras
    train = [m for m in read_jsonl(obs_dir / "metrics.jsonl")
             if m["kind"] == "train_step"]
    assert [m["step"] for m in train] == [1, 2, 3]
    assert all(m["accumulation_steps"] == 2 for m in train)
    assert all(m["epsilon"] > 0 for m in train)
    assert all(m["norm_max"] >= m["norm_mean"] > 0 for m in train)


def test_events_survive_auto_restart_with_monotone_steps(tmp_path):
    from repro.launch.train import main

    d = tmp_path / "run"
    assert main(ARCH + [
        "--ckpt-dir", str(d), "--steps", "4", "--batch", "2",
        "--ckpt-every", "2", "--auto-restart", "2", "--fail-at-step", "2",
    ]) == 0
    events = read_jsonl(d / "events.jsonl")
    kinds = [e["kind"] for e in events]
    # one stream spans both attempts: the crash AND the recovery are visible
    assert kinds.count("run_started") == 2
    assert kinds.count("plan_adopted") == 2
    assert "fault_injected" in kinds
    assert "restart_attempt" in kinds
    assert "checkpoint_restored" in kinds
    assert kinds[-1] == "run_finished"
    # seq is process-monotone across the whole supervision loop
    seqs = [e["seq"] for e in events]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    # metric step stamps never go backwards: the restart resumed, not replayed
    steps = [m["step"] for m in read_jsonl(d / "metrics.jsonl")
             if m["kind"] == "train_step"]
    assert steps and steps == sorted(steps)
    restored = next(e for e in events if e["kind"] == "checkpoint_restored")
    assert all(s >= restored["step"] for s in steps[-2:])
    # every record of both attempts shares one run_id (same-dir reconfigure)
    assert len({e["run_id"] for e in events}) == 1


# -- profiler window + timeline --------------------------------------------
def test_parse_window():
    assert parse_window("3:5") == (3, 5)
    assert parse_window("4") == (4, 4)
    with pytest.raises(ValueError, match="N or N:M"):
        parse_window("a:b")
    with pytest.raises(ValueError, match="0 <= N <= M"):
        parse_window("5:3")


def test_profile_window_captures_real_trace(tmp_path):
    import jax
    import jax.numpy as jnp

    ev, _ = _mem_sinks()
    win = ProfileWindow(0, 1, tmp_path / "profile")
    f = jax.jit(lambda x: x @ x)
    x = jnp.ones((32, 32))
    for step in range(3):
        win.before_step(step)
        jax.block_until_ready(f(x))
        win.after_step(step)
    assert win.done and not win.active
    kinds = [r["kind"] for r in ev.records]
    if "profile_started" not in kinds:
        pytest.skip("profiler unavailable on this backend")
    assert kinds.count("profile_stopped") == 1
    spans = execution_spans(tmp_path / "profile")
    assert spans, "trace written but no execution spans matched"
    assert step_wall_times_ms(tmp_path / "profile")


def test_timeline_groups_synthetic_trace(tmp_path):
    trace = {
        "traceEvents": [
            # step 0: two back-to-back executions (an accum microstep pair)
            {"ph": "X", "name": "TfrtCpuExecutable::Execute", "ts": 0,
             "dur": 100},
            {"ph": "X", "name": "TfrtCpuExecutable::Execute", "ts": 110,
             "dur": 100},
            # 5ms of host work, then step 1
            {"ph": "X", "name": "TfrtCpuExecutable::Execute", "ts": 5210,
             "dur": 300},
            # noise: a non-matching and a non-complete event
            {"ph": "X", "name": "HostLoopOverhead", "ts": 50, "dur": 10},
            {"ph": "B", "name": "TfrtCpuExecutable::Execute", "ts": 60},
        ]
    }
    d = tmp_path / "plugins" / "profile" / "2026"
    d.mkdir(parents=True)
    (d / "host.trace.json.gz").write_bytes(
        gzip.compress(json.dumps(trace).encode())
    )
    spans = execution_spans(tmp_path)
    assert [s["ts_us"] for s in spans] == [0, 110, 5210]
    times = step_wall_times_ms(tmp_path, group_us=1000.0)
    assert times == pytest.approx([0.21, 0.3])
    assert percentile(times, 0.5) == pytest.approx(0.21)
    assert percentile([], 0.5) == 0.0


# -- report + CLI ----------------------------------------------------------
def _fake_run_dir(tmp_path):
    configure_run(tmp_path, run_id="run-x")
    emit_event("run_started", arch="yi-6b")
    emit_event("plan_adopted", mode="mixed_ghost", policy="fixed",
               source="plan", physical_batch=2, accumulation_steps=2,
               branches={"f1": "ghost"}, kernels={"f1": {"fwd": "pallas"}})
    for i, (eps, dt) in enumerate([(0.1, 0.2), (0.2, 0.3), (0.3, 0.25)]):
        emit_metrics({"kind": "train_step", "loss": 1.0, "lr": 1e-3,
                      "clip_frac": 0.5, "epsilon": eps, "delta": 1e-5,
                      "step_s": dt, "examples_per_s": 4 / dt}, step=i + 1)
    emit_event("run_finished", step=3, epsilon=0.3, delta=1e-5)
    reset_sinks()
    return tmp_path


def test_summarize_run_and_render(tmp_path):
    d = _fake_run_dir(tmp_path)
    s = summarize_run(d)
    assert s["train_steps"] == 3
    assert s["epsilon_trajectory"] == [(1, 0.1), (2, 0.2), (3, 0.3)]
    assert s["final_epsilon"] == 0.3 and s["final_delta"] == 1e-5
    assert s["clip_frac_mean"] == pytest.approx(0.5)
    assert s["step_time_p50_s"] == pytest.approx(0.25)
    assert s["restarts"] == 0 and s["run_ids"] == ["run-x"]
    assert s["plan"]["branches"] == {"f1": "ghost"}
    text = render_text(s)
    assert "tap f1: branch=ghost kernels[fwd=pallas]" in text
    assert "epsilon: 0.1000 -> 0.3000" in text


def test_obs_cli_json_and_epsilon_gate(tmp_path, capsys):
    from repro.obs.__main__ import main as cli

    d = _fake_run_dir(tmp_path / "good")
    assert cli([str(d), "--json"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["final_epsilon"] == 0.3
    assert cli([str(d), "--require-epsilon"]) == 0
    empty = tmp_path / "empty"
    empty.mkdir()
    assert cli([str(empty), "--require-epsilon"]) == 1


def test_obs_cli_timeline_renders_profile(tmp_path, capsys):
    from repro.obs.__main__ import main as cli

    d = _fake_run_dir(tmp_path)
    prof = d / "profile" / "plugins" / "profile" / "x"
    prof.mkdir(parents=True)
    (prof / "h.trace.json").write_text(json.dumps({"traceEvents": [
        {"ph": "X", "name": "XlaModule:main", "ts": 0, "dur": 500},
    ]}))
    assert cli([str(d), "--timeline"]) == 0
    assert "profiled steps: 1 span group" in capsys.readouterr().out


# -- logging satellites ----------------------------------------------------
def test_log_level_reread_on_reconfigure(monkeypatch):
    from repro.utils.logging import get_logger, reconfigure

    monkeypatch.setenv("REPRO_LOG_LEVEL", "DEBUG")
    logger = get_logger("obs-test-logger")
    assert logger.level == logging.DEBUG
    monkeypatch.setenv("REPRO_LOG_LEVEL", "WARNING")
    reconfigure()  # module-level `log = get_logger(...)` bindings re-level
    assert logger.level == logging.WARNING
    # and a fresh get_logger call also re-reads the env on its own
    assert get_logger("obs-test-logger").level == logging.WARNING


def test_log_records_carry_rank_prefix_when_distributed(monkeypatch):
    import jax

    from repro.utils.logging import _rank_prefix, get_logger

    assert _rank_prefix() == ""  # single process: no prefix noise
    monkeypatch.setattr(jax, "process_count", lambda: 2)
    monkeypatch.setattr(jax, "process_index", lambda: 1)
    assert _rank_prefix() == "p1 "
    logger = get_logger("obs-rank-test")
    record = logging.LogRecord("obs-rank-test", logging.INFO, __file__, 1,
                               "msg", (), None)
    for f in logger.handlers[0].filters:
        f.filter(record)
    assert record.rank == "p1 "
    assert "p1 " in logging.Formatter(
        "%(levelname).1s %(rank)s%(name)s] %(message)s"
    ).format(record)


def test_rank_prefix_needs_no_jax_import(monkeypatch):
    from repro.utils.logging import _rank_prefix

    monkeypatch.setitem(sys.modules, "jax", None)
    monkeypatch.delitem(sys.modules, "jax")
    assert _rank_prefix() == ""


# -- epsilon budget alarm ---------------------------------------------------
def test_epsilon_alarm_fires_once_and_is_latched():
    from repro.core.engine import PrivacyEngine

    ev, _ = _mem_sinks()
    try:
        engine = PrivacyEngine(
            loss_with_ctx=lambda p, b, c: None,
            batch_size=10,
            sample_size=100,
            max_grad_norm=1.0,
            steps=20,
            target_epsilon=2.0,
        )
        assert not engine.check_epsilon_alarm(0.5, step=0)  # nothing spent yet
        fired = []
        for i in range(engine.steps):
            engine.record_step()
            fired.append(engine.check_epsilon_alarm(0.5, step=i + 1))
        # the sigma bisection lands end-of-run spend at ~target, so the 50%
        # alarm crosses strictly inside the run — and the latch keeps the
        # event one-shot even though we check after every step
        assert sum(fired) == 1
        assert fired.index(True) < engine.steps - 1
        crossed = [r for r in ev.records if r["kind"] == "epsilon_budget_crossed"]
        assert len(crossed) == 1
        rec = crossed[0]
        assert rec["step"] == fired.index(True) + 1
        assert rec["fraction"] == 0.5
        assert rec["target_epsilon"] == 2.0
        assert rec["epsilon"] >= 0.5 * rec["target_epsilon"]
        assert rec["delta"] == engine.target_delta
    finally:
        reset_sinks()


def test_epsilon_alarm_disabled_paths():
    from repro.core.engine import PrivacyEngine

    ev, _ = _mem_sinks()
    try:
        engine = PrivacyEngine(
            loss_with_ctx=lambda p, b, c: None,
            batch_size=10,
            sample_size=100,
            max_grad_norm=1.0,
            steps=5,
            noise_multiplier=0.4,  # no target_epsilon: alarm is a no-op
        )
        engine.record_step(5)
        assert not engine.check_epsilon_alarm(0.5)
        engine.target_epsilon = 0.01  # would fire, but frac<=0 disables
        assert not engine.check_epsilon_alarm(0.0)
        assert ev.records == []
    finally:
        reset_sinks()
