"""Per-kernel validation: Pallas (interpret mode) and chunked-XLA ops vs the
pure-jnp oracles, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.ghost_norm import ops as gops
from repro.kernels.ghost_norm.ghost_norm import ghost_norm_sq_pallas
from repro.kernels.ghost_norm.ref import (
    embedding_ghost_norm_sq_ref,
    ghost_norm_sq_ref,
    instantiated_norm_sq_ref,
)
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import mha_reference


GHOST_SHAPES = [
    (3, 64, 16, 24, jnp.float32),
    (2, 100, 33, 7, jnp.float32),
    (1, 256, 128, 64, jnp.bfloat16),
    (4, 32, 8, 130, jnp.float32),
]


@pytest.mark.parametrize("n,t,d,p,dt", GHOST_SHAPES)
def test_ghost_norm_pallas_vs_ref(n, t, d, p, dt):
    ks = jax.random.split(jax.random.PRNGKey(t * 7 + d), 2)
    a = jax.random.normal(ks[0], (n, t, d)).astype(dt)
    g = jax.random.normal(ks[1], (n, t, p)).astype(dt)
    got = ghost_norm_sq_pallas(a, g, block_t=32, block_f=32, interpret=True)
    want = ghost_norm_sq_ref(a, g)
    assert jnp.allclose(got, want, rtol=2e-4), float(jnp.max(jnp.abs(got - want)))


@pytest.mark.parametrize("n,t,d,p,dt", GHOST_SHAPES)
def test_ghost_norm_chunked_vs_ref(n, t, d, p, dt):
    ks = jax.random.split(jax.random.PRNGKey(n * 31 + p), 2)
    a = jax.random.normal(ks[0], (n, t, d)).astype(dt)
    g = jax.random.normal(ks[1], (n, t, p)).astype(dt)
    got = gops.ghost_norm_sq(a, g, block=32)
    want = ghost_norm_sq_ref(a, g)
    assert jnp.allclose(got, want, rtol=2e-4)


def test_ghost_norm_chunked_path_forced():
    """Force the scan path (T > direct threshold is simulated via block)."""
    import repro.kernels.ghost_norm.ops as mod

    a = jax.random.normal(jax.random.PRNGKey(0), (2, 2048, 8))
    g = jax.random.normal(jax.random.PRNGKey(1), (2, 2048, 4))
    got = mod.ghost_norm_sq(a, g, block=256)
    want = ghost_norm_sq_ref(a, g)
    assert jnp.allclose(got, want, rtol=1e-4)


@pytest.mark.parametrize("d_block", [8, 64])
def test_instantiated_norm_chunked(d_block):
    a = jax.random.normal(jax.random.PRNGKey(0), (3, 20, 50))
    g = jax.random.normal(jax.random.PRNGKey(1), (3, 20, 6))
    got = gops.instantiated_norm_sq(a, g, block_d=d_block)
    want = instantiated_norm_sq_ref(a, g)
    assert jnp.allclose(got, want, rtol=1e-5)


@pytest.mark.parametrize("t,block", [(12, 1024), (300, 128)])
def test_embedding_ghost_norm(t, block):
    ids = jax.random.randint(jax.random.PRNGKey(0), (3, t), 0, 11)
    g = jax.random.normal(jax.random.PRNGKey(1), (3, t, 5))
    got = gops.embedding_ghost_norm_sq(ids, g, block=block)
    want = embedding_ghost_norm_sq_ref(ids, g)
    assert jnp.allclose(got, want, rtol=1e-4)


ATTN_CASES = [
    (2, 64, 64, 4, 2, 16, True, None, 0),
    (1, 128, 128, 4, 4, 8, True, 32, 0),
    (2, 1, 96, 4, 2, 16, True, None, 57),
    (2, 48, 48, 6, 2, 32, False, None, 0),
    (1, 100, 100, 2, 1, 16, True, None, 0),
]


@pytest.mark.parametrize("b,sq,skv,h,kh,hd,causal,window,qoff", ATTN_CASES)
def test_flash_xla_forward(b, sq, skv, h, kh, hd, causal, window, qoff):
    ks = jax.random.split(jax.random.PRNGKey(sq + skv), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd))
    k = jax.random.normal(ks[1], (b, skv, kh, hd))
    v = jax.random.normal(ks[2], (b, skv, kh, hd))
    got = flash_attention(q, k, v, causal=causal, window=window, q_offset=qoff,
                          block_q=32, block_kv=32)
    want = mha_reference(q, k, v, causal=causal, window=window, q_offset=qoff)
    assert jnp.allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("b,sq,skv,h,kh,hd,causal,window,qoff", ATTN_CASES[:2])
def test_flash_xla_gradients(b, sq, skv, h, kh, hd, causal, window, qoff):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd))
    k = jax.random.normal(ks[1], (b, skv, kh, hd))
    v = jax.random.normal(ks[2], (b, skv, kh, hd))
    f = lambda *a: flash_attention(*a, causal=causal, window=window,
                                   q_offset=qoff, block_q=32, block_kv=32).sum()
    r = lambda *a: mha_reference(*a, causal=causal, window=window,
                                 q_offset=qoff).astype(jnp.float32).sum()
    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(r, argnums=(0, 1, 2))(q, k, v)
    for x, y in zip(gf, gr):
        assert jnp.allclose(x, y, atol=3e-5)


@pytest.mark.parametrize(
    "b,h,sq,skv,hd,causal,window,qoff,dt",
    [
        (2, 3, 64, 64, 16, True, None, 0, jnp.float32),
        (1, 2, 100, 100, 32, True, 24, 0, jnp.float32),
        (1, 2, 1, 96, 16, True, None, 95, jnp.float32),
        (2, 2, 48, 48, 16, False, None, 0, jnp.bfloat16),
    ],
)
def test_flash_pallas_vs_ref(b, h, sq, skv, hd, causal, window, qoff, dt):
    ks = jax.random.split(jax.random.PRNGKey(17), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd)).astype(dt)
    k = jax.random.normal(ks[1], (b, skv, h, hd)).astype(dt)
    v = jax.random.normal(ks[2], (b, skv, h, hd)).astype(dt)
    got = flash_attention_pallas(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal, window=window, q_offset=qoff,
        block_q=16, block_kv=32, interpret=True,
    ).transpose(0, 2, 1, 3)
    want = mha_reference(q, k, v, causal=causal, window=window, q_offset=qoff)
    tol = 5e-3 if dt == jnp.bfloat16 else 2e-5
    assert jnp.allclose(got.astype(jnp.float32), want.astype(jnp.float32), atol=tol)
