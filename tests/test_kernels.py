"""Per-kernel validation: Pallas (interpret mode off-TPU, compiled on TPU)
and chunked-XLA ops vs the pure-jnp oracles, swept over shapes and dtypes;
plus the dispatch layer that routes between them."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import dispatch
from repro.kernels.ghost_norm import ops as gops
from repro.kernels.ghost_norm.ghost_norm import (
    embedding_ghost_norm_sq_pallas,
    ghost_norm_sq_pallas,
)
from repro.kernels.ghost_norm.ref import (
    embedding_ghost_norm_sq_ref,
    ghost_norm_sq_ref,
    instantiated_norm_sq_ref,
)
from repro.kernels.psg_contract import ops as cops
from repro.kernels.psg_contract.psg_contract import (
    book_weighted_grad_pallas,
    psg_contract_pallas,
)
from repro.kernels.psg_contract.ref import (
    book_weighted_grad_ref,
    psg_contract_ref,
)
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import mha_reference

on_tpu = jax.default_backend() == "tpu"
requires_tpu = pytest.mark.skipif(
    not on_tpu, reason="compiled (non-interpret) Pallas parity needs a TPU"
)


GHOST_SHAPES = [
    (3, 64, 16, 24, jnp.float32),
    (2, 100, 33, 7, jnp.float32),
    (1, 256, 128, 64, jnp.bfloat16),
    (4, 32, 8, 130, jnp.float32),
]


@pytest.mark.parametrize("n,t,d,p,dt", GHOST_SHAPES)
def test_ghost_norm_pallas_vs_ref(n, t, d, p, dt):
    ks = jax.random.split(jax.random.PRNGKey(t * 7 + d), 2)
    a = jax.random.normal(ks[0], (n, t, d)).astype(dt)
    g = jax.random.normal(ks[1], (n, t, p)).astype(dt)
    got = ghost_norm_sq_pallas(a, g, block_t=32, block_f=32, interpret=True)
    want = ghost_norm_sq_ref(a, g)
    assert jnp.allclose(got, want, rtol=2e-4), float(jnp.max(jnp.abs(got - want)))


@pytest.mark.parametrize("n,t,d,p,dt", GHOST_SHAPES)
def test_ghost_norm_chunked_vs_ref(n, t, d, p, dt):
    ks = jax.random.split(jax.random.PRNGKey(n * 31 + p), 2)
    a = jax.random.normal(ks[0], (n, t, d)).astype(dt)
    g = jax.random.normal(ks[1], (n, t, p)).astype(dt)
    got = gops.ghost_norm_sq(a, g, block=32)
    want = ghost_norm_sq_ref(a, g)
    assert jnp.allclose(got, want, rtol=2e-4)


def test_ghost_norm_chunked_path_forced():
    """Force the scan path (T > direct threshold is simulated via block)."""
    import repro.kernels.ghost_norm.ops as mod

    a = jax.random.normal(jax.random.PRNGKey(0), (2, 2048, 8))
    g = jax.random.normal(jax.random.PRNGKey(1), (2, 2048, 4))
    got = mod.ghost_norm_sq(a, g, block=256)
    want = ghost_norm_sq_ref(a, g)
    assert jnp.allclose(got, want, rtol=1e-4)


@pytest.mark.parametrize("d_block", [8, 64])
def test_instantiated_norm_chunked(d_block):
    a = jax.random.normal(jax.random.PRNGKey(0), (3, 20, 50))
    g = jax.random.normal(jax.random.PRNGKey(1), (3, 20, 6))
    got = gops.instantiated_norm_sq(a, g, block_d=d_block)
    want = instantiated_norm_sq_ref(a, g)
    assert jnp.allclose(got, want, rtol=1e-5)


@pytest.mark.parametrize("t,block", [(12, 1024), (300, 128)])
def test_embedding_ghost_norm(t, block):
    ids = jax.random.randint(jax.random.PRNGKey(0), (3, t), 0, 11)
    g = jax.random.normal(jax.random.PRNGKey(1), (3, t, 5))
    got = gops.embedding_ghost_norm_sq(ids, g, block=block)
    want = embedding_ghost_norm_sq_ref(ids, g)
    assert jnp.allclose(got, want, rtol=1e-4)


@pytest.mark.parametrize("t", [37, 41])
def test_embedding_ghost_norm_pallas_vs_ref(t):
    """Odd T forces the padded path — the two-sentinel machinery included."""
    ids = jax.random.randint(jax.random.PRNGKey(2), (3, t), 0, 7)
    g = jax.random.normal(jax.random.PRNGKey(3), (3, t, 5))
    got = embedding_ghost_norm_sq_pallas(
        ids, g, block_t=16, block_f=8, interpret=not on_tpu
    )
    want = embedding_ghost_norm_sq_ref(ids, g)
    assert jnp.allclose(got, want, rtol=1e-4), float(jnp.max(jnp.abs(got - want)))


def test_embedding_pad_sentinels_never_match():
    """Regression for the single-sentinel padding bug: both id operands were
    padded with the same -1, so pad-vs-pad positions DID match and exactness
    silently rode on the cotangent being zero-padded.  With two distinct
    sentinels, no padded position of either operand may equal ANY position
    of the other — correctness no longer assumes anything about g's padding.
    This test fails if pad_ids_pair ever regresses to one shared sentinel.
    """
    t, block = 37, 16
    ids = jax.random.randint(jax.random.PRNGKey(0), (2, t), 0, 50)
    ids_i, ids_j = gops.pad_ids_pair(ids, block)
    assert ids_i.shape == ids_j.shape == (2, 48)
    assert not bool(jnp.any(ids_i[:, t:, None] == ids_j[:, None, :]))
    assert not bool(jnp.any(ids_j[:, t:, None] == ids_i[:, None, :]))
    # real positions are untouched on both operands
    assert bool(jnp.all(ids_i[:, :t] == ids)) and bool(jnp.all(ids_j[:, :t] == ids))
    # no-padding case: the inputs come back unchanged
    even_i, even_j = gops.pad_ids_pair(ids_i[:, :32], block)
    assert even_i.shape == even_j.shape == (2, 32)
    # end to end: the padded scan path agrees with the oracle
    g = jax.random.normal(jax.random.PRNGKey(1), (2, t, 5))
    got = gops.embedding_ghost_norm_sq(ids, g, block=block)
    assert jnp.allclose(got, embedding_ghost_norm_sq_ref(ids, g), rtol=1e-4)


# ------------------------------------------------------- psg contraction --
BOOK_SHAPES = [
    (1, 64, 16, 24, jnp.float32),
    (2, 100, 33, 7, jnp.float32),
    (3, 37, 8, 130, jnp.float32),
    (1, 256, 64, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("m,r,d,p,dt", BOOK_SHAPES)
def test_book_weighted_grad_pallas_vs_ref(m, r, d, p, dt):
    ks = jax.random.split(jax.random.PRNGKey(r * 3 + d), 3)
    a = jax.random.normal(ks[0], (m, r, d)).astype(dt)
    g = jax.random.normal(ks[1], (m, r, p)).astype(dt)
    w = jax.random.uniform(ks[2], (m, r))
    got = book_weighted_grad_pallas(
        a, g, w, block_r=32, block_d=16, block_p=16, interpret=not on_tpu
    )
    want = book_weighted_grad_ref(a, g, w)
    tol = 5e-2 if dt == jnp.bfloat16 else 2e-4
    assert jnp.allclose(got, want, rtol=tol, atol=tol), float(
        jnp.max(jnp.abs(got - want))
    )


@pytest.mark.parametrize("m,r,d,p,dt", BOOK_SHAPES[:3])
def test_book_weighted_grad_xla_vs_ref(m, r, d, p, dt):
    ks = jax.random.split(jax.random.PRNGKey(m * 13 + p), 3)
    a = jax.random.normal(ks[0], (m, r, d)).astype(dt)
    g = jax.random.normal(ks[1], (m, r, p)).astype(dt)
    w = jax.random.uniform(ks[2], (m, r))
    assert jnp.allclose(
        cops.book_weighted_grad(a, g, w), book_weighted_grad_ref(a, g, w),
        rtol=1e-5, atol=1e-5,
    )


@pytest.mark.parametrize("n,f", [(5, 33), (64, 7), (3, 1024)])
def test_psg_contract_pallas_and_xla_vs_ref(n, f):
    ks = jax.random.split(jax.random.PRNGKey(n + f), 2)
    psg = jax.random.normal(ks[0], (n, f))
    c = jax.random.uniform(ks[1], (n,))
    want = psg_contract_ref(psg, c)
    got = psg_contract_pallas(psg, c, block_n=16, block_f=16, interpret=not on_tpu)
    assert jnp.allclose(got, want, rtol=1e-5, atol=1e-5)
    assert jnp.allclose(cops.psg_contract(psg, c), want, rtol=1e-5, atol=1e-5)


def test_dispatch_psg_contract_axis():
    """The bank layout carries the batch after the stack dims (axis=1)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    psg = jax.random.normal(ks[0], (3, 5, 4, 2))  # (lead, B, *param)
    c = jax.random.uniform(ks[1], (5,))
    want = jnp.einsum("lb...,b->l...", psg, c)
    for impl in ("xla", "pallas"):
        got = dispatch.psg_contract(psg, c, axis=1, impl=impl)
        assert got.shape == (3, 4, 2)
        assert jnp.allclose(got, want, rtol=1e-5, atol=1e-5), impl


# ------------------------------------------------------------- dispatch --
def test_dispatch_constants_mirror_plan_validation():
    """plan.py duplicates the op/impl vocab to stay import-free of the
    kernels package; the two must never drift."""
    from repro.tuner.plan import KERNEL_IMPLS, KERNEL_OPS

    assert KERNEL_OPS == dispatch.OPS
    assert KERNEL_IMPLS == dispatch.IMPLS


def test_dispatch_defaults_follow_backend():
    expected = "pallas" if on_tpu else "xla"
    for op in dispatch.OPS:
        assert dispatch.default_impl(op) == expected
        assert dispatch.resolve(op) == expected
        # an explicit argument always wins
        assert dispatch.resolve(op, "xla") == "xla"
    if on_tpu:
        assert dispatch.available_impls() == ("pallas", "xla")
    else:
        assert dispatch.available_impls() == ("xla",)


def test_dispatch_force_impl_and_validation():
    with dispatch.force_impl("pallas"):
        assert dispatch.resolve("ghost_norm") == "pallas"
        assert dispatch.resolve("psg_contract") == "pallas"
        # nested per-op override wins over the blanket one
        with dispatch.force_impl(psg_contract="xla"):
            assert dispatch.resolve("psg_contract") == "xla"
            assert dispatch.resolve("ghost_norm") == "pallas"
        assert dispatch.resolve("psg_contract") == "pallas"
    # context restored
    assert dispatch.resolve("ghost_norm") == dispatch.default_impl("ghost_norm")
    with pytest.raises(ValueError):
        dispatch.resolve("ghost_norm", "cuda")
    with pytest.raises(ValueError):
        dispatch.resolve("not_an_op", "xla")
    with pytest.raises(ValueError):
        dispatch.default_impl("not_an_op")
    with pytest.raises(ValueError):
        with dispatch.force_impl("banana"):
            pass
    with pytest.raises(ValueError):
        with dispatch.force_impl(not_an_op="xla"):
            pass


def test_dispatch_ops_agree_across_impls():
    """Both impls of every dispatch op compute the same values."""
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    a = jax.random.normal(ks[0], (2, 40, 12))
    g = jax.random.normal(ks[1], (2, 40, 6))
    c = jax.random.uniform(ks[2], (2,))
    ids = jax.random.randint(ks[3], (2, 40), 0, 9)
    pairs = [
        lambda impl: dispatch.ghost_norm_sq(a, g, block=16, impl=impl),
        lambda impl: dispatch.embedding_ghost_norm_sq(ids, g, block=16, impl=impl),
        lambda impl: dispatch.book_weighted_grad(
            a, g, jnp.broadcast_to(c[:, None], (2, 40)), impl=impl
        ),
        lambda impl: dispatch.psg_contract(a, c, impl=impl),
    ]
    for fn in pairs:
        x, y = fn("xla"), fn("pallas")
        assert jnp.allclose(x, y, rtol=2e-4, atol=2e-4), float(
            jnp.max(jnp.abs(x - y))
        )


@pytest.mark.parametrize("window,n_kv", [(None, 8), (9, 8), (None, 2)])
def test_dispatch_flash_attention_impls_agree(window, n_kv):
    """Serving attention through dispatch: pallas == xla on the static-mask
    cases, including sliding windows and GQA head grouping."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, s, h, hd = 2, 37, 8, 16
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, n_kv, hd))
    v = jax.random.normal(ks[2], (b, s, n_kv, hd))
    x = dispatch.flash_attention(q, k, v, causal=True, window=window,
                                 impl="xla")
    p = dispatch.flash_attention(q, k, v, causal=True, window=window,
                                 impl="pallas")
    assert jnp.allclose(x, p, rtol=2e-5, atol=2e-5), float(
        jnp.max(jnp.abs(x - p))
    )


def test_dispatch_flash_attention_dynamic_args_fall_back():
    """Ring positions / fill levels / traced offsets have no pallas path;
    a forced pallas choice must still produce the XLA result."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    b, s, h, hd = 1, 16, 4, 8
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    pos = jnp.where(jnp.arange(s) < 10, jnp.arange(s), -1)
    want = dispatch.flash_attention(
        q, k, v, causal=True, q_offset=jnp.asarray(9), kv_positions=pos,
        impl="xla")
    with dispatch.force_impl(flash_attention="pallas"):
        got = dispatch.flash_attention(
            q, k, v, causal=True, q_offset=jnp.asarray(9), kv_positions=pos)
    assert jnp.array_equal(want, got)


# ------------------------------------- compiled TPU parity (non-interpret) --
@requires_tpu
def test_tpu_ghost_norm_compiled_parity():
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    a = jax.random.normal(ks[0], (4, 300, 96))
    g = jax.random.normal(ks[1], (4, 300, 48))
    got = ghost_norm_sq_pallas(a, g, interpret=False)
    assert jnp.allclose(got, ghost_norm_sq_ref(a, g), rtol=2e-4)


@requires_tpu
def test_tpu_embedding_ghost_norm_compiled_parity():
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    ids = jax.random.randint(ks[0], (4, 300), 0, 1000)
    g = jax.random.normal(ks[1], (4, 300, 64))
    got = embedding_ghost_norm_sq_pallas(ids.astype(jnp.float32), g, interpret=False)
    assert jnp.allclose(got, embedding_ghost_norm_sq_ref(ids, g), rtol=2e-4)


@requires_tpu
def test_tpu_psg_contract_compiled_parity():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a = jax.random.normal(ks[0], (2, 700, 130))
    g = jax.random.normal(ks[1], (2, 700, 70))
    w = jax.random.uniform(ks[2], (2, 700))
    got = book_weighted_grad_pallas(a, g, w, interpret=False)
    assert jnp.allclose(got, book_weighted_grad_ref(a, g, w), rtol=2e-4, atol=2e-4)
    psg = jax.random.normal(ks[0], (48, 1300))
    c = jax.random.uniform(ks[1], (48,))
    got = psg_contract_pallas(psg, c, interpret=False)
    assert jnp.allclose(got, psg_contract_ref(psg, c), rtol=2e-4, atol=2e-4)


ATTN_CASES = [
    (2, 64, 64, 4, 2, 16, True, None, 0),
    (1, 128, 128, 4, 4, 8, True, 32, 0),
    (2, 1, 96, 4, 2, 16, True, None, 57),
    (2, 48, 48, 6, 2, 32, False, None, 0),
    (1, 100, 100, 2, 1, 16, True, None, 0),
]


@pytest.mark.parametrize("b,sq,skv,h,kh,hd,causal,window,qoff", ATTN_CASES)
def test_flash_xla_forward(b, sq, skv, h, kh, hd, causal, window, qoff):
    ks = jax.random.split(jax.random.PRNGKey(sq + skv), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd))
    k = jax.random.normal(ks[1], (b, skv, kh, hd))
    v = jax.random.normal(ks[2], (b, skv, kh, hd))
    got = flash_attention(q, k, v, causal=causal, window=window, q_offset=qoff,
                          block_q=32, block_kv=32)
    want = mha_reference(q, k, v, causal=causal, window=window, q_offset=qoff)
    assert jnp.allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("b,sq,skv,h,kh,hd,causal,window,qoff", ATTN_CASES[:2])
def test_flash_xla_gradients(b, sq, skv, h, kh, hd, causal, window, qoff):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd))
    k = jax.random.normal(ks[1], (b, skv, kh, hd))
    v = jax.random.normal(ks[2], (b, skv, kh, hd))
    f = lambda *a: flash_attention(*a, causal=causal, window=window,
                                   q_offset=qoff, block_q=32, block_kv=32).sum()
    r = lambda *a: mha_reference(*a, causal=causal, window=window,
                                 q_offset=qoff).astype(jnp.float32).sum()
    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(r, argnums=(0, 1, 2))(q, k, v)
    for x, y in zip(gf, gr):
        assert jnp.allclose(x, y, atol=3e-5)


@pytest.mark.parametrize(
    "b,h,sq,skv,hd,causal,window,qoff,dt",
    [
        (2, 3, 64, 64, 16, True, None, 0, jnp.float32),
        (1, 2, 100, 100, 32, True, 24, 0, jnp.float32),
        (1, 2, 1, 96, 16, True, None, 95, jnp.float32),
        (2, 2, 48, 48, 16, False, None, 0, jnp.bfloat16),
    ],
)
def test_flash_pallas_vs_ref(b, h, sq, skv, hd, causal, window, qoff, dt):
    ks = jax.random.split(jax.random.PRNGKey(17), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd)).astype(dt)
    k = jax.random.normal(ks[1], (b, skv, h, hd)).astype(dt)
    v = jax.random.normal(ks[2], (b, skv, h, hd)).astype(dt)
    got = flash_attention_pallas(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal, window=window, q_offset=qoff,
        block_q=16, block_kv=32, interpret=True,
    ).transpose(0, 2, 1, 3)
    want = mha_reference(q, k, v, causal=causal, window=window, q_offset=qoff)
    tol = 5e-3 if dt == jnp.bfloat16 else 2e-5
    assert jnp.allclose(got.astype(jnp.float32), want.astype(jnp.float32), atol=tol)
