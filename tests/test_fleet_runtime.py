"""Elastic restart proven correct: fault injection, bit-exact resume,
torn-write fallback, supervisor retry classification.

The heavyweight tests drive the real CLI (``launch.train.main``) end to
end: an uninterrupted run and a crash-injected/auto-restarted run must land
on bit-identical final train state AND a bit-identical privacy spend —
including across a fleet shrink, where ``runtime.elastic.elastic_plan``
converts lost data-parallel shards into extra accumulation microsteps of
the same per-shard microbatch (the invariant that makes the replay exact).
"""
from __future__ import annotations

import json
import os
import signal
import threading

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, save_checkpoint
from repro.runtime.elastic import ElasticPlan, current_data_shards, elastic_plan
from repro.runtime.fault import PreemptionHandler, StepWatchdog
from repro.runtime.inject import InjectedCrash, InjectionPlan

ARCH = ["--arch", "yi-6b", "--reduced", "--seq", "16", "--log-every", "4"]


def _run(tmp_path, name, extra):
    from repro.launch.train import main

    d = tmp_path / name
    assert main(ARCH + ["--ckpt-dir", str(d)] + extra) == 0
    return d


def _final_state(d, step):
    with np.load(d / f"step_{step}.npz") as z:
        return {k: np.array(z[k]) for k in z.files}


def _summary(d):
    return json.loads((d / "summary.json").read_text())


def _assert_bit_identical(a: dict, b: dict):
    assert sorted(a) == sorted(b)
    for k in a:
        assert a[k].dtype == b[k].dtype, k
        assert np.array_equal(a[k], b[k]), f"leaf {k} diverged"


# -- elastic plan ----------------------------------------------------------
def test_elastic_plan_preserves_logical_batch_across_shrink():
    before = elastic_plan(logical_batch=64, data_shards=8, max_per_shard=8)
    after = elastic_plan(logical_batch=64, data_shards=2, max_per_shard=8)
    assert before.per_shard_batch * before.data_shards * before.accumulation_steps == 64
    assert after.per_shard_batch * after.data_shards * after.accumulation_steps == 64
    # the shrink grew accumulation, not the per-shard microbatch
    assert after.accumulation_steps == 4 * before.accumulation_steps
    assert after.per_shard_batch == before.per_shard_batch


def test_elastic_plan_rejects_non_dividing_layouts():
    with pytest.raises(ValueError, match="divide"):
        elastic_plan(logical_batch=10, data_shards=3, max_per_shard=4)
    with pytest.raises(ValueError, match="odd"):
        elastic_plan(logical_batch=9, data_shards=1, max_per_shard=4)
    with pytest.raises(ValueError):
        elastic_plan(logical_batch=8, data_shards=0, max_per_shard=4)


def test_elastic_execution_serializes_missing_parallelism():
    plan = ElasticPlan(data_shards=4, per_shard_batch=2, accumulation_steps=3,
                       note="")
    # one process simulating the whole fleet: shards become microsteps
    assert plan.execution(1) == (2, 12)
    # one process per shard: the mesh takes the batch dim
    assert plan.execution(4) == (8, 3)
    # two processes, two serialized shards each
    assert plan.execution(2) == (4, 6)
    with pytest.raises(ValueError, match="divide"):
        plan.execution(3)


def test_current_data_shards_precedence(monkeypatch):
    monkeypatch.delenv("REPRO_ELASTIC_SHARDS", raising=False)
    assert current_data_shards(None) == 1
    assert current_data_shards(4) == 4
    monkeypatch.setenv("REPRO_ELASTIC_SHARDS", "2")
    assert current_data_shards(None) == 2
    assert current_data_shards(8) == 8  # explicit CLI wins over env


# -- fault injection -------------------------------------------------------
def test_injection_spec_parsing_and_one_shot(monkeypatch):
    monkeypatch.delenv("REPRO_FAULT_INJECT", raising=False)
    plan = InjectionPlan.from_spec("crash@3,slow@1:0.01")
    plan.on_step(0)
    plan.on_step(1)  # slow fires (sleeps 10ms), no raise
    with pytest.raises(InjectedCrash):
        plan.on_step(3)
    plan.on_step(3)  # one-shot: the same step does not re-fire
    assert all(i.fired for i in plan.injectors if i.step in (1, 3))


def test_injection_env_merges_with_cli(monkeypatch):
    monkeypatch.setenv("REPRO_FAULT_INJECT", "torn@7")
    plan = InjectionPlan.from_spec("crash@2")
    assert sorted(i.kind for i in plan.injectors) == ["crash", "torn"]


@pytest.mark.parametrize("spec", ["crash5", "warp@3", "slow@3", "shrink@3",
                                  "shrink@3:0"])
def test_injection_rejects_bad_specs(spec, monkeypatch):
    monkeypatch.delenv("REPRO_FAULT_INJECT", raising=False)
    with pytest.raises(ValueError):
        InjectionPlan.from_spec(spec)


def test_torn_injector_truncates_checkpoint(tmp_path):
    plan = InjectionPlan.from_spec("torn@2", env="")
    p = save_checkpoint(tmp_path, 2, {"a": np.arange(100.0)})
    full = p.stat().st_size
    plan.on_checkpoint_saved(2, p)
    assert 0 < p.stat().st_size < full


# -- supervisor retry classification ---------------------------------------
def test_retry_classification():
    from repro.launch.train import is_retryable_failure
    from repro.tuner.consensus import PlanConsensusError

    assert is_retryable_failure(InjectedCrash("boom"))
    assert is_retryable_failure(RuntimeError("transient"))
    assert is_retryable_failure(OSError("storage blip"))
    assert not is_retryable_failure(ValueError("bad config"))
    assert not is_retryable_failure(AssertionError("invariant"))
    assert not is_retryable_failure(PlanConsensusError("fleet divergence"))


def test_auto_restart_does_not_burn_attempts_on_config_error(tmp_path, monkeypatch):
    """A deterministic config error (non-dividing elastic layout) must fail
    immediately instead of looping through the whole restart budget."""
    import repro.launch.train as train

    calls = []
    orig = train.run_once

    def counting(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(train, "run_once", counting)
    argv = ARCH + [
        "--steps", "4", "--batch", "4", "--data-shards", "3",
        "--auto-restart", "5", "--ckpt-dir", str(tmp_path / "cfg"),
    ]
    with pytest.raises(ValueError, match="divide"):
        train.main(argv)
    assert len(calls) == 1  # zero restart attempts were consumed


# -- watchdog / preemption units -------------------------------------------
def test_watchdog_trip_accounting(monkeypatch):
    import repro.runtime.fault as fault

    clock = {"t": 0.0}
    monkeypatch.setattr(fault.time, "monotonic", lambda: clock["t"])
    trips = []
    wd = StepWatchdog(trip_factor=3.0,
                      on_trip=lambda s, dt, med: trips.append((s, dt, med)))

    def step(i, dt):
        wd.start_step()
        clock["t"] += dt
        return wd.end_step(i)

    for i in range(10):  # below the 10-sample warmup: never trips
        step(i, 1.0)
    assert wd.trips == 0
    step(10, 10.0)  # 10x the median
    assert wd.trips == 1 and trips == [(10, 10.0, 1.0)]
    step(11, 1.0)  # back to normal
    assert wd.trips == 1
    # the slow sample joined the window but the median is robust to it
    step(12, 4.0)
    assert wd.trips == 2


def test_preemption_handler_flag_and_uninstall():
    prev = signal.getsignal(signal.SIGTERM)
    h = PreemptionHandler().install()
    try:
        assert not h.preempted()
        h.request_stop()
        assert h.preempted()
        assert signal.getsignal(signal.SIGTERM) != prev
    finally:
        h.uninstall()
    assert signal.getsignal(signal.SIGTERM) == prev


def test_preemption_install_from_worker_thread_is_noop():
    holder = {}

    def worker():
        holder["h"] = PreemptionHandler().install()

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    h = holder["h"]
    assert not h.preempted()
    h.request_stop()
    assert h.preempted()
    h.uninstall()  # no signals were installed; must not raise


# -- checkpoint manager hardening ------------------------------------------
def test_manager_skips_stray_files_and_rotates(tmp_path):
    mgr = CheckpointManager(tmp_path, save_every=1, keep=2, async_save=False)
    for s in (1, 2, 3):
        mgr.save(s, {"x": np.full((4,), float(s))})
    # stray droppings rotation/scan must skip, not crash on
    (tmp_path / ".tmp_step_9.npz").write_bytes(b"partial")
    (tmp_path / "step_3.npz.bak").write_bytes(b"junk")
    (tmp_path / "notes.txt").write_text("hi")
    (tmp_path / "subdir").mkdir()
    assert mgr.latest() == 3
    assert latest_step(tmp_path) == 3
    assert mgr.available_steps() == [2, 3]  # keep=2 rotated step 1 out
    mgr.save(4, {"x": np.full((4,), 4.0)})
    assert mgr.available_steps() == [3, 4]
    step, state = mgr.restore()
    assert step == 4 and float(state["x"][0]) == 4.0


def test_restore_falls_back_past_torn_newest_checkpoint(tmp_path):
    mgr = CheckpointManager(tmp_path, save_every=1, keep=3, async_save=False)
    for s in (1, 2, 3):
        mgr.save(s, {"x": np.full((8,), float(s))})
    # tear the newest artifact (truncate), corrupt the one before it
    p3 = tmp_path / "step_3.npz"
    p3.write_bytes(p3.read_bytes()[:40])
    (tmp_path / "step_2.npz").write_bytes(b"\x00garbage\x00" * 8)
    step, state = mgr.restore()
    assert step == 1 and float(state["x"][0]) == 1.0
    # an explicitly requested damaged step still raises (caller asserted it)
    with pytest.raises(Exception):
        mgr.restore(step=3)


def test_restore_raises_when_nothing_readable(tmp_path):
    mgr = CheckpointManager(tmp_path, save_every=1, async_save=False)
    mgr.save(1, {"x": np.zeros(2)})
    (tmp_path / "step_1.npz").write_bytes(b"nope")
    with pytest.raises(FileNotFoundError, match="no readable"):
        mgr.restore()


def test_manager_on_saved_fires_on_async_writer_thread(tmp_path):
    seen = []
    mgr = CheckpointManager(
        tmp_path, save_every=1, async_save=True,
        on_saved=lambda step, path: seen.append(
            (step, path.name, threading.current_thread().name)
        ),
    )
    mgr.save(1, {"x": np.zeros(3)})
    mgr.wait()
    assert seen and seen[0][:2] == (1, "step_1.npz")
    assert seen[0][2] != threading.main_thread().name


# -- bit-exact resume (CLI end to end) -------------------------------------
@pytest.mark.parametrize("policy", ["fixed", "automatic", "quantile"])
def test_bitexact_resume_after_crash(tmp_path, policy):
    """2N straight vs crash-at-N + auto-restart: final params, optimizer
    state, policy state, and the accountant's epsilon must be identical."""
    base = ["--steps", "6", "--batch", "2", "--ckpt-every", "2",
            "--clip-policy", policy]
    a = _run(tmp_path, "straight", base)
    b = _run(tmp_path, "restart",
             base + ["--fail-at-step", "4", "--auto-restart", "2"])
    _assert_bit_identical(_final_state(a, 6), _final_state(b, 6))
    assert _summary(a)["epsilon"] == _summary(b)["epsilon"]
    assert _summary(a)["delta"] == _summary(b)["delta"]


def test_bitexact_resume_with_fleet_shrink(tmp_path, monkeypatch):
    """THE elastic acceptance path: a crash that also shrinks the fleet
    (2 data shards -> 1) resumes via elastic_plan with the same logical
    batch and a larger accumulation — final state and epsilon bit-identical
    to the uninterrupted 2-shard run."""
    monkeypatch.delenv("REPRO_ELASTIC_SHARDS", raising=False)
    base = ["--steps", "6", "--batch", "4", "--ckpt-every", "2",
            "--elastic-max-per-shard", "2", "--clip-policy", "quantile"]
    monkeypatch.setenv("REPRO_ELASTIC_SHARDS", "2")
    a = _run(tmp_path, "fleet2", base)
    assert _summary(a)["data_shards"] == 2
    assert _summary(a)["accumulation_steps"] == 2  # 2 serialized shards

    monkeypatch.setenv("REPRO_ELASTIC_SHARDS", "2")
    try:
        b = _run(tmp_path, "shrunk",
                 base + ["--inject", "shrink@4:1", "--auto-restart", "2"])
    finally:
        os.environ.pop("REPRO_ELASTIC_SHARDS", None)
    s = _summary(b)
    # the restart REPLANNED: one shard, same logical batch, deeper accum
    assert s["data_shards"] == 1
    assert s["logical_batch"] == 4
    assert s["microbatch"] == 2 and s["accumulation_steps"] == 2
    _assert_bit_identical(_final_state(a, 6), _final_state(b, 6))
    assert _summary(a)["epsilon"] == s["epsilon"]


def test_torn_checkpoint_recovery_end_to_end(tmp_path):
    """Crash at N with the crash-time checkpoint torn: restore falls back to
    the previous rotated step and the rerun still reaches the bit-identical
    final state (recomputation is deterministic)."""
    base = ["--steps", "6", "--batch", "2", "--ckpt-every", "2"]
    a = _run(tmp_path, "straight", base)
    b = _run(tmp_path, "torn",
             base + ["--inject", "crash@4,torn@4", "--auto-restart", "2"])
    _assert_bit_identical(_final_state(a, 6), _final_state(b, 6))
    assert _summary(a)["epsilon"] == _summary(b)["epsilon"]


def test_sigterm_preemption_checkpoints_and_exits_zero(tmp_path):
    """The preemption path: SIGTERM -> flag -> checkpoint -> exit 0, then a
    later --resume completes the run."""
    d = tmp_path / "preempt"
    argv = ARCH + ["--steps", "20", "--batch", "2", "--ckpt-dir", str(d),
                   "--ckpt-every", "50", "--inject", "sigterm@2"]
    from repro.launch.train import main

    prev_disposition = signal.getsignal(signal.SIGTERM)
    assert main(argv) == 0
    preempted_at = latest_step(d)
    assert preempted_at is not None and preempted_at < 20
    # the SIGTERM disposition the run replaced is restored on the way out
    assert signal.getsignal(signal.SIGTERM) == prev_disposition
    argv = ARCH + ["--steps", "5", "--batch", "2", "--ckpt-dir", str(d),
                   "--resume"]
    assert main(argv) == 0
    assert latest_step(d) == 5
