"""repro.tuner.consensus: multi-host plan agreement, simulated with fakes.

No ``jax.distributed`` anywhere: fleets are lists of ``RankReport``s and the
gather primitive is a closure over them, which is exactly the injection
surface the production path uses.  Covers the acceptance gates of the
consensus subsystem: a simulated 2-process tune adopts byte-identical
``ClipPlan``s on every rank; mismatched plans/fingerprints are rejected
loudly before anything could be traced; the mixed-device-kind tie-break is
deterministic; v2 artifacts migrate; strict imports fail on staleness.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro.core.clipping import ClipConfig, discover_meta, dp_value_and_clipped_grad
from repro.core.engine import PrivacyEngine
from repro.nn.module import Dense
from repro.core.taps import Ctx
from repro.tuner import MeasureConfig, build_plan
from repro.tuner.consensus import (
    PlanConsensusError,
    RankReport,
    agree,
    certify_fleet_hash,
    elect_leaders,
    fleet_agree,
    fleet_roles,
    plan_step_cost_us,
    verify_adopted,
)
from repro.tuner.plan import (
    PLAN_VERSION,
    ClipPlan,
    device_string,
    shape_fingerprint,
)

from helpers import max_tree_diff


# ------------------------------------------------------------- tiny model --
class TwoLayer:
    def __init__(self):
        self.f1 = Dense("f1", 12, 8)
        self.f2 = Dense("f2", 8, 4)

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"f1": self.f1.init(k1), "f2": self.f2.init(k2)}

    def loss_with_ctx(self, params, batch, ctx: Ctx):
        h = jax.nn.relu(self.f1(params["f1"], batch["x"], ctx.scope("f1")))
        out = self.f2(params["f2"], h, ctx.scope("f2"))
        return jnp.mean((out - batch["y"]) ** 2, axis=(1, 2))


def _setup():
    model = TwoLayer()
    params = model.init(jax.random.PRNGKey(0))
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    batch = {
        "x": jax.random.normal(k1, (4, 6, 12)),
        "y": jax.random.normal(k2, (4, 6, 4)),
    }
    metas = discover_meta(model.loss_with_ctx, params, batch)
    return model, params, batch, metas


def _measured_plan(metas, **replace):
    plan = build_plan(metas, measure=MeasureConfig(repeats=1, warmup=1),
                      arch="twolayer")
    return dataclasses.replace(plan, **replace) if replace else plan


def _plan_with_timings(metas, device, scale=1.0):
    """A deterministic synthetic plan (no wall-clock measurement noise)."""
    names = sorted(n for n, m in metas.items() if m.kind == "matmul")
    return ClipPlan(
        fingerprint=shape_fingerprint(metas),
        device=device,
        branches=tuple((n, "ghost") for n in names),
        bk_branches=tuple((n, "instantiate") for n in names),
        timings=tuple(
            (n, 10.0 * scale, 20.0 * scale, 5.0 * scale, 4.0 * scale,
             30.0 * scale)
            for n in names
        ),
        arch="twolayer",
    )


class FakeFleet:
    """A gather_fn factory simulating N ranks without any distributed jax.

    Phase payloads are recorded per rank; ``gather_for(i)`` returns a
    gather_fn that hands rank i the union of every rank's payload for that
    phase — the same multiset on every rank, like a real all-gather.  The
    fleet must be *driven* rank-by-rank per phase, so tests pre-register
    the peers' payloads by constructing the same reports the driver would.
    """

    def __init__(self, phases: dict):
        self.phases = phases

    def gather_for(self, rank):
        def gather(payload):
            got = self.phases[payload["phase"]]
            assert any(
                p["process_index"] == payload["process_index"] for p in got
            ), "a rank must be part of the gather it participates in"
            return got
        return gather


def _fleet_for(reports, adopted_hash=None):
    phases = {
        "roles": [
            {"phase": "roles", "process_index": r.process_index,
             "device": r.device}
            for r in reports
        ],
        "agree": [dict(r.to_payload(), phase="agree") for r in reports],
    }
    if adopted_hash is None:
        adopted_hash = agree(reports).consensus_hash()
    phases["certify"] = [
        {"phase": "certify", "process_index": r.process_index,
         "hash": adopted_hash}
        for r in reports
    ]
    return FakeFleet(phases)


# -------------------------------------------------------- leader election --
def test_elect_leaders_lowest_rank_per_kind():
    devices = {3: "tpu:TPU v4", 1: "gpu:A100", 2: "tpu:TPU v4", 0: "gpu:A100"}
    assert elect_leaders(devices) == {"gpu:A100": 0, "tpu:TPU v4": 2}


def test_fleet_roles_single_process_is_leader():
    roles = fleet_roles()  # default gather: this one process
    assert roles.is_leader
    assert roles.n_ranks == 1
    assert roles.device == device_string()


def test_fleet_roles_non_leader_rank():
    fleet = _fleet_for([
        RankReport(0, "tpu:TPU v4", "f" * 16),
        RankReport(1, "tpu:TPU v4", "f" * 16),
    ], adopted_hash="x")
    r1 = fleet_roles(gather_fn=fleet.gather_for(1), process_index=1,
                     device="tpu:TPU v4")
    assert not r1.is_leader
    assert r1.leaders == (("tpu:TPU v4", 0),)
    r0 = fleet_roles(gather_fn=fleet.gather_for(0), process_index=0,
                     device="tpu:TPU v4")
    assert r0.is_leader


# ------------------------------------------- 2-process byte-identical tune --
def test_two_process_tune_adopts_byte_identical_plans():
    """The acceptance gate: every rank of a simulated 2-process fleet ends
    holding the same bytes, certified by the hash phase."""
    _, _, _, metas = _setup()
    fp = shape_fingerprint(metas)
    dev = device_string()
    leader_plan = _measured_plan(metas)
    reports = [
        RankReport(0, dev, fp, leader_plan.to_json(),
                   plan_step_cost_us(leader_plan)),
        RankReport(1, dev, fp, None, None),  # non-leader measured nothing
    ]
    fleet = _fleet_for(reports)
    a0 = fleet_agree(leader_plan, metas, gather_fn=fleet.gather_for(0),
                     process_index=0, device=dev)
    a1 = fleet_agree(None, metas, gather_fn=fleet.gather_for(1),
                     process_index=1, device=dev)
    assert a0.to_json() == a1.to_json()
    assert a0.agreed_ranks == 2
    assert a0.leader_process == 0
    assert a0.agreed_hash == a0.consensus_hash()
    assert a0.devices == (dev,)
    # report order must not matter: gathers are unordered on real fleets
    fleet_rev = _fleet_for(list(reversed(reports)))
    a0r = fleet_agree(leader_plan, metas, gather_fn=fleet_rev.gather_for(0),
                      process_index=0, device=dev)
    assert a0r.to_json() == a0.to_json()


def test_engine_tune_consensus_single_process(tmp_path, monkeypatch):
    """tune(consensus=True) on one process stamps provenance and stays
    consumable: the adopted plan drives the same math as the analytic rule."""
    monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path))
    model, params, batch, metas = _setup()
    eng = PrivacyEngine(
        loss_with_ctx=model.loss_with_ctx, batch_size=4, sample_size=1000,
        steps=10, max_grad_norm=1.0, noise_multiplier=1.0,
    )
    plan = eng.tune(params, batch, arch="twolayer", plan_path=None,
                    use_cache=False, search_max_batch=False,
                    measure=MeasureConfig(repeats=1, warmup=1),
                    consensus=True)
    assert plan.agreed_ranks == 1
    assert plan.leader_process == jax.process_index()
    assert plan.devices == (device_string(),)
    verify_adopted(plan, metas)  # must not raise
    f_analytic = dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig())
    f_plan = dp_value_and_clipped_grad(
        model.loss_with_ctx, ClipConfig(plan=plan)
    )
    _, g1, _ = f_analytic(params, batch)
    _, g2, _ = f_plan(params, batch)
    assert max_tree_diff(g1, g2) < 1e-5


def test_engine_tune_consensus_non_leader_adopts_without_measuring(
    tmp_path, monkeypatch
):
    """A non-leader rank must skip profiling entirely and still adopt."""
    monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path))
    model, params, batch, metas = _setup()
    fp = shape_fingerprint(metas)
    dev = device_string()
    leader_plan = _measured_plan(metas)
    reports = [
        RankReport(0, dev, fp, leader_plan.to_json(),
                   plan_step_cost_us(leader_plan)),
        RankReport(1, dev, fp, None, None),
    ]
    fleet = _fleet_for(reports)
    monkeypatch.setattr(jax, "process_index", lambda: 1)

    def boom(*a, **k):
        raise AssertionError("non-leader rank must not measure")

    import repro.tuner.measure as measure_mod
    monkeypatch.setattr(measure_mod, "measure_tap", boom)

    eng = PrivacyEngine(
        loss_with_ctx=model.loss_with_ctx, batch_size=4, sample_size=1000,
        steps=10, max_grad_norm=1.0, noise_multiplier=1.0,
    )
    plan = eng.tune(params, batch, arch="twolayer", plan_path=None,
                    use_cache=False, search_max_batch=False,
                    consensus=True, gather_fn=fleet.gather_for(1))
    assert plan.agreed_ranks == 2
    assert plan.branch_map() == leader_plan.branch_map()
    assert eng.plan == plan


# ------------------------------------------------------ mismatch rejection --
def test_same_kind_different_plans_rejected():
    _, _, _, metas = _setup()
    fp = shape_fingerprint(metas)
    p0 = _plan_with_timings(metas, "tpu:TPU v4")
    p1 = dataclasses.replace(
        p0, branches=tuple((n, "instantiate") for n, _ in p0.branches)
    )
    reports = [
        RankReport(0, "tpu:TPU v4", fp, p0.to_json(), 10.0),
        RankReport(1, "tpu:TPU v4", fp, p1.to_json(), 10.0),
    ]
    with pytest.raises(PlanConsensusError, match="different plans"):
        agree(reports)


def test_fingerprint_mismatch_rejected_loudly():
    _, _, _, metas = _setup()
    fp = shape_fingerprint(metas)
    p0 = _plan_with_timings(metas, "tpu:TPU v4")
    reports = [
        RankReport(0, "tpu:TPU v4", fp, p0.to_json(), 10.0),
        RankReport(1, "tpu:TPU v4", "deadbeef" * 2, None, None),
    ]
    with pytest.raises(PlanConsensusError, match="not running the same model"):
        agree(reports)


def test_kind_without_any_plan_rejected():
    _, _, _, metas = _setup()
    fp = shape_fingerprint(metas)
    p0 = _plan_with_timings(metas, "tpu:TPU v4")
    reports = [
        RankReport(0, "tpu:TPU v4", fp, p0.to_json(), 10.0),
        RankReport(1, "gpu:A100", fp, None, None),
    ]
    with pytest.raises(PlanConsensusError, match="no measured plan"):
        agree(reports)


def test_certify_rejects_diverged_hashes():
    _, _, _, metas = _setup()
    plan = _plan_with_timings(metas, device_string())
    fleet = FakeFleet({
        "certify": [
            {"phase": "certify", "process_index": 0,
             "hash": plan.consensus_hash()},
            {"phase": "certify", "process_index": 1, "hash": "divergent"},
        ]
    })
    with pytest.raises(PlanConsensusError, match="refusing to trace"):
        certify_fleet_hash(plan, gather_fn=fleet.gather_for(0),
                           process_index=0)


def test_certify_fleet_value_gates_post_adoption_divergence():
    """The --mode auto re-certification can fall back per rank; a rank whose
    verdict differs from its peers must abort before tracing."""
    from repro.tuner.consensus import certify_fleet_value

    fleet = FakeFleet({
        "certify:adopted mode/batch": [
            {"phase": "certify:adopted mode/batch", "process_index": 0,
             "value": "bk_mixed:64:4:abc"},
            {"phase": "certify:adopted mode/batch", "process_index": 1,
             "value": "mixed_ghost:64:4:abc"},  # rank 1 fell back
        ]
    })
    with pytest.raises(PlanConsensusError, match="diverge on adopted"):
        certify_fleet_value("adopted mode/batch", "bk_mixed:64:4:abc",
                            gather_fn=fleet.gather_for(0), process_index=0)
    # unanimity passes (single-process default gather is the trivial case)
    certify_fleet_value("adopted mode/batch", "anything")


def test_engine_consensus_cache_hit_rejects_foreign_kind_measurement(
    tmp_path, monkeypatch
):
    """A cached plan this kind only RATIFIED (measured by another kind in an
    earlier mixed fleet) must not be resubmitted as this kind's measurement:
    the engine re-measures instead of letting the kind dodge profiling."""
    monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path))
    model, params, batch, metas = _setup()
    from repro.tuner.plan import default_plan_path

    foreign = dataclasses.replace(
        _plan_with_timings(metas, "tpu:TPU v9000"),
        devices=("tpu:TPU v9000", device_string()),  # ratified locally
        agreed_ranks=2, leader_process=0, arch="twolayer",
    )
    foreign = dataclasses.replace(foreign, agreed_hash=foreign.consensus_hash())
    foreign.save(default_plan_path("twolayer", foreign.fingerprint))
    assert foreign.matches(metas)  # the ratification makes it a cache hit

    eng = PrivacyEngine(
        loss_with_ctx=model.loss_with_ctx, batch_size=4, sample_size=1000,
        steps=10, max_grad_norm=1.0, noise_multiplier=1.0,
    )
    plan = eng.tune(params, batch, arch="twolayer", search_max_batch=False,
                    measure=MeasureConfig(repeats=1, warmup=1),
                    consensus=True)
    # a fresh local measurement won the (single-kind) agreement, and the
    # adopted plan was persisted over the foreign cache entry
    assert plan.device == device_string()
    assert plan.leader_process == jax.process_index()
    cached = ClipPlan.load(default_plan_path("twolayer", plan.fingerprint))
    assert cached.device == device_string()


def test_reconcile_recertification_unanimity_and_min():
    """--mode auto's per-rank re-certification reduces fleet-wide: the mode
    is adopted only when every rank fits it, at the minimum batch."""
    from repro.tuner.consensus import reconcile_recertification

    def fleet(entries):
        return FakeFleet({"recertify": [
            {"phase": "recertify", "process_index": i,
             "mode_ok": ok, "physical_batch": b}
            for i, (ok, b) in enumerate(entries)
        ]})

    # mixed kinds fit different batches: the minimum wins everywhere
    f = fleet([(True, 128), (True, 32)])
    assert reconcile_recertification(
        True, 128, gather_fn=f.gather_for(0), process_index=0
    ) == (True, 32)
    # one rank cannot fit the recommended mode: nobody adopts it
    f = fleet([(True, 128), (False, None)])
    ok, _ = reconcile_recertification(
        True, 128, gather_fn=f.gather_for(0), process_index=0
    )
    assert not ok
    # single process: the identity
    assert reconcile_recertification(True, 64) == (True, 64)


def test_agree_rejects_empty_and_duplicate_ranks():
    _, _, _, metas = _setup()
    fp = shape_fingerprint(metas)
    p = _plan_with_timings(metas, "tpu:TPU v4")
    with pytest.raises(PlanConsensusError):
        agree([])
    with pytest.raises(PlanConsensusError, match="duplicate process"):
        agree([
            RankReport(0, "tpu:TPU v4", fp, p.to_json(), 1.0),
            RankReport(0, "tpu:TPU v4", fp, p.to_json(), 1.0),
        ])


# ------------------------------------------------------ mixed device kinds --
def test_mixed_kinds_tie_break_is_median_of_ranks_and_deterministic():
    _, _, _, metas = _setup()
    fp = shape_fingerprint(metas)
    slow = _plan_with_timings(metas, "gpu:A100", scale=10.0)
    fast = _plan_with_timings(metas, "tpu:TPU v4", scale=1.0)
    reports = [
        RankReport(0, "gpu:A100", fp, slow.to_json(), plan_step_cost_us(slow)),
        # one A100 straggler reporting an absurd cost must not flip the
        # verdict for the tpu kind (median, not min/mean)
        RankReport(1, "gpu:A100", fp, None, 1e9),
        RankReport(2, "tpu:TPU v4", fp, fast.to_json(),
                   plan_step_cost_us(fast)),
        RankReport(3, "tpu:TPU v4", fp, None, plan_step_cost_us(fast)),
    ]
    adopted = agree(reports)
    assert adopted.device == "tpu:TPU v4"
    assert adopted.leader_process == 2
    # every rank — including the gpu ones — ratified the one adopted plan
    assert adopted.devices == ("gpu:A100", "tpu:TPU v4")
    assert adopted.agreed_ranks == 4
    # report order must not change the outcome
    assert agree(list(reversed(reports))).to_json() == adopted.to_json()
    # the gpu rank can consume it: ratification extends matches()
    assert adopted.ratified_on("gpu:A100")
    verify_adopted(adopted, metas, device="gpu:A100")  # must not raise


def test_mixed_kinds_adopts_min_physical_batch():
    _, _, _, metas = _setup()
    fp = shape_fingerprint(metas)
    fast = dataclasses.replace(
        _plan_with_timings(metas, "tpu:TPU v4", scale=1.0),
        physical_batch=256, budget_bytes=1 << 30, measured_at_physical=True,
    )
    slow = dataclasses.replace(
        _plan_with_timings(metas, "gpu:A100", scale=10.0),
        physical_batch=64, budget_bytes=1 << 30,
    )
    reports = [
        RankReport(0, "tpu:TPU v4", fp, fast.to_json(),
                   plan_step_cost_us(fast)),
        RankReport(1, "gpu:A100", fp, slow.to_json(),
                   plan_step_cost_us(slow)),
    ]
    adopted = agree(reports)
    # tpu's branch maps win on time, but the weakest device bounds the
    # fleet's uniform physical microbatch
    assert adopted.device == "tpu:TPU v4"
    assert adopted.physical_batch == 64
    # the winner's timings were NOT re-measured at the lowered batch; the
    # adopted plan must not claim they were
    assert not adopted.measured_at_physical


def test_uncertified_kind_drops_the_batch_certificate():
    """A kind that never certified a batch must not inherit the winner's:
    its HBM never compiled that graph.  The adopted plan drops the
    certificate; each host re-certifies at its own per-host share."""
    _, _, _, metas = _setup()
    fp = shape_fingerprint(metas)
    fast = dataclasses.replace(
        _plan_with_timings(metas, "tpu:TPU v4", scale=1.0),
        physical_batch=256, budget_bytes=1 << 30,
    )
    slow = _plan_with_timings(metas, "gpu:A100", scale=10.0)  # no batch cert
    reports = [
        RankReport(0, "tpu:TPU v4", fp, fast.to_json(),
                   plan_step_cost_us(fast)),
        RankReport(1, "gpu:A100", fp, slow.to_json(),
                   plan_step_cost_us(slow)),
    ]
    adopted = agree(reports)
    assert adopted.device == "tpu:TPU v4"
    assert adopted.physical_batch is None
    assert adopted.accumulation_steps is None


def test_mixed_kind_cost_tie_breaks_on_device_string():
    _, _, _, metas = _setup()
    fp = shape_fingerprint(metas)
    pa = _plan_with_timings(metas, "gpu:A100", scale=1.0)
    pb = _plan_with_timings(metas, "tpu:TPU v4", scale=1.0)  # equal cost
    reports = [
        RankReport(0, "gpu:A100", fp, pa.to_json(), plan_step_cost_us(pa)),
        RankReport(1, "tpu:TPU v4", fp, pb.to_json(), plan_step_cost_us(pb)),
    ]
    adopted = agree(reports)
    assert adopted.device == "gpu:A100"  # lexicographic, deterministic


# --------------------------------------------------------- strict imports --
def test_import_mismatched_fingerprint_fails_before_tracing():
    """The acceptance gate: a rank importing a mismatched-fingerprint plan
    must fail loudly, not warn-and-fall-back like the single-host path."""
    _, _, _, metas = _setup()
    plan = _plan_with_timings(metas, device_string())
    stale = dataclasses.replace(plan, fingerprint="deadbeef" * 2)
    with pytest.raises(PlanConsensusError, match="different model"):
        verify_adopted(stale, metas)


def test_import_wrong_device_fails_unless_ratified():
    _, _, _, metas = _setup()
    plan = _plan_with_timings(metas, "tpu:TPU v9000")
    with pytest.raises(PlanConsensusError, match="ratified"):
        verify_adopted(plan, metas)
    ratified = dataclasses.replace(
        plan, devices=("tpu:TPU v9000", device_string())
    )
    verify_adopted(ratified, metas)  # must not raise


def test_import_tampered_agreement_hash_fails():
    _, _, _, metas = _setup()
    plan = _plan_with_timings(metas, device_string())
    tampered = dataclasses.replace(plan, agreed_hash="0" * 16)
    with pytest.raises(PlanConsensusError, match="edited after"):
        verify_adopted(tampered, metas)


def test_train_consensus_import_raises_on_stale_plan(tmp_path):
    """launch.train --consensus --plan <stale> must abort, not fall back."""
    from repro.launch import train as train_mod

    stale = ClipPlan(fingerprint="deadbeef" * 2, device=device_string(),
                     arch="qwen2-72b")
    path = str(tmp_path / "stale.json")
    stale.save(path)
    args = train_mod.parse_args([
        "--arch", "qwen2-72b", "--reduced", "--steps", "1", "--batch", "2",
        "--seq", "8", "--plan", path, "--consensus",
    ])
    with pytest.raises(PlanConsensusError):
        train_mod.run_once(args)


# ----------------------------------------------------------- v2 migration --
def test_v2_plan_migrates_with_empty_provenance():
    _, _, _, metas = _setup()
    plan = _plan_with_timings(metas, device_string())
    d = json.loads(plan.to_json())
    d["version"] = 2
    for f in ("devices", "agreed_hash", "agreed_ranks", "leader_process"):
        d.pop(f, None)
    v2 = ClipPlan.from_json(json.dumps(d))
    assert v2.version == PLAN_VERSION
    assert v2.devices == () and v2.agreed_hash is None
    assert v2.agreed_ranks is None and v2.leader_process is None
    # measurements survive the migration byte-for-byte
    assert v2.branches == plan.branches
    assert v2.consensus_hash() == plan.consensus_hash()
    # and the migrated plan can join a fleet agreement as-is
    fp = shape_fingerprint(metas)
    adopted = agree([RankReport(0, device_string(), fp, v2.to_json(),
                                plan_step_cost_us(v2))])
    assert adopted.agreed_hash == v2.consensus_hash()


def test_provenance_stamp_is_hash_idempotent():
    _, _, _, metas = _setup()
    plan = _plan_with_timings(metas, device_string())
    stamped = dataclasses.replace(
        plan, devices=("a", "b"), agreed_hash=plan.consensus_hash(),
        agreed_ranks=7, leader_process=3,
    )
    assert stamped.consensus_hash() == plan.consensus_hash()


# ----------------------------------------------------- per-host batch math --
def test_per_host_batch_single_host_identity():
    from repro.launch.mesh import make_host_mesh, mesh_host_count
    from repro.parallel.sharding import per_host_batch

    mesh = make_host_mesh()
    assert mesh_host_count(mesh) == 1
    assert per_host_batch(256, mesh) == 256


def test_per_host_batch_splits_across_fake_hosts(monkeypatch):
    from repro.launch import mesh as mesh_mod
    from repro.parallel import sharding as sh

    mesh = mesh_mod.make_host_mesh()
    monkeypatch.setattr(mesh_mod, "mesh_host_count", lambda m: 4)
    n_data = mesh.shape["data"]
    if 256 % n_data == 0 and n_data > 1:
        assert sh.per_host_batch(256, mesh) == -(-256 // min(4, n_data))
    else:
        # batch replicates (no divisible data axis): every host holds it all
        assert sh.per_host_batch(256, mesh) == 256
    # model axis spanning hosts: the batch shards only nb ways, so each of
    # the 4 hosts holds a 1/nb slice — the certificate must cover THAT
    monkeypatch.setattr(sh, "axis_size", lambda m, axes: 2)
    assert sh.per_host_batch(256, mesh) == 128  # min(4 hosts, 2 shards)


# -------------------------------------------------- consensus obs events --
def test_fleet_agree_emits_consensus_agreed_event():
    from repro.obs import set_sink
    from repro.obs.sinks import MemorySink

    ev = MemorySink()
    set_sink("events", ev)
    _, _, _, metas = _setup()
    fp = shape_fingerprint(metas)
    dev = device_string()
    leader_plan = _measured_plan(metas)
    reports = [
        RankReport(0, dev, fp, leader_plan.to_json(),
                   plan_step_cost_us(leader_plan)),
        RankReport(1, dev, fp, None, None),
    ]
    fleet = _fleet_for(reports)
    adopted = fleet_agree(leader_plan, metas, gather_fn=fleet.gather_for(0),
                          process_index=0, device=dev)
    agreed = [r for r in ev.records if r["kind"] == "consensus_agreed"]
    assert len(agreed) == 1
    assert agreed[0]["agreed_hash"] == adopted.agreed_hash
    assert agreed[0]["agreed_ranks"] == 2
    assert agreed[0]["leader_process"] == 0
    assert agreed[0]["devices"] == [dev]


def test_fleet_agree_emits_consensus_rejected_on_divergence():
    from repro.obs import set_sink
    from repro.obs.sinks import MemorySink

    ev = MemorySink()
    set_sink("events", ev)
    _, _, _, metas = _setup()
    dev = device_string()
    # rank 1 reports a different model fingerprint: the fleet must refuse
    reports = [
        RankReport(0, dev, shape_fingerprint(metas), None, None),
        RankReport(1, dev, "0" * 16, None, None),
    ]
    fleet = _fleet_for(reports, adopted_hash="x")
    with pytest.raises(PlanConsensusError):
        fleet_agree(None, metas, gather_fn=fleet.gather_for(0),
                    process_index=0, device=dev)
    rejected = [r for r in ev.records if r["kind"] == "consensus_rejected"]
    assert len(rejected) == 1
    assert rejected[0]["rank_index"] == 0
    assert "same model" in rejected[0]["reason"]
