"""Reproduce the paper's Table 3: VGG-11 / ImageNet layerwise decision.

The complexity model must produce the paper's exact per-layer space costs
(ghost: 2*T^2, non-ghost: p*d*kh*kw) and pick the same green cells.
"""
import pytest

from repro.core.decision import ghost_is_cheaper

# (name, T=HoutWout, d_in, p_out, k)  — VGG-11 at 224x224, conv 3x3 / fc
VGG11_LAYERS = [
    ("conv1", 224 * 224, 3, 64, 3),
    ("conv2", 112 * 112, 64, 128, 3),
    ("conv3", 56 * 56, 128, 256, 3),
    ("conv4", 56 * 56, 256, 256, 3),
    ("conv5", 28 * 28, 256, 512, 3),
    ("conv6", 28 * 28, 512, 512, 3),
    ("conv7", 14 * 14, 512, 512, 3),
    ("conv8", 14 * 14, 512, 512, 3),
    ("fc9", 1, 512 * 7 * 7, 4096, 1),
    ("fc10", 1, 4096, 4096, 1),
    ("fc11", 1, 4096, 1000, 1),
]

# Paper Table 3 values (space complexity of each branch)
PAPER_TABLE3 = {
    "conv1": (5.0e9, 1.7e3),
    "conv2": (3.0e8, 7.3e4),
    "conv3": (2.0e7, 2.9e5),
    "conv4": (2.0e7, 5.8e5),
    "conv5": (1.2e6, 1.1e6),
    "conv6": (1.2e6, 2.3e6),
    "conv7": (7.6e4, 2.3e6),
    "conv8": (7.6e4, 2.3e6),
    "fc9": (2.0, 1.0e8),
    "fc10": (2.0, 1.6e7),
    "fc11": (2.0, 4.1e6),
}

# Eq (4.1) ground truth: ghost iff 2T^2 < p*d*k^2.  conv5 is the borderline
# instantiate case (1.23e6 > 1.18e6); conv6 flips to ghost (1.23e6 < 2.36e6).
PAPER_GHOST_SELECTED = {"conv6", "conv7", "conv8", "fc9", "fc10", "fc11"}


@pytest.mark.parametrize("name,t,d,p,k", VGG11_LAYERS)
def test_table3_values(name, t, d, p, k):
    ghost_cost = 2.0 * t * t
    nonghost_cost = p * d * k * k
    want_ghost, want_nonghost = PAPER_TABLE3[name]
    assert abs(ghost_cost - want_ghost) / want_ghost < 0.15, (name, ghost_cost)
    assert abs(nonghost_cost - want_nonghost) / want_nonghost < 0.15, (name, nonghost_cost)


@pytest.mark.parametrize("name,t,d,p,k", VGG11_LAYERS)
def test_table3_selection(name, t, d, p, k):
    picked_ghost = ghost_is_cheaper(t, d * k * k, p, by="space")
    assert picked_ghost == (name in PAPER_GHOST_SELECTED), name


def test_total_mixed_cost_below_both_pure_strategies():
    """Paper totals: ghost-only 5.34e9, nonghost 1.33e8, mixed "3.40e4".

    Note: summing the paper's own per-layer minima gives ~3.4e6, so the
    printed 3.40e4 appears to be a typo for 3.40e6 (recorded in
    EXPERIMENTS.md).  We assert the arithmetic truth.
    """
    ghost_total = sum(2.0 * t * t for _, t, d, p, k in VGG11_LAYERS)
    nonghost_total = sum(p * d * k * k for _, t, d, p, k in VGG11_LAYERS)
    mixed_total = sum(
        min(2.0 * t * t, p * d * k * k) for _, t, d, p, k in VGG11_LAYERS
    )
    assert abs(ghost_total - 5.34e9) / 5.34e9 < 0.05
    assert abs(nonghost_total - 1.33e8) / 1.33e8 < 0.10
    assert abs(mixed_total - 3.40e6) / 3.40e6 < 0.15
    assert mixed_total < ghost_total and mixed_total < nonghost_total
