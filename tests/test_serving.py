"""repro.serving: continuous-batching exactness, paging, SLO admission.

The load-bearing guarantee (ISSUE 6 acceptance): the engine's greedy token
streams — with slot recycling, paged KV reuse, and mixed prompt lengths in
flight — are **bit-identical** to sequential one-request-at-a-time decode,
across multiple arch families (dense GQA, MoE, recurrent hybrids).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, build_model
from repro.serving import (
    Engine,
    LatencyModel,
    PageAllocator,
    Request,
    RequestQueue,
    aggregate_metrics,
    sequential_decode,
)
from repro.serving.kv_pages import (
    NULL_PAGE,
    gather_views,
    is_kv_node,
    kv_paths,
    make_pools,
    scatter_prefill,
    scatter_rows,
    strip_kv,
)


@functools.lru_cache(maxsize=None)
def _tiny(name):
    cfg = ARCHS[name].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(lengths, vocab, seed=11):
    return [
        (1 + jax.random.randint(
            jax.random.PRNGKey(seed + i), (l,), 0, vocab - 1, dtype=jnp.int32
        )).tolist()
        for i, l in enumerate(lengths)
    ]


# -- tentpole: bit-exactness vs the sequential oracle -----------------------
@pytest.mark.parametrize(
    "name", ["codeqwen1.5-7b", "mixtral-8x7b", "jamba-1.5-large-398b"]
)
def test_engine_matches_sequential_decode(name):
    """Slot-recycled, paged, mixed-length continuous batching == sequential
    greedy decode, bit for bit, across arch families (dense / moe / hybrid)."""
    cfg, model, params = _tiny(name)
    prompts = _prompts([5, 9, 3, 7, 4], cfg.vocab)
    engine = Engine(model, params, n_slots=2, page_size=8, max_len=24)
    for p in prompts:
        engine.submit(p, max_new=5)
    completions = engine.drain(max_steps=300)
    got = [completions[i].tokens for i in range(len(prompts))]
    want = sequential_decode(
        model, params, prompts, max_new=5, view_len=engine.view_len)
    assert got == want
    # 5 requests over 2 slots: recycling kept it under wave scheduling's
    # ceil(5/2) * 5 decode steps + admissions
    assert all(completions[i].finish == "length" for i in range(len(prompts)))


def test_engine_slot_recycled_on_next_step():
    """A freed slot takes the next queued request on the very next step."""
    cfg, model, params = _tiny("codeqwen1.5-7b")
    p0, p1 = _prompts([4, 6], cfg.vocab)
    engine = Engine(model, params, n_slots=1, page_size=8, max_len=16)
    engine.submit(p0, max_new=2)
    engine.submit(p1, max_new=2)
    first = engine.step()   # admit r0 (prefill token) + decode (finishes r0)
    assert [rid for rid, _ in first] == [0, 0]
    assert engine.completions[0].finish == "length"
    second = engine.step()  # the freed slot must host r1 immediately
    assert [rid for rid, _ in second] == [1, 1]
    assert engine.completions[1].finish == "length"


def test_engine_eos_stops_stream_exactly():
    """Post-EOS tokens are never emitted or counted; the truncated stream
    still matches the sequential oracle under the same EOS."""
    cfg, model, params = _tiny("codeqwen1.5-7b")
    prompts = _prompts([6, 5, 8], cfg.vocab, seed=23)
    view_len = Engine(model, params, n_slots=3, page_size=8,
                      max_len=24).view_len
    free_run = sequential_decode(
        model, params, prompts, max_new=8, view_len=view_len)
    # pick an EOS id that actually fires mid-stream for some request
    eos = next(
        (t for out in free_run for t in out[:-1]), None)
    assert eos is not None
    engine = Engine(model, params, n_slots=3, page_size=8, max_len=24,
                    eos_id=eos)
    for p in prompts:
        engine.submit(p, max_new=8)
    completions = engine.drain(max_steps=300)
    want = sequential_decode(
        model, params, prompts, max_new=8, view_len=view_len, eos_id=eos)
    got = [completions[i].tokens for i in range(len(prompts))]
    assert got == want
    for c in completions.values():
        assert eos not in c.tokens[:-1]  # nothing emitted past the EOS
        if c.finish == "eos":
            assert c.tokens[-1] == eos
    assert aggregate_metrics(completions)["tokens"] == sum(
        len(t) for t in want)


def test_engine_exact_with_starved_page_pool():
    """A pool too small for all slots at once forces requests to wait for
    page recycling — outputs must still match sequential decode."""
    cfg, model, params = _tiny("codeqwen1.5-7b")
    prompts = _prompts([7, 6, 5, 8], cfg.vocab, seed=41)
    # 3 pages of 8 rows: one in-flight request (<=2 pages) at a time, plus
    # the null page; the second slot starves until pages free
    engine = Engine(model, params, n_slots=2, page_size=8, max_len=16,
                    pool_pages=3)
    for p in prompts:
        engine.submit(p, max_new=4)
    completions = engine.drain(max_steps=400)
    want = sequential_decode(
        model, params, prompts, max_new=4, view_len=engine.view_len)
    assert [completions[i].tokens for i in range(len(prompts))] == want


def test_engine_rejects_oversized_and_unsupported():
    cfg, model, params = _tiny("codeqwen1.5-7b")
    engine = Engine(model, params, n_slots=1, page_size=8, max_len=16)
    with pytest.raises(ValueError):
        engine.submit(list(range(1, 14)), max_new=8)  # 13 + 7 > 16
    with pytest.raises(ValueError):
        engine.submit([], max_new=2)
    vcfg, vmodel, vparams = _tiny("phi-3-vision-4.2b")
    with pytest.raises(NotImplementedError):
        Engine(vmodel, vparams, n_slots=1, page_size=8, max_len=16)


# -- kv_pages ---------------------------------------------------------------
def test_kv_pages_roundtrip_and_classification():
    kv = {"k": jnp.zeros((2, 1, 16, 2, 4)), "v": jnp.zeros((2, 1, 16, 2, 4)),
          "pos": jnp.full((16,), -1, jnp.int32), "idx": jnp.zeros((), jnp.int32)}
    tree = {"blocks": {"kv": kv, "mamba": {"conv": jnp.zeros((2, 1, 3))}}}
    assert is_kv_node(kv)
    assert not is_kv_node({"k": 0, "v": 0})
    assert kv_paths(tree) == [("blocks", "kv")]
    dense = strip_kv(tree)
    assert set(dense["blocks"]["kv"]) == {"pos", "idx"}
    assert dense["blocks"]["mamba"]["conv"].shape == (2, 1, 3)

    pools = make_pools(tree, n_pages=5, page=8)
    key = jax.random.PRNGKey(0)
    leaf = jax.random.normal(key, (2, 1, 16, 2, 4))
    state_kv = {("blocks", "kv"): {"k": leaf, "v": 2.0 * leaf}}
    table_row = jnp.asarray([3, 1], jnp.int32)
    pools = scatter_prefill(pools, state_kv, table_row)
    table = jnp.asarray([[3, 1], [NULL_PAGE, NULL_PAGE]], jnp.int32)
    views = gather_views(pools, table)
    got = views[("blocks", "kv")]["k"]
    assert got.shape == (2, 2, 1, 16, 2, 4)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(leaf))
    # single-row decode writes land at (page, offset) derived from position
    row = {("blocks", "kv"): {
        "k": jnp.ones((2, 2, 2, 4)), "v": jnp.ones((2, 2, 2, 4))}}
    pools = scatter_rows(pools, row, jnp.asarray([1, NULL_PAGE]),
                         jnp.asarray([2, 0]))
    views = gather_views(pools, table)
    np.testing.assert_array_equal(
        np.asarray(views[("blocks", "kv")]["k"][0, :, 0, 10]), 1.0)


def test_page_allocator_reserve_release():
    alloc = PageAllocator(n_pages=5, page=8)  # pages 1..4 allocatable
    assert alloc.free_pages == 4
    got = alloc.reserve(17)  # 3 pages
    assert got is not None and len(got) == 3 and NULL_PAGE not in got
    assert alloc.reserve(17) is None  # only 1 left
    one = alloc.reserve(3)
    assert one is not None and len(one) == 1
    alloc.release(got)
    assert alloc.free_pages == 3


# -- SLO admission ----------------------------------------------------------
def test_slo_admission_sheds_on_projected_ttft():
    model = LatencyModel()
    q = RequestQueue(model)
    # cold start: no observations -> everything admits
    assert q.offer(Request(0, [1, 2, 3], slo_ttft_ms=0.001),
                   free_slots=0, active_remaining=[50])
    model.observe_prefill(10, 1.0)   # 100ms per prompt token
    model.observe_step(0.5)          # 500ms per decode step
    # slot free: projection is prefill-only (400ms)
    q2 = RequestQueue(model)
    assert q2.offer(Request(1, [1] * 4, slo_ttft_ms=500.0),
                    free_slots=2, active_remaining=[])
    # no slot free, 3 steps until one frees: 3*500 + 2*100 = 1700ms
    q3 = RequestQueue(model)
    assert not q3.offer(Request(2, [1] * 2, slo_ttft_ms=1000.0),
                        free_slots=0, active_remaining=[3, 9])
    assert [r.rid for r in q3.shed] == [2]
    # a shed request never queues, so the next offer projects from the
    # front again: 1700ms clears a 2s deadline
    assert q3.offer(Request(3, [1] * 2, slo_ttft_ms=2000.0),
                    free_slots=0, active_remaining=[3, 9])
    # behind request 3 the projection is 9 steps (4700ms) and sheds
    assert not q3.offer(Request(4, [1] * 2, slo_ttft_ms=2000.0),
                        free_slots=0, active_remaining=[3, 9])
    # no deadline -> never shed
    assert q3.offer(Request(5, [1] * 64), free_slots=0, active_remaining=[9])


def test_engine_sheds_against_measured_latency():
    cfg, model, params = _tiny("codeqwen1.5-7b")
    engine = Engine(model, params, n_slots=1, page_size=8, max_len=16)
    engine.latency.observe_prefill(1, 10.0)  # pretend prefill costs 10s/token
    engine.latency.observe_step(10.0)
    rid, admitted = engine.submit([3, 4, 5], max_new=2, slo_ttft_ms=1.0)
    assert not admitted
    assert engine.completions[rid].finish == "shed"
    rid2, admitted2 = engine.submit([3, 4, 5], max_new=2)  # no SLO: runs
    assert admitted2
    completions = engine.drain(max_steps=50)
    assert completions[rid2].finish == "length"
    m = aggregate_metrics(completions)
    assert m["shed"] == 1 and m["requests"] == 1
