"""Step-time floor gate (scripts/bench_dashboard.py --check-step-time).

The gate compares each metric's newest archived row against its closest
same-host predecessor and fails beyond the percentage budget.  These tests
drive the pure helpers on synthetic history so the CI wiring is proven
without benchmarking anything: an injected +20%-plus regression MUST fail,
same-host improvements and cross-host drift MUST pass, and the
``BENCH_STEP_TIME_WAIVER`` escape hatch must downgrade failure to a
warning.
"""
import importlib.util
import pathlib
import sys

_SCRIPT = pathlib.Path(__file__).resolve().parent.parent / "scripts" / "bench_dashboard.py"
_spec = importlib.util.spec_from_file_location("bench_dashboard", _SCRIPT)
dashboard = importlib.util.module_from_spec(_spec)
sys.modules.setdefault("bench_dashboard", dashboard)
_spec.loader.exec_module(dashboard)


def _row(name, us, host="x86_64-8-cpu"):
    row = {"name": name, "us_per_call": us, "derived": ""}
    if host is not None:
        row["host"] = host
    return row


# two commits, aaaaaaa older than bbbbbbb
ORDER = {"aaaaaaa" + "0" * 33: 0, "bbbbbbb" + "0" * 33: 1}


def _history(old_us, new_us, *, old_host="x86_64-8-cpu",
             new_host="x86_64-8-cpu"):
    return {
        "modes": {
            "aaaaaaa": [_row("modes_cnn_bk_mixed", old_us, old_host)],
            "bbbbbbb": [_row("modes_cnn_bk_mixed", new_us, new_host)],
        }
    }


def test_gate_fails_on_injected_regression():
    """+25% same-host step time against a 20% budget is an offense."""
    offenses = dashboard.step_time_regressions(
        _history(100_000.0, 125_000.0), ORDER, 20.0
    )
    assert len(offenses) == 1
    assert "modes_cnn_bk_mixed" in offenses[0]
    assert dashboard.check_step_time(
        _history(100_000.0, 125_000.0), ORDER, 20.0
    ) == 1


def test_gate_passes_within_budget_and_on_improvement():
    for new in (80_000.0, 100_000.0, 119_000.0):
        assert dashboard.step_time_regressions(
            _history(100_000.0, new), ORDER, 20.0
        ) == []
    assert dashboard.check_step_time(
        _history(100_000.0, 80_000.0), ORDER, 20.0
    ) == 0


def test_gate_never_pairs_across_hosts_or_stampless_rows():
    """Cross-host drift is noise, not regression; legacy rows without the
    host stamp (pre-harness artifacts) never participate."""
    cross = _history(100_000.0, 200_000.0, old_host="arm64-4-cpu")
    assert dashboard.step_time_regressions(cross, ORDER, 20.0) == []
    legacy = _history(100_000.0, 200_000.0, old_host=None)
    assert dashboard.step_time_regressions(legacy, ORDER, 20.0) == []
    unstamped_new = _history(100_000.0, 200_000.0, new_host=None)
    assert dashboard.step_time_regressions(unstamped_new, ORDER, 20.0) == []


def test_gate_compares_against_closest_same_host_row():
    """An intervening cross-host row is skipped; the newest row still pairs
    with the older same-host baseline behind it."""
    order = dict(ORDER)
    order["ccccccc" + "0" * 33] = 2
    history = {
        "modes": {
            "aaaaaaa": [_row("m", 100_000.0)],
            "bbbbbbb": [_row("m", 50_000.0, host="arm64-4-cpu")],
            "ccccccc": [_row("m", 130_000.0)],
        }
    }
    offenses = dashboard.step_time_regressions(history, order, 20.0)
    assert len(offenses) == 1 and "aaaaaaa" in offenses[0]


def test_gate_waiver_downgrades_failure():
    history = _history(100_000.0, 125_000.0)
    assert dashboard.check_step_time(history, ORDER, 20.0) == 1
    assert dashboard.check_step_time(
        history, ORDER, 20.0, waiver="intentional: traded time for memory"
    ) == 0


def test_gate_ignores_ratio_rows():
    """Rows with us_per_call=0 (ratios, derived-only) carry no step time."""
    history = {
        "modes": {
            "aaaaaaa": [_row("speedup", 0.0)],
            "bbbbbbb": [_row("speedup", 0.0)],
        }
    }
    assert dashboard.step_time_regressions(history, ORDER, 20.0) == []
