"""Distribution tests on 8 fake CPU devices (subprocess: device count is
locked at first jax init, so the main test process can't host these)."""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.configs.registry import build_model, get_arch
    from repro.launch.mesh import _make_mesh
    from repro.launch.specs import train_batch_specs, materialize
    from repro.launch.steps import (DPTrainConfig, make_train_state,
                                    make_train_step, abstract_train_state)
    from repro.optim import adam, warmup_cosine
    from repro.parallel.sharding import batch_shardings, state_shardings
    from repro.configs.base import ShapeConfig

    mesh = _make_mesh((2, 4), ("data", "model"))
    cfg = get_arch("mixtral-8x7b").reduced()
    model = build_model(cfg)
    opt = adam()
    shape = ShapeConfig("t", 16, 4, "train")

    step = make_train_step(model, opt, warmup_cosine(1e-3, 2, 10),
                           DPTrainConfig(logical_batch=4))
    state = make_train_state(model, jax.random.PRNGKey(0), opt)
    st_sh = state_shardings(model, mesh, cfg, jax.eval_shape(lambda: state))
    state = jax.tree_util.tree_map(jax.device_put, state, st_sh)
    specs = train_batch_specs(cfg, shape, 4)
    batch = materialize(specs, jax.random.PRNGKey(1), vocab=cfg.vocab)
    b_sh = batch_shardings(specs, mesh)
    batch = jax.tree_util.tree_map(jax.device_put, batch, b_sh)

    jit_step = jax.jit(step, in_shardings=(st_sh, b_sh),
                       out_shardings=(st_sh, None))
    state2, metrics = jit_step(state, batch)
    loss1 = float(metrics["loss"])

    # single-device reference must agree (SPMD correctness)
    ref_step = jax.jit(step)
    host_state = jax.tree_util.tree_map(
        lambda x: jax.device_put(jax.device_get(x), jax.devices()[0]),
        make_train_state(model, jax.random.PRNGKey(0), opt))
    host_batch = jax.tree_util.tree_map(
        lambda x: jax.device_put(jax.device_get(x), jax.devices()[0]), batch)
    _, ref_metrics = ref_step(host_state, host_batch)
    print(json.dumps({
        "loss_sharded": loss1,
        "loss_ref": float(ref_metrics["loss"]),
        "nan": bool(any(jnp.any(jnp.isnan(x))
                    for x in jax.tree_util.tree_leaves(state2["params"]))),
    }))
    """
)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert not res["nan"]
    assert abs(res["loss_sharded"] - res["loss_ref"]) < 5e-4, res
