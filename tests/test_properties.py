"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.accountant import compute_epsilon, find_noise_multiplier
from repro.core.decision import (
    back_propagation,
    decide,
    ghost_is_cheaper,
    ghost_norm,
    grad_instantiation,
)
from repro.core.functions import abadi_clip, automatic_clip, global_clip
from repro.core.taps import TapMeta
from repro.data.poisson import poisson_sample_mask
from repro.nn.ssm_scan import chunked_ssm, ssm_reference
from repro.optim.compression import bf16_compress_with_error_feedback

SETTINGS = dict(max_examples=20, deadline=None)


@given(
    norms=st.lists(st.floats(1e-3, 1e4), min_size=1, max_size=16),
    clip_norm=st.floats(0.01, 10.0),
)
@settings(**SETTINGS)
def test_clip_functions_bounded(norms, clip_norm):
    """Any C(.; R) must satisfy C * ||g|| <= R (the DP sensitivity bound)."""
    n = jnp.asarray(norms, jnp.float32)
    for fn in (abadi_clip, global_clip, automatic_clip):
        c = fn(n, clip_norm)
        assert bool(jnp.all(c * n <= clip_norm * (1 + 1e-5))), fn.__name__
        assert bool(jnp.all(c >= 0))


@given(
    t=st.integers(1, 4096),
    d=st.integers(1, 4096),
    p=st.integers(1, 4096),
    k=st.sampled_from([1, 3, 5, 7]),
)
@settings(**SETTINGS)
def test_decision_rule_minimizes_space(t, d, p, k):
    """Eq (4.1) picks the branch with smaller clipping-module space cost."""
    big_d = d * k * k
    ghost_cost = ghost_norm(1, t, big_d, p).space
    inst_cost = grad_instantiation(1, t, big_d, p).space
    if ghost_is_cheaper(t, big_d, p, by="space"):
        assert ghost_cost <= inst_cost + 2  # +-1 element bookkeeping terms
    else:
        assert inst_cost <= ghost_cost + 2


@given(
    t=st.integers(1, 2048),
    d=st.integers(1, 2048),
    p=st.integers(1, 2048),
)
@settings(**SETTINGS)
def test_decision_rule_time_variant(t, d, p):
    gh = ghost_norm(1, t, d, p).time
    gi = grad_instantiation(1, t, d, p).time
    if ghost_is_cheaper(t, d, p, by="time"):
        assert gh <= gi + 2 * max(d, p) + 4
    else:
        assert gi <= gh + 2 * max(d, p) + 4


@given(
    sigma=st.floats(0.5, 20.0),
    steps=st.integers(1, 2000),
    q=st.floats(0.0005, 0.2),
)
@settings(max_examples=10, deadline=None)
def test_accountant_monotonicity(sigma, steps, q):
    delta = 1e-5
    e = compute_epsilon(q=q, sigma=sigma, steps=steps, delta=delta)
    assert e > 0
    assert compute_epsilon(q=q, sigma=sigma, steps=steps * 2, delta=delta) >= e
    assert compute_epsilon(q=q, sigma=sigma * 1.5, steps=steps, delta=delta) <= e
    assert compute_epsilon(q=q / 2, sigma=sigma, steps=steps, delta=delta) <= e + 1e-9


def test_sigma_search_roundtrip():
    s = find_noise_multiplier(target_epsilon=3.0, q=0.01, steps=1000, delta=1e-5)
    e = compute_epsilon(q=0.01, sigma=s, steps=1000, delta=1e-5)
    assert e <= 3.0
    assert e > 3.0 * 0.95  # not wastefully noisy


@given(data=st.data())
@settings(max_examples=10, deadline=None)
def test_chunked_ssm_matches_sequential(data):
    b = data.draw(st.integers(1, 2))
    t = data.draw(st.integers(1, 40))
    h = data.draw(st.integers(1, 3))
    dk = data.draw(st.sampled_from([2, 4, 8]))
    dv = data.draw(st.sampled_from([2, 4]))
    chunk = data.draw(st.sampled_from([4, 8, 16]))
    ks = jax.random.split(jax.random.PRNGKey(data.draw(st.integers(0, 100))), 4)
    q = jax.random.normal(ks[0], (b, t, h, dk))
    k = jax.random.normal(ks[1], (b, t, h, dk))
    v = jax.random.normal(ks[2], (b, t, h, dv))
    la = -jax.nn.softplus(jax.random.normal(ks[3], (b, t, h)))
    y1, s1 = chunked_ssm(q, k, v, la, chunk=chunk)
    y2, s2 = ssm_reference(q, k, v, la)
    assert jnp.allclose(y1, y2, atol=1e-4)
    assert jnp.allclose(s1, s2, atol=1e-4)


def test_poisson_mask_statistics():
    key = jax.random.PRNGKey(0)
    masks = jax.vmap(lambda k: poisson_sample_mask(k, 1000, 0.1))(
        jax.random.split(key, 50)
    )
    rate = float(jnp.mean(masks))
    assert 0.09 < rate < 0.17  # q * slots_per_sample = 0.125 expected


def test_error_feedback_preserves_gradient_sum():
    """sum_t compressed_t == sum_t g_t + e_0 - e_T (telescoping)."""
    g = {"w": jnp.linspace(-1e-4, 1e-4, 128, dtype=jnp.float32)}
    ef = None
    total_comp = jnp.zeros_like(g["w"])
    total_g = jnp.zeros_like(g["w"])
    for i in range(20):
        gi = {"w": g["w"] * (1 + 0.1 * i)}
        comp, ef = bf16_compress_with_error_feedback(gi, ef)
        total_comp += comp["w"]
        total_g += gi["w"]
    resid = total_comp + ef["w"] - total_g
    assert float(jnp.max(jnp.abs(resid))) < 1e-6


@given(
    t=st.integers(1, 512),
    d=st.integers(1, 512),
    p=st.integers(1, 512),
)
@settings(**SETTINGS)
def test_decide_forced_branches(t, d, p):
    mk = lambda kind: TapMeta(kind=kind, T=t, D=d, p=p, s_shape=(1, t, p),
                              s_dtype=jnp.float32, param_path="x")
    assert decide(mk("embedding")) == "ghost"
    assert decide(mk("scale")) == "instantiate"
    assert decide(mk("bias")) == "instantiate"
    assert decide(mk("dw_conv")) == "instantiate"
    assert decide(mk("matmul"), mode="ghost") == "ghost"
    assert decide(mk("matmul"), mode="fastgradclip") == "instantiate"
