"""repro.tuner: the measured-cost ClipPlan and its decision-override plumbing.

Covers the Eq-(4.1) boundary cases, the Remark-4.1 time variant, plan JSON
round-trip + stale-plan rejection, the max-batch search, and the subsystem's
correctness oracle: clipped gradients under a (even adversarially flipped)
plan must match the analytic ``mixed_ghost`` exactly — the branch choice is
pure cost, never math.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro.core.clipping import ClipConfig, discover_meta, dp_value_and_clipped_grad
from repro.core.decision import decide, ghost_is_cheaper
from repro.core.taps import Ctx, TapMeta
from repro.nn.module import Dense
from repro.tuner import (
    ClipPlan,
    MeasureConfig,
    build_plan,
    derive_accumulation,
    device_string,
    find_max_physical_batch,
    max_batch_by_memory,
    shape_fingerprint,
)

from helpers import max_tree_diff


def _meta(kind="matmul", T=8, D=16, p=4, batch=2):
    return TapMeta(
        kind=kind, T=T, D=D, p=p, s_shape=(batch, T, p), s_dtype=jnp.float32,
        param_path="w", batch_size=batch,
    )


# ---------------------------------------------------------------- decision --
def test_eq41_tie_prefers_instantiate():
    # 2T^2 == pD is NOT strictly cheaper: the paper's rule picks instantiate.
    T, p, D = 4, 2, 16
    assert 2 * T * T == p * D
    assert not ghost_is_cheaper(T, D, p, by="space")
    assert decide(_meta(T=T, D=D, p=p), mode="mixed_ghost") == "instantiate"


def test_remark41_time_variant_differs_from_space():
    # T=2, D=16, p=1: space rule 2T^2=8 < pD=16 -> ghost, but the time rule
    # 2T^2(D+p+1) = 144 >= 2(T+1)pD = 96 -> instantiate.
    assert ghost_is_cheaper(2, 16, 1, by="space")
    assert not ghost_is_cheaper(2, 16, 1, by="time")
    m = _meta(T=2, D=16, p=1)
    assert decide(m, mode="mixed_ghost", by="space") == "ghost"
    assert decide(m, mode="mixed_ghost", by="time") == "instantiate"


def test_plan_override_wins_over_analytic_rule():
    m = _meta(T=1, D=64, p=64)  # analytic: 2 < 4096 -> ghost
    assert decide(m, mode="mixed_ghost") == "ghost"
    assert decide(m, mode="mixed_ghost", override="instantiate") == "instantiate"
    assert decide(m, mode="mixed_ghost", override="ghost") == "ghost"
    with pytest.raises(ValueError):
        decide(m, mode="mixed_ghost", override="banana")


def test_override_never_wins_over_forced_kinds():
    # embedding/scale taps have exactly one viable norm computation
    emb = _meta(kind="embedding")
    assert decide(emb, override="instantiate") == "ghost"
    scale = _meta(kind="scale")
    assert decide(scale, override="ghost") == "instantiate"


def test_override_never_wins_over_reference_modes():
    # the pure modes exist to measure a fixed branch everywhere; a plan must
    # not silently turn a 'ghost' benchmark into mixed execution
    m = _meta(T=1, D=64, p=64)
    assert decide(m, mode="ghost", override="instantiate") == "ghost"
    assert decide(m, mode="fastgradclip", override="ghost") == "instantiate"


# -------------------------------------------------------------------- plan --
def _tiny_metas():
    return {
        "a/out": _meta(T=8, D=16, p=4),
        "b/out": _meta(T=2, D=32, p=32),
        "emb/out": _meta(kind="embedding", T=8, D=1, p=16),
    }


def test_clipplan_json_round_trip(tmp_path):
    metas = _tiny_metas()
    plan = ClipPlan(
        fingerprint=shape_fingerprint(metas),
        device=device_string(),
        branches=(("a/out", "instantiate"), ("b/out", "ghost")),
        physical_batch=64,
        logical_batch=256,
        accumulation_steps=4,
        arch="tiny",
        timings=(("a/out", 10.0, 5.0), ("b/out", 3.0, 7.0)),
    )
    path = str(tmp_path / "plan.json")
    plan.save(path)
    loaded = ClipPlan.load(path)
    assert loaded == plan
    assert loaded.branch_map() == {"a/out": "instantiate", "b/out": "ghost"}
    # the artifact is plain JSON, inspectable by other tooling
    raw = json.loads(open(path).read())
    assert raw["physical_batch"] == 64


def test_clipplan_rejects_bad_json():
    with pytest.raises(ValueError):
        ClipPlan.from_json(json.dumps({"fingerprint": "x", "device": "y",
                                       "version": 99}))
    with pytest.raises(ValueError):
        ClipPlan.from_json(json.dumps({
            "fingerprint": "x", "device": "y", "version": 1,
            "branches": [["a", "banana"]],
        }))


def test_stale_plan_rejected_falls_back_to_analytic():
    metas = _tiny_metas()
    good = ClipPlan(
        fingerprint=shape_fingerprint(metas), device=device_string(),
        branches=(("a/out", "instantiate"),),
    )
    assert good.overrides_for(metas) == {"a/out": "instantiate"}

    # different shapes (stale fingerprint) -> no overrides
    stale = dataclasses.replace(good, fingerprint="deadbeefdeadbeef")
    assert stale.overrides_for(metas) == {}

    # different device -> no overrides
    wrong_dev = dataclasses.replace(good, device="tpu:TPU v9")
    assert wrong_dev.overrides_for(metas) == {}

    # fingerprint tracks shapes: changing one tap's D changes it
    other = dict(metas, **{"a/out": _meta(T=8, D=32, p=4)})
    assert shape_fingerprint(other) != shape_fingerprint(metas)
    # but not the batch size (plans transfer across physical batch)
    rebatched = dict(metas, **{"a/out": _meta(T=8, D=16, p=4, batch=64)})
    assert shape_fingerprint(rebatched) == shape_fingerprint(metas)


# --------------------------------------------------------------- max batch --
def test_find_max_physical_batch_is_exact():
    for threshold in (1, 2, 37, 64, 100):
        calls = []

        def fits(b, t=threshold):
            calls.append(b)
            return b <= t

        assert find_max_physical_batch(fits, hi_cap=128) == min(threshold, 128)
    assert find_max_physical_batch(lambda b: False, hi_cap=128) == 0
    assert find_max_physical_batch(lambda b: True, hi_cap=128) == 128


def test_derive_accumulation_invariants():
    for logical, max_phys in [(256, 96), (256, 64), (8, 64), (7, 2), (1, 1)]:
        physical, steps = derive_accumulation(logical, max_phys)
        assert physical <= max_phys
        assert physical * steps >= logical
        # steps is minimal: one fewer microstep cannot cover the logical batch
        assert (steps - 1) * max_phys < logical
    with pytest.raises(ValueError):
        derive_accumulation(0, 4)
    with pytest.raises(ValueError):
        derive_accumulation(4, 0)


# --------------------------------------------- end-to-end correctness oracle --
class TwoLayer:
    """Tiny model with one ghost-leaning and one instantiate-leaning tap."""

    def __init__(self):
        self.f1 = Dense("f1", 12, 8)
        self.f2 = Dense("f2", 8, 4)

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"f1": self.f1.init(k1), "f2": self.f2.init(k2)}

    def loss_with_ctx(self, params, batch, ctx: Ctx):
        h = jax.nn.relu(self.f1(params["f1"], batch["x"], ctx.scope("f1")))
        out = self.f2(params["f2"], h, ctx.scope("f2"))
        return jnp.mean((out - batch["y"]) ** 2, axis=(1, 2))


def _two_layer_setup():
    model = TwoLayer()
    params = model.init(jax.random.PRNGKey(0))
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    batch = {
        "x": jax.random.normal(k1, (4, 6, 12)),
        "y": jax.random.normal(k2, (4, 6, 4)),
    }
    return model, params, batch


@pytest.mark.parametrize("mode", ["mixed_ghost", "mixed_ghost_taps", "bk_mixed"])
def test_plan_changes_branch_not_math(mode):
    """Clipped grads under an adversarially flipped plan == analytic exactly."""
    model, params, batch = _two_layer_setup()
    metas = discover_meta(model.loss_with_ctx, params, batch)
    flipped = ClipPlan(
        fingerprint=shape_fingerprint(metas),
        device=device_string(),
        branches=tuple(
            (n, "instantiate" if decide(m, mode="mixed_ghost") == "ghost" else "ghost")
            for n, m in sorted(metas.items()) if m.kind == "matmul"
        ),
    )
    f_analytic = dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig(mode=mode))
    f_plan = dp_value_and_clipped_grad(
        model.loss_with_ctx, ClipConfig(mode=mode, plan=flipped)
    )
    l1, g1, a1 = f_analytic(params, batch)
    l2, g2, a2 = f_plan(params, batch)
    assert float(l1) == float(l2)
    assert jnp.allclose(a1["per_sample_norms"], a2["per_sample_norms"], atol=1e-5)
    assert max_tree_diff(g1, g2) < 1e-5


def test_measured_plan_round_trips_through_engine(tmp_path):
    """build_plan -> save -> ClipConfig(plan=...) produces analytic-equal grads."""
    model, params, batch = _two_layer_setup()
    metas = discover_meta(model.loss_with_ctx, params, batch)
    plan = build_plan(
        metas, measure=MeasureConfig(repeats=1, warmup=1), arch="twolayer"
    )
    assert set(plan.branch_map()) == {
        n for n, m in metas.items() if m.kind == "matmul"
    }
    path = str(tmp_path / "plan.json")
    plan.save(path)
    plan = ClipPlan.load(path)

    f_analytic = jax.jit(
        dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig())
    )
    f_plan = jax.jit(
        dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig(plan=plan))
    )
    _, g1, _ = f_analytic(params, batch)
    _, g2, _ = f_plan(params, batch)
    assert max_tree_diff(g1, g2) < 1e-5


def test_engine_tune_cache_hit(tmp_path, monkeypatch):
    """A second tune() for the same (arch, device, shapes) skips profiling."""
    from repro.core.engine import PrivacyEngine

    monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path))
    model, params, batch = _two_layer_setup()
    eng = PrivacyEngine(
        loss_with_ctx=model.loss_with_ctx, batch_size=4, sample_size=1000,
        steps=10, max_grad_norm=1.0, noise_multiplier=1.0,
    )
    p1 = eng.tune(params, batch, arch="twolayer", search_max_batch=False,
                  measure=MeasureConfig(repeats=1, warmup=1))
    p2 = eng.tune(params, batch, arch="twolayer", search_max_batch=False,
                  measure=MeasureConfig(repeats=1, warmup=1))
    assert p1 == p2  # identical object state: timings were not re-measured
    assert eng.plan == p1
    # use_cache=False forces a re-measure (timings will differ)
    p3 = eng.tune(params, batch, arch="twolayer", search_max_batch=False,
                  measure=MeasureConfig(repeats=1, warmup=1), use_cache=False,
                  plan_path=None)
    assert p3.fingerprint == p1.fingerprint


def test_noise_finalize_non_private_matches_train_step():
    """Accumulation finalize must not noise/rescale non_private runs."""
    from repro.launch.steps import DPTrainConfig, make_noise_finalize
    from repro.optim import adam, warmup_cosine

    model, params, batch = _two_layer_setup()
    opt = adam()
    dp = DPTrainConfig(clipping_mode="non_private", noise_multiplier=123.0,
                       logical_batch=4)
    fin = make_noise_finalize(opt, warmup_cosine(1e-3, 1, 10), dp)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32), "rng": jax.random.PRNGKey(0)}
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    out1 = fin(dict(state), grads)
    out2 = fin(dict(state), grads)
    # no Gaussian noise: identical grads give identical (deterministic) updates
    assert max_tree_diff(out1["params"], out2["params"]) == 0.0


def test_max_batch_by_memory_monotone_model():
    model, params, batch = _two_layer_setup()
    grad_fn = dp_value_and_clipped_grad(model.loss_with_ctx, ClipConfig())
    # generous budget: search caps out at hi_cap
    assert max_batch_by_memory(
        grad_fn, params, batch, budget_bytes=1 << 34, hi_cap=8
    ) == 8
    # zero budget: nothing fits
    assert max_batch_by_memory(
        grad_fn, params, batch, budget_bytes=0, hi_cap=8
    ) == 0
